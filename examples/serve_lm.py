"""Batched serving demo: prefill + greedy decode with the sharded KV cache.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen2-1.5b]

Also demonstrates the O(1)-state serving path (rwkv6) vs the KV-cache path.
"""
import argparse
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models.api import get_model
    from repro.serve.engine import ServingEngine
    from repro.serve.kvcache import cache_bytes

    cfg = get_config(args.arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    capacity = args.prompt_len + args.new_tokens + 8
    print(f"{cfg.name}: cache {cache_bytes(api, args.batch, capacity)/1e6:.1f} MB "
          f"for batch={args.batch} capacity={capacity}")
    eng = ServingEngine(cfg, params, args.batch, capacity)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32)
    out = eng.generate(prompts, args.new_tokens)
    print(f"generated {out.shape[1]} tokens x {out.shape[0]} sequences")
    print(f"prefill: {eng.stats.prefill_s*1e3:.0f} ms | "
          f"decode: {eng.stats.tokens_per_s:.1f} tokens/s")
    print("first sequence:", out[0][:12], "...")


if __name__ == "__main__":
    main()
