"""Quickstart: plan a burst-parallel schedule for an assigned architecture,
inspect its gaps, simulate collocation, then run a few real train steps at
smoke scale on the host.

    PYTHONPATH=src python examples/quickstart.py [--arch llama3-8b]
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    args = ap.parse_args()

    from repro.configs import TRAIN_4K, get_config
    from repro.core.coordinator import ClusterCoordinator, Job
    from repro.core.multiplex import MultiplexConfig
    from repro.launch.mesh import make_mesh
    from repro.models.graph import build_lm_graph
    from repro.train.loop import TrainConfig, train

    cfg = get_config(args.arch)
    print(f"=== {cfg.name}: {cfg.n_params()/1e9:.1f}B params ===\n")

    # 1. burst-parallel plan for the production shape on 256 chips
    coord = ClusterCoordinator(256)
    plan = coord.submit_foreground(
        Job(args.arch, "foreground", build_lm_graph(cfg, TRAIN_4K), amp_limit=2.0)
    )
    print(plan.summary())
    print(f"idle gaps: {plan.idle_gpu_sec():.3f} chip-s/iter "
          f"({100*plan.idle_gpu_sec()/(plan.total_time*256):.1f}% of the cluster)\n")

    # 2. multiplex a background job into the gaps (discrete-event model)
    res = coord.simulate_collocation(MultiplexConfig())
    print(f"collocation: fg_slowdown={res.fg_slowdown:.3f} "
          f"bg_steps/iter={res.bg_steps_per_iter:.1f} "
          f"cluster_util={res.cluster_throughput:.2f}\n")

    # 3. real training at smoke scale (reduced config, host devices)
    shape = dataclasses.replace(TRAIN_4K, seq_len=64, global_batch=4)
    report = train(cfg.reduced(), shape, make_mesh(1, 1), TrainConfig(steps=10))
    print(f"smoke train: loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f} "
          f"({report.steps_done} steps)")


if __name__ == "__main__":
    main()
