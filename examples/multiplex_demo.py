"""Real fg/bg multiplexed execution: a foreground job's jitted stages
interleave with paced background steps through the Collocator (the
executable TPU-submesh path of paper §5).

    PYTHONPATH=src python examples/multiplex_demo.py
"""
import sys
import time

sys.path.insert(0, "src")


def main():
    import jax

    from repro.configs import get_config
    from repro.configs.vgg16 import CONFIG as VCFG
    from repro.core.costmodel import A100
    from repro.core.multiplex import Collocator, MultiplexConfig
    from repro.core.planner import plan
    from repro.models import get_model, make_batch
    from repro.models.graph import build_vgg_graph
    from repro.optim.optimizer import make_optimizer
    from repro.train.state import init_state
    from repro.train.step import make_train_step

    # foreground plan (VGG-16 @ 8 devices, the paper's setting)
    fg_plan = plan(build_vgg_graph(VCFG, 32), 8, amp_limit=1.5, hw=A100)
    print(fg_plan.summary())

    # background job: a tiny LM training step
    cfg = get_config("qwen2-1.5b").reduced()
    api = get_model(cfg)
    opt = make_optimizer(cfg)
    state = {"v": init_state(jax.random.PRNGKey(0), api, opt)}
    step = jax.jit(make_train_step(api, opt))
    batch = make_batch(jax.random.PRNGKey(1), cfg, 2, 32)

    def bg_step():
        state["v"], m = step(state["v"], batch)
        return m["loss"]

    # foreground stages: stand-in compute kernels sized by the plan
    k = jax.random.PRNGKey(2)
    mats = jax.random.normal(k, (256, 256))
    stage_fns = [
        jax.jit(lambda m=mats: (m @ m).sum()) for _ in fg_plan.stages()
    ]

    col = Collocator(fg_plan, MultiplexConfig(max_inflight=2))
    print("collocation schedule (stage -> bg steps):", col.schedule())
    for it in range(3):
        res = col.run_iteration(stage_fns, bg_step, time.perf_counter)
        print(f"iter {it}: {res['iter_time']*1e3:.1f} ms "
              f"(QoS bans: {sorted(col.monitor.banned) or 'none'})")
    print("bg loss after multiplexed steps:",
          float(jax.block_until_ready(bg_step())))


if __name__ == "__main__":
    main()
