"""Real fg/bg multiplexed execution on disjoint submeshes: the foreground
plan's jitted stages run on their device prefix while REAL background LM
training steps from TWO prioritized tenants are paced into the plan's gap
submeshes through the Collocator (the executable multi-tenant path of
paper §5 — the cluster-throughput setting).

    PYTHONPATH=src python examples/multiplex_demo.py

Forces 8 host devices so the gap submeshes are real device subsets.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, "src")


def main():
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.vgg16 import CONFIG as VCFG
    from repro.core.costmodel import A100
    from repro.core.multiplex import BgTenant, Collocator, MultiplexConfig
    from repro.core.planner import plan
    from repro.models.graph import build_vgg_graph
    from repro.train.step import bg_step_factory

    # foreground plan (VGG-16 @ 8 devices, the paper's setting)
    fg_plan = plan(build_vgg_graph(VCFG, 32), 8, amp_limit=1.5, hw=A100)
    print(fg_plan.summary())

    # two prioritized background tenants: each gap's free device ranges are
    # packed largest-chunk-to-highest-priority, every tenant training a REAL
    # tiny LM on its own disjoint submesh with a private state replica.
    # Each tenant's step is sized to its own chunk width (per-device batch)
    # instead of one global gap-minimum quantum.
    losses = []
    tenants = [
        BgTenant("bg-hi", 2, bg_step_factory("qwen2-1.5b", seq=8, seed=0,
                                             on_loss=losses.append,
                                             per_device_batch=2)),
        BgTenant("bg-lo", 1, bg_step_factory("qwen2-1.5b", seq=8, seed=1,
                                             on_loss=losses.append,
                                             per_device_batch=2)),
    ]
    col = Collocator(fg_plan, MultiplexConfig(max_inflight=2),
                     tenants=tenants)
    # admission control: sweep candidate tenant counts through predict()
    # BEFORE compiling anything — the argmax-cluster-throughput roster under
    # the paper's 1.33x QoS bound is what actually runs
    decision = col.admit()
    print("admission:", decision.row())
    print("tenant schedule (stage, tenant, bg steps):",
          col.schedule_tenants())
    split = col.submeshes()
    for si, slots in sorted(split.bg_tenants.items()):
        carve = " ".join(
            f"{tenants[i].job}=[{rng[0]},{rng[1]})"
            for i, entry in enumerate(slots) if entry is not None
            for rng, _m in (entry,)
        )
        print(f"  stage {si}: fg devices {split.stage_fg_range[si]} "
              f"bg {carve}")

    # foreground stages: stand-in compute kernels on the stage's submesh
    def make_fg_stage_fn(stage, mesh):
        x = jax.device_put(jnp.full((256, 256), 0.01, jnp.float32),
                           NamedSharding(mesh, P(None, None)))

        @jax.jit
        def f(x):
            for _ in range(8):
                x = jnp.tanh(x @ x) * 0.1 + 0.01
            return x

        return lambda: f(x)

    res = col.run_executable(make_fg_stage_fn, iterations=5,
                             tenants=list(decision.admitted))
    print(res.row())
    print(f"fg iter {res.fg_iter_time*1e3:.1f} ms "
          f"(isolated {res.fg_iter_time_isolated*1e3:.1f} ms) "
          f"jain_fairness={res.jain_fairness():.3f}")
    for t in res.tenants:
        print(f"  {t.row()} (weight {t.weight:g}, deficit {t.deficit:.1f})")
    n_submeshes = sum(
        sum(1 for e in s if e is not None) for s in split.bg_tenants.values()
    )
    print(f"{len(losses)} real bg train steps dispatched across "
          f"{n_submeshes} tenant gap submeshes (independent model replicas; "
          f"includes one warmup step per replica)")
    # per-stage calibration: fit the per-gap-op inflation vector from the
    # measured result and show the (device-free) prediction tracking it
    model = col.calibrate([res])
    pred = col.predict()
    print(f"calibrated gap_inflation={model.gap_inflation:.3f} "
          f"per-stage={dict(model.gap_inflation_stages)} -> "
          f"predict fg_slowdown={pred.fg_slowdown:.3f} "
          f"(measured {max(res.fg_slowdown, 1.0):.3f})")


if __name__ == "__main__":
    main()
