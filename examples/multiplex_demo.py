"""Real fg/bg multiplexed execution on disjoint submeshes: the foreground
plan's jitted stages run on their device prefix while REAL background LM
training steps are paced into the plan's gap submeshes through the
Collocator (the executable path of paper §5).

    PYTHONPATH=src python examples/multiplex_demo.py

Forces 8 host devices so the gap submeshes are real device subsets.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, "src")


def main():
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.vgg16 import CONFIG as VCFG
    from repro.core.costmodel import A100
    from repro.core.multiplex import Collocator, MultiplexConfig
    from repro.core.planner import plan
    from repro.models.graph import build_vgg_graph
    from repro.train.step import bg_step_factory

    # foreground plan (VGG-16 @ 8 devices, the paper's setting)
    fg_plan = plan(build_vgg_graph(VCFG, 32), 8, amp_limit=1.5, hw=A100)
    print(fg_plan.summary())

    col = Collocator(fg_plan, MultiplexConfig(max_inflight=2))
    print("collocation schedule (stage -> bg steps):", col.schedule())
    split = col.submeshes()
    for si, (rng, mesh) in sorted(split.bg.items()):
        print(f"  stage {si}: fg devices {split.stage_fg_range[si]} "
              f"bg submesh devices [{rng[0]}, {rng[1]})")

    # foreground stages: stand-in compute kernels on the stage's submesh
    def make_fg_stage_fn(stage, mesh):
        x = jax.device_put(jnp.full((256, 256), 0.01, jnp.float32),
                           NamedSharding(mesh, P(None, None)))

        @jax.jit
        def f(x):
            for _ in range(8):
                x = jnp.tanh(x @ x) * 0.1 + 0.01
            return x

        return lambda: f(x)

    # background job: a REAL tiny-LM training step jitted per gap submesh
    # (each submesh gets its own independent state replica)
    losses = []
    make_bg_step_fn = bg_step_factory("qwen2-1.5b", batch=4, seq=8,
                                      on_loss=losses.append)

    res = col.run_executable(make_fg_stage_fn, make_bg_step_fn, iterations=5)
    print(res.row())
    print(f"fg iter {res.fg_iter_time*1e3:.1f} ms "
          f"(isolated {res.fg_iter_time_isolated*1e3:.1f} ms)")
    print(f"{len(losses)} real bg train steps dispatched across "
          f"{len(split.bg)} gap submeshes (independent model replicas; "
          f"includes one warmup step per replica)")


if __name__ == "__main__":
    main()
