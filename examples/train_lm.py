"""End-to-end training driver: a ~100M-parameter llama-style model trained
for a few hundred steps with the full production stack — sharded step,
checkpointing, straggler monitoring, burst plan + multiplexed background job.

    PYTHONPATH=src python examples/train_lm.py                # ~100M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --fast         # ~6M, 50 steps

(One CPU core ≈ tens of minutes for the full run; --fast finishes in ~1 min.)
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/deeppool_train_lm")
    args = ap.parse_args()

    import jax

    from repro.configs import TRAIN_4K, get_config
    from repro.configs.base import ModelConfig, register
    from repro.launch.mesh import make_mesh
    from repro.train.loop import TrainConfig, train

    if args.fast:
        cfg = get_config("llama3-8b").reduced()
        shape = dataclasses.replace(TRAIN_4K, seq_len=128, global_batch=4)
        steps = args.steps or 50
    else:
        # ~100M params: 12L, d=768, llama-style
        cfg = ModelConfig(
            name="llama-100m", family="dense", block_type="attn_mlp",
            num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
            d_head=64, d_ff=2048, vocab_size=32000, rope_theta=1e4,
            tie_embeddings=True, attn_tp=False, kv_tp=False,
        )
        print(f"model: {cfg.n_params()/1e6:.0f}M params")
        shape = dataclasses.replace(TRAIN_4K, seq_len=256, global_batch=8)
        steps = args.steps or 300

    mesh = make_mesh(1, 1)
    tc = TrainConfig(steps=steps, ckpt_dir=args.ckpt_dir, ckpt_every=50)
    report = train(cfg, shape, mesh, tc)
    n = len(report.losses)
    print(f"steps={report.steps_done} restarts={report.restarts}")
    print(f"loss: {report.losses[0]:.4f} -> {report.losses[-1]:.4f} "
          f"(mean of last 10: {sum(report.losses[-10:])/min(10,n):.4f})")
    print(f"mean step time: {1e3*sum(report.step_times)/n:.0f} ms; "
          f"straggler events: {report.mitigations.count('straggler')}")


if __name__ == "__main__":
    main()
