"""Burst-parallel planning across every assigned architecture: plans, stage
structure, gaps, amplification, and the DP-vs-BP comparison — the paper's
core contribution applied to the 2024-era model zoo.

    PYTHONPATH=src python examples/burst_plan_demo.py
"""
import sys

sys.path.insert(0, "src")


def main():
    from repro.configs import ASSIGNED_ARCHS, TRAIN_4K, get_config
    from repro.core.planner import _dp_plan, plan
    from repro.models.graph import build_lm_graph

    G = 256
    print(f"{'arch':24s} {'DP iter':>9s} {'BP iter':>9s} {'gain':>6s} "
          f"{'amp':>5s} {'stages':>6s} {'idle%':>6s}")
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        g = build_lm_graph(cfg, TRAIN_4K)
        dp = _dp_plan(g, G, None)
        bp = plan(g, G, amp_limit=2.0)
        idle = 100 * bp.idle_gpu_sec() / (bp.total_time * G)
        print(f"{arch:24s} {dp.total_time*1e3:8.1f}ms {bp.total_time*1e3:8.1f}ms "
              f"{dp.total_time/bp.total_time:5.2f}x {bp.amplification:5.2f} "
              f"{len(bp.stages()):6d} {idle:5.1f}%")
    print("\nper-stage detail for zamba2-2.7b (SSM scan limits sample-split):")
    bp = plan(build_lm_graph(get_config("zamba2-2.7b"), TRAIN_4K), G, amp_limit=2.0)
    print(bp.summary())


if __name__ == "__main__":
    main()
