"""Burst-parallel planning across every assigned architecture: plans, stage
structure, gaps, amplification, and the DP-vs-BP comparison — the paper's
core contribution applied to the 2024-era model zoo.

    PYTHONPATH=src python examples/burst_plan_demo.py
"""
import sys

sys.path.insert(0, "src")


def main():
    from repro.configs import ASSIGNED_ARCHS, TRAIN_4K, get_config
    from repro.core.planner import _dp_plan, plan
    from repro.models.graph import build_lm_graph

    G = 256
    print(f"{'arch':24s} {'DP iter':>9s} {'BP iter':>9s} {'gain':>6s} "
          f"{'amp':>5s} {'stages':>6s} {'idle%':>6s}")
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        g = build_lm_graph(cfg, TRAIN_4K)
        dp = _dp_plan(g, G, None)
        bp = plan(g, G, amp_limit=2.0)
        idle = 100 * bp.idle_gpu_sec() / (bp.total_time * G)
        print(f"{arch:24s} {dp.total_time*1e3:8.1f}ms {bp.total_time*1e3:8.1f}ms "
              f"{dp.total_time/bp.total_time:5.2f}x {bp.amplification:5.2f} "
              f"{len(bp.stages()):6d} {idle:5.1f}%")
    print("\nper-stage detail for zamba2-2.7b (SSM scan limits sample-split):")
    bp = plan(build_lm_graph(get_config("zamba2-2.7b"), TRAIN_4K), G, amp_limit=2.0)
    print(bp.summary())

    dag_demo()
    encdec_demo()


def dag_demo():
    """Branch-parallel DAG placement: Inception-style blocks get per-branch
    device ranges (critical branch at [0, peak), parallel branches stacked
    onto the block's idle devices)."""
    from repro.core.planner import plan
    from repro.models.graph import build_inception_like_graph

    print("\nDAG placement for an Inception-style graph @ 64 devices:")
    bp = plan(build_inception_like_graph(32, n_blocks=3), 64, amp_limit=2.0)
    for name, placements in sorted(bp.block_details.items()):
        print(f"  {name}:")
        for p in placements:
            tag = "critical" if p.critical else ("parallel" if p.parallel else "sequential")
            print(f"    branch {p.branch} [{tag:>10s}] devices "
                  f"[{p.device_start},{p.device_end}) scales={p.scales} "
                  f"t={p.time*1e6:.1f}us")


def encdec_demo():
    """Two-chain DAG: encoder + decoder joined by a resharding cross-edge."""
    import dataclasses

    from repro.configs import TRAIN_4K, get_config
    from repro.core.planner import plan
    from repro.models.graph import build_encdec_graph

    cfg = get_config("seamless-m4t-large-v2")
    shape = dataclasses.replace(TRAIN_4K, seq_len=1024, global_batch=16, name="demo")
    eg = build_encdec_graph(cfg, shape)
    bp = plan(eg, 64, amp_limit=2.0)
    j = bp.block_details["encdec_join"]
    print(f"\nenc-dec cross-edge plan for {cfg.name} @ 64 devices:")
    print(f"  encoder exits at g={j['encoder_exit_gpus']}, decoder enters at "
          f"g={j['decoder_entry_gpus']}, reshard join "
          f"{j['reshard_time']*1e6:.1f}us over "
          f"{j['cross_act_bytes']/2**20:.1f} MiB of encoder memory")
    print(f"  iter={bp.total_time*1e3:.2f} ms amp={bp.amplification:.2f} "
          f"stages={len(bp.stages())}")


if __name__ == "__main__":
    main()
