"""Fault-tolerant checkpointing (orbax is not available offline — this is a
self-contained implementation).

Features required at 1000+-node scale (DESIGN.md §7):
  - atomic:      write to ``step_<N>.tmp/`` then rename — a crash mid-save
                 never corrupts the latest checkpoint;
  - async:       serialization happens on a background thread so the train
                 loop only blocks on device->host transfer;
  - keep-k GC:   old checkpoints garbage-collected after a successful save;
  - elastic restore: arrays are saved unsharded (single-host gather) and
                 re-device_put with the *target* mesh's shardings on load —
                 restoring onto a different device count / mesh re-shards;
  - metadata:    step, timestamp, config name, data-pipeline cursor, RNG.

Format: one ``.npz`` per checkpoint (flattened pytree, '/'-joined keys) +
``meta.json``.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> Dict[str, Any]:
    flat = {}
    paths, _ = jax.tree_util.tree_flatten_with_path(tree)
    for kp, leaf in paths:
        flat[_SEP.join(_key_str(k) for k in kp)] = leaf
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def save(
    ckpt_dir: str,
    state: Any,
    step: int,
    *,
    keep: int = 3,
    extra_meta: Optional[dict] = None,
    async_: bool = True,
) -> threading.Thread:
    """Checkpoint `state` at `step`. Returns the writer thread (joined by
    callers that need durability barriers, e.g. before exit)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(state)
    # device -> host (this is the only synchronous part)
    host = {k: np.asarray(v) for k, v in flat.items()}
    meta = {"step": int(step), "time": time.time(), **(extra_meta or {})}

    def _write():
        tmp = os.path.join(ckpt_dir, f"step_{step:010d}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step:010d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    if not async_:
        t.join()
    return t


def _steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def _gc(ckpt_dir: str, keep: int):
    steps = _steps(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(
    ckpt_dir: str,
    like: Any,
    *,
    step: Optional[int] = None,
    shardings: Any = None,
) -> tuple:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  With `shardings`, arrays are placed with the target
    sharding — restoring onto a different mesh re-shards transparently
    (elastic restart)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    flat_like = _flatten(like)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    restored = {}
    for key, leaf in flat_like.items():
        if key not in data:
            raise KeyError(f"checkpoint {path} missing {key}")
        arr = data[key]
        want = np.asarray(leaf) if not hasattr(leaf, "shape") else leaf
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(f"{key}: ckpt shape {arr.shape} != state {want.shape}")
        sh = flat_sh.get(key)
        restored[key] = jax.device_put(arr, sh) if sh is not None else jax.device_put(arr)

    # unflatten back into the structure of `like`
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = [restored[_SEP.join(_key_str(k) for k in kp)] for kp, _ in paths]
    return jax.tree_util.tree_unflatten(treedef, leaves), meta
