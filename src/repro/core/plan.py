"""Burst-parallel plan representation + mapping to mesh shardings.

``BurstPlan`` is the planner's output: per layer, the number of devices it
runs on, its time along the chosen path and its GPU-sec amplification.
``stages()`` groups contiguous equal-scale layers — the unit at which the
executor applies sharding re-maps and the multiplexer finds gaps.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import cached_property
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class LayerPlan:
    index: int
    name: str
    gpus: int
    time: float       # T[i][g]: comm_in + comp + sync along the chosen path
    comp: float
    sync: float
    comm_in: float
    amp: float        # GPU-sec amplification of this layer
    kind: str = "generic"


@dataclass(frozen=True)
class StagePlan:
    first: int
    last: int
    gpus: int
    start: float
    duration: float

    @property
    def n_layers(self) -> int:
        return self.last - self.first + 1


@dataclass(frozen=True)
class GapWindow:
    """Idle devices during one stage of the foreground plan."""

    start: float
    duration: float
    free_gpus: int
    stage_index: int


@dataclass(frozen=True)
class BranchPlacement:
    """Placement of one ParallelBlock branch inside the block's device window.

    The critical branch occupies devices [0, gpus); branches that run
    *parallel* to it are stacked onto disjoint ranges above it (the idle
    devices of the block's GapWindow); *sequential* branches reuse the
    critical branch's range after it finishes.  ``scales`` is the backtraced
    per-layer device count along the branch's top-level chain.
    """

    block: str
    branch: int
    critical: bool
    parallel: bool         # placed on disjoint devices concurrently
    time: float
    gpus: int              # peak devices used by this branch
    device_start: int
    device_end: int        # exclusive
    scales: Tuple[int, ...]
    demoted: bool = False  # reduction decided parallel, but the gap window
                           # was full — the planned block time is optimistic
                           # by up to this branch's ``time``
    layer_index: int = -1  # plan-layer whose ``comm_in`` folds this block:
                           # the branch devices are busy only during the
                           # stage containing that layer (-1: unknown ->
                           # excluded for the whole iteration, conservative)

    @property
    def devices(self) -> Tuple[int, int]:
        return (self.device_start, self.device_end)


def pow2_floor(n: int) -> int:
    """Largest power of two <= n (the planner's scale set is powers of two)."""
    g = 1
    while g * 2 <= n:
        g *= 2
    return g


# -- device-index range arithmetic (used by gap collocation) ----------------


def merge_ranges(ranges) -> List[Tuple[int, int]]:
    """Sort + coalesce half-open [start, end) index ranges."""
    out: List[List[int]] = []
    for s, e in sorted((int(s), int(e)) for s, e in ranges if e > s):
        if out and s <= out[-1][1]:
            out[-1][1] = max(out[-1][1], e)
        else:
            out.append([s, e])
    return [(s, e) for s, e in out]


def complement_ranges(busy, total: int) -> List[Tuple[int, int]]:
    """Free [start, end) ranges inside [0, total) not covered by ``busy``."""
    free: List[Tuple[int, int]] = []
    cur = 0
    for s, e in merge_ranges(busy):
        s, e = max(0, min(s, total)), max(0, min(e, total))
        if s > cur:
            free.append((cur, s))
        cur = max(cur, e)
    if cur < total:
        free.append((cur, total))
    return free


def normalize_quanta(quanta, n: int) -> List[int]:
    """Per-tenant quantum vector, normalized: ints clamped >= 1, truncated
    to ``n`` entries and padded with the last value (1 when empty).  Shared
    by ``pack_ranges`` and the submesh carving so the two can never diverge
    on the padding rule."""
    q = [max(1, int(v)) for v in quanta][:n]
    q += [q[-1] if q else 1] * (n - len(q))
    return q


def pack_ranges(free, n: int, quantum=1, shares=None):
    """Carve up to ``n`` disjoint chunks out of free [start, end) ranges for
    priority-ordered tenants.

    ``quantum`` is either a single int (every chunk size a multiple of it —
    the tenant submesh's model width) or a *per-tenant sequence* of ints
    (slot-aware mode: each tenant sizes its chunk to its own quantum).

    Scalar mode (back-compat): chunks never overlap, each lies inside one
    input range, and the result is a dense largest-first list (ties: lower
    start) of at most ``n`` chunks, so chunk *i* goes to the *i*-th
    highest-priority tenant.  While there are fewer chunks than tenants, the
    largest chunk is split in half (quantum-aligned) — two tenants share one
    big gap rather than one tenant hoarding it.

    Per-tenant mode: the result has exactly ``n`` entries where entry *i* is
    slot *i*'s chunk — size a multiple of ``quantum[i]`` — or ``None`` when
    the remaining free devices cannot satisfy that tenant's quantum.
    Candidate chunks are carved (and halved toward ``n`` shares) at gcd
    alignment, then slots claim greedily in priority order: slot *i* takes
    the ``quantum[i]``-aligned prefix of the candidate with the largest such
    prefix (ties: lower start), returning the unclaimed remainder to the
    pool; when no single candidate fits, adjacent unclaimed fragments of
    the same free range re-coalesce — a wide-quantum (high-priority) tenant
    is never starved by the sharing split when the unsplit range would have
    satisfied it.  A sequence shorter than ``n`` is padded with its last
    value.

    ``shares`` (per-tenant mode only) sizes chunks by weighted share instead
    of equal halving: slot *i*'s claim is capped at its ``quantum[i]``-
    aligned proportional share ``total_free * shares[i] / sum(shares)``
    (floor: one quantum), and earlier slots leave the un-taken surplus to
    later ones.  This is the deficit-sizing hook: a lagging tenant's share
    grows with its fair-share deficit, so it claims a *wider* chunk instead
    of rotating into the same equal-split chunk forever.  ``shares=None``
    (or uniform shares over a single free run) reproduces the equal-halving
    layout exactly.
    """
    if n <= 0:
        return []
    per_tenant = not isinstance(quantum, int)
    if per_tenant:
        quanta = normalize_quanta(quantum, n)
        base = math.gcd(*quanta)
    else:
        quanta = [quantum] * n
        base = quantum
    if shares is not None and not per_tenant:
        raise ValueError("shares requires the per-tenant quantum mode")
    chunks: List[Tuple[int, int]] = []
    for s, e in merge_ranges(free):
        m = (e - s) - (e - s) % base
        if m > 0:
            chunks.append((s, s + m))
    if not chunks:
        return [None] * n if per_tenant else []
    key = lambda r: (-(r[1] - r[0]), r[0])
    chunks.sort(key=key)
    caps = [None] * n
    if shares is not None:
        w = [max(0.0, float(v)) for v in shares][:n]
        w += [1.0] * (n - len(w))
        wsum = sum(w)
        if wsum > 0.0:
            total = sum(e - s for s, e in chunks)
            caps = [
                max(q, int(total * wi / wsum) // q * q)
                for q, wi in zip(quanta, w)
            ]
        else:
            shares = None
    if shares is None:
        while len(chunks) < n:
            s, e = chunks[0]
            if e - s < 2 * base:  # largest can't split -> none can
                break
            half = ((e - s) // 2 // base) * base
            chunks[0:1] = [(s, s + half), (s + half, e)]
            chunks.sort(key=key)
    if not per_tenant:
        return sorted(chunks[:n], key=key)
    out: List[Optional[Tuple[int, int]]] = []
    pool = list(chunks)
    for q, cap in zip(quanta, caps):
        cand = [
            (-((e - s) - (e - s) % q), s, i)
            for i, (s, e) in enumerate(pool)
            if (e - s) >= q
        ]
        if not cand:
            # no single candidate fits: adjacent unclaimed fragments of one
            # free range re-coalesce (the sharing split must not starve a
            # wide-quantum tenant the unsplit range could satisfy)
            pool = merge_ranges(pool)
            cand = [
                (-((e - s) - (e - s) % q), s, i)
                for i, (s, e) in enumerate(pool)
                if (e - s) >= q
            ]
        if not cand:
            out.append(None)
            continue
        negsz, s, i = min(cand)  # largest aligned size, then lowest start
        e = pool[i][1]
        take = -negsz
        if cap is not None:
            # share-sized claim: take the proportional cap, leave the rest
            take = min(take, cap)
        # claim the aligned prefix; the remainder returns to the pool
        pool[i:i + 1] = [(s + take, e)] if e > s + take else []
        out.append((s, s + take))
    return out


@dataclass(frozen=True)
class BurstPlan:
    layers: Tuple[LayerPlan, ...]
    num_gpus: int
    amp_limit: float
    single_gpu_time: float  # sum_i comp(i, 1)
    block_details: Dict[str, object] = field(default_factory=dict)

    @property
    def total_time(self) -> float:
        return sum(l.time for l in self.layers)

    @property
    def gpu_sec(self) -> float:
        return sum(l.time * l.gpus for l in self.layers)

    @property
    def amplification(self) -> float:
        return self.gpu_sec / max(self.single_gpu_time, 1e-30)

    @property
    def speedup(self) -> float:
        """vs the same job on a single device (paper Fig 10 x-axis)."""
        return self.single_gpu_time / max(self.total_time, 1e-30)

    @cached_property
    def _stages(self) -> Tuple[StagePlan, ...]:
        # layers are immutable, so the stage grouping is computed once per
        # plan (cached_property writes to __dict__, bypassing frozen) — the
        # per-stage gap scheduling paths call stages() in tight loops
        out: List[StagePlan] = []
        t = 0.0
        cur_first, cur_g, cur_t0 = 0, self.layers[0].gpus, 0.0
        for i, l in enumerate(self.layers):
            if l.gpus != cur_g:
                out.append(StagePlan(cur_first, i - 1, cur_g, cur_t0, t - cur_t0))
                cur_first, cur_g, cur_t0 = i, l.gpus, t
            t += l.time
        out.append(StagePlan(cur_first, len(self.layers) - 1, cur_g, cur_t0, t - cur_t0))
        return tuple(out)

    def stages(self) -> List[StagePlan]:
        return list(self._stages)

    def gaps(self) -> List[GapWindow]:
        """Idle-device windows the multiplexer can fill (paper §3.1)."""
        return [
            GapWindow(s.start, s.duration, self.num_gpus - s.gpus, idx)
            for idx, s in enumerate(self._stages)
            if s.gpus < self.num_gpus and s.duration > 0.0
        ]

    def idle_gpu_sec(self) -> float:
        return sum(g.duration * g.free_gpus for g in self.gaps())

    def branch_device_ranges(
        self, stage_index: Optional[int] = None
    ) -> List[Tuple[int, int]]:
        """Device ranges hosting *parallel-placed* ParallelBlock branches.

        The critical branch of each block lives in [0, peak) — inside the
        stage's own device window — so only non-critical branches placed on
        disjoint devices widen the busy set.  Demoted branches time-multiplex
        the critical range and occupy nothing extra.

        With ``stage_index``, only branches whose block is folded into that
        stage (``BranchPlacement.layer_index`` within the stage's layer
        span) count as busy — a stage whose branches are idle returns its
        window to the gap.  Placements with unknown provenance
        (``layer_index < 0``) stay excluded for every stage, conservative."""
        st = self._stages[stage_index] if stage_index is not None else None
        out = []
        for v in self.block_details.values():
            if not isinstance(v, tuple):
                continue
            for p in v:
                if getattr(p, "parallel", False) and not getattr(p, "critical", False):
                    li = getattr(p, "layer_index", -1)
                    if st is None or li < 0 or st.first <= li <= st.last:
                        out.append((p.device_start, p.device_end))
        return merge_ranges(out)

    def busy_device_ranges(self, stage_index: int) -> List[Tuple[int, int]]:
        """Devices a background job must avoid during ``stage_index``: the
        stage's own [0, gpus) plus the parallel branch placements whose block
        executes during this stage (per-stage exclusion)."""
        st = self._stages[stage_index]
        return merge_ranges(
            [(0, st.gpus)] + self.branch_device_ranges(stage_index)
        )

    def free_device_ranges(self, stage_index: int) -> List[Tuple[int, int]]:
        """Device ranges a background job may occupy during ``stage_index``."""
        return complement_ranges(self.busy_device_ranges(stage_index), self.num_gpus)

    def placement_slack(self) -> float:
        """Total time of branches the reduction decided to run in parallel
        but the placement had to demote (gap window full).  ``total_time``
        is optimistic by up to this much; 0.0 when every parallel decision
        was physically placeable."""
        slack = 0.0
        for v in self.block_details.values():
            if isinstance(v, tuple):
                slack += sum(p.time for p in v if getattr(p, "demoted", False))
        return slack

    def summary(self) -> str:
        st = self.stages()
        lines = [
            f"BurstPlan G={self.num_gpus} amp_limit={self.amp_limit:g} "
            f"iter={self.total_time*1e3:.3f} ms speedup={self.speedup:.1f}x "
            f"amp={self.amplification:.2f} stages={len(st)}"
        ]
        for s in st:
            lines.append(
                f"  layers {s.first:>3}-{s.last:<3} g={s.gpus:<5} "
                f"dur={s.duration*1e3:.3f} ms"
            )
        return "\n".join(lines)


def serving_plan(n_devices: int, n_prefill: int,
                 prefill_time: float = 1.0) -> BurstPlan:
    """Cast disaggregated serving as a one-stage BurstPlan.

    Prefill is the latency-critical foreground: a single stage occupying
    devices [0, n_prefill) for ``prefill_time``.  The remaining
    ``n_devices - n_prefill`` devices are that stage's burst gap — exactly
    where the decode stage (and each decode request, as a ``BgTenant``)
    packs.  Casting it this way means the whole gap machinery applies
    unchanged to serving: ``gaps()``/``free_device_ranges`` locate the
    decode carving, ``split_mesh_for_plan`` builds the disjoint submeshes,
    and ``Collocator.admit()`` becomes request-level admission under a
    latency SLO instead of the training QoS bound.
    """
    if not 0 < n_prefill < n_devices:
        raise ValueError(
            f"serving plan needs 0 < n_prefill < n_devices, got "
            f"n_prefill={n_prefill}, n_devices={n_devices}"
        )
    if prefill_time <= 0.0:
        raise ValueError(f"prefill_time must be > 0, got {prefill_time}")
    layer = LayerPlan(
        index=0, name="prefill", gpus=n_prefill, time=prefill_time,
        comp=prefill_time, sync=0.0, comm_in=0.0, amp=1.0, kind="prefill",
    )
    return BurstPlan(
        layers=(layer,), num_gpus=n_devices, amp_limit=1.0,
        single_gpu_time=prefill_time * n_prefill,
    )


# ---------------------------------------------------------------------------
# Plan -> mesh sharding re-maps (DESIGN.md §2: burst = per-stage axis re-map)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StageSharding:
    """How one stage maps onto the fixed production mesh.

    batch_axes: mesh axes carrying the sample dimension for this stage.
    model_active: whether the 'model' axis does TP work in this stage; if
    False the model axis is a *gap* the multiplexer may fill.
    free_ranges: device-index ranges a background job may occupy during this
    stage — the complement of the stage's own devices AND of the parallel
    ParallelBlock branch placements executing *during this stage*
    (``plan.block_details``; per-stage exclusion — an idle branch window is
    returned to the gap), so collocated work never lands on devices hosting
    a concurrent branch.
    """

    stage: StagePlan
    batch_axes: Tuple[str, ...]
    model_active: bool
    free_ranges: Tuple[Tuple[int, int], ...] = ()


def map_plan_to_mesh(plan: BurstPlan, mesh_axes: Dict[str, int]) -> List[StageSharding]:
    """Quantize each stage's device count onto the mesh factorization.

    With a (data=Nd, model=Nm[, pod=Np]) mesh, a stage using g devices maps
    to one of:
      g >= Nd*Nm(*Np): full DP over all batch-capable axes  -> ('pod','data','model')
      g >= Nd(*Np):    DP over ('pod','data'), TP over 'model'
      else:            DP over 'data' only; 'model' (and 'pod') idle -> gap
    """
    nd = mesh_axes.get("data", 1)
    nm = mesh_axes.get("model", 1)
    np_ = mesh_axes.get("pod", 1)
    total = nd * nm * np_
    out = []
    for idx, s in enumerate(plan.stages()):
        free = tuple(plan.free_device_ranges(idx))  # per-stage branch windows
        if s.gpus >= total:
            axes = tuple(a for a in ("pod", "data", "model") if a in mesh_axes)
            out.append(StageSharding(s, axes, model_active=True, free_ranges=free))
        elif s.gpus >= nd * np_:
            axes = tuple(a for a in ("pod", "data") if a in mesh_axes)
            out.append(StageSharding(s, axes, model_active=True, free_ranges=free))
        else:
            out.append(StageSharding(s, ("data",), model_active=False,
                                     free_ranges=free))
    return out
