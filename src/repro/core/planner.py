"""Burst-parallel training planner — the paper's Algorithm 1.

Dynamic program over (layer, device-count) with the user-given GPU-sec
amplification limit:

    S[i][g] = shortest time to complete L_1..L_i with L_i at scale g
    T[i][g] = time spent on L_i while minimizing S[i][g]
    Amp(i,g) = T[i][g] · g / comp(i,1)

Search space is powers of two (paper §7.4).  Branch/join blocks are reduced
to transition-cost edges by core/graph_reduce.py (paper Fig 7) — the linear
search below treats a CostedBlock between two layers as the paper's
tr((i,g)→(j,h)) edge.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.costmodel import Hardware, comm_time
from repro.core.plan import BurstPlan, LayerPlan
from repro.core.profiler import CostedBlock, CostedLayer, powers_of_two

INF = float("inf")


@dataclass
class _ChainResult:
    """DP tables for one chain: indexed [layer][g]."""

    S: List[Dict[int, float]]
    T: List[Dict[int, float]]
    P: List[Dict[int, Optional[int]]]  # backpointer: chosen predecessor scale
    layers: List[CostedLayer]
    trans: List  # trans[i](h, g) -> transition time from layer i-1@h to i@g


def _layer_cost(layer: CostedLayer, g: int) -> float:
    return layer.comp[g] + layer.sync[g]


def search_linear(
    chain: Sequence,
    scales: Sequence[int],
    amp_limit: float,
    hw: Hardware,
    entry_scale: Optional[int] = None,
    entry_act_bytes: float = 0.0,
) -> _ChainResult:
    """Paper Algorithm 1 over a chain of CostedLayer/CostedBlock elements.

    ``entry_scale`` fixes the scale feeding the first layer (used by the
    graph reduction when planning a branch whose branching layer is pinned).
    """
    from repro.core.graph_reduce import block_transition_table  # lazy: avoids cycle

    # Collapse the chain into layers + per-edge transition functions.
    layers: List[CostedLayer] = []
    trans: List = []
    pending_blocks: List[CostedBlock] = []
    prev_layer: Optional[CostedLayer] = None
    for el in chain:
        if isinstance(el, CostedBlock):
            pending_blocks.append(el)
            continue
        blocks = tuple(pending_blocks)
        pending_blocks = []
        if prev_layer is None:
            if entry_scale is None:
                trans.append(lambda h, g: 0.0)
            else:
                eb = entry_act_bytes

                def entry_tr(h, g, eb=eb):
                    return comm_time(eb, h, g, hw)

                trans.append(entry_tr)
        else:
            pb = prev_layer.act_bytes
            if blocks:
                tables = [
                    block_transition_table(b, scales, amp_limit, hw, pb) for b in blocks
                ]

                def tr(h, g, tables=tables):
                    t = 0.0
                    cur = h
                    for tab in tables:
                        t += tab[(cur, g)][0]
                        cur = g
                    return t

                trans.append(tr)
            else:

                def tr(h, g, pb=pb):
                    return comm_time(pb, h, g, hw)

                trans.append(tr)
        layers.append(el)
        prev_layer = el
    if pending_blocks:
        raise ValueError("chain must not end with a ParallelBlock")

    L = len(layers)
    S: List[Dict[int, float]] = [dict() for _ in range(L)]
    T: List[Dict[int, float]] = [dict() for _ in range(L)]
    P: List[Dict[int, Optional[int]]] = [dict() for _ in range(L)]

    def amp(i: int, g: int) -> float:
        return T[i][g] * g / max(layers[i].comp1, 1e-30)

    for i in range(L):
        for g in scales:
            if i == 0:
                src_scales = [entry_scale] if entry_scale is not None else [g]
                best_s, best_t, best_h = INF, INF, None
                for h in src_scales:
                    c = trans[0](h, g)
                    if c < best_s:
                        best_s, best_t, best_h = c, c, h
            else:
                best_amp, best_s, best_t, best_h = INF, INF, INF, None
                for h in scales:
                    a_prev = amp(i - 1, h)
                    if a_prev <= max(best_amp, amp_limit) and (
                        S[i - 1][h] + trans[i](h, g) <= best_s
                    ):
                        best_s = S[i - 1][h] + trans[i](h, g)
                        best_t = trans[i](h, g)
                        best_amp = min(best_amp, a_prev)
                        best_h = h
            S[i][g] = best_s + _layer_cost(layers[i], g)
            T[i][g] = best_t + _layer_cost(layers[i], g)
            P[i][g] = best_h

    return _ChainResult(S=S, T=T, P=P, layers=layers, trans=trans)


def _backtrace(res: _ChainResult, final_g: int) -> List[int]:
    gs = [final_g]
    for i in range(len(res.layers) - 1, 0, -1):
        gs.append(res.P[i][gs[-1]])
    gs.reverse()
    return gs


def plan(
    graph,
    num_gpus: int,
    amp_limit: float = 2.0,
    hw: Optional[Hardware] = None,
) -> BurstPlan:
    """Plan a LayerGraph (models/graph.py) or pre-costed chain."""
    from repro.core.profiler import profile_graph
    from repro.models.graph import LayerNode, ParallelBlock

    hw = hw or Hardware()
    if graph and isinstance(graph[0], (LayerNode, ParallelBlock)):
        chain = profile_graph(graph, num_gpus, hw)
    else:
        chain = list(graph)
    scales = powers_of_two(num_gpus)
    res = search_linear(chain, scales, amp_limit, hw)
    L = len(res.layers)

    def amp(i, g):
        return res.T[i][g] * g / max(res.layers[i].comp1, 1e-30)

    feasible = [g for g in scales if amp(L - 1, g) <= amp_limit]
    pool = feasible if feasible else scales
    final_g = min(pool, key=lambda g: res.S[L - 1][g])
    gs = _backtrace(res, final_g)

    layer_plans = []
    for i, (layer, g) in enumerate(zip(res.layers, gs)):
        h = gs[i - 1] if i > 0 else (g if res.P[0][g] is None else res.P[0][g])
        comm_in = res.trans[i](h, g)
        layer_plans.append(
            LayerPlan(
                index=i,
                name=layer.name,
                gpus=g,
                time=comm_in + _layer_cost(layer, g),
                comp=layer.comp[g],
                sync=layer.sync[g],
                comm_in=comm_in,
                amp=amp(i, g),
                kind=layer.kind,
            )
        )
    single = sum(l.comp1 for l in res.layers)
    return BurstPlan(
        layers=tuple(layer_plans),
        num_gpus=num_gpus,
        amp_limit=amp_limit,
        single_gpu_time=single,
    )


def plan_data_parallel(graph, num_gpus: int, hw: Optional[Hardware] = None) -> BurstPlan:
    """The paper's 'DP' baseline: every layer at full scale."""
    return plan(graph, num_gpus, amp_limit=INF if num_gpus == 1 else 1e30, hw=hw) \
        if False else _dp_plan(graph, num_gpus, hw)


def _dp_plan(graph, num_gpus: int, hw: Optional[Hardware]) -> BurstPlan:
    from repro.core.profiler import profile_graph
    from repro.models.graph import LayerNode, ParallelBlock

    hw = hw or Hardware()
    if graph and isinstance(graph[0], (LayerNode, ParallelBlock)):
        chain = profile_graph(graph, num_gpus, hw)
    else:
        chain = list(graph)
    # flatten blocks: DP runs branches sequentially at full scale
    flat: List[CostedLayer] = []

    def _flat(els):
        for el in els:
            if isinstance(el, CostedLayer):
                flat.append(el)
            else:
                for br in el.branches:
                    _flat(br)

    _flat(chain)
    g = num_gpus
    plans = [
        LayerPlan(
            index=i, name=l.name, gpus=g, time=_layer_cost(l, g), comp=l.comp[g],
            sync=l.sync[g], comm_in=0.0, amp=_layer_cost(l, g) * g / max(l.comp1, 1e-30),
            kind=l.kind,
        )
        for i, l in enumerate(flat)
    ]
    return BurstPlan(
        layers=tuple(plans),
        num_gpus=g,
        amp_limit=INF,
        single_gpu_time=sum(l.comp1 for l in flat),
    )
