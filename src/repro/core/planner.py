"""Burst-parallel training planner — the paper's Algorithm 1.

Dynamic program over (layer, device-count) with the user-given GPU-sec
amplification limit:

    S[i][g] = shortest time to complete L_1..L_i with L_i at scale g
    T[i][g] = time spent on L_i while minimizing S[i][g]
    Amp(i,g) = T[i][g] · g / comp(i,1)

Search space is powers of two (paper §7.4).

Two engines implement the same DP:

``search_linear_reference``
    The original pure-Python dict-of-dict formulation.  It is kept verbatim
    as the *oracle* for the differential test harness
    (tests/test_planner_diff.py) and as the baseline for the recorded
    search-time trajectory (BENCH_planner.json).

``search_linear`` (default, vectorized)
    Matrix formulation over numpy arrays.  Per edge i the transition costs
    form an S×S matrix Tr_i with Tr_i[h, g] = tr((i-1, g_h) → (i, g_g));
    the DP step is a min-plus product of the state row S[i-1, :] with Tr_i
    under the amplification mask — implemented as a short scan over the ≤
    log2(G)+1 source scales with vectorized updates over all (entry,
    destination) cells at once, preserving the reference's exact greedy
    tie-breaking (and therefore its bit pattern).  Branch/join blocks reduce
    to S×S matrices via ``graph_reduce.block_transition_matrix``, which also
    plans *all* pinned entry scales in one matrix DP (the E axis below)
    instead of one search per (g_in, g_out) pair — the source of the
    planner's order-of-magnitude search-time win at 1024+ devices.

DAG support beyond linear chains: ``ParallelBlock``s (arbitrarily nested)
are folded into transition edges with per-branch device placements
(``graph_reduce.block_placements``), and ``EncDecGraph`` two-chain DAGs are
planned by ``plan_encdec`` — encoder and decoder chains joined by a
resharding cross-edge, with the decoder's entry scale pinned to every
candidate encoder exit scale in a single matrix DP.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.costmodel import Hardware, comm_matrix, comm_time
from repro.core.plan import BurstPlan, LayerPlan
from repro.core.profiler import (
    CostedBlock,
    CostedLayer,
    plan_scales,
    powers_of_two,
)

INF = float("inf")


@dataclass
class _ChainResult:
    """Reference-engine DP tables for one chain: indexed [layer][g]."""

    S: List[Dict[int, float]]
    T: List[Dict[int, float]]
    P: List[Dict[int, Optional[int]]]  # backpointer: chosen predecessor scale
    layers: List[CostedLayer]
    trans: List  # trans[i](h, g) -> transition time from layer i-1@h to i@g


def _layer_cost(layer: CostedLayer, g: int) -> float:
    return layer.comp[g] + layer.sync[g]


# ---------------------------------------------------------------------------
# Reference engine: the original pure-Python DP (differential-test oracle)
# ---------------------------------------------------------------------------


def search_linear_reference(
    chain: Sequence,
    scales: Sequence[int],
    amp_limit: float,
    hw: Hardware,
    entry_scale: Optional[int] = None,
    entry_act_bytes: float = 0.0,
) -> _ChainResult:
    """Paper Algorithm 1 over a chain of CostedLayer/CostedBlock elements.

    ``entry_scale`` fixes the scale feeding the first layer (used by the
    graph reduction when planning a branch whose branching layer is pinned).
    """
    from repro.core.graph_reduce import block_transition_table  # lazy: avoids cycle

    # Collapse the chain into layers + per-edge transition functions.
    layers: List[CostedLayer] = []
    trans: List = []
    pending_blocks: List[CostedBlock] = []
    prev_layer: Optional[CostedLayer] = None
    for el in chain:
        if isinstance(el, CostedBlock):
            pending_blocks.append(el)
            continue
        blocks = tuple(pending_blocks)
        pending_blocks = []
        if prev_layer is None:
            if entry_scale is None:
                trans.append(lambda h, g: 0.0)
            else:
                eb = entry_act_bytes

                def entry_tr(h, g, eb=eb):
                    return comm_time(eb, h, g, hw)

                trans.append(entry_tr)
        else:
            pb = prev_layer.act_bytes
            if blocks:
                tables = [
                    block_transition_table(b, scales, amp_limit, hw, pb) for b in blocks
                ]

                def tr(h, g, tables=tables):
                    t = 0.0
                    cur = h
                    for tab in tables:
                        t += tab[(cur, g)][0]
                        cur = g
                    return t

                trans.append(tr)
            else:

                def tr(h, g, pb=pb):
                    return comm_time(pb, h, g, hw)

                trans.append(tr)
        layers.append(el)
        prev_layer = el
    if pending_blocks:
        raise ValueError("chain must not end with a ParallelBlock")

    L = len(layers)
    S: List[Dict[int, float]] = [dict() for _ in range(L)]
    T: List[Dict[int, float]] = [dict() for _ in range(L)]
    P: List[Dict[int, Optional[int]]] = [dict() for _ in range(L)]

    def amp(i: int, g: int) -> float:
        return T[i][g] * g / max(layers[i].comp1, 1e-30)

    for i in range(L):
        for g in scales:
            if i == 0:
                src_scales = [entry_scale] if entry_scale is not None else [g]
                best_s, best_t, best_h = INF, INF, None
                for h in src_scales:
                    c = trans[0](h, g)
                    if c < best_s:
                        best_s, best_t, best_h = c, c, h
            else:
                best_amp, best_s, best_t, best_h = INF, INF, INF, None
                for h in scales:
                    a_prev = amp(i - 1, h)
                    if a_prev <= max(best_amp, amp_limit) and (
                        S[i - 1][h] + trans[i](h, g) <= best_s
                    ):
                        best_s = S[i - 1][h] + trans[i](h, g)
                        best_t = trans[i](h, g)
                        best_amp = min(best_amp, a_prev)
                        best_h = h
            S[i][g] = best_s + _layer_cost(layers[i], g)
            T[i][g] = best_t + _layer_cost(layers[i], g)
            P[i][g] = best_h

    return _ChainResult(S=S, T=T, P=P, layers=layers, trans=trans)


def _backtrace(res: _ChainResult, final_g: int) -> List[int]:
    gs = [final_g]
    for i in range(len(res.layers) - 1, 0, -1):
        gs.append(res.P[i][gs[-1]])
    gs.reverse()
    return gs


# ---------------------------------------------------------------------------
# Vectorized engine: matrix DP over numpy transition matrices
# ---------------------------------------------------------------------------


@dataclass
class _VecResult:
    """Vectorized DP tables.

    Arrays are indexed [entry, layer, scale]: the entry axis has size 1 for
    an unpinned chain, or len(scales) when *every* entry scale is planned at
    once (``entry="all"``, used by the block reduction).
    """

    S: np.ndarray               # (E, L, n) shortest completion time
    T: np.ndarray               # (E, L, n) time on layer i along chosen path
    P: np.ndarray               # (E, L, n) predecessor scale index; -1 = none
    layers: List[CostedLayer]
    edge_mats: List[np.ndarray]  # [0]: (E, n) entry costs; [i>0]: (n, n)
    edge_blocks: List[tuple]     # CostedBlocks folded into edge i ([] for 0)
    lc: np.ndarray               # (L, n) per-layer comp+sync
    scales: Tuple[int, ...]


def _collapse_chain(chain: Sequence):
    """Split a chain into layers + per-edge metadata, mirroring the reference
    collapse exactly (blocks before the first layer are dropped; a trailing
    block is an error)."""
    layers: List[CostedLayer] = []
    edge_blocks: List[tuple] = []
    act_in: List[Optional[float]] = []
    pending: List[CostedBlock] = []
    prev: Optional[CostedLayer] = None
    for el in chain:
        if isinstance(el, CostedBlock):
            pending.append(el)
            continue
        blocks = tuple(pending)
        pending = []
        if prev is None:
            edge_blocks.append(())
            act_in.append(None)
        else:
            edge_blocks.append(blocks)
            act_in.append(prev.act_bytes)
        layers.append(el)
        prev = el
    if pending:
        raise ValueError("chain must not end with a ParallelBlock")
    return layers, edge_blocks, act_in


def _edge_matrices(
    layers, edge_blocks, act_in, scales, amp_limit, hw, entry, entry_act_bytes
) -> List[np.ndarray]:
    """Materialize every edge's transition costs as matrices: (E, n) for the
    entry edge, (n, n) [src, dst] for interior edges.  Blocks on an edge
    contribute their reduced S×S time matrix (first block h→g, subsequent
    blocks g→g on the diagonal, as in the reference closure)."""
    from repro.core.graph_reduce import block_transition_matrix  # lazy: cycle

    n = len(scales)
    mats: List[np.ndarray] = []
    if entry is None:
        mats.append(np.zeros((1, n)))
    elif entry == "all":
        mats.append(comm_matrix(entry_act_bytes, scales, scales, hw))
    else:
        mats.append(comm_matrix(entry_act_bytes, [entry], scales, hw))
    for i in range(1, len(layers)):
        blocks = edge_blocks[i]
        if blocks:
            bm = block_transition_matrix(blocks[0], scales, amp_limit, hw, act_in[i])
            tr = bm.time.copy()
            for b in blocks[1:]:
                bm2 = block_transition_matrix(b, scales, amp_limit, hw, act_in[i])
                tr = tr + np.diagonal(bm2.time)[None, :]
        else:
            tr = comm_matrix(act_in[i], scales, scales, hw)
        mats.append(tr)
    return mats


def _search_vec(
    chain: Sequence,
    scales: Sequence[int],
    amp_limit: float,
    hw: Hardware,
    entry=None,
    entry_act_bytes: float = 0.0,
) -> _VecResult:
    """Vectorized Algorithm 1.  ``entry`` is None (free), an int scale
    (pinned, one DP row), or "all" (every entry scale pinned at once — one
    DP row per entry, the block reduction's batched mode)."""
    layers, edge_blocks, act_in = _collapse_chain(list(chain))
    scales = tuple(scales)
    n = len(scales)
    scales_f = np.asarray(scales, dtype=np.float64)
    mats = _edge_matrices(
        layers, edge_blocks, act_in, scales, amp_limit, hw, entry, entry_act_bytes
    )
    E = mats[0].shape[0]
    L = len(layers)
    lc = np.empty((L, n))
    for i, l in enumerate(layers):
        comp = np.array([l.comp[g] for g in scales])
        sync = np.array([l.sync[g] for g in scales])
        lc[i] = comp + sync
    comp1 = np.array([max(l.comp1, 1e-30) for l in layers])

    S = np.empty((E, L, n))
    T = np.empty((E, L, n))
    P = np.full((E, L, n), -1, dtype=np.int64)
    S[:, 0, :] = mats[0] + lc[0]
    T[:, 0, :] = mats[0] + lc[0]
    if entry == "all":
        P[:, 0, :] = np.arange(n)[:, None]
    elif entry is not None and entry in scales:
        P[:, 0, :] = scales.index(entry)
    # an entry scale outside the search space (elastic shrink) keeps -1:
    # the comm row above already prices it, and backtrace stops at layer 1

    for i in range(1, L):
        prev_amp = T[:, i - 1, :] * scales_f[None, :] / comp1[i - 1]
        tr = mats[i]
        best_amp = np.full((E, n), INF)
        best_s = np.full((E, n), INF)
        best_t = np.full((E, n), INF)
        best_h = np.full((E, n), -1, dtype=np.int64)
        # Short scan over source scales with vectorized updates over every
        # (entry, destination) cell — replicates the reference's greedy
        # `a_prev <= max(bestAmp, AmpLimit) and cand <= bestS` selection
        # elementwise, so chosen predecessors (and bits) are identical.
        for hi in range(n):
            a_prev = prev_amp[:, hi][:, None]                      # (E, 1)
            cand = S[:, i - 1, hi][:, None] + tr[hi][None, :]      # (E, n)
            ok = (a_prev <= np.maximum(best_amp, amp_limit)) & (cand <= best_s)
            best_s = np.where(ok, cand, best_s)
            best_t = np.where(ok, np.broadcast_to(tr[hi], cand.shape), best_t)
            best_amp = np.where(ok, np.minimum(best_amp, a_prev), best_amp)
            best_h = np.where(ok, hi, best_h)
        S[:, i, :] = best_s + lc[i]
        T[:, i, :] = best_t + lc[i]
        P[:, i, :] = best_h

    return _VecResult(
        S=S, T=T, P=P, layers=layers, edge_mats=mats, edge_blocks=edge_blocks,
        lc=lc, scales=scales,
    )


def search_linear(
    chain: Sequence,
    scales: Sequence[int],
    amp_limit: float,
    hw: Hardware,
    entry_scale: Optional[int] = None,
    entry_act_bytes: float = 0.0,
) -> _VecResult:
    """Vectorized drop-in for ``search_linear_reference`` (same signature)."""
    return _search_vec(
        chain, scales, amp_limit, hw,
        entry=entry_scale, entry_act_bytes=entry_act_bytes,
    )


def _backtrace_idx(res: _VecResult, e_idx: int, g_idx: int) -> List[int]:
    idxs = [g_idx]
    for i in range(len(res.layers) - 1, 0, -1):
        idxs.append(int(res.P[e_idx, i, idxs[-1]]))
    idxs.reverse()
    return idxs


def _backtrace_grid(P: np.ndarray, g_final: np.ndarray) -> np.ndarray:
    """Vectorized backtrace for every (entry, exit) cell at once.

    P: (E, L, n) backpointers; g_final: (E, H) chosen final scale indices.
    Returns (L, E, H) per-layer scale indices along each cell's path."""
    E, L, _ = P.shape
    out = np.empty((L,) + g_final.shape, dtype=np.int64)
    out[L - 1] = g_final
    er = np.arange(E)[:, None]
    for i in range(L - 1, 0, -1):
        out[i - 1] = P[er, i, out[i]]
    return out


# ---------------------------------------------------------------------------
# Plan construction
# ---------------------------------------------------------------------------


def plan(
    graph,
    num_gpus: int,
    amp_limit: float = 2.0,
    hw: Optional[Hardware] = None,
    engine: str = "vectorized",
) -> BurstPlan:
    """Plan a LayerGraph / EncDecGraph (models/graph.py) or pre-costed chain.

    ``engine="vectorized"`` (default) runs the matrix DP; ``"reference"``
    runs the original pure-Python DP — both produce bit-identical plans
    (tests/test_planner_diff.py pins this).
    """
    from repro.core.profiler import profile_graph
    from repro.models.graph import EncDecGraph, LayerNode, ParallelBlock

    if engine not in ("vectorized", "reference"):
        raise ValueError(f"unknown planner engine: {engine!r}")
    hw = hw or Hardware()
    if isinstance(graph, EncDecGraph):
        return plan_encdec(graph, num_gpus, amp_limit, hw, engine=engine)
    if graph and isinstance(graph[0], (LayerNode, ParallelBlock)):
        chain = profile_graph(graph, num_gpus, hw)
    else:
        chain = list(graph)
    scales = plan_scales(num_gpus)
    first = next((l for l in chain if isinstance(l, CostedLayer)), None)
    if first is not None:
        # a pre-costed chain may carry tables for the pow2-only scale set;
        # never index a scale its tables don't cover
        scales = [s for s in scales if s in first.comp]
    if engine == "reference":
        return _plan_reference(chain, num_gpus, scales, amp_limit, hw)
    return _plan_vectorized(chain, num_gpus, scales, amp_limit, hw)


def _plan_reference(chain, num_gpus, scales, amp_limit, hw) -> BurstPlan:
    res = search_linear_reference(chain, scales, amp_limit, hw)
    L = len(res.layers)

    def amp(i, g):
        return res.T[i][g] * g / max(res.layers[i].comp1, 1e-30)

    feasible = [g for g in scales if amp(L - 1, g) <= amp_limit]
    pool = feasible if feasible else scales
    final_g = min(pool, key=lambda g: res.S[L - 1][g])
    gs = _backtrace(res, final_g)

    layer_plans = []
    for i, (layer, g) in enumerate(zip(res.layers, gs)):
        h = gs[i - 1] if i > 0 else (g if res.P[0][g] is None else res.P[0][g])
        comm_in = res.trans[i](h, g)
        layer_plans.append(
            LayerPlan(
                index=i,
                name=layer.name,
                gpus=g,
                time=comm_in + _layer_cost(layer, g),
                comp=layer.comp[g],
                sync=layer.sync[g],
                comm_in=comm_in,
                amp=amp(i, g),
                kind=layer.kind,
            )
        )
    # count branch layers folded into transition edges too, so amplification
    # and speedup stay meaningful on DAG graphs
    from repro.core.graph_reduce import _single_gpu_time

    single = _single_gpu_time(chain)
    return BurstPlan(
        layers=tuple(layer_plans),
        num_gpus=num_gpus,
        amp_limit=amp_limit,
        single_gpu_time=single,
    )


def _plan_vectorized(chain, num_gpus, scales, amp_limit, hw) -> BurstPlan:
    from repro.core.graph_reduce import block_placements

    res = _search_vec(chain, scales, amp_limit, hw)
    L = len(res.layers)
    n = len(scales)
    scales_f = np.asarray(scales, dtype=np.float64)

    amp_last = res.T[0, -1, :] * scales_f / max(res.layers[-1].comp1, 1e-30)
    feas = np.nonzero(amp_last <= amp_limit)[0]
    pool = feas if feas.size else np.arange(n)
    final_idx = int(pool[int(np.argmin(res.S[0, -1, pool]))])
    idxs = _backtrace_idx(res, 0, final_idx)

    layer_plans = []
    details: Dict[str, object] = {}
    for i, (layer, gi) in enumerate(zip(res.layers, idxs)):
        g = scales[gi]
        if i > 0:
            comm_in = float(res.edge_mats[i][idxs[i - 1], gi])
        else:
            comm_in = float(res.edge_mats[0][0, gi])
        amp_i = float(res.T[0, i, gi]) * g / max(layer.comp1, 1e-30)
        layer_plans.append(
            LayerPlan(
                index=i,
                name=layer.name,
                gpus=g,
                time=comm_in + _layer_cost(layer, g),
                comp=layer.comp[g],
                sync=layer.sync[g],
                comm_in=comm_in,
                amp=amp_i,
                kind=layer.kind,
            )
        )
        if i > 0 and res.edge_blocks[i]:
            cur = idxs[i - 1]
            for b in res.edge_blocks[i]:
                # the block folds into layer i's comm_in: its branch devices
                # are busy only during the stage containing layer i
                details[b.name] = block_placements(
                    b, cur, gi, scales, amp_limit, hw,
                    res.layers[i - 1].act_bytes, num_gpus, layer_index=i,
                )
                cur = gi
    from repro.core.graph_reduce import _single_gpu_time

    single = _single_gpu_time(chain)  # includes branch layers inside blocks
    return BurstPlan(
        layers=tuple(layer_plans),
        num_gpus=num_gpus,
        amp_limit=amp_limit,
        single_gpu_time=single,
        block_details=details,
    )


# ---------------------------------------------------------------------------
# Encoder-decoder two-chain DAG planning (resharding join on the cross-edge)
# ---------------------------------------------------------------------------


def plan_encdec(
    graph,
    num_gpus: int,
    amp_limit: float = 2.0,
    hw: Optional[Hardware] = None,
    engine: str = "vectorized",
) -> BurstPlan:
    """Plan an EncDecGraph as a two-chain DAG.

    The encoder chain runs first; the decoder chain's cross-attention then
    consumes the encoder output memory, paying a resharding join of
    ``cross_act_bytes`` from the encoder's exit scale to the decoder's entry
    scale.  The vectorized engine plans the decoder once with *every* entry
    scale pinned (matrix DP E axis) and jointly minimizes
    S_enc[e] + S_dec[e][g] over (encoder exit e, decoder exit g).
    """
    from repro.core.profiler import profile_graph

    if engine not in ("vectorized", "reference"):
        raise ValueError(f"unknown planner engine: {engine!r}")
    hw = hw or Hardware()
    scales = plan_scales(num_gpus)
    enc_chain = profile_graph(list(graph.encoder), num_gpus, hw)
    dec_chain = profile_graph(list(graph.decoder), num_gpus, hw)
    if engine == "reference":
        return _plan_encdec_reference(
            graph, enc_chain, dec_chain, num_gpus, scales, amp_limit, hw
        )

    n = len(scales)
    scales_f = np.asarray(scales, dtype=np.float64)
    enc = _search_vec(enc_chain, scales, amp_limit, hw)
    dec = _search_vec(
        dec_chain, scales, amp_limit, hw,
        entry="all", entry_act_bytes=graph.cross_act_bytes,
    )
    amp_enc = enc.T[0, -1, :] * scales_f / max(enc.layers[-1].comp1, 1e-30)
    amp_dec = dec.T[:, -1, :] * scales_f[None, :] / max(dec.layers[-1].comp1, 1e-30)
    total = enc.S[0, -1, :][:, None] + dec.S[:, -1, :]          # (e, g)
    feas = (amp_enc[:, None] <= amp_limit) & (amp_dec <= amp_limit)
    if not feas.any():
        feas = np.ones_like(feas)
    e_idx, gd_idx = np.unravel_index(
        int(np.argmin(np.where(feas, total, INF))), total.shape
    )
    e_idx, gd_idx = int(e_idx), int(gd_idx)

    enc_idxs = _backtrace_idx(enc, 0, e_idx)
    dec_idxs = _backtrace_idx(dec, e_idx, gd_idx)

    from repro.core.graph_reduce import _single_gpu_time, block_placements

    layer_plans: List[LayerPlan] = []
    details: Dict[str, object] = {}

    def _emit(res, row, idxs, base, amp_limit_=amp_limit):
        for i, (layer, gi) in enumerate(zip(res.layers, idxs)):
            g = scales[gi]
            if i > 0:
                comm_in = float(res.edge_mats[i][idxs[i - 1], gi])
            else:
                comm_in = float(res.edge_mats[0][row, gi])
            layer_plans.append(
                LayerPlan(
                    index=base + i, name=layer.name, gpus=g,
                    time=comm_in + _layer_cost(layer, g),
                    comp=layer.comp[g], sync=layer.sync[g], comm_in=comm_in,
                    amp=float(res.T[row, i, gi]) * g / max(layer.comp1, 1e-30),
                    kind=layer.kind,
                )
            )
            if i > 0 and res.edge_blocks[i]:
                cur = idxs[i - 1]
                for b in res.edge_blocks[i]:
                    details[b.name] = block_placements(
                        b, cur, gi, scales, amp_limit_, hw,
                        res.layers[i - 1].act_bytes, num_gpus,
                        layer_index=base + i,
                    )
                    cur = gi

    _emit(enc, 0, enc_idxs, 0)
    base = len(enc.layers)
    _emit(dec, e_idx, dec_idxs, base)  # edge 0 row e_idx = resharding join
    single = _single_gpu_time(enc_chain) + _single_gpu_time(dec_chain)
    details |= {
        "encdec_join": {
            "encoder_layers": base,
            "encoder_exit_gpus": scales[e_idx],
            "decoder_entry_gpus": scales[dec_idxs[0]],
            "reshard_time": float(dec.edge_mats[0][e_idx, dec_idxs[0]]),
            "cross_act_bytes": graph.cross_act_bytes,
        }
    }
    return BurstPlan(
        layers=tuple(layer_plans),
        num_gpus=num_gpus,
        amp_limit=amp_limit,
        single_gpu_time=single,
        block_details=details,
    )


def _plan_encdec_reference(
    graph, enc_chain, dec_chain, num_gpus, scales, amp_limit, hw
) -> BurstPlan:
    """Pure-Python oracle for plan_encdec: one entry-pinned reference search
    per candidate encoder exit scale; same joint objective and tie-breaks."""
    enc = search_linear_reference(enc_chain, scales, amp_limit, hw)
    Le = len(enc.layers)
    dec_by_entry = {
        e: search_linear_reference(
            dec_chain, scales, amp_limit, hw,
            entry_scale=e, entry_act_bytes=graph.cross_act_bytes,
        )
        for e in scales
    }
    Ld = len(dec_by_entry[scales[0]].layers)

    def enc_amp(i, g):
        return enc.T[i][g] * g / max(enc.layers[i].comp1, 1e-30)

    def dec_amp(res, i, g):
        return res.T[i][g] * g / max(res.layers[i].comp1, 1e-30)

    pairs = [
        (e, g)
        for e in scales
        for g in scales
        if enc_amp(Le - 1, e) <= amp_limit
        and dec_amp(dec_by_entry[e], Ld - 1, g) <= amp_limit
    ]
    if not pairs:
        pairs = [(e, g) for e in scales for g in scales]
    best_e, best_g, best_total = None, None, INF
    for e, g in pairs:  # e-major, ascending: same tie-break as np.argmin
        t = enc.S[Le - 1][e] + dec_by_entry[e].S[Ld - 1][g]
        if t < best_total:
            best_e, best_g, best_total = e, g, t
    dec = dec_by_entry[best_e]
    enc_gs = _backtrace(enc, best_e)
    dec_gs = _backtrace(dec, best_g)

    layer_plans: List[LayerPlan] = []
    for i, (layer, g) in enumerate(zip(enc.layers, enc_gs)):
        h = enc_gs[i - 1] if i > 0 else g
        comm_in = enc.trans[i](h, g)
        layer_plans.append(
            LayerPlan(
                index=i, name=layer.name, gpus=g,
                time=comm_in + _layer_cost(layer, g),
                comp=layer.comp[g], sync=layer.sync[g], comm_in=comm_in,
                amp=enc_amp(i, g), kind=layer.kind,
            )
        )
    for j, (layer, g) in enumerate(zip(dec.layers, dec_gs)):
        h = dec_gs[j - 1] if j > 0 else best_e
        comm_in = dec.trans[j](h, g)
        layer_plans.append(
            LayerPlan(
                index=Le + j, name=layer.name, gpus=g,
                time=comm_in + _layer_cost(layer, g),
                comp=layer.comp[g], sync=layer.sync[g], comm_in=comm_in,
                amp=dec_amp(dec, j, g), kind=layer.kind,
            )
        )
    from repro.core.graph_reduce import _single_gpu_time

    single = _single_gpu_time(enc_chain) + _single_gpu_time(dec_chain)
    details = {
        "encdec_join": {
            "encoder_layers": Le,
            "encoder_exit_gpus": best_e,
            "decoder_entry_gpus": dec_gs[0],
            "reshard_time": dec.trans[0](best_e, dec_gs[0]),
            "cross_act_bytes": graph.cross_act_bytes,
        }
    }
    return BurstPlan(
        layers=tuple(layer_plans),
        num_gpus=num_gpus,
        amp_limit=amp_limit,
        single_gpu_time=single,
        block_details=details,
    )


# ---------------------------------------------------------------------------
# Data-parallel baseline
# ---------------------------------------------------------------------------


def plan_data_parallel(graph, num_gpus: int, hw: Optional[Hardware] = None) -> BurstPlan:
    """The paper's 'DP' baseline: every layer at full scale."""
    return _dp_plan(graph, num_gpus, hw)


def _dp_plan(graph, num_gpus: int, hw: Optional[Hardware]) -> BurstPlan:
    from repro.core.profiler import profile_graph
    from repro.models.graph import EncDecGraph, LayerNode, ParallelBlock

    hw = hw or Hardware()
    if isinstance(graph, EncDecGraph):
        # DP baseline runs both chains back-to-back at full scale
        graph = list(graph.encoder) + list(graph.decoder)
    if graph and isinstance(graph[0], (LayerNode, ParallelBlock)):
        chain = profile_graph(graph, num_gpus, hw)
    else:
        chain = list(graph)
    # flatten blocks: DP runs branches sequentially at full scale
    flat: List[CostedLayer] = []

    def _flat(els):
        for el in els:
            if isinstance(el, CostedLayer):
                flat.append(el)
            else:
                for br in el.branches:
                    _flat(br)

    _flat(chain)
    g = num_gpus
    plans = [
        LayerPlan(
            index=i, name=l.name, gpus=g, time=_layer_cost(l, g), comp=l.comp[g],
            sync=l.sync[g], comm_in=0.0, amp=_layer_cost(l, g) * g / max(l.comp1, 1e-30),
            kind=l.kind,
        )
        for i, l in enumerate(flat)
    ]
    return BurstPlan(
        layers=tuple(plans),
        num_gpus=g,
        amp_limit=INF,
        single_gpu_time=sum(l.comp1 for l in flat),
    )
