"""Hardware + cost model (paper §4.1, re-parameterized for TPU v5e).

The paper profiles comp(i,g) on A100s and models comm as payload/bandwidth +
propagation delay over NVSwitch.  Here the same three cost terms are derived
for a TPU v5e pod:

  comp(i,g)              fwd+bwd compute time of layer i at scale g
  comm((i,g) -> (j,h))   activation/grad resharding when scale changes
  sync(i,g)              ring all-reduce of layer i's gradients at scale g

Constants match the roofline section of the task spec: 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI with 4 links/chip (2-D torus), DCN between
pods.  ``kernel_overhead`` plays the role of the paper's per-op launch cost
(whose elimination via CUDA graphs the paper measures); on TPU the analogue
is per-op dispatch/fusion boundary cost inside one XLA executable.

Efficiency model: a device processing u = parallel_units/g independent work
units runs at eff = u/(u+1) of peak (≈50% at one unit — matches the paper's
Fig 4 utilization collapse at small per-GPU batches) with a hard cap of
min(g, parallel_units) useful devices.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.models.graph import LayerNode


@dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197.0e12  # bf16 per chip
    hbm_bw: float = 819.0e9  # bytes/s per chip
    link_bw: float = 50.0e9  # bytes/s per ICI link
    links_per_chip: int = 4  # 2-D torus
    prop_delay: float = 1.0e-6
    dcn_bw: float = 25.0e9  # bytes/s per host, across pods
    kernel_overhead: float = 2.0e-6  # per layer per pass

    @property
    def chip_bw(self) -> float:
        return self.link_bw * self.links_per_chip


# A100 + NVSwitch variant used by the paper-fidelity benchmarks (Fig 1/3).
A100 = Hardware(
    name="a100-nvswitch",
    peak_flops=312.0e12,  # bf16 tensor core
    hbm_bw=2.0e12,
    link_bw=300.0e9,  # NVSwitch 600 GB/s bidirectional → 300 each way
    links_per_chip=1,
    prop_delay=2.0e-6,
    kernel_overhead=5.0e-6,
)

V5E = Hardware()


def efficiency(units_per_device: float) -> float:
    """MXU/SM utilization vs per-device independent work units."""
    u = max(units_per_device, 1e-9)
    return u / (u + 1.0)


def comp_time(node: LayerNode, g: int, hw: Hardware, bwd: bool = True) -> float:
    """fwd(+bwd) seconds for `node` when strong-scaled to g devices."""
    g_eff = min(g, max(node.parallel_units, 1))
    mult = 1.0 + (node.bwd_mult if bwd else 0.0)
    flops = node.flops * mult / g_eff
    eff = efficiency(node.parallel_units / g_eff)
    t_flops = flops / (hw.peak_flops * eff)
    bytes_hbm = (node.param_bytes + 2.0 * node.act_out_bytes / g_eff) * (
        1.5 if bwd else 1.0
    )
    t_mem = bytes_hbm / hw.hbm_bw
    t_seq = node.seq_flops * mult / hw.peak_flops  # not divisible
    passes = 2 if bwd else 1
    return max(t_flops, t_mem) + t_seq + passes * hw.kernel_overhead


def comm_time(act_bytes: float, g: int, h: int, hw: Hardware) -> float:
    """Activation (and, in bwd, gradient) resharding when scale changes g→h.

    Paper §4.1: payload / bandwidth + propagation delay.  Payload per device
    is bounded by the smaller group, which must redistribute everything it
    holds beyond what it keeps."""
    if g == h:
        return 0.0
    lo, hi = min(g, h), max(g, h)
    payload_per_dev = act_bytes * (1.0 / lo - 1.0 / hi)
    t = payload_per_dev / hw.chip_bw + hw.prop_delay
    return 2.0 * t  # fwd activations + bwd gradients


def sync_time(param_bytes: float, g: int, hw: Hardware) -> float:
    """Ring all-reduce of gradients across g data-parallel replicas
    (not overlapped with backward, per the paper)."""
    if g <= 1:
        return 0.0
    t = 2.0 * (g - 1) / g * param_bytes / hw.chip_bw
    return t + hw.prop_delay * math.log2(g)


def allreduce_time(bytes_total: float, n: int, hw: Hardware, bw: float = 0.0) -> float:
    """Generic ring all-reduce estimate (used by roofline + multi-pod model)."""
    if n <= 1:
        return 0.0
    bw = bw or hw.chip_bw
    return 2.0 * (n - 1) / n * bytes_total / bw + hw.prop_delay * math.log2(n)


# ---------------------------------------------------------------------------
# Batched cost evaluation over scale vectors (vectorized planner hot path).
#
# Each *_batch function evaluates the scalar formula above elementwise in
# float64, in the same operation order, so values are bit-identical to the
# scalar path — a requirement of the differential test harness
# (tests/test_planner_diff.py), which pins vectorized == reference exactly.
# ---------------------------------------------------------------------------


def comp_time_batch(node: LayerNode, scales, hw: Hardware, bwd: bool = True) -> np.ndarray:
    """``comp_time`` evaluated at a vector of scales; returns float64 array."""
    g = np.asarray(scales, dtype=np.float64)
    g_eff = np.minimum(g, float(max(node.parallel_units, 1)))
    mult = 1.0 + (node.bwd_mult if bwd else 0.0)
    flops = node.flops * mult / g_eff
    u = np.maximum(node.parallel_units / g_eff, 1e-9)
    eff = u / (u + 1.0)
    t_flops = flops / (hw.peak_flops * eff)
    bytes_hbm = (node.param_bytes + 2.0 * node.act_out_bytes / g_eff) * (
        1.5 if bwd else 1.0
    )
    t_mem = bytes_hbm / hw.hbm_bw
    t_seq = node.seq_flops * mult / hw.peak_flops
    passes = 2 if bwd else 1
    return np.maximum(t_flops, t_mem) + t_seq + passes * hw.kernel_overhead


def sync_time_batch(param_bytes: float, scales, hw: Hardware) -> np.ndarray:
    """``sync_time`` evaluated at a vector of replica counts.

    Scales are powers of two, so ``log2`` is exact and matches math.log2.
    """
    g = np.asarray(scales, dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        t = 2.0 * (g - 1.0) / g * param_bytes / hw.chip_bw
        out = t + hw.prop_delay * np.log2(g)
    return np.where(g <= 1.0, 0.0, out)


def comm_matrix(act_bytes: float, src_scales, dst_scales, hw: Hardware) -> np.ndarray:
    """``comm_time`` for every (src, dst) pair: the planner's per-edge S×S
    transition-cost matrix, indexed [src][dst]."""
    g = np.asarray(src_scales, dtype=np.float64)[:, None]
    h = np.asarray(dst_scales, dtype=np.float64)[None, :]
    lo = np.minimum(g, h)
    hi = np.maximum(g, h)
    payload_per_dev = act_bytes * (1.0 / lo - 1.0 / hi)
    t = payload_per_dev / hw.chip_bw + hw.prop_delay
    return np.where(g == h, 0.0, 2.0 * t)
