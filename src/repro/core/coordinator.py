"""Cluster coordinator (paper §3.2): job registry, placement, elasticity.

Manages all runtimes: places a new foreground job on the device subset its
burst plan requests, registers background jobs per device, and handles
cluster-size changes (device failure / elastic scale) by *re-planning* —
elastic scaling falls out of the planner abstraction, since a BurstPlan is a
pure function of (graph, G, amp_limit).
"""
from __future__ import annotations

import dataclasses
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.costmodel import Hardware
from repro.core.multiplex import (
    AdmissionDecision,
    BgTenant,
    Collocator,
    CollocationResult,
    ExecutableCache,
    InterferenceModel,
    MultiplexConfig,
    MultiplexSim,
    QoSMonitor,
)
from repro.core.plan import BurstPlan
from repro.core.planner import plan as make_plan

# paper §5: the fg slowdown the QoS/admission machinery must hold
QOS_SLOWDOWN_BOUND = 1.33


def _placeholder_factory(mesh):
    """Stand-in step factory for prediction-only admission sweeps: rosters
    jobs registered without a ``step_fn_factory`` so ``readmit`` can reason
    about them analytically; never compiled or called."""
    return lambda: None


@dataclass
class Job:
    name: str
    kind: str  # 'foreground' | 'background'
    graph: list  # LayerGraph
    amp_limit: float = 2.0
    plan: Optional[BurstPlan] = None
    devices: tuple = ()
    status: str = "pending"  # pending | running | failed | done
    steps_done: int = 0
    priority: int = 0  # background jobs: higher packs first into gaps
    step_fn_factory: Optional[Callable] = None  # mesh -> zero-arg bg step
    weight: float = 1.0  # fair-share weight among equal-priority tenants
    quantum: Optional[int] = None  # device-chunk alignment for gap packing


@dataclass
class ClusterEvent:
    t: float
    kind: str  # 'failure' | 'join' | 'replan' | 'straggler'
    detail: str


class ClusterCoordinator:
    """Single source of truth for placement + plan lifecycle.

    ``clock`` injects a time source for the event log (the trace-driven
    cluster simulator advances a virtual clock per replayed event; default
    is wall time).  ``virtual_devices=True`` decouples the coordinator from
    the jax process devices entirely: device ids ARE the healthy indices,
    so a 1024-device cluster can be simulated on a 1-device host and
    executable-cache eviction reasons about simulated ids instead of
    positionally mapping onto ``jax.devices()``.
    """

    def __init__(self, num_devices: int, hw: Optional[Hardware] = None, *,
                 clock: Optional[Callable[[], float]] = None,
                 virtual_devices: bool = False,
                 verify_plans: Optional[bool] = None):
        self.num_devices = num_devices
        self.hw = hw or Hardware()
        self._clock = clock or time.time
        self.virtual_devices = virtual_devices
        # every installed/re-planned plan goes through the static verifier
        # (repro.analysis.verify) — O(layers + stages) pure metadata, so it
        # is on by default; REPRO_VERIFY_PLANS=0 (or verify_plans=False)
        # turns it off for hot replay loops that re-plan thousands of times
        if verify_plans is None:
            verify_plans = os.environ.get("REPRO_VERIFY_PLANS", "1") != "0"
        self.verify_plans = verify_plans
        self.healthy = set(range(num_devices))
        self.jobs: Dict[str, Job] = {}
        self.events: List[ClusterEvent] = []
        self.monitor = QoSMonitor()
        # survives re-plans: unchanged gap shapes reuse compiled bg steps
        self.exec_cache = ExecutableCache()
        self.interference = InterferenceModel()
        self.collocation_results: List[CollocationResult] = []
        self._last_mcfg = MultiplexConfig()  # config of the last collocation
        self.last_admission: Optional[AdmissionDecision] = None

    # -- job lifecycle ------------------------------------------------------

    def submit_foreground(self, job: Job) -> BurstPlan:
        job.kind = "foreground"
        job.plan = make_plan(job.graph, self._usable_devices(), job.amp_limit, self.hw)
        job.devices = tuple(sorted(self.healthy))
        job.status = "running"
        self.jobs[job.name] = job
        self._verify_installed(job.plan, f"submit_foreground({job.name})")
        return job.plan

    def submit_background(self, job: Job) -> None:
        job.kind = "background"
        job.status = "running"
        self.jobs[job.name] = job

    def foreground(self) -> Optional[Job]:
        for j in self.jobs.values():
            if j.kind == "foreground" and j.status == "running":
                return j
        return None

    def background_tenants(
        self, default_step_fn_factory: Optional[Callable] = None
    ) -> List[BgTenant]:
        """Running background jobs as a prioritized BgTenant roster.

        A job without its own ``step_fn_factory`` falls back to
        ``default_step_fn_factory`` (the ``make_bg_step_fn`` passed to
        ``collocate``); jobs with neither are skipped.  Sorted by priority
        (higher first), stable in submission order.
        """
        out = []
        for j in self.jobs.values():
            if j.kind != "background" or j.status != "running":
                continue
            factory = j.step_fn_factory or default_step_fn_factory
            if factory is None:
                continue
            sig = None
            if j.step_fn_factory is None:
                # shared default factory: scope the executable identity per
                # job, or two jobs whose chunks happen to land on the same
                # device range would silently share one compiled step (and
                # its training state) through the cache
                sig = (j.name,
                       getattr(factory, "signature", None) or factory)
            out.append(BgTenant(j.name, j.priority, factory, signature=sig,
                                weight=j.weight, quantum=j.quantum))
        out.sort(key=lambda t: -t.priority)
        return out

    def _usable_devices(self) -> int:
        """Every healthy device.  The planner's scale set covers non-pow2
        pool sizes (``plan_scales``), so a 1024-device pool with 3 dead
        devices plans at 1021 instead of rounding down to 512 and silently
        discarding ~half the survivors."""
        return len(self.healthy)

    def _verify_installed(self, plan: Optional[BurstPlan],
                          context: str) -> None:
        """Statically verify a just-installed plan against the current pool
        (range disjointness, coverage, amp limits, survivor-pool exactness
        — ``repro.analysis.verify``).  Debug-gated via ``verify_plans``;
        raises ``PlanVerificationError`` so a planner regression fails at
        install time instead of surfacing as silent throughput loss."""
        if plan is None or not self.verify_plans:
            return
        from repro.analysis.verify import verify_plan_or_raise

        verify_plan_or_raise(plan, pool_size=len(self.healthy),
                             context=context)

    # -- elasticity / fault handling ---------------------------------------

    def handle_failure(self, device_id: int) -> Optional[BurstPlan]:
        """Device loss: shrink the healthy set and re-plan the foreground
        job onto the exact surviving pool. Returns the new plan.
        Compiled bg steps whose submesh touched the dead device are evicted
        from the executable cache — their device-committed state is gone, so
        holding them alive would only pin dead jitted state."""
        self.healthy.discard(device_id)
        self.events.append(ClusterEvent(self._clock(), "failure", f"device {device_id}"))
        self._evict_stale_executables()
        fg = self.foreground()
        if fg is None:
            return None
        old = fg.plan
        fg.plan = make_plan(fg.graph, self._usable_devices(), fg.amp_limit, self.hw)
        fg.devices = tuple(sorted(self.healthy))
        self._drop_stale_measurements(old, fg.plan)
        self.events.append(
            ClusterEvent(self._clock(), "replan", f"G={fg.plan.num_gpus}")
        )
        self._verify_installed(fg.plan, f"handle_failure({device_id})")
        return fg.plan

    def handle_join(self, device_ids) -> Optional[BurstPlan]:
        """Elastic scale-up: devices join, re-plan to exploit them.

        Idempotent: a join announcement covering only already-healthy
        devices (re-delivered heartbeat, duplicate trace event) changes
        nothing — no join event is logged and no spurious re-plan runs.
        Returns the new plan, or None when the healthy set is unchanged
        or no foreground job is running.
        """
        new = set(device_ids) - self.healthy
        if not new:
            return None
        self.healthy.update(new)
        self.events.append(ClusterEvent(self._clock(), "join", f"+{len(new)}"))
        self._evict_stale_executables()
        fg = self.foreground()
        if fg is None:
            return None
        old = fg.plan
        fg.plan = make_plan(fg.graph, self._usable_devices(), fg.amp_limit, self.hw)
        fg.devices = tuple(sorted(self.healthy))
        self._drop_stale_measurements(old, fg.plan)
        self._verify_installed(fg.plan, f"handle_join(+{len(new)})")
        return fg.plan

    def restore_pool(self, devices) -> None:
        """Coordinator failover: adopt the surviving pool a previous holder
        already re-planned onto (``CoordinatorLoop.bootstrap_from_log``).

        Unlike ``handle_failure``/``handle_join`` this fires no mitigation
        and publishes nothing — those mitigations already ran on the old
        coordinator and the workers already hold the reconfig events; a
        fresh holder that re-fired them would double-plan and double-log.
        The foreground is re-planned *silently* when its plan does not
        match the restored pool, and stale executables are evicted."""
        self.healthy = set(int(d) for d in devices)
        self._evict_stale_executables()
        fg = self.foreground()
        if fg is None:
            return
        if fg.plan is None or fg.plan.num_gpus != len(self.healthy):
            old = fg.plan
            fg.plan = make_plan(fg.graph, self._usable_devices(),
                                fg.amp_limit, self.hw)
            fg.devices = tuple(sorted(self.healthy))
            self._drop_stale_measurements(old, fg.plan)
            self._verify_installed(fg.plan, "restore_pool")

    def handle_departure(self, name: str) -> bool:
        """Tenant churn: a running job finishes/leaves the cluster.  The job
        is marked done (so ``background_tenants`` stops rostering it) and
        the departure is logged; the next ``collocate``/admission sweep sees
        the shrunken roster.  Returns False for unknown/already-gone jobs
        (trace replay may race a departure against a crash)."""
        job = self.jobs.get(name)
        if job is None or job.status != "running":
            return False
        job.status = "done"
        self.events.append(ClusterEvent(self._clock(), "departure", name))
        return True

    def readmit(self, admission_bound: float = QOS_SLOWDOWN_BOUND, *,
                reason: str = "epoch") -> Optional[AdmissionDecision]:
        """Continuous admission: re-sweep the current tenant roster against
        the current plan (prediction only — nothing compiles).

        The live control plane calls this each epoch and on every churn
        event (``CoordinatorLoop``), instead of admission running once at
        submesh-carving time: after a failure shrinks the gaps, or a tenant
        arrives/departs, the argmax-cluster-throughput sweep re-decides
        which prefix of the roster stays under the QoS bound.  With the
        density-aware ``InterferenceModel`` the sweep rejects the
        *marginal* tenant — each extra collocated tenant inflates the gap
        stages a bit more, so the curve peaks at some 0 < k < n instead of
        all-or-nothing.

        The sweep predicts against a fresh ``QoSMonitor`` (stale feedback
        bans from a previous operating point must not leak into the
        decision) and uses placeholder factories for rostered jobs so
        prediction works with or without compiled steps.  Logs an
        'admission' ClusterEvent only when the admitted set *changed* since
        the previous decision (churn is the signal; a stable roster
        re-admitted every epoch stays silent).  Returns the decision, or
        None when there is no planned foreground job or no tenants.
        """
        fg = self.foreground()
        if fg is None or fg.plan is None:
            return None
        tenants = self.background_tenants(_placeholder_factory)
        if not tenants:
            return None
        col = Collocator(fg.plan, self._last_mcfg, monitor=QoSMonitor(),
                         tenants=tenants, interference=self.interference)
        decision = col.admit(max_fg_slowdown=admission_bound)
        prev = self.last_admission
        prev_set = tuple(t.job for t in prev.admitted) if prev else None
        now_set = tuple(t.job for t in decision.admitted)
        if prev_set != now_set:
            self.events.append(ClusterEvent(
                self._clock(), "admission", f"{reason}: {decision.row()}"
            ))
        self.last_admission = decision
        return decision

    def _drop_stale_measurements(self, old: Optional[BurstPlan],
                                 new: Optional[BurstPlan]) -> None:
        """A re-plan that actually changed the foreground plan invalidates
        the accumulated CollocationResults: their per-stage slowdowns (and
        schedules) describe the old plan's stages, and feeding them to
        ``calibrate`` would attribute interference to the wrong stages of
        the new plan.  The fitted per-stage inflation vector is stale for
        the same reason (keyed by old-plan stage indices) and is dropped
        too; the scalar ``gap_inflation`` survives — it measures the host,
        not the plan shape, and is the best prior for the next admission
        sweep until the new plan is measured.  A no-op re-plan (identical
        layer tuple) keeps everything."""
        if old is not None and new is not None and old.layers != new.layers:
            self.collocation_results.clear()
            if self.interference.gap_inflation_stages:
                self.interference = dataclasses.replace(
                    self.interference, gap_inflation_stages=()
                )

    def _evict_stale_executables(self) -> int:
        """Drop executable-cache entries whose submesh uses a device outside
        the healthy set (device indices mapped positionally onto the process
        device list, the same positional contract ``submesh_from_range``
        uses).  In ``virtual_devices`` mode the healthy indices themselves
        are the device ids — no jax needed, so simulated 1024-device
        clusters get real eviction semantics on a 1-device host.  No-op
        when the cache is empty or jax is unavailable."""
        if not self.exec_cache.entries:
            return 0
        if self.virtual_devices:
            live = set(self.healthy)
        else:
            try:
                import jax

                devs = jax.devices()
            except Exception:
                return 0
            live = {devs[i].id for i in self.healthy if i < len(devs)}
        n = self.exec_cache.evict_stale(live)
        if n:
            self.events.append(
                ClusterEvent(self._clock(), "evict", f"{n} stale executables")
            )
        return n

    # -- multiplexing -------------------------------------------------------

    def simulate_collocation(self, mcfg: Optional[MultiplexConfig] = None):
        fg = self.foreground()
        assert fg is not None and fg.plan is not None
        sim = MultiplexSim(fg.plan, mcfg or MultiplexConfig(),
                           self.interference, monitor=self.monitor)
        return sim.run()

    def collocate(
        self,
        mcfg: Optional[MultiplexConfig] = None,
        *,
        executable: bool = False,
        make_fg_stage_fn: Optional[Callable] = None,
        make_bg_step_fn: Optional[Callable] = None,
        iterations: int = 3,
        calibrate: bool = False,
        admission_bound: Optional[float] = QOS_SLOWDOWN_BOUND,
    ):
        """Collocate background work into the foreground plan's gaps.

        ``executable=True`` dispatches real jitted steps onto disjoint
        submeshes (``Collocator.run_executable``), returning a measured
        ``CollocationResult``; when the process has fewer devices than the
        plan assumes it falls back to the costless ``MultiplexSim`` (logged
        as a 'fallback' ClusterEvent) and returns a ``SimResult`` — both
        expose ``fg_slowdown`` / ``bg_steps_per_iter`` / ``row()``.

        Every running background job becomes a tenant
        (``background_tenants``), so several ``submit_background`` jobs
        actually co-run inside the gaps, packed by priority; a job without
        its own ``step_fn_factory`` uses ``make_bg_step_fn``.  With no
        background jobs registered, ``make_bg_step_fn`` runs as a single
        anonymous tenant.  Compiled bg steps go through the coordinator's
        ``exec_cache`` — after a ``handle_failure``/``handle_join`` re-plan
        with unchanged gap shapes the jitted steps are reused.
        ``calibrate=True`` refits ``self.interference`` from the measured
        result so subsequent ``simulate_collocation`` calls track hardware.

        Admission control runs *before anything compiles*: the candidate
        roster is swept through the calibrated ``Collocator.predict`` and
        only the argmax-cluster-throughput prefix whose predicted fg
        slowdown stays within ``admission_bound`` (paper §5: 1.33x) is
        compiled and run — rejected tenants are reported on
        ``CollocationResult.rejected_tenants`` and logged as an 'admission'
        ClusterEvent, and never touch the executable cache.  With an
        uncalibrated model (``gap_inflation`` 1.0) every tenant is
        predicted harmless and admitted.  ``admission_bound=None`` disables
        the sweep.
        """
        fg = self.foreground()
        assert fg is not None and fg.plan is not None
        self._last_mcfg = mcfg or MultiplexConfig()
        if executable:
            tenants = self.background_tenants(make_bg_step_fn)
            if make_fg_stage_fn is None or (
                not tenants and make_bg_step_fn is None
            ):
                raise ValueError(
                    "executable collocation needs make_fg_stage_fn and "
                    "background work (make_bg_step_fn or submitted "
                    "background jobs with step_fn_factory)"
                )
            import jax

            # collocate onto the SURVIVING devices (positional over the
            # sorted healthy set): after a low-index failure the carving
            # must not place work on the dead device, and the eviction
            # semantics (entries touching a dead device are dropped) only
            # hold if the dead device is actually excluded from new meshes
            devs = jax.devices()
            survivors = [devs[i] for i in sorted(self.healthy)
                         if i < len(devs)]
            if len(survivors) >= fg.plan.num_gpus:
                col = Collocator(fg.plan, mcfg or MultiplexConfig(),
                                 monitor=self.monitor, tenants=tenants,
                                 devices=survivors,
                                 cache=self.exec_cache,
                                 interference=self.interference)
                rejected: tuple = ()
                if admission_bound is not None and col.tenants:
                    # the measured run re-derives per-stage QoS state from
                    # wall-clock measurement; the admission sweep must
                    # predict against that same reset state, not stale bans
                    # the run is about to discard
                    col.reset_measured_qos()
                    decision = self.last_admission = col.admit(
                        max_fg_slowdown=admission_bound
                    )
                    if decision.rejected:
                        rejected = tuple(t.job for t in decision.rejected)
                        self.events.append(ClusterEvent(
                            self._clock(), "admission", decision.row()
                        ))
                    if decision.n_admitted == 0:
                        # nothing admitted: return the fg-only prediction —
                        # no tenant is ever compiled (iterations == 0 marks
                        # it predicted, so calibrate() ignores it)
                        res = col.predict(0)
                        res.rejected_tenants = rejected
                        return res
                    if decision.rejected:
                        col = Collocator(fg.plan, self._last_mcfg,
                                         monitor=self.monitor,
                                         tenants=decision.admitted,
                                         devices=survivors,
                                         cache=self.exec_cache,
                                         interference=self.interference)
                res = col.run_executable(
                    make_fg_stage_fn, make_bg_step_fn, iterations=iterations
                )
                res.rejected_tenants = rejected
                self.collocation_results.append(res)
                if calibrate:
                    self.interference = col.calibrate(self.collocation_results)
                return res
            self.events.append(ClusterEvent(
                self._clock(), "fallback",
                f"executable collocation wants {fg.plan.num_gpus} devices, "
                f"process has {len(survivors)} healthy -> MultiplexSim",
            ))
        return self.simulate_collocation(mcfg)

    def calibrate(self) -> InterferenceModel:
        """Refit ``self.interference`` from every measured CollocationResult
        so far (``Collocator.calibrate``), making ``simulate_collocation``
        track the measured hardware.  Uses the coordinator's live monitor
        and the config of the last collocation, so feedback bans and pacing
        limits attribute the measured slowdown to the same gap stages the
        measurements actually collocated."""
        fg = self.foreground()
        assert fg is not None and fg.plan is not None
        col = Collocator(fg.plan, self._last_mcfg, monitor=self.monitor,
                         tenants=self.background_tenants(lambda m: None)
                         or (), interference=self.interference)
        self.interference = col.calibrate(self.collocation_results)
        return self.interference
