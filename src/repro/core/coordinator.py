"""Cluster coordinator (paper §3.2): job registry, placement, elasticity.

Manages all runtimes: places a new foreground job on the device subset its
burst plan requests, registers background jobs per device, and handles
cluster-size changes (device failure / elastic scale) by *re-planning* —
elastic scaling falls out of the planner abstraction, since a BurstPlan is a
pure function of (graph, G, amp_limit).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.costmodel import Hardware
from repro.core.multiplex import (
    Collocator,
    MultiplexConfig,
    MultiplexSim,
    QoSMonitor,
)
from repro.core.plan import BurstPlan
from repro.core.planner import plan as make_plan


@dataclass
class Job:
    name: str
    kind: str  # 'foreground' | 'background'
    graph: list  # LayerGraph
    amp_limit: float = 2.0
    plan: Optional[BurstPlan] = None
    devices: tuple = ()
    status: str = "pending"  # pending | running | failed | done
    steps_done: int = 0


@dataclass
class ClusterEvent:
    t: float
    kind: str  # 'failure' | 'join' | 'replan' | 'straggler'
    detail: str


class ClusterCoordinator:
    """Single source of truth for placement + plan lifecycle."""

    def __init__(self, num_devices: int, hw: Optional[Hardware] = None):
        self.num_devices = num_devices
        self.hw = hw or Hardware()
        self.healthy = set(range(num_devices))
        self.jobs: Dict[str, Job] = {}
        self.events: List[ClusterEvent] = []
        self.monitor = QoSMonitor()

    # -- job lifecycle ------------------------------------------------------

    def submit_foreground(self, job: Job) -> BurstPlan:
        job.kind = "foreground"
        job.plan = make_plan(job.graph, self._usable_devices(), job.amp_limit, self.hw)
        job.devices = tuple(sorted(self.healthy))
        job.status = "running"
        self.jobs[job.name] = job
        return job.plan

    def submit_background(self, job: Job) -> None:
        job.kind = "background"
        job.status = "running"
        self.jobs[job.name] = job

    def foreground(self) -> Optional[Job]:
        for j in self.jobs.values():
            if j.kind == "foreground" and j.status == "running":
                return j
        return None

    def _usable_devices(self) -> int:
        """Largest power of two that fits the healthy set (planner search
        space is powers of two)."""
        from repro.core.plan import pow2_floor

        return pow2_floor(len(self.healthy))

    # -- elasticity / fault handling ---------------------------------------

    def handle_failure(self, device_id: int) -> Optional[BurstPlan]:
        """Device loss: shrink the healthy set and re-plan the foreground
        job onto the surviving power-of-two subset. Returns the new plan."""
        self.healthy.discard(device_id)
        self.events.append(ClusterEvent(time.time(), "failure", f"device {device_id}"))
        fg = self.foreground()
        if fg is None:
            return None
        fg.plan = make_plan(fg.graph, self._usable_devices(), fg.amp_limit, self.hw)
        fg.devices = tuple(sorted(self.healthy))
        self.events.append(
            ClusterEvent(time.time(), "replan", f"G={fg.plan.num_gpus}")
        )
        return fg.plan

    def handle_join(self, device_ids) -> Optional[BurstPlan]:
        """Elastic scale-up: devices join, re-plan to exploit them."""
        self.healthy.update(device_ids)
        self.events.append(ClusterEvent(time.time(), "join", f"+{len(device_ids)}"))
        fg = self.foreground()
        if fg is None:
            return None
        fg.plan = make_plan(fg.graph, self._usable_devices(), fg.amp_limit, self.hw)
        fg.devices = tuple(sorted(self.healthy))
        return fg.plan

    # -- multiplexing -------------------------------------------------------

    def simulate_collocation(self, mcfg: Optional[MultiplexConfig] = None):
        fg = self.foreground()
        assert fg is not None and fg.plan is not None
        sim = MultiplexSim(fg.plan, mcfg or MultiplexConfig(), monitor=self.monitor)
        return sim.run()

    def collocate(
        self,
        mcfg: Optional[MultiplexConfig] = None,
        *,
        executable: bool = False,
        make_fg_stage_fn: Optional[Callable] = None,
        make_bg_step_fn: Optional[Callable] = None,
        iterations: int = 3,
    ):
        """Collocate background work into the foreground plan's gaps.

        ``executable=True`` dispatches real jitted steps onto disjoint
        submeshes (``Collocator.run_executable``), returning a measured
        ``CollocationResult``; when the process has fewer devices than the
        plan assumes it falls back to the costless ``MultiplexSim`` (logged
        as a 'fallback' ClusterEvent) and returns a ``SimResult`` — both
        expose ``fg_slowdown`` / ``bg_steps_per_iter`` / ``row()``.
        """
        fg = self.foreground()
        assert fg is not None and fg.plan is not None
        if executable:
            if make_fg_stage_fn is None or make_bg_step_fn is None:
                raise ValueError(
                    "executable collocation needs both make_fg_stage_fn and "
                    "make_bg_step_fn"
                )
            import jax

            if len(jax.devices()) >= fg.plan.num_gpus:
                col = Collocator(fg.plan, mcfg or MultiplexConfig(),
                                 monitor=self.monitor)
                return col.run_executable(
                    make_fg_stage_fn, make_bg_step_fn, iterations=iterations
                )
            self.events.append(ClusterEvent(
                time.time(), "fallback",
                f"executable collocation wants {fg.plan.num_gpus} devices, "
                f"process has {len(jax.devices())} -> MultiplexSim",
            ))
        return self.simulate_collocation(mcfg)
