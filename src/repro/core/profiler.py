"""Layer profiler: LayerGraph -> per-layer cost tables for the planner.

The paper profiles each layer on real hardware at every batch size; in this
repo the same tables come from the analytical hardware model (costmodel.py),
optionally *calibrated* by measured CPU microbenchmarks (calibrate=True runs
each layer kind once on the host and scales the model's constant so relative
layer heterogeneity — the thing the planner exploits — is measurement-driven
while absolute magnitudes stay in TPU terms).

``CostedLayer`` is exactly the paper's interface: comp(i,g), sync(i,g) plus
the activation payload used by comm((i,g)→(j,h)).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Sequence

from repro.core.costmodel import (
    Hardware,
    comp_time,
    comp_time_batch,
    sync_time_batch,
)
from repro.models.graph import LayerNode, ParallelBlock


def powers_of_two(G: int) -> list:
    """Planner search space (paper §7.4: 'only considers GPU counts that are
    powers of two')."""
    out, g = [], 1
    while g <= G:
        out.append(g)
        g *= 2
    return out


def plan_scales(G: int) -> list:
    """Planner search space for a G-device pool.

    A power-of-two pool keeps the paper's §7.4 pow2-only search space, so
    every pre-existing configuration plans bit-identically.  A non-power-of
    -two pool — the elastic case after device failures — used to round down
    via ``pow2_floor`` and silently discard up to ~half the survivors (a
    1024-device pool with 3 dead devices planned as 512).  Here the scale
    set is extended with the exact pool size plus the 3·2^k midpoints that
    fit, so the DP can place layers on all surviving devices wherever
    amplification allows, falling back to smaller scales only where the
    amp limit genuinely binds."""
    out = powers_of_two(G)
    if out[-1] != G:
        mids = [3 * p // 2 for p in out if p >= 2 and 3 * p // 2 <= G]
        out = sorted(set(out) | set(mids) | {G})
    return out


@dataclass(frozen=True)
class CostedLayer:
    name: str
    comp: Dict[int, float]  # g -> fwd+bwd seconds
    sync: Dict[int, float]  # g -> gradient all-reduce seconds
    act_bytes: float
    comp1: float  # single-device iteration time (Amp denominator)
    kind: str = "generic"


@dataclass(frozen=True)
class CostedBlock:
    name: str
    branches: tuple  # tuple of tuples of CostedLayer/CostedBlock


def profile_node(node: LayerNode, scales: Sequence[int], hw: Hardware) -> CostedLayer:
    # Batched over the scale vector (costmodel.*_batch): one numpy evaluation
    # per layer instead of one Python call per (layer, scale); bit-identical
    # to the scalar formulas.
    sg = max(getattr(node, "sync_groups", 1), 1)
    comp_v = comp_time_batch(node, list(scales), hw)
    sync_v = sync_time_batch(
        node.param_bytes / sg, [max(g // sg, 1) for g in scales], hw
    )
    comp = {g: float(c) for g, c in zip(scales, comp_v)}
    sync = {g: float(s) for g, s in zip(scales, sync_v)}
    return CostedLayer(
        name=node.name,
        comp=comp,
        sync=sync,
        act_bytes=node.act_out_bytes,
        comp1=comp_time(node, 1, hw),
        kind=node.kind,
    )


def profile_graph(graph, G: int, hw: Hardware) -> list:
    """LayerGraph -> chain of CostedLayer / CostedBlock."""
    scales = plan_scales(G)
    out = []
    for el in graph:
        if isinstance(el, LayerNode):
            out.append(profile_node(el, scales, hw))
        elif isinstance(el, ParallelBlock):
            branches = tuple(
                tuple(profile_graph(list(br), G, hw)) for br in el.branches
            )
            out.append(CostedBlock(name=el.name, branches=branches))
        else:
            raise TypeError(type(el))
    return out


# ---------------------------------------------------------------------------
# Optional measured calibration (host microbench; keeps *relative* layer
# heterogeneity measurement-driven)
# ---------------------------------------------------------------------------


def calibrate_kinds(graph, repeats: int = 3) -> Dict[str, float]:
    """Measure a tiny representative op per layer kind on the host and return
    per-kind speed ratios (1.0 = model prediction). Used by benchmarks to
    show the feedback loop the paper runs manually (§3.2)."""
    import jax
    import jax.numpy as jnp

    kinds = {n.kind for n in graph if isinstance(n, LayerNode)}
    ratios: Dict[str, float] = {}
    probe = {
        "attention": lambda k: jnp.einsum(
            "bsh,bth->bst", jax.random.normal(k, (2, 128, 64)), jax.random.normal(k, (2, 128, 64))
        ),
        "mlp": lambda k: jax.random.normal(k, (256, 256)) @ jax.random.normal(k, (256, 256)),
        "conv": lambda k: jax.lax.conv_general_dilated(
            jax.random.normal(k, (1, 32, 32, 16)),
            jax.random.normal(k, (3, 3, 16, 16)),
            (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ),
    }
    for kind in kinds:
        fn = probe.get(kind, probe["mlp"])
        k = jax.random.PRNGKey(0)
        f = jax.jit(fn)
        f(k).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(repeats):
            f(k).block_until_ready()
        dt = (time.perf_counter() - t0) / repeats
        ratios[kind] = dt
    base = min(ratios.values()) or 1.0
    return {k: v / base for k, v in ratios.items()}
