"""Foreground/background multiplexing (paper §5), TPU-adapted.

Two layers — a costless simulation and an executable path — chosen by the
caller (``ClusterCoordinator.collocate(executable=...)``):

1. ``MultiplexSim`` — a discrete-event model of one accelerator cluster
   multiplexing a burst-parallel foreground job with background jobs.  It
   reproduces the paper's §7.2 ablation (Fig 11): each QoS mechanism
   (priorities, launch pacing, slowdown feedback loop, background
   granularity reduction) can be toggled, and the simulator reports
   foreground slowdown + background throughput.  The interference model is
   parameterized by the paper's own measurements (naive collocation ≈ halves
   fg throughput; NCCL all-reduce >2× sensitive; non-preemptive overrun).
   This path needs no accelerators and runs everywhere: planning-time
   what-ifs, coordinator policy decisions, and the Fig-11 ablation tests.

2. ``Collocator`` — the executable path: real jitted steps are dispatched
   onto the devices left idle by the plan's gaps.  ``submeshes()`` carves
   the device set into the plan's foreground submesh plus per-gap background
   submeshes (``repro.launch.mesh.split_mesh_for_plan``), excluding devices
   that host parallel ``BranchPlacement`` branches *during that stage*;
   ``run_executable()`` compiles fg stage fns and bg train steps onto those
   submeshes and interleaves them with dispatch pacing (bounded in-flight
   futures) and the slowdown feedback loop driven by a QoSMonitor of
   *measured* stage times.  It runs whenever the process has at least
   ``plan.num_gpus`` devices (real TPU slice, or CPU with a forced
   host-device count); the coordinator falls back to ``MultiplexSim``
   otherwise.

Multi-tenant gap scheduling (paper §5's cluster-throughput setting — several
background jobs packed into one foreground job's gaps):

- ``BgTenant(job, priority, step_fn_factory)`` names one background job.
  ``Collocator(tenants=[...])`` packs the tenants into each gap's free
  device ranges by priority — ``repro.core.plan.pack_ranges`` carves the
  free set into disjoint quantum-aligned chunks, largest chunk to the
  highest-priority tenant — and ``run_executable`` interleaves every
  tenant's paced dispatch under the shared QoS loop, reporting per-tenant
  throughput as ``CollocationResult.tenants`` (``TenantResult`` rows).
- ``ExecutableCache`` memoizes compiled bg step fns across re-plans, keyed
  on (tenant signature, gap submesh device ids, submesh shape).  A
  coordinator-owned cache survives ``handle_failure``/``handle_join``
  re-plans, so a re-plan whose gap shape is unchanged reuses the jitted bg
  steps (and their training state) instead of recompiling — the dominant
  cost of burst re-scaling.
- ``Collocator.calibrate(results)`` fits the ``InterferenceModel``'s
  submesh-mode multipliers (``gap_inflation``) from measured
  ``CollocationResult``s, and ``Collocator.predict()`` replays the tenant
  schedule through the calibrated model so ``MultiplexSim`` / planning-time
  what-ifs track the hardware the executable path actually measured.

Admission-controlled fair sharing (this layer decides *who runs* before
anything compiles):

- Per-tenant quanta: ``BgTenant.quantum`` aligns that tenant's gap chunks to
  its own submesh width (``pack_ranges`` per-tenant mode) and each tenant's
  bg step-time quantum is sized to the smallest gap *it* occupies rather
  than the global gap minimum — a tenant holding only wide gaps runs bigger
  (more efficient) steps.
- Weighted fair sharing with a starvation guard: within an equal-priority
  group, chunk ownership rotates across iterations and a per-tenant deficit
  counter (``BgTenant.weight``-scaled fair share minus actual launches)
  promotes starved tenants to the front of the next assignment, so no
  tenant's measured throughput stays at zero while peers run.  Reported per
  tenant via ``TenantResult.deficit``.
- ``ExecutableCache`` is a bounded LRU (``max_entries``) with explicit
  eviction of stale device subsets (``evict_stale``) — repeated
  ``handle_failure``/``handle_join`` re-plan cycles no longer hold dead
  jitted state alive.
- Per-stage calibration: ``InterferenceModel.gap_inflation`` generalizes to
  a per-gap-op vector (``gap_inflation_stages``) fitted by
  ``Collocator.calibrate`` from per-stage measurements
  (``CollocationResult.stage_slowdowns``), applied by both
  ``MultiplexSim.run`` and ``Collocator.predict``.
- Admission control: ``Collocator.admit`` sweeps candidate tenant counts
  through the calibrated ``predict()`` and admits the
  argmax-cluster-throughput roster *before compiling anything*, rejecting
  tenants that would push fg slowdown past the paper's 1.33x QoS bound
  (``ClusterCoordinator.collocate`` runs this by default).
"""
from __future__ import annotations

import math
import time as _time
from collections import OrderedDict, defaultdict
from dataclasses import dataclass, field, replace as _dc_replace
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.plan import BurstPlan, GapWindow, pack_ranges


# ---------------------------------------------------------------------------
# QoS monitoring (slowdown feedback loop — paper §5 "monitors the runtimes of
# each operation, and pauses collocation when a foreground job runs an
# operator that has been observed to suffer large slowdowns")
# ---------------------------------------------------------------------------


@dataclass
class QoSMonitor:
    slowdown_threshold: float = 1.3
    ema_alpha: float = 0.3
    baseline: Dict[str, float] = field(default_factory=dict)
    ema: Dict[str, float] = field(default_factory=dict)
    banned: set = field(default_factory=set)

    def record_baseline(self, op: str, t: float) -> None:
        self.baseline[op] = t

    def record(self, op: str, t: float, collocated: bool) -> None:
        prev = self.ema.get(op, t)
        self.ema[op] = (1 - self.ema_alpha) * prev + self.ema_alpha * t
        if collocated and self.slowdown(op) > self.slowdown_threshold:
            self.banned.add(op)

    def slowdown(self, op: str) -> float:
        b = self.baseline.get(op)
        if not b:
            return 1.0
        return self.ema.get(op, b) / b

    def collocation_allowed(self, op: str) -> bool:
        return op not in self.banned


# ---------------------------------------------------------------------------
# Interference model (paper Fig 11 / Fig 12 calibration)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InterferenceModel:
    """Foreground inflation when a background task shares the device.

    Calibrated to the paper's measurements on A100:
      naive same-device collocation        -> ~1.9× fg stage time
      + stream priorities alone            -> ~1.8× (barely helps; Fig 11)
      + launch pacing                      -> ~1.25×
      sensitive ops (all-reduce/sync)      -> ≥2.1× unless banned
      non-preemptive overrun               -> bg tail blocks the next fg stage

    ``gap_inflation`` is the submesh-mode (TPU) counterpart: the measured fg
    stage-time multiplier while disjoint-device tenants collocate in the
    stage's gap (host-side dispatch contention, shared interconnect).  It is
    1.0 by default (ideal disjointness) and is *fitted from measurement* by
    ``Collocator.calibrate`` so simulator predictions track the hardware.

    ``gap_inflation_stages`` refines the scalar into a per-gap-op vector:
    ``(stage_index, multiplier)`` pairs fitted from per-stage measurements
    (``CollocationResult.stage_slowdowns``).  ``gap_inflation_for(si)``
    returns the stage's fitted multiplier, falling back to the scalar for
    stages without a per-stage fit.  Every fitted multiplier is clamped to
    >= 1.0 — a noisy host can measure a sub-1.0 slowdown, but interference
    never *speeds up* the foreground.

    ``density_slope`` makes the model *tenant-density aware*: the fitted
    multipliers describe interference at one collocated tenant per gap
    stage (density 1), and a stage shared by ``d`` tenants inflates its
    excess linearly — ``gap_inflation_at(si, d)`` returns
    ``1 + (base-1) * (1 + density_slope*(d-1))``.  Host-side dispatch
    contention and interconnect pressure scale with how many tenants pile
    into a gap, so the admission sweep's predicted slowdown becomes
    monotone in roster size and ``Collocator.admit`` can reject the
    *marginal* tenant (0 < k < n) instead of all-or-nothing.  The default
    0.0 is density-blind (every prior behavior unchanged); ``calibrate``
    fits it from measurements taken at different densities.
    """

    naive_inflation: float = 1.9
    priority_inflation: float = 1.8
    paced_inflation: float = 1.25
    sensitive_inflation: float = 2.1
    sensitive_kinds: tuple = ("sync", "allreduce")
    gap_inflation: float = 1.0  # submesh mode; calibrated from measurement
    gap_inflation_stages: Tuple[Tuple[int, float], ...] = ()  # per-stage fit
    density_slope: float = 0.0  # per-extra-tenant excess growth; fitted

    def gap_inflation_for(self, stage_index: int) -> float:
        """Submesh-mode fg multiplier for one gap stage at density 1
        (per-stage fit when available, else the scalar ``gap_inflation``)."""
        for si, v in self.gap_inflation_stages:
            if si == stage_index:
                return v
        return self.gap_inflation

    def density_factor(self, density: float) -> float:
        """Excess-inflation multiplier for ``density`` collocated tenants
        sharing one gap stage (1.0 at density <= 1 or with no fitted slope)."""
        if density <= 1.0 or self.density_slope <= 0.0:
            return 1.0
        return 1.0 + self.density_slope * (density - 1.0)

    def gap_inflation_at(self, stage_index: int, density: float = 1.0) -> float:
        """Submesh-mode fg multiplier for one gap stage shared by
        ``density`` tenants."""
        base = self.gap_inflation_for(stage_index)
        return 1.0 + (base - 1.0) * self.density_factor(density)

    def fg_multiplier(self, *, priorities: bool, pacing: bool, sensitive: bool,
                      banned: bool) -> float:
        if banned:
            return 1.0
        if sensitive:
            return self.sensitive_inflation
        if priorities and pacing:
            return self.paced_inflation
        if priorities:
            return self.priority_inflation
        return self.naive_inflation


@dataclass(frozen=True)
class MultiplexConfig:
    use_priorities: bool = True
    use_pacing: bool = True  # launch pacing (bounded outstanding work)
    use_feedback: bool = True  # slowdown feedback loop (ban sensitive ops)
    use_granularity: bool = True  # reduce bg step size (non-preemption guard)
    collocate_same_device: bool = False  # GPU mode (paper) vs TPU submesh mode
    max_inflight: int = 2
    bg_step_time: float = 2.0e-3  # isolated bg step latency at full batch
    bg_min_step_time: float = 0.25e-3  # granularity floor (smaller batch)
    sync_fraction: float = 0.25  # fraction of each fg stage that is grad sync


@dataclass
class SimResult:
    fg_iter_time: float
    fg_iter_time_isolated: float
    bg_steps_per_iter: float
    fg_slowdown: float
    bg_throughput_frac: float  # vs one device running bg flat-out
    cluster_throughput: float  # fg + bg useful device-seconds per second

    def row(self) -> str:
        return (
            f"fg_slowdown={self.fg_slowdown:.3f} bg_steps/iter={self.bg_steps_per_iter:.1f} "
            f"cluster_util={self.cluster_throughput:.3f}"
        )


class MultiplexSim:
    """Discrete-event multiplexing of one fg BurstPlan + one bg job."""

    def __init__(
        self,
        plan: BurstPlan,
        cfg: MultiplexConfig,
        interference: InterferenceModel = InterferenceModel(),
        monitor: Optional[QoSMonitor] = None,
    ):
        self.plan = plan
        self.cfg = cfg
        self.imodel = interference
        self.monitor = monitor or QoSMonitor()

    def bg_step_time(self) -> float:
        """Granularity reduction: size bg steps to the smallest gap."""
        t = self.cfg.bg_step_time
        if not self.cfg.use_granularity:
            return t
        gaps = self.plan.gaps()
        if gaps:
            smallest = min(g.duration for g in gaps)
            t = min(t, max(self.cfg.bg_min_step_time, smallest / 2.0))
        return max(t, self.cfg.bg_min_step_time)

    def run(self, iterations: int = 50) -> SimResult:
        cfg, plan = self.cfg, self.plan
        stages = plan.stages()
        G = plan.num_gpus
        bg_t = self.bg_step_time()
        bg_eff = min(1.0, bg_t / cfg.bg_step_time) ** 0.25  # small batches less efficient
        fg_iso = plan.total_time
        unpaced_queue = 2  # unbounded-queue depth proxy (paper: loss of QoS)

        fg_time_total = 0.0
        bg_busy_total = 0.0
        bg_steps_total = 0.0
        for _ in range(iterations):
            t = 0.0
            carry_overrun = 0.0
            prev_free = 0
            for si, st in enumerate(stages):
                free = G - st.gpus
                op = f"stage{si}"
                window = st.duration
                sf = cfg.sync_fraction if st.gpus > 1 else 0.0
                stage_time = window

                if cfg.collocate_same_device:
                    # GPU mode (paper's setting): bg shares the fg devices.
                    # Slowdown feedback bans collocation on the sensitive
                    # (gradient-sync) portion once observed.
                    m_norm = self.imodel.fg_multiplier(
                        priorities=cfg.use_priorities, pacing=cfg.use_pacing,
                        sensitive=False, banned=False,
                    )
                    if cfg.use_feedback:
                        m_sens = 1.0  # banned after first observation
                    else:
                        m_sens = self.imodel.fg_multiplier(
                            priorities=cfg.use_priorities, pacing=cfg.use_pacing,
                            sensitive=True, banned=False,
                        )
                    stage_time = window * (1.0 - sf) * m_norm + window * sf * m_sens
                    # half of the inflation is useful bg cycles, half is waste
                    stolen = (stage_time - window) * st.gpus * 0.5
                    bg_busy_total += stolen * bg_eff
                    bg_steps_total += stolen / bg_t

                if free > 0:
                    # gap: bg runs on the disjoint idle devices.  In submesh
                    # mode the calibrated gap_inflation models the measured
                    # residual interference (host dispatch, interconnect) —
                    # but only where collocation actually happens: a gap the
                    # feedback loop banned admits no bg and stays clean.
                    if (not cfg.collocate_same_device
                            and (not cfg.use_feedback
                                 or self.monitor.collocation_allowed(op))):
                        stage_time = window * self.imodel.gap_inflation_for(si)
                    n_per_dev = math.floor(window / bg_t)
                    if cfg.use_pacing:
                        # paced: bounded outstanding work; residual overrun is
                        # one half-step of estimation error
                        overrun = 0.5 * bg_t
                    else:
                        n_per_dev += unpaced_queue
                        overrun = unpaced_queue * bg_t
                    bg_steps_total += n_per_dev * free
                    bg_busy_total += n_per_dev * bg_t * free * bg_eff
                    carry_overrun = max(carry_overrun, overrun)
                    prev_free = free
                else:
                    # non-preemptive bg tail on previously-free devices delays
                    # this stage iff it now needs those devices
                    if carry_overrun > 0.0 and st.gpus > G - prev_free:
                        stage_time += carry_overrun
                    carry_overrun = 0.0

                self.monitor.record_baseline(op, window)
                self.monitor.record(op, stage_time, collocated=True)
                t += stage_time
            t += carry_overrun  # tail overrun beyond the iteration boundary
            fg_time_total += t

        fg_iter = fg_time_total / iterations
        fg_busy = sum(s.duration * s.gpus for s in stages)
        # bg cannot use more device-time than exists beyond fg's actual usage
        budget = fg_iter * G - fg_busy
        bg_busy = min(bg_busy_total / iterations, max(budget, 0.0))
        bg_per_iter = bg_steps_total / iterations * (
            bg_busy / max(bg_busy_total / iterations, 1e-30)
        )
        cluster = (fg_busy + bg_busy) / (fg_iter * G)
        return SimResult(
            fg_iter_time=fg_iter,
            fg_iter_time_isolated=fg_iso,
            bg_steps_per_iter=bg_per_iter,
            fg_slowdown=fg_iter / fg_iso,
            bg_throughput_frac=bg_busy / (fg_iter * G),
            cluster_throughput=cluster,
        )


# ---------------------------------------------------------------------------
# Executable collocation (TPU submesh mode)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BgTenant:
    """One background job competing for gap devices.

    ``priority`` orders tenants (higher first): the highest-priority tenant
    gets the largest chunk of each gap's free device ranges and dispatches
    first.  ``step_fn_factory(mesh)`` returns a zero-arg callable dispatching
    one training step on the tenant's gap submesh (the ``make_bg_step_fn``
    contract of ``run_executable``).  ``signature`` identifies the compiled
    executable for cache reuse across re-plans; it defaults to the factory's
    ``signature`` attribute (set by ``train.step.bg_step_factory``) and,
    for untagged factories, to the factory object itself — never to the job
    name alone, so two *different* factories submitted under one name can't
    silently share a compiled executable.

    ``weight`` scales the tenant's fair share among equal-priority peers
    (deficit-rotation fair sharing); ``quantum`` is the tenant's own device
    chunk alignment (its submesh model width) — when set, each of the
    tenant's gap chunks is a multiple of it instead of the scheduler's
    global ``bg_model``, and the tenant's bg step-time quantum is sized to
    its own chunks rather than the global gap minimum.
    """

    job: str
    priority: int = 0
    step_fn_factory: Optional[Callable] = None
    signature: Optional[object] = None  # any hashable executable identity
    weight: float = 1.0                 # fair share among equal priorities
    quantum: Optional[int] = None       # per-tenant chunk alignment

    @property
    def cache_signature(self):
        if self.signature:
            return self.signature
        sig = getattr(self.step_fn_factory, "signature", None)
        if sig:
            return sig
        return self.step_fn_factory if self.step_fn_factory is not None \
            else self.job


@dataclass
class ExecutableCache:
    """Compiled bg-step reuse across re-plans — a bounded LRU.

    Keyed on (tenant signature, gap submesh device ids, submesh shape): a
    jitted step closes over device-committed state, so identity of the
    *device subset* — not just its shape — is what makes reuse sound.  After
    a ``handle_failure``/``handle_join`` re-plan whose gap ranges are
    unchanged, the same key recurs and the jitted step (with its training
    state) is reused instead of re-jitted — re-compilation is the dominant
    cost of burst re-scaling.

    Two bounds keep the cache from holding dead jitted state alive across
    repeated re-plans:

    - ``max_entries`` caps the entry count; inserting beyond it evicts the
      least-recently-used entry (lookups refresh recency).
    - ``evict_stale(live_device_ids)`` drops every entry whose submesh uses
      a device outside the live set — after a device failure the jitted
      steps (and their device-committed training state) on that subset are
      dead and must be rebuilt even if the same gap shape later returns.
    """

    max_entries: int = 64
    entries: "OrderedDict[tuple, Callable]" = field(default_factory=OrderedDict)
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @staticmethod
    def key(signature: str, mesh) -> tuple:
        return (
            signature,
            tuple(d.id for d in mesh.devices.flat),
            tuple(mesh.devices.shape),
        )

    def __len__(self) -> int:
        return len(self.entries)

    def get_or_build(self, key: tuple, build: Callable[[], Callable]) -> Callable:
        fn = self.entries.get(key)
        if fn is not None:
            self.hits += 1
            self.entries.move_to_end(key)
            return fn
        self.misses += 1
        fn = self.entries[key] = build()
        while len(self.entries) > self.max_entries:
            self.entries.popitem(last=False)  # LRU out
            self.evictions += 1
        return fn

    def evict_stale(self, live_device_ids: Iterable[int]) -> int:
        """Drop entries whose submesh touches a device outside ``live``
        (explicit post-re-plan eviction of stale device subsets).  Returns
        the number of entries evicted."""
        live = set(live_device_ids)
        stale = [k for k in self.entries if not set(k[1]) <= live]
        for k in stale:
            del self.entries[k]
        self.evictions += len(stale)
        return len(stale)


@dataclass(frozen=True)
class TenantResult:
    """Per-tenant slice of a CollocationResult.

    ``weight``/``deficit`` report the fair-sharing state: ``deficit`` is the
    tenant's accumulated weighted fair share minus its actual launches — a
    persistently positive deficit means the starvation guard is owed steps
    and will promote this tenant in upcoming chunk assignments."""

    job: str
    priority: int
    bg_steps_per_iter: float
    bg_throughput: float  # steps per second of collocated fg wall time
    gap_stages: Tuple[int, ...] = ()  # stages where this tenant held devices
    devices: int = 0                  # largest submesh the tenant held
    weight: float = 1.0
    deficit: float = 0.0              # fair-share owed at end of run
    quantum: int = 1                  # chunk alignment the tenant packed with
    step_time: float = 0.0            # the tenant's bg step-time quantum

    def row(self) -> str:
        return (f"{self.job}(p{self.priority}): "
                f"{self.bg_steps_per_iter:.1f} steps/iter on "
                f"<= {self.devices} devices")


@dataclass
class CollocationResult:
    """Measured (not simulated) outcome of executable gap collocation.

    ``fg_slowdown`` is the steady state after the feedback loop has banned
    harmful origins — the bound the QoS mechanism promises.  ``iter_details``
    exposes every collocated iteration as (wall_time, bg_steps_launched) so
    the learning-phase tradeoff (iterations that collocated heavily may have
    run slower) stays visible rather than hidden by the min.
    """

    fg_iter_time: float
    fg_iter_time_isolated: float
    fg_slowdown: float
    bg_steps_per_iter: float
    bg_throughput: float  # bg steps per second of collocated fg wall time
    iterations: int
    banned_ops: Tuple[str, ...] = ()
    iter_details: Tuple[Tuple[float, int], ...] = ()
    tenants: Tuple[TenantResult, ...] = ()  # per-tenant accounting
    cache_hits: int = 0    # executable-cache hits while building this run
    cache_misses: int = 0
    # measured per-gap-stage fg slowdown (stage_index, min_col/baseline) for
    # collocated stages — the raw material of per-stage calibration
    stage_slowdowns: Tuple[Tuple[int, float], ...] = ()
    # (fg + bg useful device-seconds) / (iteration wall x cluster size), in
    # plan-time units — the admission controller's objective
    cluster_throughput: float = 0.0
    # tenants the admission controller refused to compile (job names)
    rejected_tenants: Tuple[str, ...] = ()
    # Jain's index over per-tenant weighted service time, recorded at
    # construction (mixed-quanta rosters included: service-time
    # normalization makes heterogeneous step sizes comparable)
    jain_index: float = 1.0

    def jain_fairness(self) -> float:
        """Jain's fairness index over per-tenant weighted *service time*
        (1.0 = perfectly fair; 1/n = one tenant has everything).  Service is
        steps x the tenant's own step-time quantum — tenants deliberately
        run different step sizes, so raw step counts are incomparable
        across quanta (same rationale as the deficit accounting in
        ``note_launched``).  Tenants with zero weight are excluded; rows
        without a recorded step time (hand-built results) count steps
        directly; no tenants -> 1.0."""
        xs = [
            t.bg_steps_per_iter
            * (t.step_time if t.step_time > 0 else 1.0) / t.weight
            for t in self.tenants if t.weight > 0
        ]
        if not xs:
            return 1.0
        denom = len(xs) * sum(x * x for x in xs)
        if denom <= 0.0:
            return 1.0
        return sum(xs) ** 2 / denom

    def row(self) -> str:
        per_tenant = ""
        if self.tenants:
            per_tenant = " " + " ".join(t.row() for t in self.tenants)
        return (
            f"fg_slowdown={self.fg_slowdown:.3f} "
            f"bg_steps/iter={self.bg_steps_per_iter:.1f} "
            f"bg_steps/s={self.bg_throughput:.1f} "
            f"banned={list(self.banned_ops) or 'none'}" + per_tenant
        )


@dataclass(frozen=True)
class AdmissionDecision:
    """Outcome of the predict-before-compile admission sweep.

    ``curve`` holds one (k, predicted fg slowdown, predicted cluster
    throughput) triple per candidate tenant count 0..n; ``n_admitted`` is
    the argmax-cluster-throughput k among those whose predicted fg slowdown
    stays within ``bound``.  ``rejected`` tenants are never compiled.
    """

    bound: float
    n_admitted: int
    admitted: Tuple[BgTenant, ...]
    rejected: Tuple[BgTenant, ...]
    curve: Tuple[Tuple[int, float, float], ...]

    def row(self) -> str:
        pts = " ".join(f"k={k}:{s:.3f}x/{c:.3f}" for k, s, c in self.curve)
        rej = ",".join(t.job for t in self.rejected) or "none"
        return (f"admitted {self.n_admitted}/{self.n_admitted + len(self.rejected)} "
                f"(bound {self.bound:.2f}x, rejected: {rej}) curve: {pts}")


@dataclass
class Collocator:
    """Dispatches background steps into plan gaps with pacing + feedback.

    ``run_executable`` is the real path: it builds disjoint fg/bg submeshes
    from the plan (``submeshes()``), compiles the caller's stage/step
    factories onto them, and interleaves paced background dispatch with the
    foreground stages, measuring slowdown via the QoSMonitor.
    ``run_iteration`` is the lighter legacy harness: the caller supplies
    already-jitted callables and only the dispatch loop runs here.
    ``devices`` pins an explicit device subset (default: process devices).

    ``tenants`` is a prioritized list of background jobs (``BgTenant``);
    each gap's free device ranges are packed among them largest-chunk-to-
    highest-priority (``schedule_tenants``).  ``cache`` (``ExecutableCache``)
    memoizes compiled bg steps across collocators — pass the coordinator's
    cache so re-plans with unchanged gap shapes reuse jitted steps.
    ``interference`` seeds the analytic model used by ``predict()``;
    ``calibrate()`` refits it from measured results.
    """

    plan: BurstPlan
    cfg: MultiplexConfig
    monitor: QoSMonitor = field(default_factory=QoSMonitor)
    devices: Optional[Sequence] = None
    tenants: Sequence[BgTenant] = ()
    cache: Optional[ExecutableCache] = None
    interference: InterferenceModel = field(default_factory=InterferenceModel)

    def __post_init__(self):
        # priority order is fixed at construction: slot 0 = highest priority
        # (stable for equal priorities, preserving submission order)
        self.tenants = tuple(
            sorted(self.tenants, key=lambda t: -t.priority)
        )
        # hoisted: one sim + one bg step-time quantum for the collocator's
        # lifetime (previously rebuilt inside every schedule() call)
        self._sim = MultiplexSim(self.plan, self.cfg, self.interference,
                                 monitor=self.monitor)
        self.bg_step_quantum = self._sim.bg_step_time()
        # fair-sharing state: per-roster-slot deficit counters (in service
        # seconds) and the rotation round (advanced by note_launched after
        # each collocated iteration) — see _fair_assignment
        self._deficits: Dict[int, float] = defaultdict(float)
        self._round = 0
        # last per-slot step-time quanta (set by _schedule_detail): converts
        # launched step counts into service time for the deficit accounting
        self._last_step_t: List[float] = []

    def set_tenants(self, tenants: Sequence[BgTenant]) -> None:
        """Replace the tenant roster in place.

        Request-level admission (serving) re-sweeps ``admit()`` every
        scheduler tick with the *current* candidate requests as tenants —
        rebuilding the Collocator each tick would discard the calibrated
        interference model, the QoS monitor's baselines/bans, and the
        hoisted sim/step quantum (plan and cfg are unchanged, so those all
        stay valid).  Per-slot deficits are kept positionally: a deficit
        describes the service history of the i-th chunk position, which is
        what the fair-share rotation needs even as roster *identity*
        churns request-to-request.
        """
        self.tenants = tuple(sorted(tenants, key=lambda t: -t.priority))

    def schedule(self) -> List[Tuple[int, int]]:
        """(stage_index, n_bg_steps) pairs for one iteration (single-tenant
        view; see ``schedule_tenants`` for the multi-tenant packing)."""
        bg_t = self.bg_step_quantum
        out = []
        for gap in self.plan.gaps():
            op = f"stage{gap.stage_index}"
            if self.cfg.use_feedback and not self.monitor.collocation_allowed(op):
                continue
            n = math.floor(gap.duration / bg_t)
            if self.cfg.use_pacing:
                n = min(n, self.cfg.max_inflight)
            if n > 0:
                out.append((gap.stage_index, n))
        return out

    def reset_measured_qos(self) -> None:
        """Drop this plan's per-stage QoS state (baselines/EMAs/bans) from
        the monitor.  The monitor may hold *simulated* times (a shared
        coordinator monitor fed by MultiplexSim) — a different time domain
        than wall-clock measurement — so ``run_executable`` re-derives QoS
        state from measurement, and the admission sweep must predict
        against the same reset state or it would admit a roster for a
        schedule (banned gaps excluded) the measured run then abandons."""
        for si in range(len(self.plan.stages())):
            op = f"stage{si}"
            self.monitor.baseline.pop(op, None)
            self.monitor.ema.pop(op, None)
            self.monitor.banned.discard(op)

    # -- fair-share scheduling ---------------------------------------------

    def _roster_for(self, n: int) -> List[BgTenant]:
        """The first ``n`` tenants, padded with placeholder slots for
        admission-control what-ifs beyond the current roster."""
        roster = list(self.tenants[:n])
        while len(roster) < n:
            roster.append(BgTenant(f"bg{len(roster)}"))
        return roster

    @staticmethod
    def _roster_quanta(roster: Sequence[BgTenant],
                       bg_model: int) -> List[int]:
        """Effective per-slot chunk quanta: each tenant's own ``quantum``,
        falling back to the scheduler-wide ``bg_model``.  The single source
        for scheduling, submesh carving and executable prebuild — they must
        agree or chunks and compiled meshes diverge."""
        return [t.quantum or bg_model for t in roster]

    @staticmethod
    def _priority_groups(roster: Sequence[BgTenant]) -> List[Tuple[int, int]]:
        """[start, end) slot spans of equal-priority runs (roster is
        priority-sorted, so equal priorities are contiguous)."""
        groups: List[Tuple[int, int]] = []
        i = 0
        while i < len(roster):
            j = i
            while j < len(roster) and roster[j].priority == roster[i].priority:
                j += 1
            groups.append((i, j))
            i = j
        return groups

    def _fair_assignment(self, roster: Sequence[BgTenant], iteration: int,
                         quanta: Sequence[int]) -> List[int]:
        """chunk position -> roster slot permutation for one iteration.

        Chunk positions are priority-ordered (position 0 = largest chunk).
        Within each equal-priority group the owning slot is chosen by
        (largest deficit first, then round-robin rotation by ``iteration``),
        so a tenant the packing starved accumulates deficit and is promoted
        to the front — the starvation guard: over k iterations every member
        of a k-tenant group owns the group's best chunk at least once.
        Rotation spans *mixed quanta* too: ``_schedule_detail`` carves each
        chunk position with the assigned tenant's own quantum (not the
        canonical owner's), so any group member's submesh tiles its chunk by
        construction — heterogeneous rosters no longer silently degrade to
        fixed priority-order ownership.  ``quanta`` is kept for signature
        stability (the carving, not the rotation, consumes it now).
        Singleton groups keep the identity assignment.
        """
        del quanta  # rotation no longer restricted to equal-quantum peers
        perm = list(range(len(roster)))
        for i, j in self._priority_groups(roster):
            k = j - i
            if k <= 1:
                continue
            order = sorted(
                range(i, j),
                key=lambda s: (-self._deficits[s], (s - i - iteration) % k),
            )
            for pos, slot in zip(range(i, j), order):
                perm[pos] = slot
        return perm

    def _slot_step_times(self, n: int, gap_chunks: Dict[int, list],
                         perm: Optional[Sequence[int]] = None) -> List[float]:
        """Per-slot bg step-time quantum: each tenant's step is sized to the
        smallest gap *it* occupies in the canonical layout, not the global
        gap minimum — a tenant holding only wide gaps runs bigger steps.
        A step's size is a property of the tenant's compiled executable, so
        it is sized once from the canonical (identity) layout — a rotation
        that moves the tenant into a narrower gap must fall back, not
        shrink the step mid-run.  ``perm`` overrides the position -> slot
        mapping for callers that want layout-specific sizing."""
        cfg = self.cfg
        if not cfg.use_granularity:
            return [cfg.bg_step_time] * n
        stages = self.plan.stages()
        out = [self.bg_step_quantum] * n
        slot_durs: Dict[int, list] = defaultdict(list)
        for si, chunks in gap_chunks.items():
            for pos, c in enumerate(chunks):
                if c is None:
                    continue
                slot = perm[pos] if perm is not None else pos
                slot_durs[slot].append(stages[si].duration)
        for slot in range(n):
            durs = slot_durs.get(slot)
            if durs:
                t = min(cfg.bg_step_time,
                        max(cfg.bg_min_step_time, min(durs) / 2.0))
                out[slot] = max(t, cfg.bg_min_step_time)
        return out

    def _schedule_detail(
        self, n_tenants: Optional[int] = None, bg_model: int = 1,
        iteration: Optional[int] = None,
        roster: Optional[Sequence[BgTenant]] = None,
    ) -> List[Tuple[int, int, int, Tuple[int, int], int, float]]:
        """Full per-iteration packing: (stage_index, tenant_slot, chunk_pos,
        (start, end), n_bg_steps, bg_step_time) rows.

        Each unbanned gap's per-stage free ranges are carved into per-tenant
        chunks (``pack_ranges`` per-tenant mode).  ``_fair_assignment``
        first maps chunk positions to owning slots (deficit promotion +
        round-robin rotation within each equal-priority group, mixed quanta
        included), and the carving aligns each position to the *assigned*
        tenant's quantum — so every owner's submesh tiles its chunk by
        construction, whatever the rotation round.  When any tenant carries
        a significant fair-share deficit, the per-position deficits feed
        ``pack_ranges``'s share-sizing (``shares``): lagging tenants claim
        *wider* chunks instead of rotating into the same equal-split chunk
        forever; a gap falls back to the equal-halving layout if share
        sizing would drop a slot the equal split served.  Steps pace at
        ``min(floor(gap / slot_step_time), max_inflight)`` per tenant.
        """
        n = n_tenants if n_tenants is not None else max(1, len(self.tenants))
        if n <= 0:
            return []
        roster = list(roster) if roster is not None else self._roster_for(n)
        quanta = self._roster_quanta(roster, bg_model)
        it = self._round if iteration is None else iteration
        perm = self._fair_assignment(roster, it, quanta)
        # carve at the assigned owner's quantum; size by its deficit share
        pos_quanta = [quanta[perm[pos]] for pos in range(n)]
        unit = max(self.bg_step_quantum, 1e-12)
        deficits = [self._deficits[s] for s in range(n)]
        pos_shares = None
        if any(d > 0.5 * unit for d in deficits):
            pos_shares = [1.0 + min(3.0, deficits[perm[pos]] / unit)
                          for pos in range(n)]
        gap_chunks: Dict[int, list] = {}
        for gap in self.plan.gaps():
            op = f"stage{gap.stage_index}"
            if self.cfg.use_feedback and not self.monitor.collocation_allowed(op):
                continue
            free = self.plan.free_device_ranges(gap.stage_index)
            chunks = pack_ranges(free, n, quantum=pos_quanta)
            if pos_shares is not None:
                sized = pack_ranges(free, n, quantum=pos_quanta,
                                    shares=pos_shares)
                # share sizing must never starve a slot the equal split
                # served (a boosted claim can make a later slot
                # unsatisfiable in tight layouts)
                if ({i for i, c in enumerate(chunks) if c is not None}
                        <= {i for i, c in enumerate(sized) if c is not None}):
                    chunks = sized
            if any(c is not None for c in chunks):
                gap_chunks[gap.stage_index] = chunks
        step_t = self._slot_step_times(n, gap_chunks)
        self._last_step_t = step_t
        stages = self.plan.stages()
        rows: List[Tuple[int, int, int, Tuple[int, int], int, float]] = []
        for si in sorted(gap_chunks):
            chunks = gap_chunks[si]
            dur = stages[si].duration
            assign = {pos: perm[pos] for pos, c in enumerate(chunks)
                      if c is not None}
            for pos in sorted(assign):
                slot = assign[pos]
                cs, ce = chunks[pos]
                nsteps = math.floor(dur / step_t[slot])
                if (nsteps <= 0 and slot != pos
                        and (ce - cs) % quanta[pos] == 0):
                    # a rotated-in tenant whose step is too big for this gap
                    # would leave the chunk idle — hand it back to the
                    # canonical owner (when its quantum tiles the chunk)
                    # rather than waste it
                    slot = pos
                    nsteps = math.floor(dur / step_t[slot])
                if self.cfg.use_pacing:
                    nsteps = min(nsteps, self.cfg.max_inflight)
                if nsteps > 0:
                    rows.append((si, slot, pos, (cs, ce), nsteps,
                                 step_t[slot]))
        return rows

    def schedule_tenants(
        self, n_tenants: Optional[int] = None, bg_model: int = 1,
        iteration: Optional[int] = None,
    ) -> List[Tuple[int, int, int]]:
        """(stage_index, tenant_slot, n_bg_steps) triples for one iteration.

        Mirrors the executable packing exactly — see ``_schedule_detail``
        for the per-tenant quantum / fair-rotation semantics.  ``iteration``
        selects the rotation round (default: the collocator's internal
        round, advanced by ``note_launched``)."""
        return [(si, slot, n) for si, slot, _pos, _c, n, _t in
                self._schedule_detail(n_tenants, bg_model, iteration)]

    def note_launched(self, launched_by: Sequence[int],
                      roster: Optional[Sequence[BgTenant]] = None) -> None:
        """Record one collocated iteration's per-slot launches: updates the
        fair-share deficit counters (weighted fair share minus actual, floor
        0) and advances the rotation round.  Called by ``run_executable``
        after every collocated iteration; scheduling-only callers drive it
        directly to exercise the starvation guard.

        Accounting is in *service time* (launched steps x the slot's
        step-time quantum), not raw step counts: tenants deliberately run
        different step sizes (per-tenant quanta), so counting steps would
        let a big-step tenant's deficit grow without bound — it can never
        match a small-step peer's count — freezing the rotation with that
        tenant pinned to the best chunk forever."""
        roster = list(roster) if roster is not None else list(self.tenants)
        step_t = self._last_step_t

        def service(s: int) -> float:
            got = launched_by[s] if s < len(launched_by) else 0
            t = step_t[s] if s < len(step_t) else self.bg_step_quantum
            return got * t

        for i, j in self._priority_groups(roster):
            if j - i <= 1:
                continue
            total = sum(service(s) for s in range(i, j))
            wsum = sum(max(roster[s].weight, 0.0) for s in range(i, j))
            if wsum <= 0.0:
                continue
            for s in range(i, j):
                fair = total * max(roster[s].weight, 0.0) / wsum
                self._deficits[s] = max(
                    0.0, self._deficits[s] + fair - service(s)
                )
        self._round += 1

    # -- executable submesh path -------------------------------------------

    def submeshes(self, *, fg_model: int = 1, bg_model: int = 1,
                  tenants: Optional[int] = None,
                  tenant_quanta: Optional[Sequence[int]] = None):
        """Disjoint fg/bg submeshes for this plan (PlanSubmeshes).

        ``tenants`` (default: this collocator's tenant count) splits each
        gap's free ranges into that many per-tenant submeshes.
        ``tenant_quanta`` (default: the roster's per-tenant quanta, when any
        tenant sets one) switches to the slot-aware per-tenant carving.
        What-if counts beyond the roster pad with placeholder slots exactly
        like the scheduler (quantum = ``bg_model``), so the carved chunks
        always match what ``schedule_tenants(n)`` packs."""
        from repro.launch.mesh import split_mesh_for_plan

        n = tenants if tenants is not None else max(1, len(self.tenants))
        if tenant_quanta is None and any(t.quantum for t in self.tenants[:n]):
            tenant_quanta = self._roster_quanta(self._roster_for(n), bg_model)
        return split_mesh_for_plan(self.plan, devices=self.devices,
                                   fg_model=fg_model, bg_model=bg_model,
                                   tenants=n, tenant_quanta=tenant_quanta)

    # -- calibration + analytic prediction ---------------------------------

    def _current_densities(self, bg_model: int = 1) -> Dict[int, float]:
        """Per-stage tenant density of the current schedule (distinct
        tenant slots packed into each collocated gap stage)."""
        return _stage_densities(self._schedule_detail(None, bg_model))

    def calibrate(self, results: Sequence[CollocationResult]) -> InterferenceModel:
        """Fit the interference model's submesh-mode multipliers from
        measured ``CollocationResult``s.

        Scalar fit (always): the measured foreground slowdown is attributed
        to the collocated gap stages of the current tenant schedule — with
        collocated gap time ``W_gap`` out of total iteration time ``W``, a
        measured (geometric mean) slowdown ``s`` inverts to
        ``gap_inflation = 1 + (s-1)*W/W_gap`` — exactly the multiplier that
        makes ``predict()`` reproduce ``s``.

        Per-stage fit (when results carry ``stage_slowdowns``): each
        measured gap stage's multiplier is the geometric mean of its
        per-stage slowdowns, then the vector's excess over 1.0 is rescaled
        so the duration-weighted aggregate still reproduces ``s`` exactly —
        per-stage *shape* from the stage measurements, the closed-form
        aggregate inversion preserved.  Collocated stages without a
        per-stage measurement keep the scalar multiplier, and the vector is
        rescaled to the *residual* excess only, so partial stage coverage
        never double-counts the measured slowdown.

        Density fit (when results span *different* tenant densities): the
        measured excess slowdowns ``(s_r - 1)`` are regressed against each
        result's mean collocated density ``d_r`` under the linear model
        ``s - 1 = c * (1 + slope*(d-1))`` — an ordinary least-squares line
        ``y = b0 + b1*x`` over ``(d_r - 1, s_r - 1)`` gives
        ``density_slope = b1/b0``, clamped to [0, 10] and kept only when
        both coefficients are positive (interference grows with density or
        the fit is noise).  Results at a single density keep the prior
        slope — one operating point cannot identify it.  The scalar and
        per-stage inversions below then divide out the *current* schedule's
        per-stage density, so the stored multipliers are density-1 bases
        and ``predict()``'s ``gap_inflation_at`` reproduces ``s`` exactly
        at the calibration density.

        Every fitted multiplier (scalar and per-stage) is clamped to >= 1.0:
        on a noisy host a measured slowdown below 1.0 would otherwise fit a
        sub-1.0 multiplier and make ``predict()``/``MultiplexSim`` forecast
        that interference *speeds up* the foreground.  Installs the fitted
        model on this collocator's sim and returns it.
        """
        measured = [r for r in results
                    if r.iterations > 0 and r.fg_slowdown > 0.0]
        meas = [max(float(r.fg_slowdown), 1.0) for r in measured]
        if not meas:
            return self.interference
        log_mean = sum(math.log(s) for s in meas) / len(meas)
        s = math.exp(log_mean)
        slope = _fit_density_slope(measured, self.interference.density_slope)
        stages = self.plan.stages()
        detail = self._schedule_detail()
        cur_density = _stage_densities(detail)
        col_stages = set(cur_density)

        def dfac(si: int) -> float:
            d = cur_density.get(si, 1.0)
            if d <= 1.0 or slope <= 0.0:
                return 1.0
            return 1.0 + slope * (d - 1.0)

        # density-weighted gap time: the inversion divides the measured
        # excess across collocated stages in proportion to how much each
        # stage's density amplifies its base multiplier, so the stored base
        # is density-1 and predict() at the calibration density round-trips
        gap_t = sum(stages[si].duration * dfac(si) for si in col_stages)
        total = self.plan.total_time
        if gap_t <= 0.0 or total <= 0.0:
            gi = 1.0
        else:
            gi = 1.0 + (s - 1.0) * total / gap_t
        # per-stage fit: geomean of measured per-stage slowdowns, clamped,
        # then rescaled so the aggregate inversion stays exact.  Ingestion
        # keeps only stages the CURRENT schedule collocates: indices from an
        # earlier, differently-shaped plan would attribute slowdowns to the
        # wrong stages, and a stage the feedback loop has since banned never
        # inflates in predict() — folding its measurement into the rescale
        # denominator would dilute alpha and under-reproduce ``s``
        per_stage: Dict[int, List[float]] = defaultdict(list)
        for r in results:
            if r.iterations > 0:
                for si, v in r.stage_slowdowns:
                    if si in col_stages:
                        per_stage[si].append(max(float(v), 1.0))
        stage_vec: Tuple[Tuple[int, float], ...] = ()
        gi = max(gi, 1.0)
        if per_stage:
            fitted = {
                si: math.exp(sum(math.log(v) for v in vals) / len(vals))
                for si, vals in per_stage.items()
            }
            excess = sum(stages[si].duration * (fitted[si] - 1.0)
                         for si in fitted)
            # collocated stages WITHOUT a per-stage measurement keep the
            # scalar multiplier at predict() time, so the fitted vector must
            # explain only the residual excess — otherwise the aggregate is
            # double-counted and admission over-rejects
            unfitted_excess = sum(
                stages[si].duration * (gi - 1.0) * dfac(si)
                for si in col_stages if si not in fitted
            )
            want = max(0.0, (s - 1.0) * total - unfitted_excess)
            if excess > 0.0 and want > 0.0:
                alpha = want / excess
                # the measured per-stage slowdowns are *effective* values at
                # the calibration density; store the density-1 base so
                # gap_inflation_at reproduces the effective value exactly
                stage_vec = tuple(sorted(
                    (si, max(1.0, 1.0 + (fitted[si] - 1.0) * alpha / dfac(si)))
                    for si in fitted
                ))
            # excess == 0 (stage noise hid all inflation) -> no per-stage
            # shape to keep; fall back to the scalar inversion alone
        model = _dc_replace(self.interference, gap_inflation=gi,
                            gap_inflation_stages=stage_vec,
                            density_slope=slope)
        self.interference = model
        self._sim.imodel = model
        return model

    def predict(self, n_tenants: Optional[int] = None,
                bg_model: int = 1) -> CollocationResult:
        """Analytic (device-free) prediction of ``run_executable`` under the
        current (possibly calibrated) interference model and monitor state.

        Replays the tenant schedule through the calibrated multipliers:
        every collocated gap stage inflates by its per-stage
        ``gap_inflation_at`` — the fitted per-stage base (vector where
        available, scalar elsewhere) scaled by the stage's *tenant density*
        (how many distinct tenants pack into that gap this iteration, via
        the fitted ``density_slope``) — every packed tenant contributes its
        paced step count, and ``cluster_throughput`` — the admission
        objective — is (fg busy + bg busy) device-seconds over the inflated
        iteration, with bg busy estimated from each tenant's own step-time
        quantum and chunk width.  ``n_tenants=0`` is the fg-only operating
        point.  ``iterations == 0`` marks the result as predicted, not
        measured.
        """
        n = n_tenants if n_tenants is not None else max(1, len(self.tenants))
        n = max(0, n)
        detail = self._schedule_detail(n, bg_model) if n > 0 else []
        stages = self.plan.stages()
        fg_iso = self.plan.total_time
        density = _stage_densities(detail)
        fg_col = fg_iso + sum(
            stages[si].duration
            * (self.interference.gap_inflation_at(si, d) - 1.0)
            for si, d in density.items()
        )
        per_slot: Dict[int, int] = defaultdict(int)
        slot_stages: Dict[int, List[int]] = defaultdict(list)
        slot_devices: Dict[int, int] = defaultdict(int)
        slot_step_t: Dict[int, float] = {}
        bg_busy = 0.0
        for si, slot, _pos, (cs, ce), nsteps, bg_t in detail:
            per_slot[slot] += nsteps
            slot_stages[slot].append(si)
            slot_devices[slot] = max(slot_devices[slot], ce - cs)
            slot_step_t[slot] = bg_t
            bg_busy += nsteps * bg_t * (ce - cs)
        total_steps = float(sum(per_slot.values()))
        fg_busy = sum(s.duration * s.gpus for s in stages)
        cluster = (fg_busy + bg_busy) / max(fg_col * self.plan.num_gpus, 1e-30)
        # every scheduled slot gets a row — hypothetical tenant counts
        # (admission-control what-ifs beyond the current roster) show up as
        # placeholder tenants, so the per-tenant rows always sum to the
        # aggregate
        roster = self._roster_for(n)
        rows = tuple(
            TenantResult(
                job=t.job, priority=t.priority,
                bg_steps_per_iter=float(per_slot.get(slot, 0)),
                bg_throughput=per_slot.get(slot, 0) / max(fg_col, 1e-30),
                gap_stages=tuple(sorted(slot_stages.get(slot, ()))),
                devices=slot_devices.get(slot, 0),
                weight=t.weight,
                deficit=self._deficits[slot],
                quantum=t.quantum or bg_model,
                step_time=slot_step_t.get(slot, 0.0),
            )
            for slot, t in enumerate(roster)
        )
        res = CollocationResult(
            fg_iter_time=fg_col,
            fg_iter_time_isolated=fg_iso,
            fg_slowdown=fg_col / max(fg_iso, 1e-30),
            bg_steps_per_iter=total_steps,
            bg_throughput=total_steps / max(fg_col, 1e-30),
            iterations=0,
            banned_ops=tuple(sorted(self.monitor.banned)),
            tenants=rows,
            cluster_throughput=cluster,
        )
        res.jain_index = res.jain_fairness()
        return res

    def predicted_cache_keys(self, n_tenants: Optional[int] = None,
                             bg_model: int = 1,
                             device_ids: Optional[Sequence[int]] = None,
                             iteration: Optional[int] = None) -> List[tuple]:
        """Prediction-only collocation path: the ``ExecutableCache`` keys
        ``run_executable`` would compile for this iteration's schedule,
        without touching devices or jax.

        Each scheduled (chunk, tenant) pair maps to the same
        ``(signature, device ids, mesh shape)`` triple ``ExecutableCache.key``
        derives from a real submesh — ``device_ids`` supplies the positional
        id mapping (the trace-driven cluster sim passes the sorted healthy
        set; default: identity).  Lets a device-free caller replay realistic
        cache reuse/eviction dynamics (LRU bound, ``evict_stale`` after
        re-plans) at simulated cluster scale.  Deduplicated, schedule order.
        """
        n = n_tenants if n_tenants is not None else max(1, len(self.tenants))
        if n <= 0:
            return []
        roster = self._roster_for(n)
        quanta = self._roster_quanta(roster, bg_model)
        keys: List[tuple] = []
        seen = set()
        for _si, slot, _pos, (cs, ce), _n, _t in self._schedule_detail(
                n, bg_model, iteration=iteration):
            if device_ids is not None:
                ids = tuple(device_ids[cs:ce])
            else:
                ids = tuple(range(cs, ce))
            model = quanta[slot]
            key = (roster[slot].cache_signature, ids,
                   ((ce - cs) // model, model))
            if key not in seen:
                seen.add(key)
                keys.append(key)
        return keys

    def admit(self, *, max_fg_slowdown: float = 1.33, bg_model: int = 1,
              max_tenants: Optional[int] = None) -> AdmissionDecision:
        """Predict-before-compile admission control (paper §5 operating-point
        selection): sweep candidate tenant counts 0..n through the
        calibrated ``predict()`` and admit the roster prefix whose predicted
        cluster throughput is highest among those keeping fg slowdown within
        ``max_fg_slowdown`` (the paper's 1.33x QoS bound).  Predicted
        throughput ties go to the *larger* roster — serving one more tenant
        at no predicted cluster cost is strictly better for fairness.  k=0
        (fg only, slowdown 1.0) is always feasible, so the decision never
        admits a roster the model says breaks the bound.  Nothing is
        compiled here — rejected tenants never reach the executable cache.
        """
        n_max = len(self.tenants) if max_tenants is None else max_tenants
        curve: List[Tuple[int, float, float]] = []
        best_k, best_c = 0, float("-inf")
        for k in range(n_max + 1):
            pred = self.predict(k, bg_model)
            curve.append((k, pred.fg_slowdown, pred.cluster_throughput))
            if (pred.fg_slowdown <= max_fg_slowdown + 1e-12
                    and pred.cluster_throughput >= best_c - 1e-9):
                best_k = k
                best_c = max(best_c, pred.cluster_throughput)
        return AdmissionDecision(
            bound=max_fg_slowdown,
            n_admitted=best_k,
            admitted=tuple(self.tenants[:best_k]),
            rejected=tuple(self.tenants[best_k:]),
            curve=tuple(curve),
        )

    def run_executable(
        self,
        make_fg_stage_fn: Callable,
        make_bg_step_fn: Optional[Callable] = None,
        *,
        tenants: Optional[Sequence[BgTenant]] = None,
        iterations: int = 3,
        fg_model: int = 1,
        bg_model: int = 1,
        time_fn: Callable[[], float] = _time.perf_counter,
    ) -> CollocationResult:
        """Measure real gap collocation on this process's devices.

        ``make_fg_stage_fn(stage, mesh)`` -> zero-arg callable running that
        foreground stage on its submesh (a Mesh over the stage's device
        prefix).  Background work comes from the prioritized tenant list —
        ``tenants`` here, else ``self.tenants``, else a single anonymous
        tenant wrapping ``make_bg_step_fn`` — and each tenant's
        ``step_fn_factory(mesh)`` yields a zero-arg callable dispatching one
        background step on its gap submesh (async; its result is blocked on
        by the pacing loop).  Tenants pace independently on disjoint device
        chunks (per-tenant in-flight bound); dispatch per stage is in
        priority order.  When ``self.cache`` is set, compiled bg steps are
        looked up by (signature, device ids, shape) before building — a
        re-plan whose gap shapes are unchanged re-uses jitted steps.

        Runs ``iterations`` isolated iterations (recording per-stage
        baselines), ``iterations`` collocated ones, plus one final settled
        iteration after the feedback loop has banned harmful origins;
        returns min-over-iterations times so compile noise and the feedback
        loop's learning phase don't pollute the steady state the QoS
        mechanism is meant to deliver.  The isolated baseline is then
        *re-measured* after the collocated phase and the slowdown computed
        against the slower of the two baselines (paired drift control:
        host-wide speed changes mid-measurement would otherwise read as
        collocation slowdown).  ``CollocationResult.tenants`` carries
        per-tenant throughput.
        """
        from repro.launch.mesh import submesh_from_range

        import jax

        roster = list(tenants) if tenants is not None else list(self.tenants)
        if not roster:
            if make_bg_step_fn is None:
                raise ValueError(
                    "run_executable needs background work: pass tenants or "
                    "make_bg_step_fn"
                )
            roster = [BgTenant("bg0", 0, make_bg_step_fn)]
        roster.sort(key=lambda t: -t.priority)  # stable: slot 0 = highest
        for t in roster:
            if t.step_fn_factory is None:
                raise ValueError(f"tenant {t.job!r} has no step_fn_factory")

        devs = list(self.devices) if self.devices is not None else jax.devices()
        # re-derive QoS state for this plan's ops from measurement so stale
        # (possibly simulated-domain) baselines can't poison the feedback
        self.reset_measured_qos()
        n_slots = len(roster)
        quanta = self._roster_quanta(roster, bg_model)
        # always pass the roster's quanta explicitly: submeshes() must carve
        # exactly the chunks _schedule_detail packs for THIS roster, even
        # when it differs from self.tenants (an override roster)
        split = self.submeshes(fg_model=fg_model, bg_model=bg_model,
                               tenants=n_slots, tenant_quanta=quanta)
        stages = self.plan.stages()
        mesh_cache: Dict[Tuple[int, int], object] = {
            split.fg_range: split.fg_mesh
        }
        fg_fns = []
        for i, st in enumerate(stages):
            rng = split.stage_fg_range[i]
            if rng not in mesh_cache:
                model = fg_model if st.gpus % fg_model == 0 else 1
                mesh_cache[rng] = submesh_from_range(
                    rng[0], rng[1], model=model, devices=devs
                )
            fg_fns.append(make_fg_stage_fn(st, mesh_cache[rng]))

        # per-(stage, chunk position, tenant-slot) bg step fns, built through
        # the executable cache so an unchanged gap submesh reuses the jitted
        # step.  Only the canonical owner of each position (slot i on chunk
        # i) pre-compiles; a fair-rotated (position, peer) combination jits
        # lazily on first dispatch — a k-member equal-priority group costs k
        # compiles up front plus one per combination the rotation actually
        # reaches, never k^2 executables (and k^2 device-resident state
        # replicas) for assignments that may never occur.  A lazy compile
        # lands inside one measured iteration; the min-over-iterations
        # steady state discards that sample.
        hits0 = self.cache.hits if self.cache else 0
        miss0 = self.cache.misses if self.cache else 0
        # bg step fns are keyed by (device chunk, tenant slot) — NOT by
        # (stage, position): rotation and deficit share-sizing re-carve the
        # chunks per iteration, and the same chunk reappearing in another
        # stage (or rotation round) must reuse the same jitted step.  Meshes
        # are keyed by (chunk, model width) so a rotated-in tenant whose
        # quantum differs from the canonical owner's gets a mesh shaped for
        # ITS model axis over the same devices.
        bg_fns: Dict[Tuple[Tuple[int, int], int], Callable] = {}
        bg_meshes: Dict[Tuple[int, int, int], object] = {}
        slot_devices: Dict[int, int] = defaultdict(int)
        lazy_builds: List[Tuple[Tuple[int, int], int]] = []

        def build_bg_fn(chunk: Tuple[int, int],
                        slot: int) -> Optional[Callable]:
            fn = bg_fns.get((chunk, slot))
            if fn is not None:
                return fn
            if slot >= len(roster):
                return None
            cs, ce = chunk
            model = quanta[slot]
            if (ce - cs) % model:
                return None  # scheduler never emits this; belt-and-braces
            mesh = bg_meshes.get((cs, ce, model))
            if mesh is None:
                mesh = submesh_from_range(cs, ce, model=model, devices=devs)
                bg_meshes[(cs, ce, model)] = mesh
            tnt = roster[slot]

            def build(t=tnt, m=mesh, combo=(chunk, slot)):
                # only a REAL build marks the iteration as a compile
                # warm-up — a warm-cache hit costs nothing and must not
                # make run_iter discard the iteration's QoS measurements
                lazy_builds.append(combo)
                return t.step_fn_factory(m)

            if self.cache is not None:
                key = ExecutableCache.key(tnt.cache_signature, mesh)
                fn = self.cache.get_or_build(key, build)
            else:
                fn = build()
            bg_fns[(chunk, slot)] = fn
            return fn

        for si, slots in split.bg_tenants.items():
            for pos, entry in enumerate(slots):
                if pos >= n_slots or entry is None:
                    continue
                bg_meshes[(entry[0][0], entry[0][1], quanta[pos])] = entry[1]
                build_bg_fn(entry[0], pos)  # canonical owner pre-compiles

        # compile warmup outside the timed region (cache hits re-warm too:
        # one step is cheap and keeps first-iteration timing honest)
        for fn in fg_fns:
            _block(fn())
        for bf in bg_fns.values():
            _block(bf())

        def run_iter(collocate: bool):
            rows = (
                self._schedule_detail(n_slots, bg_model,
                                      iteration=self._round, roster=roster)
                if collocate else []
            )
            by_stage: Dict[int, List[Tuple[int, int, Tuple[int, int], int]]] = (
                defaultdict(list))
            for si, slot, pos, c, n, _t in rows:
                by_stage[si].append((slot, pos, c, n))
            # per-tenant pacing: each tenant's submesh is a disjoint device
            # set, so the in-flight bound (non-preemptive tail control)
            # applies per tenant, not across them
            inflight: Dict[int, List[Tuple[int, object]]] = {
                s: [] for s in range(n_slots)
            }
            launched_by = [0] * n_slots
            stage_dts = [0.0] * len(fg_fns)
            builds_before = len(lazy_builds)
            t_start = time_fn()
            for si, fn in enumerate(fg_fns):
                op = f"stage{si}"
                for slot, pos, chunk, n_bg in sorted(by_stage.get(si, ())):
                    bf = build_bg_fn(chunk, slot)  # lazy for rotated combos
                    if bf is None:
                        continue
                    q = inflight[slot]
                    for _ in range(n_bg):
                        while len(q) >= self.cfg.max_inflight:
                            _block(q.pop(0)[1])  # launch pacing
                        q.append((si, bf()))
                        launched_by[slot] += 1
                # completed futures no longer interfere — drop them so a
                # slow stage doesn't ban origins whose work already finished
                outstanding = set()
                for q in inflight.values():
                    q[:] = [(o, f) for o, f in q if not _future_done(f)]
                    outstanding.update(o for o, _ in q)
                t0 = time_fn()
                _block(fn())
                dt = time_fn() - t0
                stage_dts[si] = dt
                compiled = len(lazy_builds) > builds_before
                if not collocate:
                    prev = self.monitor.baseline.get(op)
                    self.monitor.record_baseline(
                        op, dt if prev is None else min(prev, dt)
                    )
                elif not compiled:
                    # an iteration that lazily jitted a rotated combo is a
                    # warm-up sample: its stage times include compile +
                    # state-replica setup, which must not feed the slowdown
                    # feedback (it would ban every collocated stage and shut
                    # collocation off for the rest of the run)
                    self.monitor.record(op, dt, collocated=bool(outstanding))
                    # non-preemptive bg tails harm *later* stages, not the
                    # gap they were launched into — attribute the overrun to
                    # the originating gap ops so the feedback loop converges
                    if (self.cfg.use_feedback and outstanding
                            and self.monitor.slowdown(op)
                            > self.monitor.slowdown_threshold):
                        self.monitor.banned.update(
                            f"stage{o}" for o in outstanding
                        )
            for q in inflight.values():
                for _, f in q:
                    _block(f)
            if collocate:
                # fair sharing: book per-slot launches into the deficit
                # counters and advance the rotation round
                self.note_launched(launched_by, roster)
            return (time_fn() - t_start, launched_by, rows, stage_dts,
                    len(lazy_builds) > builds_before)

        iso = [run_iter(False)[0] for _ in range(max(1, iterations))]
        fg_iso = min(iso)
        col: List[Tuple[float, int]] = []
        col_by_tenant: List[List[int]] = []
        col_bg_busy: List[float] = []
        slot_stages_ran: Dict[int, set] = defaultdict(set)
        col_stage_min: Dict[int, float] = {}

        def col_iter() -> None:
            t, launched_by, rows, stage_dts, compiled = run_iter(True)
            col.append((t, sum(launched_by)))
            col_by_tenant.append(launched_by)
            # bg device-seconds and per-tenant device footprint come from
            # the rows actually dispatched this iteration (not from every
            # chunk a rotation *candidate* could have held)
            col_bg_busy.append(sum(
                n * bg_t * (ce - cs) for _si, _sl, _p, (cs, ce), n, bg_t in rows
            ))
            for si, slot, _pos, (cs, ce), _n, _t in rows:
                slot_stages_ran[slot].add(si)
                slot_devices[slot] = max(slot_devices[slot], ce - cs)
                if not compiled:
                    col_stage_min[si] = min(
                        col_stage_min.get(si, float("inf")), stage_dts[si]
                    )
            # iteration-level watchdog: per-op feedback only bans ops whose
            # own slowdown crosses the threshold, but many sub-threshold
            # inflations can still break the iteration bound — ban every
            # origin that collocated in an over-bound iteration.  Warm-up
            # iterations (a rotated combo jitted lazily mid-iteration) are
            # exempt: their time is compile + state setup, not interference
            if (self.cfg.use_feedback and rows and not compiled
                    and t > self.monitor.slowdown_threshold * fg_iso):
                self.monitor.banned.update(
                    f"stage{s}" for s, _, _, _, _, _ in rows
                )

        for _ in range(max(1, iterations)):
            col_iter()
        # settled phase: keep iterating until the feedback loop stops
        # learning (an iteration adds no new bans), so the measurement
        # includes the converged steady state the QoS mechanism promises
        # (bounded fg slowdown), not just the learning phase
        for _ in range(len(fg_fns)):
            before = set(self.monitor.banned)
            col_iter()
            if set(self.monitor.banned) == before:
                break
        # extra steady-state samples: the post-convergence min is the QoS
        # claim under test, so give it more than one draw against host
        # timing noise
        for _ in range(2):
            col_iter()
        # drift control: re-measure the isolated baseline now that the
        # collocated phase is done; min(col) is compared against the slower
        # of the before/after baselines so a host that slowed down (or sped
        # up) mid-run doesn't fake a slowdown the QoS loop never caused
        iso_post = [run_iter(False)[0] for _ in range(max(1, iterations))]
        fg_iso = max(fg_iso, min(iso_post))
        fg_col = min(t for t, _ in col)
        bg_steps = sum(n for _, n in col) / len(col)
        # per-gap-stage measured slowdown: collocated per-stage min against
        # the isolated per-stage baseline (per-stage calibration input).
        # Raw ratios — calibrate() clamps to >= 1.0 when fitting.
        stage_slowdowns = tuple(
            (si, col_stage_min[si] / self.monitor.baseline[f"stage{si}"])
            for si in sorted(col_stage_min)
            if self.monitor.baseline.get(f"stage{si}", 0.0) > 0.0
        )
        # measured cluster throughput in plan-time units: planned fg busy
        # over the slowdown-inflated iteration, plus the bg device-seconds
        # of the rows actually dispatched (per-row step-time quantum x its
        # own chunk width, averaged over the collocated iterations)
        slowdown = fg_col / max(fg_iso, 1e-30)
        fg_busy = sum(s.duration * s.gpus for s in stages)
        bg_busy = sum(col_bg_busy) / len(col_bg_busy)
        cluster = (fg_busy + bg_busy) / max(
            self.plan.total_time * slowdown * self.plan.num_gpus, 1e-30
        )
        tenant_rows = tuple(
            TenantResult(
                job=t.job, priority=t.priority,
                bg_steps_per_iter=(
                    sum(row[slot] for row in col_by_tenant) / len(col_by_tenant)
                ),
                bg_throughput=(
                    sum(row[slot] for row in col_by_tenant)
                    / len(col_by_tenant) / max(fg_col, 1e-30)
                ),
                gap_stages=tuple(sorted(slot_stages_ran.get(slot, ()))),
                devices=slot_devices.get(slot, 0),
                weight=t.weight,
                deficit=self._deficits[slot],
                quantum=quanta[slot],
                step_time=(self._last_step_t[slot]
                           if slot < len(self._last_step_t)
                           else self.bg_step_quantum),
            )
            for slot, t in enumerate(roster)
        )
        res = CollocationResult(
            fg_iter_time=fg_col,
            fg_iter_time_isolated=fg_iso,
            fg_slowdown=slowdown,
            bg_steps_per_iter=bg_steps,
            bg_throughput=bg_steps / max(fg_col, 1e-30),
            iterations=len(col),
            banned_ops=tuple(sorted(self.monitor.banned)),
            iter_details=tuple((t, n) for t, n in col),
            tenants=tenant_rows,
            cache_hits=(self.cache.hits - hits0) if self.cache else 0,
            cache_misses=(self.cache.misses - miss0) if self.cache else 0,
            stage_slowdowns=stage_slowdowns,
            cluster_throughput=cluster,
        )
        res.jain_index = res.jain_fairness()
        return res

    def run_iteration(self, fg_stage_fns: List[Callable], bg_step_fn: Callable,
                      time_fn: Callable[[], float]) -> Dict[str, float]:
        """Execute one fg iteration, filling gaps with bg steps (real
        dispatch, used by examples + small-scale tests)."""
        sched = dict(self.schedule())
        inflight: List = []
        t_start = time_fn()
        for si, fn in enumerate(fg_stage_fns):
            op = f"stage{si}"
            n_bg = sched.get(si, 0)
            for _ in range(n_bg):
                while len(inflight) >= self.cfg.max_inflight:
                    inflight.pop(0)()  # block on oldest (pacing)
                fut = bg_step_fn()
                inflight.append(lambda f=fut: _block(f))
            t0 = time_fn()
            out = fn()
            _block(out)
            dt = time_fn() - t0
            if op not in self.monitor.baseline:
                self.monitor.record_baseline(op, dt)
            self.monitor.record(op, dt, collocated=n_bg > 0)
        for f in inflight:
            f()
        return {"iter_time": time_fn() - t_start}


def _stage_densities(detail) -> Dict[int, float]:
    """Per-stage tenant density from ``_schedule_detail`` rows: the number
    of distinct tenant slots launching steps inside each gap stage."""
    slots: Dict[int, set] = defaultdict(set)
    for si, slot, _pos, _chunk, nsteps, _t in detail:
        if nsteps > 0:
            slots[si].add(slot)
    return {si: float(len(s)) for si, s in slots.items()}


def _result_density(r: "CollocationResult") -> float:
    """Mean collocated-tenant density of a measured result: for each gap
    stage any tenant occupied, how many active tenants shared it, averaged
    over stages.  1.0 when the result carries no per-tenant rows (a
    single-tenant measurement)."""
    occupancy: Dict[int, int] = defaultdict(int)
    for t in r.tenants:
        if t.bg_steps_per_iter > 0:
            for si in t.gap_stages:
                occupancy[si] += 1
    if not occupancy:
        return 1.0
    return sum(occupancy.values()) / len(occupancy)


def _fit_density_slope(measured, prior: float) -> float:
    """OLS fit of ``density_slope`` from measured results at different
    tenant densities: under ``s - 1 = c * (1 + slope*(d-1))`` the line
    ``y = b0 + b1*x`` over points ``(d_r - 1, s_r - 1)`` has
    ``slope = b1/b0``.  Needs >= 2 distinct densities to identify the
    slope (else keeps ``prior``); negative or degenerate fits (interference
    shrinking with density = measurement noise) fall back to 0; clamped to
    [0, 10] so one noisy pair can't make admission reject everything."""
    pts = [(max(_result_density(r), 1.0) - 1.0,
            max(float(r.fg_slowdown), 1.0) - 1.0) for r in measured]
    if len({round(x, 9) for x, _ in pts}) < 2:
        return prior
    n = float(len(pts))
    mx = sum(x for x, _ in pts) / n
    my = sum(y for _, y in pts) / n
    sxx = sum((x - mx) ** 2 for x, _ in pts)
    sxy = sum((x - mx) * (y - my) for x, y in pts)
    if sxx <= 0.0:
        return prior
    b1 = sxy / sxx
    b0 = my - b1 * mx
    if b0 <= 1e-9 or b1 <= 0.0:
        return 0.0
    return min(10.0, b1 / b0)


def _block(x):
    try:
        import jax

        return jax.block_until_ready(x)
    except Exception:
        return x


def _future_done(x) -> bool:
    """True when a dispatched bg result has already materialized (jax arrays
    expose is_ready()); unknown objects count as still outstanding."""
    ready = getattr(x, "is_ready", None)
    if callable(ready):
        try:
            return bool(ready())
        except Exception:
            return False
    return False
