"""Foreground/background multiplexing (paper §5), TPU-adapted.

Two layers:

1. ``MultiplexSim`` — a discrete-event model of one accelerator cluster
   multiplexing a burst-parallel foreground job with background jobs.  It
   reproduces the paper's §7.2 ablation (Fig 11): each QoS mechanism
   (priorities, launch pacing, slowdown feedback loop, background
   granularity reduction) can be toggled, and the simulator reports
   foreground slowdown + background throughput.  The interference model is
   parameterized by the paper's own measurements (naive collocation ≈ halves
   fg throughput; NCCL all-reduce >2× sensitive; non-preemptive overrun).

2. ``Collocator`` — the executable TPU path: background steps are dispatched
   onto the devices left idle by the plan's gaps (disjoint submeshes —
   DESIGN.md §2), with dispatch pacing (bounded in-flight futures) and the
   slowdown feedback loop driven by a QoSMonitor of measured stage times.
"""
from __future__ import annotations

import math
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.plan import BurstPlan, GapWindow


# ---------------------------------------------------------------------------
# QoS monitoring (slowdown feedback loop — paper §5 "monitors the runtimes of
# each operation, and pauses collocation when a foreground job runs an
# operator that has been observed to suffer large slowdowns")
# ---------------------------------------------------------------------------


@dataclass
class QoSMonitor:
    slowdown_threshold: float = 1.3
    ema_alpha: float = 0.3
    baseline: Dict[str, float] = field(default_factory=dict)
    ema: Dict[str, float] = field(default_factory=dict)
    banned: set = field(default_factory=set)

    def record_baseline(self, op: str, t: float) -> None:
        self.baseline[op] = t

    def record(self, op: str, t: float, collocated: bool) -> None:
        prev = self.ema.get(op, t)
        self.ema[op] = (1 - self.ema_alpha) * prev + self.ema_alpha * t
        if collocated and self.slowdown(op) > self.slowdown_threshold:
            self.banned.add(op)

    def slowdown(self, op: str) -> float:
        b = self.baseline.get(op)
        if not b:
            return 1.0
        return self.ema.get(op, b) / b

    def collocation_allowed(self, op: str) -> bool:
        return op not in self.banned


# ---------------------------------------------------------------------------
# Interference model (paper Fig 11 / Fig 12 calibration)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InterferenceModel:
    """Foreground inflation when a background task shares the device.

    Calibrated to the paper's measurements on A100:
      naive same-device collocation        -> ~1.9× fg stage time
      + stream priorities alone            -> ~1.8× (barely helps; Fig 11)
      + launch pacing                      -> ~1.25×
      sensitive ops (all-reduce/sync)      -> ≥2.1× unless banned
      non-preemptive overrun               -> bg tail blocks the next fg stage
    """

    naive_inflation: float = 1.9
    priority_inflation: float = 1.8
    paced_inflation: float = 1.25
    sensitive_inflation: float = 2.1
    sensitive_kinds: tuple = ("sync", "allreduce")

    def fg_multiplier(self, *, priorities: bool, pacing: bool, sensitive: bool,
                      banned: bool) -> float:
        if banned:
            return 1.0
        if sensitive:
            return self.sensitive_inflation
        if priorities and pacing:
            return self.paced_inflation
        if priorities:
            return self.priority_inflation
        return self.naive_inflation


@dataclass(frozen=True)
class MultiplexConfig:
    use_priorities: bool = True
    use_pacing: bool = True  # launch pacing (bounded outstanding work)
    use_feedback: bool = True  # slowdown feedback loop (ban sensitive ops)
    use_granularity: bool = True  # reduce bg step size (non-preemption guard)
    collocate_same_device: bool = False  # GPU mode (paper) vs TPU submesh mode
    max_inflight: int = 2
    bg_step_time: float = 2.0e-3  # isolated bg step latency at full batch
    bg_min_step_time: float = 0.25e-3  # granularity floor (smaller batch)
    sync_fraction: float = 0.25  # fraction of each fg stage that is grad sync


@dataclass
class SimResult:
    fg_iter_time: float
    fg_iter_time_isolated: float
    bg_steps_per_iter: float
    fg_slowdown: float
    bg_throughput_frac: float  # vs one device running bg flat-out
    cluster_throughput: float  # fg + bg useful device-seconds per second

    def row(self) -> str:
        return (
            f"fg_slowdown={self.fg_slowdown:.3f} bg_steps/iter={self.bg_steps_per_iter:.1f} "
            f"cluster_util={self.cluster_throughput:.3f}"
        )


class MultiplexSim:
    """Discrete-event multiplexing of one fg BurstPlan + one bg job."""

    def __init__(
        self,
        plan: BurstPlan,
        cfg: MultiplexConfig,
        interference: InterferenceModel = InterferenceModel(),
        monitor: Optional[QoSMonitor] = None,
    ):
        self.plan = plan
        self.cfg = cfg
        self.imodel = interference
        self.monitor = monitor or QoSMonitor()

    def bg_step_time(self) -> float:
        """Granularity reduction: size bg steps to the smallest gap."""
        t = self.cfg.bg_step_time
        if not self.cfg.use_granularity:
            return t
        gaps = self.plan.gaps()
        if gaps:
            smallest = min(g.duration for g in gaps)
            t = min(t, max(self.cfg.bg_min_step_time, smallest / 2.0))
        return max(t, self.cfg.bg_min_step_time)

    def run(self, iterations: int = 50) -> SimResult:
        cfg, plan = self.cfg, self.plan
        stages = plan.stages()
        G = plan.num_gpus
        bg_t = self.bg_step_time()
        bg_eff = min(1.0, bg_t / cfg.bg_step_time) ** 0.25  # small batches less efficient
        fg_iso = plan.total_time
        unpaced_queue = 2  # unbounded-queue depth proxy (paper: loss of QoS)

        fg_time_total = 0.0
        bg_busy_total = 0.0
        bg_steps_total = 0.0
        for _ in range(iterations):
            t = 0.0
            carry_overrun = 0.0
            prev_free = 0
            for si, st in enumerate(stages):
                free = G - st.gpus
                op = f"stage{si}"
                window = st.duration
                sf = cfg.sync_fraction if st.gpus > 1 else 0.0
                stage_time = window

                if cfg.collocate_same_device:
                    # GPU mode (paper's setting): bg shares the fg devices.
                    # Slowdown feedback bans collocation on the sensitive
                    # (gradient-sync) portion once observed.
                    m_norm = self.imodel.fg_multiplier(
                        priorities=cfg.use_priorities, pacing=cfg.use_pacing,
                        sensitive=False, banned=False,
                    )
                    if cfg.use_feedback:
                        m_sens = 1.0  # banned after first observation
                    else:
                        m_sens = self.imodel.fg_multiplier(
                            priorities=cfg.use_priorities, pacing=cfg.use_pacing,
                            sensitive=True, banned=False,
                        )
                    stage_time = window * (1.0 - sf) * m_norm + window * sf * m_sens
                    # half of the inflation is useful bg cycles, half is waste
                    stolen = (stage_time - window) * st.gpus * 0.5
                    bg_busy_total += stolen * bg_eff
                    bg_steps_total += stolen / bg_t

                if free > 0:
                    # gap: bg runs on the disjoint idle devices
                    n_per_dev = math.floor(window / bg_t)
                    if cfg.use_pacing:
                        # paced: bounded outstanding work; residual overrun is
                        # one half-step of estimation error
                        overrun = 0.5 * bg_t
                    else:
                        n_per_dev += unpaced_queue
                        overrun = unpaced_queue * bg_t
                    bg_steps_total += n_per_dev * free
                    bg_busy_total += n_per_dev * bg_t * free * bg_eff
                    carry_overrun = max(carry_overrun, overrun)
                    prev_free = free
                else:
                    # non-preemptive bg tail on previously-free devices delays
                    # this stage iff it now needs those devices
                    if carry_overrun > 0.0 and st.gpus > G - prev_free:
                        stage_time += carry_overrun
                    carry_overrun = 0.0

                self.monitor.record_baseline(op, window)
                self.monitor.record(op, stage_time, collocated=True)
                t += stage_time
            t += carry_overrun  # tail overrun beyond the iteration boundary
            fg_time_total += t

        fg_iter = fg_time_total / iterations
        fg_busy = sum(s.duration * s.gpus for s in stages)
        # bg cannot use more device-time than exists beyond fg's actual usage
        budget = fg_iter * G - fg_busy
        bg_busy = min(bg_busy_total / iterations, max(budget, 0.0))
        bg_per_iter = bg_steps_total / iterations * (
            bg_busy / max(bg_busy_total / iterations, 1e-30)
        )
        cluster = (fg_busy + bg_busy) / (fg_iter * G)
        return SimResult(
            fg_iter_time=fg_iter,
            fg_iter_time_isolated=fg_iso,
            bg_steps_per_iter=bg_per_iter,
            fg_slowdown=fg_iter / fg_iso,
            bg_throughput_frac=bg_busy / (fg_iter * G),
            cluster_throughput=cluster,
        )


# ---------------------------------------------------------------------------
# Executable collocation (TPU submesh mode)
# ---------------------------------------------------------------------------


@dataclass
class Collocator:
    """Dispatches background steps into plan gaps with pacing + feedback.

    ``fg_stage_fns``: callables per stage (already jitted on the fg submesh).
    ``bg_step_fn``: one background step (jitted on the complement submesh).
    The dispatcher bounds in-flight bg futures (launch pacing) and consults
    the QoSMonitor before collocating around sensitive stages.
    """

    plan: BurstPlan
    cfg: MultiplexConfig
    monitor: QoSMonitor = field(default_factory=QoSMonitor)

    def schedule(self) -> List[Tuple[int, int]]:
        """(stage_index, n_bg_steps) pairs for one iteration."""
        bg_t = MultiplexSim(self.plan, self.cfg).bg_step_time()
        out = []
        for gap in self.plan.gaps():
            op = f"stage{gap.stage_index}"
            if self.cfg.use_feedback and not self.monitor.collocation_allowed(op):
                continue
            n = math.floor(gap.duration / bg_t)
            if self.cfg.use_pacing:
                n = min(n, self.cfg.max_inflight)
            if n > 0:
                out.append((gap.stage_index, n))
        return out

    def run_iteration(self, fg_stage_fns: List[Callable], bg_step_fn: Callable,
                      time_fn: Callable[[], float]) -> Dict[str, float]:
        """Execute one fg iteration, filling gaps with bg steps (real
        dispatch, used by examples + small-scale tests)."""
        sched = dict(self.schedule())
        inflight: List = []
        t_start = time_fn()
        for si, fn in enumerate(fg_stage_fns):
            op = f"stage{si}"
            n_bg = sched.get(si, 0)
            for _ in range(n_bg):
                while len(inflight) >= self.cfg.max_inflight:
                    inflight.pop(0)()  # block on oldest (pacing)
                fut = bg_step_fn()
                inflight.append(lambda f=fut: _block(f))
            t0 = time_fn()
            out = fn()
            _block(out)
            dt = time_fn() - t0
            if op not in self.monitor.baseline:
                self.monitor.record_baseline(op, dt)
            self.monitor.record(op, dt, collocated=n_bg > 0)
        for f in inflight:
            f()
        return {"iter_time": time_fn() - t_start}


def _block(x):
    try:
        import jax

        return jax.block_until_ready(x)
    except Exception:
        return x
