"""Foreground/background multiplexing (paper §5), TPU-adapted.

Two layers — a costless simulation and an executable path — chosen by the
caller (``ClusterCoordinator.collocate(executable=...)``):

1. ``MultiplexSim`` — a discrete-event model of one accelerator cluster
   multiplexing a burst-parallel foreground job with background jobs.  It
   reproduces the paper's §7.2 ablation (Fig 11): each QoS mechanism
   (priorities, launch pacing, slowdown feedback loop, background
   granularity reduction) can be toggled, and the simulator reports
   foreground slowdown + background throughput.  The interference model is
   parameterized by the paper's own measurements (naive collocation ≈ halves
   fg throughput; NCCL all-reduce >2× sensitive; non-preemptive overrun).
   This path needs no accelerators and runs everywhere: planning-time
   what-ifs, coordinator policy decisions, and the Fig-11 ablation tests.

2. ``Collocator`` — the executable path: real jitted steps are dispatched
   onto the devices left idle by the plan's gaps.  ``submeshes()`` carves
   the device set into the plan's foreground submesh plus per-gap background
   submeshes (``repro.launch.mesh.split_mesh_for_plan``), excluding devices
   that host parallel ``BranchPlacement`` branches *during that stage*;
   ``run_executable()`` compiles fg stage fns and bg train steps onto those
   submeshes and interleaves them with dispatch pacing (bounded in-flight
   futures) and the slowdown feedback loop driven by a QoSMonitor of
   *measured* stage times.  It runs whenever the process has at least
   ``plan.num_gpus`` devices (real TPU slice, or CPU with a forced
   host-device count); the coordinator falls back to ``MultiplexSim``
   otherwise.

Multi-tenant gap scheduling (paper §5's cluster-throughput setting — several
background jobs packed into one foreground job's gaps):

- ``BgTenant(job, priority, step_fn_factory)`` names one background job.
  ``Collocator(tenants=[...])`` packs the tenants into each gap's free
  device ranges by priority — ``repro.core.plan.pack_ranges`` carves the
  free set into disjoint quantum-aligned chunks, largest chunk to the
  highest-priority tenant — and ``run_executable`` interleaves every
  tenant's paced dispatch under the shared QoS loop, reporting per-tenant
  throughput as ``CollocationResult.tenants`` (``TenantResult`` rows).
- ``ExecutableCache`` memoizes compiled bg step fns across re-plans, keyed
  on (tenant signature, gap submesh device ids, submesh shape).  A
  coordinator-owned cache survives ``handle_failure``/``handle_join``
  re-plans, so a re-plan whose gap shape is unchanged reuses the jitted bg
  steps (and their training state) instead of recompiling — the dominant
  cost of burst re-scaling.
- ``Collocator.calibrate(results)`` fits the ``InterferenceModel``'s
  submesh-mode multipliers (``gap_inflation``) from measured
  ``CollocationResult``s, and ``Collocator.predict()`` replays the tenant
  schedule through the calibrated model so ``MultiplexSim`` / planning-time
  what-ifs track the hardware the executable path actually measured.
"""
from __future__ import annotations

import math
import time as _time
from collections import defaultdict
from dataclasses import dataclass, field, replace as _dc_replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.plan import BurstPlan, GapWindow, pack_ranges


# ---------------------------------------------------------------------------
# QoS monitoring (slowdown feedback loop — paper §5 "monitors the runtimes of
# each operation, and pauses collocation when a foreground job runs an
# operator that has been observed to suffer large slowdowns")
# ---------------------------------------------------------------------------


@dataclass
class QoSMonitor:
    slowdown_threshold: float = 1.3
    ema_alpha: float = 0.3
    baseline: Dict[str, float] = field(default_factory=dict)
    ema: Dict[str, float] = field(default_factory=dict)
    banned: set = field(default_factory=set)

    def record_baseline(self, op: str, t: float) -> None:
        self.baseline[op] = t

    def record(self, op: str, t: float, collocated: bool) -> None:
        prev = self.ema.get(op, t)
        self.ema[op] = (1 - self.ema_alpha) * prev + self.ema_alpha * t
        if collocated and self.slowdown(op) > self.slowdown_threshold:
            self.banned.add(op)

    def slowdown(self, op: str) -> float:
        b = self.baseline.get(op)
        if not b:
            return 1.0
        return self.ema.get(op, b) / b

    def collocation_allowed(self, op: str) -> bool:
        return op not in self.banned


# ---------------------------------------------------------------------------
# Interference model (paper Fig 11 / Fig 12 calibration)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InterferenceModel:
    """Foreground inflation when a background task shares the device.

    Calibrated to the paper's measurements on A100:
      naive same-device collocation        -> ~1.9× fg stage time
      + stream priorities alone            -> ~1.8× (barely helps; Fig 11)
      + launch pacing                      -> ~1.25×
      sensitive ops (all-reduce/sync)      -> ≥2.1× unless banned
      non-preemptive overrun               -> bg tail blocks the next fg stage

    ``gap_inflation`` is the submesh-mode (TPU) counterpart: the measured fg
    stage-time multiplier while disjoint-device tenants collocate in the
    stage's gap (host-side dispatch contention, shared interconnect).  It is
    1.0 by default (ideal disjointness) and is *fitted from measurement* by
    ``Collocator.calibrate`` so simulator predictions track the hardware.
    """

    naive_inflation: float = 1.9
    priority_inflation: float = 1.8
    paced_inflation: float = 1.25
    sensitive_inflation: float = 2.1
    sensitive_kinds: tuple = ("sync", "allreduce")
    gap_inflation: float = 1.0  # submesh mode; calibrated from measurement

    def fg_multiplier(self, *, priorities: bool, pacing: bool, sensitive: bool,
                      banned: bool) -> float:
        if banned:
            return 1.0
        if sensitive:
            return self.sensitive_inflation
        if priorities and pacing:
            return self.paced_inflation
        if priorities:
            return self.priority_inflation
        return self.naive_inflation


@dataclass(frozen=True)
class MultiplexConfig:
    use_priorities: bool = True
    use_pacing: bool = True  # launch pacing (bounded outstanding work)
    use_feedback: bool = True  # slowdown feedback loop (ban sensitive ops)
    use_granularity: bool = True  # reduce bg step size (non-preemption guard)
    collocate_same_device: bool = False  # GPU mode (paper) vs TPU submesh mode
    max_inflight: int = 2
    bg_step_time: float = 2.0e-3  # isolated bg step latency at full batch
    bg_min_step_time: float = 0.25e-3  # granularity floor (smaller batch)
    sync_fraction: float = 0.25  # fraction of each fg stage that is grad sync


@dataclass
class SimResult:
    fg_iter_time: float
    fg_iter_time_isolated: float
    bg_steps_per_iter: float
    fg_slowdown: float
    bg_throughput_frac: float  # vs one device running bg flat-out
    cluster_throughput: float  # fg + bg useful device-seconds per second

    def row(self) -> str:
        return (
            f"fg_slowdown={self.fg_slowdown:.3f} bg_steps/iter={self.bg_steps_per_iter:.1f} "
            f"cluster_util={self.cluster_throughput:.3f}"
        )


class MultiplexSim:
    """Discrete-event multiplexing of one fg BurstPlan + one bg job."""

    def __init__(
        self,
        plan: BurstPlan,
        cfg: MultiplexConfig,
        interference: InterferenceModel = InterferenceModel(),
        monitor: Optional[QoSMonitor] = None,
    ):
        self.plan = plan
        self.cfg = cfg
        self.imodel = interference
        self.monitor = monitor or QoSMonitor()

    def bg_step_time(self) -> float:
        """Granularity reduction: size bg steps to the smallest gap."""
        t = self.cfg.bg_step_time
        if not self.cfg.use_granularity:
            return t
        gaps = self.plan.gaps()
        if gaps:
            smallest = min(g.duration for g in gaps)
            t = min(t, max(self.cfg.bg_min_step_time, smallest / 2.0))
        return max(t, self.cfg.bg_min_step_time)

    def run(self, iterations: int = 50) -> SimResult:
        cfg, plan = self.cfg, self.plan
        stages = plan.stages()
        G = plan.num_gpus
        bg_t = self.bg_step_time()
        bg_eff = min(1.0, bg_t / cfg.bg_step_time) ** 0.25  # small batches less efficient
        fg_iso = plan.total_time
        unpaced_queue = 2  # unbounded-queue depth proxy (paper: loss of QoS)

        fg_time_total = 0.0
        bg_busy_total = 0.0
        bg_steps_total = 0.0
        for _ in range(iterations):
            t = 0.0
            carry_overrun = 0.0
            prev_free = 0
            for si, st in enumerate(stages):
                free = G - st.gpus
                op = f"stage{si}"
                window = st.duration
                sf = cfg.sync_fraction if st.gpus > 1 else 0.0
                stage_time = window

                if cfg.collocate_same_device:
                    # GPU mode (paper's setting): bg shares the fg devices.
                    # Slowdown feedback bans collocation on the sensitive
                    # (gradient-sync) portion once observed.
                    m_norm = self.imodel.fg_multiplier(
                        priorities=cfg.use_priorities, pacing=cfg.use_pacing,
                        sensitive=False, banned=False,
                    )
                    if cfg.use_feedback:
                        m_sens = 1.0  # banned after first observation
                    else:
                        m_sens = self.imodel.fg_multiplier(
                            priorities=cfg.use_priorities, pacing=cfg.use_pacing,
                            sensitive=True, banned=False,
                        )
                    stage_time = window * (1.0 - sf) * m_norm + window * sf * m_sens
                    # half of the inflation is useful bg cycles, half is waste
                    stolen = (stage_time - window) * st.gpus * 0.5
                    bg_busy_total += stolen * bg_eff
                    bg_steps_total += stolen / bg_t

                if free > 0:
                    # gap: bg runs on the disjoint idle devices.  In submesh
                    # mode the calibrated gap_inflation models the measured
                    # residual interference (host dispatch, interconnect) —
                    # but only where collocation actually happens: a gap the
                    # feedback loop banned admits no bg and stays clean.
                    if (not cfg.collocate_same_device
                            and (not cfg.use_feedback
                                 or self.monitor.collocation_allowed(op))):
                        stage_time = window * self.imodel.gap_inflation
                    n_per_dev = math.floor(window / bg_t)
                    if cfg.use_pacing:
                        # paced: bounded outstanding work; residual overrun is
                        # one half-step of estimation error
                        overrun = 0.5 * bg_t
                    else:
                        n_per_dev += unpaced_queue
                        overrun = unpaced_queue * bg_t
                    bg_steps_total += n_per_dev * free
                    bg_busy_total += n_per_dev * bg_t * free * bg_eff
                    carry_overrun = max(carry_overrun, overrun)
                    prev_free = free
                else:
                    # non-preemptive bg tail on previously-free devices delays
                    # this stage iff it now needs those devices
                    if carry_overrun > 0.0 and st.gpus > G - prev_free:
                        stage_time += carry_overrun
                    carry_overrun = 0.0

                self.monitor.record_baseline(op, window)
                self.monitor.record(op, stage_time, collocated=True)
                t += stage_time
            t += carry_overrun  # tail overrun beyond the iteration boundary
            fg_time_total += t

        fg_iter = fg_time_total / iterations
        fg_busy = sum(s.duration * s.gpus for s in stages)
        # bg cannot use more device-time than exists beyond fg's actual usage
        budget = fg_iter * G - fg_busy
        bg_busy = min(bg_busy_total / iterations, max(budget, 0.0))
        bg_per_iter = bg_steps_total / iterations * (
            bg_busy / max(bg_busy_total / iterations, 1e-30)
        )
        cluster = (fg_busy + bg_busy) / (fg_iter * G)
        return SimResult(
            fg_iter_time=fg_iter,
            fg_iter_time_isolated=fg_iso,
            bg_steps_per_iter=bg_per_iter,
            fg_slowdown=fg_iter / fg_iso,
            bg_throughput_frac=bg_busy / (fg_iter * G),
            cluster_throughput=cluster,
        )


# ---------------------------------------------------------------------------
# Executable collocation (TPU submesh mode)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BgTenant:
    """One background job competing for gap devices.

    ``priority`` orders tenants (higher first): the highest-priority tenant
    gets the largest chunk of each gap's free device ranges and dispatches
    first.  ``step_fn_factory(mesh)`` returns a zero-arg callable dispatching
    one training step on the tenant's gap submesh (the ``make_bg_step_fn``
    contract of ``run_executable``).  ``signature`` identifies the compiled
    executable for cache reuse across re-plans; it defaults to the factory's
    ``signature`` attribute (set by ``train.step.bg_step_factory``) and,
    for untagged factories, to the factory object itself — never to the job
    name alone, so two *different* factories submitted under one name can't
    silently share a compiled executable.
    """

    job: str
    priority: int = 0
    step_fn_factory: Optional[Callable] = None
    signature: Optional[object] = None  # any hashable executable identity

    @property
    def cache_signature(self):
        if self.signature:
            return self.signature
        sig = getattr(self.step_fn_factory, "signature", None)
        if sig:
            return sig
        return self.step_fn_factory if self.step_fn_factory is not None \
            else self.job


@dataclass
class ExecutableCache:
    """Compiled bg-step reuse across re-plans.

    Keyed on (tenant signature, gap submesh device ids, submesh shape): a
    jitted step closes over device-committed state, so identity of the
    *device subset* — not just its shape — is what makes reuse sound.  After
    a ``handle_failure``/``handle_join`` re-plan whose gap ranges are
    unchanged, the same key recurs and the jitted step (with its training
    state) is reused instead of re-jitted — re-compilation is the dominant
    cost of burst re-scaling.
    """

    entries: Dict[tuple, Callable] = field(default_factory=dict)
    hits: int = 0
    misses: int = 0

    @staticmethod
    def key(signature: str, mesh) -> tuple:
        return (
            signature,
            tuple(d.id for d in mesh.devices.flat),
            tuple(mesh.devices.shape),
        )

    def get_or_build(self, key: tuple, build: Callable[[], Callable]) -> Callable:
        fn = self.entries.get(key)
        if fn is not None:
            self.hits += 1
            return fn
        self.misses += 1
        fn = self.entries[key] = build()
        return fn


@dataclass(frozen=True)
class TenantResult:
    """Per-tenant slice of a CollocationResult."""

    job: str
    priority: int
    bg_steps_per_iter: float
    bg_throughput: float  # steps per second of collocated fg wall time
    gap_stages: Tuple[int, ...] = ()  # stages where this tenant held devices
    devices: int = 0                  # largest submesh the tenant held

    def row(self) -> str:
        return (f"{self.job}(p{self.priority}): "
                f"{self.bg_steps_per_iter:.1f} steps/iter on "
                f"<= {self.devices} devices")


@dataclass
class CollocationResult:
    """Measured (not simulated) outcome of executable gap collocation.

    ``fg_slowdown`` is the steady state after the feedback loop has banned
    harmful origins — the bound the QoS mechanism promises.  ``iter_details``
    exposes every collocated iteration as (wall_time, bg_steps_launched) so
    the learning-phase tradeoff (iterations that collocated heavily may have
    run slower) stays visible rather than hidden by the min.
    """

    fg_iter_time: float
    fg_iter_time_isolated: float
    fg_slowdown: float
    bg_steps_per_iter: float
    bg_throughput: float  # bg steps per second of collocated fg wall time
    iterations: int
    banned_ops: Tuple[str, ...] = ()
    iter_details: Tuple[Tuple[float, int], ...] = ()
    tenants: Tuple[TenantResult, ...] = ()  # per-tenant accounting
    cache_hits: int = 0    # executable-cache hits while building this run
    cache_misses: int = 0

    def row(self) -> str:
        per_tenant = ""
        if self.tenants:
            per_tenant = " " + " ".join(t.row() for t in self.tenants)
        return (
            f"fg_slowdown={self.fg_slowdown:.3f} "
            f"bg_steps/iter={self.bg_steps_per_iter:.1f} "
            f"bg_steps/s={self.bg_throughput:.1f} "
            f"banned={list(self.banned_ops) or 'none'}" + per_tenant
        )


@dataclass
class Collocator:
    """Dispatches background steps into plan gaps with pacing + feedback.

    ``run_executable`` is the real path: it builds disjoint fg/bg submeshes
    from the plan (``submeshes()``), compiles the caller's stage/step
    factories onto them, and interleaves paced background dispatch with the
    foreground stages, measuring slowdown via the QoSMonitor.
    ``run_iteration`` is the lighter legacy harness: the caller supplies
    already-jitted callables and only the dispatch loop runs here.
    ``devices`` pins an explicit device subset (default: process devices).

    ``tenants`` is a prioritized list of background jobs (``BgTenant``);
    each gap's free device ranges are packed among them largest-chunk-to-
    highest-priority (``schedule_tenants``).  ``cache`` (``ExecutableCache``)
    memoizes compiled bg steps across collocators — pass the coordinator's
    cache so re-plans with unchanged gap shapes reuse jitted steps.
    ``interference`` seeds the analytic model used by ``predict()``;
    ``calibrate()`` refits it from measured results.
    """

    plan: BurstPlan
    cfg: MultiplexConfig
    monitor: QoSMonitor = field(default_factory=QoSMonitor)
    devices: Optional[Sequence] = None
    tenants: Sequence[BgTenant] = ()
    cache: Optional[ExecutableCache] = None
    interference: InterferenceModel = field(default_factory=InterferenceModel)

    def __post_init__(self):
        # priority order is fixed at construction: slot 0 = highest priority
        # (stable for equal priorities, preserving submission order)
        self.tenants = tuple(
            sorted(self.tenants, key=lambda t: -t.priority)
        )
        # hoisted: one sim + one bg step-time quantum for the collocator's
        # lifetime (previously rebuilt inside every schedule() call)
        self._sim = MultiplexSim(self.plan, self.cfg, self.interference,
                                 monitor=self.monitor)
        self.bg_step_quantum = self._sim.bg_step_time()

    def schedule(self) -> List[Tuple[int, int]]:
        """(stage_index, n_bg_steps) pairs for one iteration (single-tenant
        view; see ``schedule_tenants`` for the multi-tenant packing)."""
        bg_t = self.bg_step_quantum
        out = []
        for gap in self.plan.gaps():
            op = f"stage{gap.stage_index}"
            if self.cfg.use_feedback and not self.monitor.collocation_allowed(op):
                continue
            n = math.floor(gap.duration / bg_t)
            if self.cfg.use_pacing:
                n = min(n, self.cfg.max_inflight)
            if n > 0:
                out.append((gap.stage_index, n))
        return out

    def schedule_tenants(
        self, n_tenants: Optional[int] = None, bg_model: int = 1
    ) -> List[Tuple[int, int, int]]:
        """(stage_index, tenant_slot, n_bg_steps) triples for one iteration.

        Mirrors the executable packing exactly: each gap's per-stage free
        device ranges (branch windows excluded per-stage) are carved into up
        to ``n_tenants`` disjoint ``bg_model``-aligned chunks
        (``pack_ranges``), largest chunk to slot 0 (highest priority).
        Every packed tenant paces ``min(floor(gap/bg_t), max_inflight)``
        steps on its own disjoint devices; a feedback-banned gap admits no
        tenant at all.
        """
        n = n_tenants if n_tenants is not None else max(1, len(self.tenants))
        bg_t = self.bg_step_quantum
        out: List[Tuple[int, int, int]] = []
        for gap in self.plan.gaps():
            op = f"stage{gap.stage_index}"
            if self.cfg.use_feedback and not self.monitor.collocation_allowed(op):
                continue
            nsteps = math.floor(gap.duration / bg_t)
            if self.cfg.use_pacing:
                nsteps = min(nsteps, self.cfg.max_inflight)
            if nsteps <= 0:
                continue
            chunks = pack_ranges(
                self.plan.free_device_ranges(gap.stage_index), n,
                quantum=bg_model,
            )
            for slot in range(len(chunks)):
                out.append((gap.stage_index, slot, nsteps))
        return out

    # -- executable submesh path -------------------------------------------

    def submeshes(self, *, fg_model: int = 1, bg_model: int = 1,
                  tenants: Optional[int] = None):
        """Disjoint fg/bg submeshes for this plan (PlanSubmeshes).

        ``tenants`` (default: this collocator's tenant count) splits each
        gap's free ranges into that many per-tenant submeshes."""
        from repro.launch.mesh import split_mesh_for_plan

        n = tenants if tenants is not None else max(1, len(self.tenants))
        return split_mesh_for_plan(self.plan, devices=self.devices,
                                   fg_model=fg_model, bg_model=bg_model,
                                   tenants=n)

    # -- calibration + analytic prediction ---------------------------------

    def calibrate(self, results: Sequence[CollocationResult]) -> InterferenceModel:
        """Fit the interference model's submesh-mode multipliers from
        measured ``CollocationResult``s.

        The measured foreground slowdown is attributed to the collocated gap
        stages of the current tenant schedule: with collocated gap time
        ``W_gap`` out of total iteration time ``W``, a measured (geometric
        mean) slowdown ``s`` inverts to ``gap_inflation = 1 + (s-1)*W/W_gap``
        — exactly the multiplier that makes ``predict()`` reproduce ``s``.
        ``MultiplexSim.run`` applies the same multiplier to unbanned gap
        stages, so its submesh path tracks ``s`` too, up to its own overrun
        modeling and any gap stage that has free devices but admits no
        tenant chunk (branch-covered free ranges).  Installs the fitted
        model on this collocator's sim and returns it.
        """
        meas = [max(float(r.fg_slowdown), 1.0) for r in results
                if r.iterations > 0 and r.fg_slowdown > 0.0]
        if not meas:
            return self.interference
        log_mean = sum(math.log(s) for s in meas) / len(meas)
        s = math.exp(log_mean)
        stages = self.plan.stages()
        col_stages = {si for si, _, _ in self.schedule_tenants()}
        gap_t = sum(stages[si].duration for si in col_stages)
        total = self.plan.total_time
        if gap_t <= 0.0 or total <= 0.0:
            gi = 1.0
        else:
            gi = 1.0 + (s - 1.0) * total / gap_t
        model = _dc_replace(self.interference, gap_inflation=max(gi, 1.0))
        self.interference = model
        self._sim.imodel = model
        return model

    def predict(self, n_tenants: Optional[int] = None,
                bg_model: int = 1) -> CollocationResult:
        """Analytic (device-free) prediction of ``run_executable`` under the
        current (possibly calibrated) interference model and monitor state.

        Replays ``schedule_tenants`` through ``gap_inflation``: collocated
        gap stages inflate by the calibrated multiplier, every packed tenant
        contributes its paced step count.  ``iterations == 0`` marks the
        result as predicted, not measured.
        """
        n = n_tenants if n_tenants is not None else max(1, len(self.tenants))
        sched = self.schedule_tenants(n, bg_model)
        stages = self.plan.stages()
        fg_iso = self.plan.total_time
        gi = self.interference.gap_inflation
        col_stages = {si for si, _, _ in sched}
        fg_col = fg_iso + sum(
            stages[si].duration * (gi - 1.0) for si in col_stages
        )
        per_slot: Dict[int, int] = defaultdict(int)
        slot_stages: Dict[int, List[int]] = defaultdict(list)
        for si, slot, nsteps in sched:
            per_slot[slot] += nsteps
            slot_stages[slot].append(si)
        total_steps = float(sum(per_slot.values()))
        # every scheduled slot gets a row — hypothetical tenant counts
        # (admission-control what-ifs beyond the current roster) show up as
        # placeholder tenants, so the per-tenant rows always sum to the
        # aggregate
        roster = list(self.tenants[:n])
        while len(roster) < n:
            roster.append(BgTenant(f"bg{len(roster)}"))
        rows = tuple(
            TenantResult(
                job=t.job, priority=t.priority,
                bg_steps_per_iter=float(per_slot.get(slot, 0)),
                bg_throughput=per_slot.get(slot, 0) / max(fg_col, 1e-30),
                gap_stages=tuple(sorted(slot_stages.get(slot, ()))),
            )
            for slot, t in enumerate(roster)
        )
        return CollocationResult(
            fg_iter_time=fg_col,
            fg_iter_time_isolated=fg_iso,
            fg_slowdown=fg_col / max(fg_iso, 1e-30),
            bg_steps_per_iter=total_steps,
            bg_throughput=total_steps / max(fg_col, 1e-30),
            iterations=0,
            banned_ops=tuple(sorted(self.monitor.banned)),
            tenants=rows,
        )

    def run_executable(
        self,
        make_fg_stage_fn: Callable,
        make_bg_step_fn: Optional[Callable] = None,
        *,
        tenants: Optional[Sequence[BgTenant]] = None,
        iterations: int = 3,
        fg_model: int = 1,
        bg_model: int = 1,
        time_fn: Callable[[], float] = _time.perf_counter,
    ) -> CollocationResult:
        """Measure real gap collocation on this process's devices.

        ``make_fg_stage_fn(stage, mesh)`` -> zero-arg callable running that
        foreground stage on its submesh (a Mesh over the stage's device
        prefix).  Background work comes from the prioritized tenant list —
        ``tenants`` here, else ``self.tenants``, else a single anonymous
        tenant wrapping ``make_bg_step_fn`` — and each tenant's
        ``step_fn_factory(mesh)`` yields a zero-arg callable dispatching one
        background step on its gap submesh (async; its result is blocked on
        by the pacing loop).  Tenants pace independently on disjoint device
        chunks (per-tenant in-flight bound); dispatch per stage is in
        priority order.  When ``self.cache`` is set, compiled bg steps are
        looked up by (signature, device ids, shape) before building — a
        re-plan whose gap shapes are unchanged re-uses jitted steps.

        Runs ``iterations`` isolated iterations (recording per-stage
        baselines), ``iterations`` collocated ones, plus one final settled
        iteration after the feedback loop has banned harmful origins;
        returns min-over-iterations times so compile noise and the feedback
        loop's learning phase don't pollute the steady state the QoS
        mechanism is meant to deliver.  The isolated baseline is then
        *re-measured* after the collocated phase and the slowdown computed
        against the slower of the two baselines (paired drift control:
        host-wide speed changes mid-measurement would otherwise read as
        collocation slowdown).  ``CollocationResult.tenants`` carries
        per-tenant throughput.
        """
        from repro.launch.mesh import submesh_from_range

        import jax

        roster = list(tenants) if tenants is not None else list(self.tenants)
        if not roster:
            if make_bg_step_fn is None:
                raise ValueError(
                    "run_executable needs background work: pass tenants or "
                    "make_bg_step_fn"
                )
            roster = [BgTenant("bg0", 0, make_bg_step_fn)]
        roster.sort(key=lambda t: -t.priority)  # stable: slot 0 = highest
        for t in roster:
            if t.step_fn_factory is None:
                raise ValueError(f"tenant {t.job!r} has no step_fn_factory")

        devs = list(self.devices) if self.devices is not None else jax.devices()
        # The monitor may hold *simulated* times (a shared coordinator
        # monitor fed by MultiplexSim) — a different time domain than the
        # wall-clock measurements below.  Re-derive QoS state for this
        # plan's ops from measurement so stale baselines can't poison the
        # slowdown feedback.
        for si in range(len(self.plan.stages())):
            op = f"stage{si}"
            self.monitor.baseline.pop(op, None)
            self.monitor.ema.pop(op, None)
            self.monitor.banned.discard(op)
        split = self.submeshes(fg_model=fg_model, bg_model=bg_model,
                               tenants=len(roster))
        stages = self.plan.stages()
        mesh_cache: Dict[Tuple[int, int], object] = {
            split.fg_range: split.fg_mesh
        }
        fg_fns = []
        for i, st in enumerate(stages):
            rng = split.stage_fg_range[i]
            if rng not in mesh_cache:
                model = fg_model if st.gpus % fg_model == 0 else 1
                mesh_cache[rng] = submesh_from_range(
                    rng[0], rng[1], model=model, devices=devs
                )
            fg_fns.append(make_fg_stage_fn(st, mesh_cache[rng]))

        # per-(stage, tenant-slot) bg step fns, built through the executable
        # cache so an unchanged gap submesh reuses the jitted step
        hits0 = self.cache.hits if self.cache else 0
        miss0 = self.cache.misses if self.cache else 0
        bg_fns: Dict[Tuple[int, int], Callable] = {}
        slot_devices: Dict[int, int] = defaultdict(int)
        for si, slots in split.bg_tenants.items():
            for slot, (rng, mesh) in enumerate(slots):
                if slot >= len(roster):
                    break
                tnt = roster[slot]
                if self.cache is not None:
                    key = ExecutableCache.key(tnt.cache_signature, mesh)
                    fn = self.cache.get_or_build(
                        key, lambda t=tnt, m=mesh: t.step_fn_factory(m)
                    )
                else:
                    fn = tnt.step_fn_factory(mesh)
                bg_fns[(si, slot)] = fn
                slot_devices[slot] = max(slot_devices[slot], rng[1] - rng[0])
        n_slots = len(roster)

        # compile warmup outside the timed region (cache hits re-warm too:
        # one step is cheap and keeps first-iteration timing honest)
        for fn in fg_fns:
            _block(fn())
        for bf in bg_fns.values():
            _block(bf())

        def run_iter(collocate: bool):
            sched = (
                {(si, slot): n
                 for si, slot, n in self.schedule_tenants(n_slots, bg_model)}
                if collocate else {}
            )
            # per-tenant pacing: each tenant's submesh is a disjoint device
            # set, so the in-flight bound (non-preemptive tail control)
            # applies per tenant, not across them
            inflight: Dict[int, List[Tuple[int, object]]] = {
                s: [] for s in range(n_slots)
            }
            launched_by = [0] * n_slots
            t_start = time_fn()
            for si, fn in enumerate(fg_fns):
                op = f"stage{si}"
                for slot in range(n_slots):  # priority order
                    bf = bg_fns.get((si, slot))
                    n_bg = sched.get((si, slot), 0) if bf is not None else 0
                    q = inflight[slot]
                    for _ in range(n_bg):
                        while len(q) >= self.cfg.max_inflight:
                            _block(q.pop(0)[1])  # launch pacing
                        q.append((si, bf()))
                        launched_by[slot] += 1
                # completed futures no longer interfere — drop them so a
                # slow stage doesn't ban origins whose work already finished
                outstanding = set()
                for q in inflight.values():
                    q[:] = [(o, f) for o, f in q if not _future_done(f)]
                    outstanding.update(o for o, _ in q)
                t0 = time_fn()
                _block(fn())
                dt = time_fn() - t0
                if not collocate:
                    prev = self.monitor.baseline.get(op)
                    self.monitor.record_baseline(
                        op, dt if prev is None else min(prev, dt)
                    )
                else:
                    self.monitor.record(op, dt, collocated=bool(outstanding))
                    # non-preemptive bg tails harm *later* stages, not the
                    # gap they were launched into — attribute the overrun to
                    # the originating gap ops so the feedback loop converges
                    if (self.cfg.use_feedback and outstanding
                            and self.monitor.slowdown(op)
                            > self.monitor.slowdown_threshold):
                        self.monitor.banned.update(
                            f"stage{o}" for o in outstanding
                        )
            for q in inflight.values():
                for _, f in q:
                    _block(f)
            return time_fn() - t_start, launched_by, sched

        iso = [run_iter(False)[0] for _ in range(max(1, iterations))]
        fg_iso = min(iso)
        col: List[Tuple[float, int]] = []
        col_by_tenant: List[List[int]] = []

        def col_iter() -> None:
            t, launched_by, sched = run_iter(True)
            col.append((t, sum(launched_by)))
            col_by_tenant.append(launched_by)
            # iteration-level watchdog: per-op feedback only bans ops whose
            # own slowdown crosses the threshold, but many sub-threshold
            # inflations can still break the iteration bound — ban every
            # origin that collocated in an over-bound iteration
            if (self.cfg.use_feedback and sched
                    and t > self.monitor.slowdown_threshold * fg_iso):
                self.monitor.banned.update(f"stage{s}" for s, _ in sched)

        for _ in range(max(1, iterations)):
            col_iter()
        # settled phase: keep iterating until the feedback loop stops
        # learning (an iteration adds no new bans), so the measurement
        # includes the converged steady state the QoS mechanism promises
        # (bounded fg slowdown), not just the learning phase
        for _ in range(len(fg_fns)):
            before = set(self.monitor.banned)
            col_iter()
            if set(self.monitor.banned) == before:
                break
        # extra steady-state samples: the post-convergence min is the QoS
        # claim under test, so give it more than one draw against host
        # timing noise
        for _ in range(2):
            col_iter()
        # drift control: re-measure the isolated baseline now that the
        # collocated phase is done; min(col) is compared against the slower
        # of the before/after baselines so a host that slowed down (or sped
        # up) mid-run doesn't fake a slowdown the QoS loop never caused
        iso_post = [run_iter(False)[0] for _ in range(max(1, iterations))]
        fg_iso = max(fg_iso, min(iso_post))
        fg_col = min(t for t, _ in col)
        bg_steps = sum(n for _, n in col) / len(col)
        tenant_rows = tuple(
            TenantResult(
                job=t.job, priority=t.priority,
                bg_steps_per_iter=(
                    sum(row[slot] for row in col_by_tenant) / len(col_by_tenant)
                ),
                bg_throughput=(
                    sum(row[slot] for row in col_by_tenant)
                    / len(col_by_tenant) / max(fg_col, 1e-30)
                ),
                gap_stages=tuple(sorted(
                    si for (si, s2) in bg_fns if s2 == slot
                )),
                devices=slot_devices.get(slot, 0),
            )
            for slot, t in enumerate(roster)
        )
        return CollocationResult(
            fg_iter_time=fg_col,
            fg_iter_time_isolated=fg_iso,
            fg_slowdown=fg_col / max(fg_iso, 1e-30),
            bg_steps_per_iter=bg_steps,
            bg_throughput=bg_steps / max(fg_col, 1e-30),
            iterations=len(col),
            banned_ops=tuple(sorted(self.monitor.banned)),
            iter_details=tuple((t, n) for t, n in col),
            tenants=tenant_rows,
            cache_hits=(self.cache.hits - hits0) if self.cache else 0,
            cache_misses=(self.cache.misses - miss0) if self.cache else 0,
        )

    def run_iteration(self, fg_stage_fns: List[Callable], bg_step_fn: Callable,
                      time_fn: Callable[[], float]) -> Dict[str, float]:
        """Execute one fg iteration, filling gaps with bg steps (real
        dispatch, used by examples + small-scale tests)."""
        sched = dict(self.schedule())
        inflight: List = []
        t_start = time_fn()
        for si, fn in enumerate(fg_stage_fns):
            op = f"stage{si}"
            n_bg = sched.get(si, 0)
            for _ in range(n_bg):
                while len(inflight) >= self.cfg.max_inflight:
                    inflight.pop(0)()  # block on oldest (pacing)
                fut = bg_step_fn()
                inflight.append(lambda f=fut: _block(f))
            t0 = time_fn()
            out = fn()
            _block(out)
            dt = time_fn() - t0
            if op not in self.monitor.baseline:
                self.monitor.record_baseline(op, dt)
            self.monitor.record(op, dt, collocated=n_bg > 0)
        for f in inflight:
            f()
        return {"iter_time": time_fn() - t_start}


def _block(x):
    try:
        import jax

        return jax.block_until_ready(x)
    except Exception:
        return x


def _future_done(x) -> bool:
    """True when a dispatched bg result has already materialized (jax arrays
    expose is_ready()); unknown objects count as still outstanding."""
    ready = getattr(x, "is_ready", None)
    if callable(ready):
        try:
            return bool(ready())
        except Exception:
            return False
    return False
