"""Multi-chain graph reduction (paper §4.2, Fig 7).

A branch/join region (``CostedBlock``) is reduced to a single transition
edge: for every (branching-scale g, joining-scale h) pair we plan each branch
with its entry pinned to g and exit resharded to h, find the critical
branch, and decide per non-critical branch whether it runs *in parallel* on
disjoint devices (doesn't extend the block) or *sequentially* (reuses the
critical branch's devices) — parallel only when it neither increases total
time nor overshoots the amplification limit, per the paper.

``block_transition_table`` memoizes the full (g, h) table; the linear search
(core/planner.py) consumes it as tr((i,g)→(j,h)).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from repro.core.costmodel import Hardware
from repro.core.profiler import CostedBlock, CostedLayer

INF = float("inf")


@dataclass(frozen=True)
class BranchPlan:
    time: float
    gpu_sec: float
    peak_gpus: int
    parallel: bool  # runs concurrently with the critical branch?


@dataclass(frozen=True)
class BlockTransition:
    time: float
    gpu_sec: float
    branches: Tuple[BranchPlan, ...]


def _plan_branch(
    branch: Sequence,
    scales: Sequence[int],
    amp_limit: float,
    hw: Hardware,
    entry_scale: int,
    exit_scale: int,
    entry_act_bytes: float,
) -> Tuple[float, float, int]:
    """Best (time, gpu_sec, peak_gpus) through one branch with pinned
    entry/exit scales (exit reshard included)."""
    from repro.core.costmodel import comm_time
    from repro.core.planner import _backtrace, _layer_cost, search_linear

    res = search_linear(
        branch, scales, amp_limit, hw, entry_scale=entry_scale,
        entry_act_bytes=entry_act_bytes,
    )
    L = len(res.layers)
    best = (INF, 0.0, entry_scale)
    for g in scales:
        t = res.S[L - 1][g] + comm_time(res.layers[-1].act_bytes, g, exit_scale, hw)
        if t < best[0]:
            best = (t, g, g)
    t_best, g_final, _ = best
    gs = _backtrace(res, g_final)
    gpu_sec = 0.0
    for i, (layer, g) in enumerate(zip(res.layers, gs)):
        h = gs[i - 1] if i > 0 else entry_scale
        gpu_sec += (res.trans[i](h, g) + _layer_cost(layer, g)) * g
    gpu_sec += comm_time(res.layers[-1].act_bytes, g_final, exit_scale, hw) * g_final
    return t_best, gpu_sec, max(gs)


def _single_gpu_time(els) -> float:
    t = 0.0
    for el in els:
        if isinstance(el, CostedLayer):
            t += el.comp1
        else:
            for br in el.branches:
                t += _single_gpu_time(br)
    return t


def block_transition(
    block: CostedBlock,
    g_in: int,
    g_out: int,
    scales: Sequence[int],
    amp_limit: float,
    hw: Hardware,
    entry_act_bytes: float,
) -> BlockTransition:
    plans = [
        _plan_branch(br, scales, amp_limit, hw, g_in, g_out, entry_act_bytes)
        for br in block.branches
    ]
    order = sorted(range(len(plans)), key=lambda i: -plans[i][0])
    crit = order[0]
    total_time = plans[crit][0]
    comp1 = max(_single_gpu_time([block]), 1e-30)
    gpu_sec = plans[crit][1]
    decided: List[BranchPlan] = [None] * len(plans)  # type: ignore
    decided[crit] = BranchPlan(*plans[crit][:3], parallel=False)
    for i in order[1:]:
        t_i, gs_i, peak_i = plans[i]
        # parallel = needs disjoint devices: extra gpu-sec but no extra time;
        # allowed iff amp stays under the limit and it doesn't extend the block
        amp_if_parallel = (gpu_sec + gs_i) / comp1
        run_parallel = (t_i <= total_time) and (amp_if_parallel <= amp_limit)
        if run_parallel:
            gpu_sec += gs_i
        else:
            total_time += t_i
            gpu_sec += gs_i
        decided[i] = BranchPlan(t_i, gs_i, peak_i, parallel=run_parallel)
    return BlockTransition(time=total_time, gpu_sec=gpu_sec, branches=tuple(decided))


def block_transition_table(
    block: CostedBlock,
    scales: Sequence[int],
    amp_limit: float,
    hw: Hardware,
    entry_act_bytes: float,
) -> Dict[Tuple[int, int], Tuple[float, float]]:
    """(g_in, g_out) -> (time, gpu_sec). Memoized per (block, params)."""
    key = (id(block), tuple(scales), amp_limit, id(hw), entry_act_bytes)
    cached = _TABLE_CACHE.get(key)
    if cached is not None:
        return cached
    table = {}
    for g in scales:
        for h in scales:
            bt = block_transition(block, g, h, scales, amp_limit, hw, entry_act_bytes)
            table[(g, h)] = (bt.time, bt.gpu_sec)
    _TABLE_CACHE[key] = table
    return table


_TABLE_CACHE: Dict = {}
