"""Multi-chain graph reduction (paper §4.2, Fig 7).

A branch/join region (``CostedBlock``) is reduced to a single transition
edge: for every (branching-scale g, joining-scale h) pair we plan each branch
with its entry pinned to g and exit resharded to h, find the critical
branch, and decide per non-critical branch whether it runs *in parallel* on
disjoint devices (doesn't extend the block) or *sequentially* (reuses the
critical branch's devices) — parallel only when it neither increases total
time nor overshoots the amplification limit, per the paper.

Two implementations of the same reduction:

``block_transition`` / ``block_transition_table``
    The original per-(g, h) formulation: one pure-Python entry-pinned search
    per branch per (g, h) cell — O(S²) searches per branch.  Consumed by
    ``planner.search_linear_reference`` (the differential-test oracle).

``block_transition_matrix``
    Vectorized: each branch is planned *once* by the matrix DP with every
    entry scale pinned (the E axis of ``planner._search_vec``), the exit
    reshard is folded in as an S×S min over final scales, and the
    critical/parallel decisions run as stable-argsort + masked updates over
    the whole (g_in, g_out) grid at once.  Produces the block's S×S time /
    gpu-sec matrices plus per-branch paths — enough to also emit genuine
    branch-parallel *placements* (``block_placements``): the critical branch
    on devices [0, peak), parallel branches stacked onto disjoint device
    ranges above it (the block's GapWindow of idle devices), sequential
    branches reusing the critical range.

Both paths produce bit-identical (time, gpu_sec) tables; the differential
suite (tests/test_planner_diff.py) pins this.
"""
from __future__ import annotations

import weakref
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.costmodel import Hardware, comm_matrix
from repro.core.profiler import CostedBlock, CostedLayer

INF = float("inf")


@dataclass(frozen=True)
class BranchPlan:
    time: float
    gpu_sec: float
    peak_gpus: int
    parallel: bool  # runs concurrently with the critical branch?


@dataclass(frozen=True)
class BlockTransition:
    time: float
    gpu_sec: float
    branches: Tuple[BranchPlan, ...]
    critical: int = 0  # index of the critical (longest) branch


def _plan_branch(
    branch: Sequence,
    scales: Sequence[int],
    amp_limit: float,
    hw: Hardware,
    entry_scale: int,
    exit_scale: int,
    entry_act_bytes: float,
) -> Tuple[float, float, int]:
    """Best (time, gpu_sec, peak_gpus) through one branch with pinned
    entry/exit scales (exit reshard included).  Reference path."""
    from repro.core.costmodel import comm_time
    from repro.core.planner import _backtrace, _layer_cost, search_linear_reference

    res = search_linear_reference(
        branch, scales, amp_limit, hw, entry_scale=entry_scale,
        entry_act_bytes=entry_act_bytes,
    )
    L = len(res.layers)
    best = (INF, 0.0, entry_scale)
    for g in scales:
        t = res.S[L - 1][g] + comm_time(res.layers[-1].act_bytes, g, exit_scale, hw)
        if t < best[0]:
            best = (t, g, g)
    t_best, g_final, _ = best
    gs = _backtrace(res, g_final)
    gpu_sec = 0.0
    for i, (layer, g) in enumerate(zip(res.layers, gs)):
        h = gs[i - 1] if i > 0 else entry_scale
        gpu_sec += (res.trans[i](h, g) + _layer_cost(layer, g)) * g
    gpu_sec += comm_time(res.layers[-1].act_bytes, g_final, exit_scale, hw) * g_final
    return t_best, gpu_sec, max(gs)


def _single_gpu_time(els) -> float:
    t = 0.0
    for el in els:
        if isinstance(el, CostedLayer):
            t += el.comp1
        else:
            for br in el.branches:
                t += _single_gpu_time(br)
    return t


def block_transition(
    block: CostedBlock,
    g_in: int,
    g_out: int,
    scales: Sequence[int],
    amp_limit: float,
    hw: Hardware,
    entry_act_bytes: float,
) -> BlockTransition:
    plans = [
        _plan_branch(br, scales, amp_limit, hw, g_in, g_out, entry_act_bytes)
        for br in block.branches
    ]
    order = sorted(range(len(plans)), key=lambda i: -plans[i][0])
    crit = order[0]
    total_time = plans[crit][0]
    comp1 = max(_single_gpu_time([block]), 1e-30)
    gpu_sec = plans[crit][1]
    decided: List[BranchPlan] = [None] * len(plans)  # type: ignore
    decided[crit] = BranchPlan(*plans[crit][:3], parallel=False)
    for i in order[1:]:
        t_i, gs_i, peak_i = plans[i]
        # parallel = needs disjoint devices: extra gpu-sec but no extra time;
        # allowed iff amp stays under the limit and it doesn't extend the block
        amp_if_parallel = (gpu_sec + gs_i) / comp1
        run_parallel = (t_i <= total_time) and (amp_if_parallel <= amp_limit)
        if run_parallel:
            gpu_sec += gs_i
        else:
            total_time += t_i
            gpu_sec += gs_i
        decided[i] = BranchPlan(t_i, gs_i, peak_i, parallel=run_parallel)
    return BlockTransition(
        time=total_time, gpu_sec=gpu_sec, branches=tuple(decided), critical=crit
    )


def block_transition_table(
    block: CostedBlock,
    scales: Sequence[int],
    amp_limit: float,
    hw: Hardware,
    entry_act_bytes: float,
) -> Dict[Tuple[int, int], Tuple[float, float]]:
    """(g_in, g_out) -> (time, gpu_sec). Memoized per (block, params)."""
    key = (id(block), tuple(scales), amp_limit, hw, entry_act_bytes)
    cached = _TABLE_CACHE.get(key)
    if cached is not None:
        return cached
    table = {}
    for g in scales:
        for h in scales:
            bt = block_transition(block, g, h, scales, amp_limit, hw, entry_act_bytes)
            table[(g, h)] = (bt.time, bt.gpu_sec)
    _cache_put(_TABLE_CACHE, key, block, table)
    return table


_TABLE_CACHE: Dict = {}


def _cache_put(cache: Dict, key, block, value) -> None:
    """Memoize keyed by id(block): evict on the block's GC so a recycled id
    can't alias a stale entry, and so long-lived replanning processes don't
    grow the cache without bound."""
    cache[key] = value
    weakref.finalize(block, cache.pop, key, None)


# ---------------------------------------------------------------------------
# Vectorized reduction: whole (g_in, g_out) grid in one matrix DP per branch
# ---------------------------------------------------------------------------


@dataclass
class BlockMatrix:
    """Vectorized block reduction over the full (g_in, g_out) grid.

    All arrays are indexed [g_in, g_out] (scale indices); the branch axis
    where present is leading.  ``branch_paths[b]`` is (L_b, S, S): the
    backtraced per-layer scale index of branch b's top-level chain for every
    grid cell.
    """

    time: np.ndarray             # (S, S) block transition time
    gpu_sec: np.ndarray          # (S, S) block gpu-seconds
    branch_times: np.ndarray     # (nb, S, S)
    branch_gsecs: np.ndarray     # (nb, S, S)
    branch_peaks: np.ndarray     # (nb, S, S) int peak devices
    branch_parallel: np.ndarray  # (nb, S, S) bool
    critical: np.ndarray         # (S, S) int critical branch index
    branch_paths: List[np.ndarray]
    branch_layers: List[list]    # per branch: its top-level CostedLayers


def _branch_matrix(branch, scales, amp_limit, hw, entry_act_bytes):
    """One branch, every (entry, exit) pair at once: (time, gpu_sec, peak,
    paths) arrays of shape (S, S) / (L, S, S)."""
    from repro.core.planner import _backtrace_grid, _search_vec

    res = _search_vec(
        branch, scales, amp_limit, hw, entry="all", entry_act_bytes=entry_act_bytes
    )
    n = len(scales)
    L = len(res.layers)
    scales_f = np.asarray(scales, dtype=np.float64)
    c_exit = comm_matrix(res.layers[-1].act_bytes, scales, scales, hw)  # (g, h)
    tot = res.S[:, -1, :, None] + c_exit[None, :, :]                    # (e, g, h)
    g_final = np.argmin(tot, axis=1)                                    # (e, h)
    t_best = np.take_along_axis(tot, g_final[:, None, :], axis=1)[:, 0, :]
    paths = _backtrace_grid(res.P, g_final)                             # (L, e, h)

    erange = np.arange(n)[:, None]
    hrange = np.arange(n)[None, :]
    gpu_sec = np.zeros((n, n))
    for i in range(L):
        gi = paths[i]
        if i == 0:
            tr = res.edge_mats[0][erange, gi]
        else:
            tr = res.edge_mats[i][paths[i - 1], gi]
        gpu_sec += (tr + res.lc[i][gi]) * scales_f[gi]
    gfin = paths[-1]
    gpu_sec += c_exit[gfin, hrange] * scales_f[gfin]
    peak = np.asarray(scales)[paths].max(axis=0)
    return t_best, gpu_sec, peak, paths, res.layers


def block_transition_matrix(
    block: CostedBlock,
    scales: Sequence[int],
    amp_limit: float,
    hw: Hardware,
    entry_act_bytes: float,
) -> BlockMatrix:
    """Vectorized ``block_transition_table``: the full S×S grid at once,
    bit-identical to the reference per-cell reduction.  Memoized."""
    key = (id(block), tuple(scales), amp_limit, hw, entry_act_bytes)
    cached = _MATRIX_CACHE.get(key)
    if cached is not None:
        return cached
    nb = len(block.branches)
    n = len(scales)
    times = np.empty((nb, n, n))
    gsecs = np.empty((nb, n, n))
    peaks = np.empty((nb, n, n), dtype=np.int64)
    paths: List[np.ndarray] = []
    blayers: List[list] = []
    for b, br in enumerate(block.branches):
        t, gs, pk, pth, lyrs = _branch_matrix(br, scales, amp_limit, hw, entry_act_bytes)
        times[b], gsecs[b], peaks[b] = t, gs, pk
        paths.append(pth)
        blayers.append(lyrs)

    # Critical branch + parallel/sequential decisions, every cell at once.
    # Stable argsort on -time == the reference's `sorted(key=-time)`.
    order = np.argsort(-times, axis=0, kind="stable")
    crit = order[0]
    total = np.take_along_axis(times, crit[None], axis=0)[0]
    gpu_sec = np.take_along_axis(gsecs, crit[None], axis=0)[0]
    comp1 = max(_single_gpu_time([block]), 1e-30)
    par = np.zeros((nb, n, n), dtype=bool)
    for r in range(1, nb):
        idx = order[r]
        t_i = np.take_along_axis(times, idx[None], axis=0)[0]
        gs_i = np.take_along_axis(gsecs, idx[None], axis=0)[0]
        run_par = (t_i <= total) & ((gpu_sec + gs_i) / comp1 <= amp_limit)
        np.put_along_axis(par, idx[None], run_par[None], axis=0)
        gpu_sec = gpu_sec + gs_i
        total = np.where(run_par, total, total + t_i)

    bm = BlockMatrix(
        time=total, gpu_sec=gpu_sec, branch_times=times, branch_gsecs=gsecs,
        branch_peaks=peaks, branch_parallel=par, critical=crit,
        branch_paths=paths, branch_layers=blayers,
    )
    _cache_put(_MATRIX_CACHE, key, block, bm)
    return bm


_MATRIX_CACHE: Dict = {}


def block_placements(
    block: CostedBlock,
    g_in_idx: int,
    g_out_idx: int,
    scales: Sequence[int],
    amp_limit: float,
    hw: Hardware,
    entry_act_bytes: float,
    num_gpus: int,
    layer_index: int = -1,
) -> tuple:
    """Per-branch device-range assignment for the chosen (g_in, g_out) cell.

    The critical branch runs on devices [0, peak).  Branches decided
    *parallel* by the reduction stack onto disjoint ranges above it — the
    idle devices of the block's GapWindow — for as long as they fit inside
    the ``num_gpus`` machine; a parallel-decided branch that no longer fits
    is demoted to time-multiplexing the critical range (the DP's amp
    accounting admits more concurrency than the device count can host).
    ``BranchPlacement.parallel`` therefore reports *placed-on-disjoint-
    devices*; the reduction's raw decision stays in
    ``BlockMatrix.branch_parallel``.  Paths cover each branch's top-level
    chain (nested blocks stay folded into their edge).  ``layer_index`` tags
    each placement with the plan layer whose ``comm_in`` folds this block,
    so the multiplexer can exclude branch device windows per-stage instead
    of for the whole iteration.
    """
    from repro.core.plan import BranchPlacement

    bm = block_transition_matrix(block, scales, amp_limit, hw, entry_act_bytes)
    nb = len(block.branches)
    crit = int(bm.critical[g_in_idx, g_out_idx])
    offset = int(bm.branch_peaks[crit, g_in_idx, g_out_idx])
    out = []
    for b in range(nb):
        peak = int(bm.branch_peaks[b, g_in_idx, g_out_idx])
        parallel = bool(bm.branch_parallel[b, g_in_idx, g_out_idx])
        path = tuple(
            int(scales[int(bm.branch_paths[b][i][g_in_idx, g_out_idx])])
            for i in range(bm.branch_paths[b].shape[0])
        )
        demoted = False
        if b == crit:
            start, end = 0, peak
        elif parallel and offset + peak <= num_gpus:
            start, end = offset, offset + peak
            offset += peak
        else:
            # decided parallel but the gap window is full: demote to
            # time-multiplexing the critical range, and flag it — the block
            # transition time consumed by the DP assumed this branch was free
            demoted = parallel
            parallel = False
            start, end = 0, peak
        out.append(
            BranchPlacement(
                block=block.name, branch=b, critical=(b == crit),
                parallel=parallel,
                time=float(bm.branch_times[b, g_in_idx, g_out_idx]),
                gpus=peak, device_start=start, device_end=end, scales=path,
                demoted=demoted, layer_index=layer_index,
            )
        )
    return tuple(out)
