"""Optimizers + LR schedules (self-contained; optax is not available).

AdamW for the small/medium archs; Adafactor (factored second moments — the
PaLM/T5 TPU-production choice) for the 72B/314B configs where Adam's fp32
state would not fit a single pod (DESIGN.md §5).  Schedules include minicpm's
WSD (warmup-stable-decay).

Optimizer state mirrors parameter sharding: state_axes() maps each state
leaf to logical axes derived from the param schema so dist.sharding can
shard m/v/factored stats exactly like the weights.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, is_spec


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------


def wsd_schedule(peak_lr: float, warmup: int, stable: int, decay: int,
                 floor_frac: float = 0.1) -> Callable:
    """MiniCPM's warmup-stable-decay [arXiv:2404.06395]."""

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        in_decay = jnp.clip((step - warmup - stable) / jnp.maximum(decay, 1), 0.0, 1.0)
        dec = peak_lr * (1.0 - (1.0 - floor_frac) * in_decay)
        return jnp.where(step < warmup + stable, warm, dec)

    return lr


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(math.pi * t)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def constant_schedule(lr_val: float) -> Callable:
    return lambda step: jnp.asarray(lr_val, jnp.float32)


# ---------------------------------------------------------------------------
# Optimizer interface
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Optimizer:
    init: Callable  # params -> opt_state
    update: Callable  # (grads, opt_state, params) -> (new_params, new_opt_state)
    state_schema: Callable  # param schema -> opt-state schema (ParamSpec tree)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(
    lr: Callable,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1.0e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        lr_t = lr(cf)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m / (1 - b1 ** cf)
            vhat = v / (1 - b2 ** cf)
            step = mhat / (jnp.sqrt(vhat) + eps)
            decay = weight_decay * p.astype(jnp.float32) * (p.ndim >= 2)
            new_p = p.astype(jnp.float32) - lr_t * (step + decay)
            return new_p.astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        res = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = tdef.unflatten([r[0] for r in res])
        new_m = tdef.unflatten([r[1] for r in res])
        new_v = tdef.unflatten([r[2] for r in res])
        return new_p, {"m": new_m, "v": new_v, "count": count}

    def state_schema(schema):
        moment = lambda s: ParamSpec(s.shape, s.axes, init="zeros", dtype="float32")
        return {
            "m": jax.tree.map(moment, schema, is_leaf=is_spec),
            "v": jax.tree.map(moment, schema, is_leaf=is_spec),
            "count": ParamSpec((), (), init="zeros", dtype="int32"),
        }

    return Optimizer(init, update, state_schema)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments over the trailing two dims)
# ---------------------------------------------------------------------------


def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 2 and p.shape[-2] >= 2


def adafactor(
    lr: Callable,
    decay: float = 0.8,
    eps: float = 1.0e-30,
    clip_threshold: float = 1.0,
    max_grad_norm: float = 1.0,
) -> Optimizer:
    def init(params):
        def st(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

        return {
            "stats": jax.tree.map(st, params, is_leaf=lambda x: hasattr(x, "ndim")),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        beta = 1.0 - cf ** (-decay)
        lr_t = lr(cf)

        def upd(g, st, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                vr = beta * st["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * st["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                u = g / (jnp.sqrt(rfac)[..., None] * jnp.sqrt(vc)[..., None, :] + eps)
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                u = g / (jnp.sqrt(v) + eps)
                new_st = {"v": v}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            new_p = p.astype(jnp.float32) - lr_t * u
            return new_p.astype(p.dtype), new_st

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["stats"])
        res = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = tdef.unflatten([r[0] for r in res])
        new_s = tdef.unflatten([r[1] for r in res])
        return new_p, {"stats": new_s, "count": count}

    def state_schema(schema):
        def st(s):
            if len(s.shape) >= 2 and s.shape[-1] >= 2 and s.shape[-2] >= 2:
                return {
                    "vr": ParamSpec(s.shape[:-1], s.axes[:-1], init="zeros", dtype="float32"),
                    "vc": ParamSpec(s.shape[:-2] + s.shape[-1:], s.axes[:-2] + s.axes[-1:],
                                    init="zeros", dtype="float32"),
                }
            return {"v": ParamSpec(s.shape, s.axes, init="zeros", dtype="float32")}

        return {
            "stats": jax.tree.map(st, schema, is_leaf=is_spec),
            "count": ParamSpec((), (), init="zeros", dtype="int32"),
        }

    return Optimizer(init, update, state_schema)


def make_optimizer(cfg, total_steps: int = 10_000) -> Optimizer:
    if cfg.name.startswith("minicpm"):
        sched = wsd_schedule(1e-3 * 0.3, warmup=int(0.01 * total_steps),
                             stable=int(0.79 * total_steps), decay=int(0.2 * total_steps))
    else:
        sched = cosine_schedule(3e-4, warmup=min(2000, total_steps // 10), total=total_steps)
    if cfg.optimizer == "adafactor":
        return adafactor(sched)
    return adamw(sched)
