"""PowerSGD gradient compression with error feedback [Vogels et al. 2019].

Distributed-optimization trick for the DP gradient all-reduce: each 2-D
gradient G (m×n) is compressed to rank-r factors P (m×r), Q (n×r); only P/Q
are all-reduced (r·(m+n) ≪ m·n), and the compression error is fed back into
the next step's gradient (error feedback keeps SGD convergent).

Two entry points:
  - ``powersgd_allreduce``: inside shard_map over the DP axis (the explicit
    collective path — per-shard gradients in, synchronized decompressed
    gradients out);
  - ``compress_decompress``: the pjit path used by the train step factory —
    under GSPMD the mean-reduction is implicit, so this transforms the
    gradient to its low-rank approximation + error feedback, modelling the
    bandwidth reduction while staying semantically a gradient transform.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def _orthonormalize(m: jax.Array) -> jax.Array:
    """Gram-Schmidt via QR (columns)."""
    q, _ = jnp.linalg.qr(m.astype(jnp.float32))
    return q


def _as_matrix(g: jax.Array) -> Tuple[jax.Array, tuple]:
    shape = g.shape
    if g.ndim == 1:
        return g.reshape(1, -1), shape
    return g.reshape(-1, shape[-1]), shape


def init_state(params: Any, rank: int = 4, seed: int = 0) -> Dict[str, Any]:
    """Q factors + error-feedback buffers, matching param structure."""
    flat, treedef = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(flat))

    def one(p, k):
        m2, _ = _as_matrix(jnp.zeros_like(p))
        q = jax.random.normal(k, (m2.shape[1], rank), jnp.float32)
        return {"q": q, "err": jnp.zeros_like(p, dtype=jnp.float32)}

    return jax.tree.unflatten(treedef, [one(p, k) for p, k in zip(flat, keys)])


def compress_decompress(
    grads: Any, state: Any, rank: int = 4, psum_axis: str = ""
) -> Tuple[Any, Any]:
    """One PowerSGD round per leaf. With `psum_axis` (inside shard_map) the
    P/Q factors are all-reduced over that axis; otherwise local (pjit mode).
    Returns (approx_grads, new_state)."""

    def one(g, st):
        gf = g.astype(jnp.float32) + st["err"]
        m2, shape = _as_matrix(gf)
        if min(m2.shape) <= rank:  # tiny leaves: exact
            if psum_axis:
                exact = jax.lax.pmean(gf, psum_axis)
            else:
                exact = gf
            return exact.astype(g.dtype), {"q": st["q"], "err": jnp.zeros_like(st["err"])}
        p = m2 @ st["q"]  # (m, r)
        if psum_axis:
            p = jax.lax.pmean(p, psum_axis)
        p = _orthonormalize(p)
        q = m2.T @ p  # (n, r)
        if psum_axis:
            q = jax.lax.pmean(q, psum_axis)
        approx = (p @ q.T).reshape(shape)
        err = gf - approx
        return approx.astype(g.dtype), {"q": q, "err": err}

    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(state)
    outs = [one(g, s) for g, s in zip(flat_g, flat_s)]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in outs]),
        jax.tree.unflatten(treedef, [o[1] for o in outs]),
    )


def powersgd_allreduce(grads: Any, state: Any, axis: str, rank: int = 4):
    """shard_map entry point: per-shard grads -> synchronized approx grads."""
    return compress_decompress(grads, state, rank=rank, psum_axis=axis)


def compression_ratio(params: Any, rank: int = 4) -> float:
    """Bytes over the wire vs dense all-reduce."""
    dense = 0
    comp = 0
    for p in jax.tree.leaves(params):
        m2, _ = _as_matrix(jnp.zeros(p.shape, jnp.int8))
        m, n = m2.shape
        dense += m * n
        comp += (m + n) * rank if min(m, n) > rank else m * n
    return comp / max(dense, 1)
