"""qwen2-72b [dense] — GQA, QKV bias.

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 [arXiv:2407.10671; hf]

kv=8 heads are NOT divisible by the 16-way model axis → baseline replicates
KV projections over 'model' (kv_tp=False); fixing this is a §Perf hillclimb
target. Uses Adafactor (72B params × Adam fp32 would be 1TB+grad; Adafactor
is the PaLM/T5 TPU-production choice).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2-72b",
        family="dense",
        block_type="attn_mlp",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_head=128,
        d_ff=29568,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1.0e6,
        attn_tp=True,    # 64 / 16 = 4
        kv_tp=False,     # 8 kv heads < 16-way model axis → replicate (baseline)
        optimizer="adafactor",
        supports_long_context=False,  # pure full attention → skip long_500k
    )
)
