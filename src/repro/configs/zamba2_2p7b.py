"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf]

Zamba2 runs a Mamba-2 backbone with ONE shared attention+MLP block invoked
every 6 layers (weights shared across invocations, input is
concat(hidden, original_embedding) → 2*d_model). long_500k is supported:
the SSM backbone is O(1)-state; the periodic shared attention block uses a
4096-token sliding window at that shape (config ``sliding_window``).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        block_type="mamba2",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        d_head=80,
        d_ff=10240,
        vocab_size=32000,
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_conv=4,
        attn_every=6,
        sliding_window=4096,
        rope_theta=1.0e4,
        tie_embeddings=True,
        attn_tp=True,   # 32 heads / 16-way model axis = 2
        kv_tp=True,
        supports_long_context=True,  # hybrid / state-space backbone
    )
)
