"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent decay.

24L d_model=2048 (attn-free) d_ff=7168 vocab=65536 [arXiv:2404.05892]

RWKV-6 time-mix heads: d_model / 64 = 32 heads of size 64. O(1) decode state
→ supports long_500k.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="rwkv6-1.6b",
        family="ssm",
        block_type="rwkv6",
        num_layers=24,
        d_model=2048,
        num_heads=32,     # wkv heads (d_model / 64)
        num_kv_heads=32,
        d_head=64,
        d_ff=7168,
        vocab_size=65536,
        attn_tp=True,  # 32 / 16 = 2
        kv_tp=True,
        supports_long_context=True,  # attention-free, O(1) state
    )
)
