"""grok-1-314b [moe] — 8 experts, top-2 routing.

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2
[hf:xai-org/grok-1]

8 experts < 16-way model axis → tensor-parallel experts (shard d_ff=32768
16-way inside each expert) instead of expert parallelism. Grok-1 applies a
30.0 attention-logit softcap. Adafactor: 314B × Adam fp32 state would not
fit a 256-chip v5e pod (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="grok-1-314b",
        family="moe",
        block_type="moe",
        num_layers=64,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_head=128,
        d_ff=32768,
        vocab_size=131072,
        num_experts=8,
        experts_per_tok=2,
        moe_d_ff=32768,
        moe_parallelism="tensor",  # 8 experts < 16-way axis
        attn_logit_softcap=30.0,
        rope_theta=1.0e4,
        attn_tp=True,  # 48 / 16 = 3
        kv_tp=False,
        optimizer="adafactor",
        supports_long_context=False,
    )
)
