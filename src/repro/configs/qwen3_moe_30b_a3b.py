"""qwen3-moe-30b-a3b [moe] — 128 experts, top-8 routing.

48L d_model=2048 32H (GQA kv=4) d_ff=768 vocab=151936, MoE 128e top-8
[hf:Qwen/Qwen3-30B-A3B]

128 experts shard 8-per-device over the 16-way model axis (expert
parallelism with capacity-based scatter dispatch). d_head=128 (attention dim
4096 != d_model 2048, per the HF config).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        block_type="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        d_head=128,
        d_ff=768,  # per-expert FFN width (moe_d_ff)
        vocab_size=151936,
        num_experts=128,
        experts_per_tok=8,
        moe_d_ff=768,
        moe_parallelism="expert",
        rope_theta=1.0e6,
        attn_tp=True,  # 32 / 16 = 2
        kv_tp=False,   # 4 kv heads < 16
        supports_long_context=False,
    )
)
