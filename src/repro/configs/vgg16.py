"""VGG-16 — the paper's own evaluation model (Simonyan & Zisserman 2015).

Used to validate the burst-parallel planner against the paper's claims
(Fig 1/3/5, Fig 9/10, Table 3). This is a CNN so it is described by a layer
list rather than ModelConfig; models/vgg.py consumes it. Input 3x224x224,
global batch = 32 for the strong-scaling experiments (paper Fig 9a).
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ConvSpec:
    name: str
    in_ch: int
    out_ch: int
    spatial: int       # input H=W
    kernel: int = 3
    pool_after: bool = False


@dataclass(frozen=True)
class DenseSpec:
    name: str
    in_dim: int
    out_dim: int


# Standard VGG-16: 13 conv + 3 dense (paper Table 1: 21 "layers" counts pools)
VGG16_LAYERS = (
    ConvSpec("conv1_1", 3, 64, 224),
    ConvSpec("conv1_2", 64, 64, 224, pool_after=True),
    ConvSpec("conv2_1", 64, 128, 112),
    ConvSpec("conv2_2", 128, 128, 112, pool_after=True),
    ConvSpec("conv3_1", 128, 256, 56),
    ConvSpec("conv3_2", 256, 256, 56),
    ConvSpec("conv3_3", 256, 256, 56, pool_after=True),
    ConvSpec("conv4_1", 256, 512, 28),
    ConvSpec("conv4_2", 512, 512, 28),
    ConvSpec("conv4_3", 512, 512, 28, pool_after=True),
    ConvSpec("conv5_1", 512, 512, 14),
    ConvSpec("conv5_2", 512, 512, 14),
    ConvSpec("conv5_3", 512, 512, 14, pool_after=True),
    DenseSpec("fc6", 512 * 7 * 7, 4096),
    DenseSpec("fc7", 4096, 4096),
    DenseSpec("fc8", 4096, 1000),
)


@dataclass(frozen=True)
class VGGConfig:
    name: str = "vgg16"
    layers: tuple = VGG16_LAYERS
    num_classes: int = 1000
    image_size: int = 224
    # paper Fig 9(a): strong scaling with global batch 32
    global_batch: int = 32


CONFIG = VGGConfig()
