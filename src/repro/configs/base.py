"""Configuration system for DeepPool-JAX.

Every architecture is a frozen ``ModelConfig``; every benchmark input shape is
a frozen ``ShapeConfig``.  Configs are pure data — they never touch jax device
state — so importing this package is always safe (dry-run sets XLA_FLAGS
before any jax import; smoke tests must see exactly 1 device).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Optional

VOCAB_PAD_MULTIPLE = 256


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ShapeConfig:
    """One benchmark input shape (assigned per-arch in the task spec)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The four LM shapes shared by all 10 assigned architectures.
TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``block_type`` selects the per-layer block implementation:
      - 'attn_mlp'  : pre-norm GQA attention + (GLU) MLP            (dense LMs)
      - 'moe'       : pre-norm GQA attention + top-k MoE FFN        (grok, qwen3)
      - 'mamba2'    : Mamba-2 SSD block (used by zamba2 backbone)
      - 'rwkv6'     : RWKV-6 time-mix + channel-mix

    ``family`` is informational (matches the assignment table).
    """

    name: str
    family: str  # dense|moe|hybrid|ssm|encdec|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    block_type: str = "attn_mlp"

    # encoder-decoder (seamless-m4t)
    num_encoder_layers: int = 0

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 1.0e4
    sliding_window: int = 0  # 0 == full causal; >0 == sliding-window attention
    attn_logit_softcap: float = 0.0

    # MoE
    num_experts: int = 0
    experts_per_tok: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_parallelism: str = "expert"  # 'expert' (EP all-to-all) | 'tensor' (TP d_ff)

    # SSM (Mamba-2) / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    attn_every: int = 0  # hybrid: shared attention block applied every k layers

    # embeddings / head
    tie_embeddings: bool = False
    norm_eps: float = 1.0e-5

    # parallelism hints (consumed by dist.sharding)
    attn_tp: bool = True       # False when num_heads is not divisible by model axis
    kv_tp: bool = True         # False when num_kv_heads is not divisible by model axis
    sequence_parallel: bool = False  # SP for norms/residual (hillclimb lever)

    # numerics / optimizer
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    optimizer: str = "adamw"   # 'adafactor' for the largest models
    remat_policy: str = "full"  # 'full'|'dots'|'none'

    # modality frontend stub ([vlm]/[audio] per assignment: backbone only)
    frontend: str = "none"  # 'none'|'vision'|'audio'

    # which benchmark shapes this arch supports (long_500k only for sub-quadratic)
    supports_long_context: bool = False

    # ----- derived -----
    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, VOCAB_PAD_MULTIPLE)

    @property
    def attn_dim(self) -> int:
        return self.num_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.d_head

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    def n_params(self) -> int:
        """Analytical parameter count (used by roofline MODEL_FLOPS and memory
        sanity checks; exact to within norm/bias epsilon)."""
        p = 0
        V, D = self.padded_vocab, self.d_model
        p += V * D  # token embedding
        if not self.tie_embeddings:
            p += V * D  # lm head
        layers = []
        if self.block_type in ("attn_mlp", "moe"):
            layers += [("decoder", self.num_layers)]
        elif self.block_type == "mamba2":
            layers += [("mamba", self.num_layers)]
        elif self.block_type == "rwkv6":
            layers += [("rwkv", self.num_layers)]
        if self.num_encoder_layers:
            layers += [("encoder", self.num_encoder_layers)]
        for kind, n in layers:
            per = 0
            if kind in ("decoder", "encoder"):
                per += D * self.attn_dim + 2 * D * self.kv_dim + self.attn_dim * D
                if kind == "decoder" and self.num_encoder_layers:
                    per += D * self.attn_dim + 2 * D * self.kv_dim + self.attn_dim * D  # cross-attn
                if self.is_moe:
                    per += self.num_experts * 3 * D * self.moe_d_ff
                    per += D * self.num_experts  # router
                else:
                    per += 3 * D * self.d_ff  # GLU (gate, up, down)
                per += 2 * D  # norms
            elif kind == "mamba":
                din, S, H = self.ssm_d_inner, self.ssm_state, self.ssm_heads
                per += D * 2 * din          # in_proj (x, z)
                per += din * (2 * S)        # B, C projections
                per += din * H // self.ssm_heads * H if False else din  # dt proj (per-head)
                per += self.ssm_conv * din  # depthwise conv
                per += din * D              # out proj
                per += 2 * D + H            # norms + A_log
            elif kind == "rwkv":
                per += 4 * D * D            # r,k,v,g (time mix)
                per += 2 * 64 * D           # data-dependent decay LoRA (rank 64)
                per += D * D                # output proj
                per += 2 * D * self.d_ff    # channel mix (k, v)
                per += D * D                # channel mix receptance
                per += 2 * D
            p += per * n
        if self.attn_every and self.block_type == "mamba2":
            # zamba2: ONE shared attention+MLP block (weights shared across uses)
            D2 = 2 * D  # zamba2 shared block consumes concat(hidden, residual)
            p += D2 * self.attn_dim + 2 * D2 * self.kv_dim + self.attn_dim * D
            p += 3 * D * self.d_ff
        return p

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests (1 device)."""

        def shrink(v, lo, factor):
            return max(lo, v // factor)

        kw: dict = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2),
            num_encoder_layers=min(self.num_encoder_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(max(1, self.num_kv_heads * 4 // max(1, self.num_heads)), 4),
            d_head=32,
            d_ff=256,
            vocab_size=512,
            rope_theta=self.rope_theta,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
        )
        if self.is_moe:
            kw.update(num_experts=4, experts_per_tok=min(2, self.experts_per_tok), moe_d_ff=64)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=32, ssm_conv=self.ssm_conv)
        if self.attn_every:
            kw.update(attn_every=2)
        return replace(self, **kw)


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import registers everything
        from repro import configs as _c  # noqa: F401

        if name not in _REGISTRY:
            raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    from repro import configs as _c  # noqa: F401

    return sorted(_REGISTRY)


def shapes_for(cfg: ModelConfig) -> list:
    """The assigned shape cells for this arch (skips noted in DESIGN.md)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context:
        out.append(LONG_500K)
    return out
