"""pixtral-12b [vlm] — pixtral-ViT frontend + mistral-nemo backbone.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409]

Per the assignment spec, the vision frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings (frontend='vision'); only the
transformer backbone is modeled. d_head=128 (mistral-nemo style: attention
dim 4096 != d_model 5120).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="pixtral-12b",
        family="vlm",
        block_type="attn_mlp",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        d_head=128,
        d_ff=14336,
        vocab_size=131072,
        rope_theta=1.0e6,
        attn_tp=True,
        kv_tp=False,
        frontend="vision",
        supports_long_context=False,
    )
)
