"""Config registry: one module per assigned architecture + the paper's own.

``--arch <id>`` anywhere in the framework resolves through ``get_config``.
"""
from repro.configs.base import (  # noqa: F401
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
    get_config,
    list_configs,
    register,
    shapes_for,
)

# importing each module registers its CONFIG
from repro.configs import (  # noqa: F401
    grok1_314b,
    llama3_8b,
    minicpm_2b,
    pixtral_12b,
    qwen2_1p5b,
    qwen2_72b,
    qwen3_moe_30b_a3b,
    rwkv6_1p6b,
    seamless_m4t_large_v2,
    zamba2_2p7b,
)
from repro.configs import vgg16  # noqa: F401  (paper's own model; CNN config)

ASSIGNED_ARCHS = (
    "zamba2-2.7b",
    "qwen2-72b",
    "minicpm-2b",
    "qwen2-1.5b",
    "llama3-8b",
    "pixtral-12b",
    "grok-1-314b",
    "qwen3-moe-30b-a3b",
    "seamless-m4t-large-v2",
    "rwkv6-1.6b",
)
