"""minicpm-2b [dense] — WSD schedule, llama-like arch.

40L d_model=2304 36H (GQA kv=36) d_ff=5760 vocab=122753 [arXiv:2404.06395; hf]

36 heads are not divisible by the 16-way model axis → attention weights are
replicated over 'model' at baseline (attn_tp=False); the MLP is TP-sharded
(5760 % 16 == 0). vocab 122753 is padded to 122880 (multiple of 256).
Trains with the paper's WSD (warmup-stable-decay) schedule.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="minicpm-2b",
        family="dense",
        block_type="attn_mlp",
        num_layers=40,
        d_model=2304,
        num_heads=36,
        num_kv_heads=36,
        d_head=64,
        d_ff=5760,
        vocab_size=122753,
        rope_theta=1.0e4,
        tie_embeddings=True,
        attn_tp=False,  # 36 % 16 != 0
        kv_tp=False,
        supports_long_context=False,
    )
)
