"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal.

24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206 [arXiv:2308.11596; hf]

Modeled as a 24L encoder + 24L decoder transformer backbone; the speech
frontend is a STUB per the assignment (``input_specs()`` provides precomputed
frame embeddings, frontend='audio'). It is enc-dec (NOT encoder-only), so
decode shapes apply: decode lowers the decoder step with cached encoder
output + decoder KV cache. vocab 256206 padded to 256256.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        block_type="attn_mlp",
        num_layers=24,
        num_encoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_head=64,
        d_ff=8192,
        vocab_size=256206,
        rope_theta=1.0e4,
        attn_tp=True,  # 16 / 16 = 1
        kv_tp=True,
        frontend="audio",
        supports_long_context=False,
    )
)
