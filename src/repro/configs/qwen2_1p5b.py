"""qwen2-1.5b [dense] — GQA, QKV bias.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936 [arXiv:2407.10671; hf]

12 q heads and 2 kv heads are not divisible by the 16-way model axis →
attention replicated over 'model' at baseline; MLP TP-sharded (8960 % 16==0).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2-1.5b",
        family="dense",
        block_type="attn_mlp",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        d_head=128,
        d_ff=8960,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=1.0e6,
        tie_embeddings=True,
        attn_tp=False,  # 12 % 16 != 0
        kv_tp=False,
        supports_long_context=False,
    )
)
