"""llama3-8b [dense] — GQA, 128k vocab.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256 [arXiv:2407.21783]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="llama3-8b",
        family="dense",
        block_type="attn_mlp",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_head=128,
        d_ff=14336,
        vocab_size=128256,
        rope_theta=5.0e5,
        attn_tp=True,   # 32 / 16 = 2
        kv_tp=False,    # 8 kv heads < 16
        supports_long_context=False,
    )
)
