"""Pallas TPU flash attention (blocked online-softmax, causal + GQA).

Target: TPU MXU — block shapes are multiples of 128 on the matmul dims; Q
tile stays resident in VMEM while K/V stream through the innermost grid
dimension; softmax statistics (m, l) and the output accumulator live in VMEM
scratch across K-block iterations.

Grid: (batch·q_heads, n_q_blocks, n_kv_blocks) with the last dim
'arbitrary' (sequential) so the scratch carry is legal.  GQA is expressed in
the K/V index_map (query head h reads kv head h // group).

Validated on CPU via interpret=True against kernels/ref.py (tests/).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1.0e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, block_q, block_k, causal,
    sm_scale, window,
):
    i = pl.program_id(1)  # q block
    j = pl.program_id(2)  # kv block
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = i * block_q
    k_start = j * block_k

    # skip fully-masked blocks (strictly above the causal diagonal)
    def compute():
        q = q_ref[0].astype(jnp.float32) * sm_scale  # (block_q, d)
        k = k_ref[0].astype(jnp.float32)  # (block_k, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (block_q, block_k)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    if causal:
        # block is live iff some k position <= some q position
        live = k_start <= q_start + block_q - 1
        pl.when(live)(compute)
    else:
        compute()

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, Sq, H, d)
    k: jax.Array,  # (B, Skv, KV, d)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, d = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv, block_q, block_k)
    sm_scale = 1.0 / math.sqrt(d)

    qh = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, d)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * KV, Skv, d)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * KV, Skv, d)

    grid = (B * H, Sq // block_q, Skv // block_k)

    def q_map(bh, i, j):
        return (bh, i, 0)

    def kv_map(bh, i, j):
        b, h = bh // H, bh % H
        return (b * KV + h // G, j, 0)

    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q,
        block_k=block_k,
        causal=causal,
        sm_scale=sm_scale,
        window=window,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qh, kh, vh)
    return jnp.moveaxis(out.reshape(B, H, Sq, d), 1, 2)
