"""jit'd dispatch wrappers over the Pallas kernels.

On TPU backends the Pallas kernels run natively; on CPU (this container, the
dry-run, CI) we dispatch to the XLA chunked/blocked formulations that the
kernels mirror (models/attention.py blocked path, models/mamba2.ssd_chunked,
models/rwkv6.wkv6_chunked).  ``force`` overrides for tests
('pallas_interpret' runs the kernel body in Python on CPU).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd
from repro.kernels import wkv6 as _wkv


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal=True, window=0, force: Optional[str] = None):
    if force == "pallas_interpret":
        return _fa.flash_attention(q, k, v, causal=causal, window=window, interpret=True)
    if force == "pallas" or (force is None and _on_tpu()):
        return _fa.flash_attention(q, k, v, causal=causal, window=window)
    from repro.models.attention import attend

    return attend(q, k, v, causal=causal, window=window)


def ssd(x, dt, A, Bm, Cm, *, chunk=128, force: Optional[str] = None):
    if force == "pallas_interpret":
        return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    if force == "pallas" or (force is None and _on_tpu()):
        return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    from repro.models.mamba2 import ssd_chunked

    y, _ = ssd_chunked(x, dt.astype(jnp.float32), A, Bm, Cm, chunk=chunk)
    return y


def wkv(r, k, v, w, u, *, chunk=64, force: Optional[str] = None):
    if force == "pallas_interpret":
        return _wkv.wkv6(r, k, v, w, u, chunk=chunk, interpret=True)
    if force == "pallas" or (force is None and _on_tpu()):
        return _wkv.wkv6(r, k, v, w, u, chunk=chunk)
    from repro.models.rwkv6 import wkv6_chunked

    o, _ = wkv6_chunked(r, k, v, w, u, chunk=chunk)
    return o
