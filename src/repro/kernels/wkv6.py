"""Pallas TPU kernel for the RWKV-6 WKV recurrence (chunked parallel form).

Same chunking structure as the SSD kernel: per-(batch, head) the sequential
chunk dimension is the innermost grid axis; the (K, V) state matrix is a
VMEM scratch carried across chunks; intra-chunk work is dense MXU matmuls
with per-channel data-dependent decays.

Layouts (chunk L, key dim K, value dim V):
  r/k/w (B, nc, L, H, K)   v (B, nc, L, H, V)   u (H, K)
  o     (B, nc, L, H, V)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _wkv6_kernel(u_ref, r_ref, k_ref, v_ref, w_ref, o_ref, state_ref, *, chunk):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0, 0, :, 0].astype(jnp.float32)  # (L, K)
    k = k_ref[0, 0, :, 0].astype(jnp.float32)
    v = v_ref[0, 0, :, 0].astype(jnp.float32)  # (L, V)
    w = w_ref[0, 0, :, 0].astype(jnp.float32)  # (L, K) decays in (0,1)
    u = u_ref[0].astype(jnp.float32)  # (K,)

    lw = jnp.log(jnp.clip(w, 1e-6, 1.0))
    cs = jnp.cumsum(lw, axis=0)  # (L, K) inclusive

    r_dec = r * jnp.exp(cs - lw)  # r_t ⊙ exp(cs_{t-1})
    k_dec = k * jnp.exp(-cs)  # k_j ⊙ exp(-cs_j)

    A = jax.lax.dot_general(
        r_dec, k_dec, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, L)
    strict = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        > jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    A = jnp.where(strict, A, 0.0)
    diag = jnp.sum(r * u[None, :] * k, axis=1)  # (L,)

    o = jax.lax.dot_general(
        A, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o = o + diag[:, None] * v
    # inter-chunk: o += (r ⊙ exp(cs_{t-1})) · state   (state: (K, V))
    o = o + jax.lax.dot_general(
        r_dec, state_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[0, 0, :, 0] = o.astype(o_ref.dtype)

    # state' = diag(exp(cs_L)) state + (k ⊙ exp(cs_L - cs))^T v
    k_tail = k * jnp.exp(cs[-1][None, :] - cs)
    state_ref[...] = state_ref[...] * jnp.exp(cs[-1])[:, None] + jax.lax.dot_general(
        k_tail, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(
    r: jax.Array,  # (B, S, H, K)
    k: jax.Array,
    v: jax.Array,  # (B, S, H, V)
    w: jax.Array,  # (B, S, H, K) decays in (0,1)
    u: jax.Array,  # (H, K)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, K = r.shape
    V = v.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    def re(a, last):
        return a.reshape(B, nc, chunk, H, last)

    grid = (B, H, nc)
    io_spec = lambda last: pl.BlockSpec(
        (1, 1, chunk, 1, last), lambda b, h, c: (b, c, 0, h, 0)
    )
    out = pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, K), lambda b, h, c: (h, 0)),
            io_spec(K),
            io_spec(K),
            io_spec(V),
            io_spec(K),
        ],
        out_specs=io_spec(V),
        out_shape=jax.ShapeDtypeStruct((B, nc, chunk, H, V), r.dtype),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(u, re(r, K), re(k, K), re(v, V), re(w, K))
    return out.reshape(B, S, H, V)
