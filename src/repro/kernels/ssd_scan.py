"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

One grid step processes one (batch, head, chunk) cell: the intra-chunk
decay-weighted attention-like matmuls run on the MXU, while the inter-chunk
state recurrence is carried in a VMEM scratch accumulator across the
sequential chunk dimension (innermost grid dim, 'arbitrary' semantics).
This is the TPU-native replacement for the paper-era CUDA selective scan:
chunking converts the sequential recurrence into dense matmuls
(DESIGN.md §2, §6).

Layouts (chunk L, head dim P, state N — L,P multiples of 8/128 as needed):
  x  (B, nc, L, H, P)   dt (B, nc, L, H)   A (H,)
  Bm (B, nc, L, N)      Cm (B, nc, L, N)
  y  (B, nc, L, H, P)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_ref, *, chunk):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    A = a_ref[0]  # scalar decay rate for this head
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)  # (L,)
    x = x_ref[0, 0, :, 0].astype(jnp.float32)  # (L, P)
    Bm = b_ref[0, 0].astype(jnp.float32)  # (L, N)
    Cm = c_ref[0, 0].astype(jnp.float32)  # (L, N)

    dA = dt * A  # (L,) log-decay per step
    cum = jnp.cumsum(dA)  # inclusive
    xb = x * dt[:, None]

    # intra-chunk: Y = (C B^T ⊙ L) X̄ ; L[i,j] = exp(cum_i - cum_j), j <= i
    seg = cum[:, None] - cum[None, :]
    mask = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    L = jnp.where(mask, jnp.exp(seg), 0.0)
    CB = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    y = jax.lax.dot_general(
        CB * L, xb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # off-diagonal: Y += exp(cum) ⊙ (C · state)   (state: (N, P))
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, state_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y_ref[0, 0, :, 0] = y.astype(y_ref.dtype)

    # state update: S' = exp(cum_L) S + (B ⊙ exp(cum_L - cum))^T X̄
    decay_to_end = jnp.exp(cum[-1] - cum)
    state_ref[...] = state_ref[...] * jnp.exp(cum[-1]) + jax.lax.dot_general(
        Bm * decay_to_end[:, None], xb, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) fp32 step sizes
    A: jax.Array,  # (H,) negative decay rates
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    xr = x.reshape(B, nc, chunk, H, P)
    dtr = dt.reshape(B, nc, chunk, H)
    br = Bm.reshape(B, nc, chunk, N)
    cr = Cm.reshape(B, nc, chunk, N)

    grid = (B, H, nc)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, chunk, 1, P), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, c: (b, c, 0, h)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, 1, P), lambda b, h, c: (b, c, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nc, chunk, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(A.astype(jnp.float32), xr, dtr, br, cr)
    return out.reshape(B, S, H, P)
