"""jax version compat for pallas TPU kernels.

jax < 0.5 names the TPU compiler-params struct ``TPUCompilerParams``;
newer releases renamed it ``CompilerParams``.  All kernels import the
alias from here so the next rename is a one-line fix.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
