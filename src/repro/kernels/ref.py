"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

These are deliberately naive — full softmax materialization, per-timestep
sequential recurrences — so the tests compare two *independent*
formulations (naive vs chunked/blocked).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_reference(
    q: jax.Array,  # (B, Sq, H, d)
    k: jax.Array,  # (B, Skv, KV, d)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    B, Sq, H, d = q.shape
    KV = k.shape[2]
    G = H // KV
    kr = jnp.repeat(k, G, axis=2).astype(jnp.float32)
    vr = jnp.repeat(v, G, axis=2).astype(jnp.float32)
    qf = q.astype(jnp.float32) / math.sqrt(d)
    s = jnp.einsum("bshd,bthd->bhst", qf, kr)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(k.shape[1])[None, :]
    ok = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", p, vr)
    return o.astype(q.dtype)


def ssd_reference(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
) -> jax.Array:
    """Sequential SSM recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32
    xb = x.astype(f32) * dt.astype(f32)[..., None]  # (B,S,H,P)
    dec = jnp.exp(dt.astype(f32) * A.astype(f32)[None, None, :])  # (B,S,H)

    def step(h, inp):
        xb_t, dec_t, b_t, c_t = inp  # (B,H,P), (B,H), (B,N), (B,N)
        h = h * dec_t[..., None, None] + jnp.einsum("bhp,bn->bhpn", xb_t, b_t)
        y = jnp.einsum("bhpn,bn->bhp", h, c_t)
        return h, y

    h0 = jnp.zeros((B, H, P, N), f32)
    xs = (
        jnp.moveaxis(xb, 1, 0),
        jnp.moveaxis(dec, 1, 0),
        jnp.moveaxis(Bm.astype(f32), 1, 0),
        jnp.moveaxis(Cm.astype(f32), 1, 0),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def wkv6_reference(
    r: jax.Array,  # (B, S, H, K)
    k: jax.Array,
    v: jax.Array,  # (B, S, H, V)
    w: jax.Array,  # (B, S, H, K)
    u: jax.Array,  # (H, K)
) -> jax.Array:
    """Sequential WKV-6: o_t = r_t·(S_{t-1} + diag(u) k_t⊗v_t);
    S_t = diag(w_t) S_{t-1} + k_t⊗v_t."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    f32 = jnp.float32

    def step(s, inp):
        r_t, k_t, v_t, w_t = (a.astype(f32) for a in inp)  # (B,H,*)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        o = jnp.einsum("bhk,bhkv->bhv", r_t, s + u.astype(f32)[None, :, :, None] * kv)
        s = s * w_t[..., None] + kv
        return s, o

    s0 = jnp.zeros((B, H, K, V), f32)
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    _, os = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(os, 0, 1).astype(r.dtype)
