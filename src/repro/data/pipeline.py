"""Deterministic synthetic LM data pipeline.

Produces the same token stream for a given (seed, step) on every host —
restart-safe (the cursor is checkpointed) and shardable (each batch is
device_put with the mesh's batch sharding).  A background prefetch thread
keeps `prefetch` batches ready so host data work overlaps device compute
(the data-side analogue of compute/comm overlap).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig


@dataclass
class DataCursor:
    seed: int
    step: int


def _skewed_tokens(rng, shape, V):
    """Zipf-ish unigram skew (p(i) ∝ i^{-2/3}): a learnable distribution so
    smoke-training loss actually decreases below the uniform entropy."""
    u = rng.random(shape)
    return np.minimum((u ** 3 * V), V - 1).astype(np.int32)


class SyntheticLMData:
    """Skewed-unigram synthetic tokens (deterministic per (seed, step))."""

    def __init__(
        self,
        cfg: ModelConfig,
        batch: int,
        seq: int,
        seed: int = 0,
        start_step: int = 0,
        shardings: Optional[Dict[str, Any]] = None,
        prefetch: int = 2,
    ):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.cursor = DataCursor(seed=seed, step=start_step)
        self.shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _make_host_batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.cursor.seed << 20) ^ step)
        V = self.cfg.vocab_size
        cfg = self.cfg
        if cfg.num_encoder_layers:
            from repro.models.encdec import DEC_RATIO

            sd = max(self.seq // DEC_RATIO, 8)
            toks = _skewed_tokens(rng, (self.batch, sd), V)
            return {
                "frames": rng.standard_normal(
                    (self.batch, self.seq, cfg.d_model), dtype=np.float32
                ),
                "tokens": toks,
                "labels": np.roll(toks, -1, axis=1).astype(np.int32),
            }
        if cfg.frontend == "vision":
            si = max(self.seq // 4, 4)
            st = self.seq - si
            toks = _skewed_tokens(rng, (self.batch, st), V)
            return {
                "tokens": toks,
                "labels": np.roll(toks, -1, axis=1).astype(np.int32),
                "patch_embeds": rng.standard_normal(
                    (self.batch, si, cfg.d_model), dtype=np.float32
                ),
            }
        toks = _skewed_tokens(rng, (self.batch, self.seq), V)
        return {"tokens": toks, "labels": np.roll(toks, -1, axis=1).astype(np.int32)}

    def _producer(self):
        step = self.cursor.step
        while not self._stop.is_set():
            hb = self._make_host_batch(step)
            try:
                self._q.put((step, hb), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __next__(self) -> Dict[str, Any]:
        while True:
            step, hb = self._q.get()
            if step >= self.cursor.step:
                break
        self.cursor.step = step + 1
        if self.shardings:
            return {
                k: jax.device_put(v, self.shardings.get(k)) for k, v in hb.items()
            }
        return {k: jax.device_put(v) for k, v in hb.items()}

    def __iter__(self) -> Iterator:
        return self

    def state(self) -> dict:
        return {"seed": self.cursor.seed, "step": self.cursor.step}

    def restore(self, state: dict):
        self.cursor = DataCursor(seed=state["seed"], step=state["step"])

    def close(self):
        self._stop.set()
