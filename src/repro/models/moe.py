"""Top-k MoE FFN with capacity-based scatter dispatch.

Dispatch avoids the O(T·E·C) GShard combine tensor: each (token, k) pair
computes its (expert, slot) coordinate via a cumulative-sum over the one-hot
routing matrix, then a scatter-add builds the (E, C, D) expert buffer and a
gather reads results back.  Tokens beyond capacity are dropped (standard
capacity-factor semantics); the router load-balancing auxiliary loss is
returned alongside the output.

Parallelism (dist/sharding.py rules):
  - 'expert'  -> 'model'  (EP; qwen3-moe: 128 experts / 16 = 8 per device)
  - 'moe_mlp' -> 'model'  (TP-on-experts; grok-1: 8 experts < 16-way axis)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import fsdp
from repro.models.layers import ParamSpec, cast, swiglu


def moe_schema(cfg) -> dict:
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    return {
        "router": ParamSpec((D, E), ("embed", "expert"), init="small_normal"),
        "wg": ParamSpec((E, D, F), ("expert", "embed", "moe_mlp")),
        "wu": ParamSpec((E, D, F), ("expert", "embed", "moe_mlp")),
        "wd": ParamSpec((E, F, D), ("expert", "moe_mlp", "embed")),
    }


def _capacity(tokens: int, cfg) -> int:
    c = int(tokens * cfg.experts_per_tok * cfg.capacity_factor / cfg.num_experts)
    return max(cfg.experts_per_tok, c)


def moe_apply(p: dict, x: jax.Array, cfg) -> tuple:
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar fp32)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_tok
    T = B * S
    C = _capacity(T, cfg)
    xt = x.reshape(T, D)

    # --- routing (fp32) ---
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_vals, idx = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)  # (E,)
    onehot_top1 = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(onehot_top1, axis=0)
    aux = E * jnp.sum(me * ce)

    # --- group-local slot assignment (GShard-style): capacity is per DATA
    # shard so scatter indices never cross the token sharding — the only
    # cross-device movement left is the expert-axis all-to-all ---
    groups = fsdp.group_count("act_tokens")
    TK = T * K
    while TK % groups != 0:  # defensive (token count always divides in practice)
        groups //= 2
    TKg = TK // groups
    Cg = max(K, C // groups)
    flat_e = idx.reshape(groups, TKg)  # (G, TKg)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (G, TKg, E)
    pos = jnp.cumsum(onehot, axis=1) - onehot  # per-group prefix count
    slot = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]  # (G, TKg)
    keep = slot < Cg
    slot_c = jnp.minimum(slot, Cg - 1)
    g_idx = jax.lax.broadcasted_iota(jnp.int32, (groups, TKg), 0)

    # --- dispatch: scatter tokens into (G, E, Cg, D).
    # The scatter stays LOCAL: its result is sharded only on the group (data)
    # dim — scattering directly into an expert-sharded buffer would make
    # GSPMD emit buffer-sized partial-scatter all-reduces over 'model'
    # (EXPERIMENTS.md §Perf, qwen3 iteration 2). The expert dim is then
    # sliced onto the EP axis by a constraint (a local slice, no collective).
    src = jnp.repeat(xt, K, axis=0).reshape(groups, TKg, D)
    src = src * keep[..., None].astype(src.dtype)
    src = fsdp.constrain(src, ("act_tokens", None, "act_embed"))
    buf = jnp.zeros((groups, E, Cg, D), dtype=x.dtype)
    buf = buf.at[g_idx, flat_e, slot_c].add(src, mode="drop")
    buf = fsdp.constrain(buf, ("act_tokens", None, None, "act_embed"))
    # EP slice: each model shard keeps its experts
    buf = fsdp.constrain(buf, ("act_tokens", "act_expert", None, "act_embed"))

    # --- expert GLU compute ---
    dt = x.dtype
    g = jnp.einsum("gecd,edf->gecf", buf, cast(p["wg"], dt))
    g = fsdp.constrain(g, ("act_tokens", "act_expert", None, "act_moe_ff"))
    u = jnp.einsum("gecd,edf->gecf", buf, cast(p["wu"], dt))
    u = fsdp.constrain(u, ("act_tokens", "act_expert", None, "act_moe_ff"))
    y = jnp.einsum("gecf,efd->gecd", swiglu(g, u), cast(p["wd"], dt))
    y = fsdp.constrain(y, ("act_tokens", "act_expert", None, "act_embed"))
    # combine side: gather needs all experts per group -> all-gather over the
    # EP axis (the GSPMD analogue of the return all-to-all)
    y = fsdp.constrain(y, ("act_tokens", None, None, "act_embed"))

    # --- combine: gather each (t,k) result, weight by gate ---
    out_tk = y[g_idx, flat_e, slot_c]  # (G, TKg, D)
    out_tk = fsdp.constrain(out_tk, ("act_tokens", None, "act_embed"))
    w = (gate_vals.reshape(groups, TKg) * keep.astype(jnp.float32)).astype(dt)
    out = (out_tk * w[..., None]).reshape(T, K, D).sum(axis=1)
    return out.reshape(B, S, D), aux
