"""Model facade: one uniform API over every architecture family.

``get_model(cfg)`` returns a ``ModelAPI`` whose members are pure functions of
(params, inputs).  ``input_specs`` produces ShapeDtypeStruct stand-ins for
every model input of a given benchmark shape — weak-type-correct, shardable,
zero allocation — which is what launch/dryrun.py lowers against.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, rwkv_lm, transformer
from repro.models.graph import build_lm_graph
from repro.models.layers import abstract_params, init_params, logical_axes

I32 = jnp.int32


@dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    schema: Any
    loss: Callable  # (params, batch) -> (loss, metrics)
    forward: Callable  # (params, *inputs) -> logits
    decode_step: Optional[Callable]  # (params, token, cache, cache_len) -> (logits, cache)
    #   cache_len: scalar, or (B,) per-lane lengths (attn families only)
    cache_schema: Optional[Callable]  # (batch, capacity) -> schema
    prefill: Optional[Callable] = None

    def init(self, rng: jax.Array):
        return init_params(rng, self.schema)

    def abstract(self):
        return abstract_params(self.schema)

    def axes(self):
        return logical_axes(self.schema)

    def layer_graph(self, shape: ShapeConfig):
        return build_lm_graph(self.cfg, shape)


def get_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.block_type in ("attn_mlp", "moe") and not cfg.num_encoder_layers:
        return ModelAPI(
            cfg=cfg,
            schema=transformer.lm_schema(cfg),
            loss=lambda p, b: transformer.loss_fn(p, b, cfg),
            forward=lambda p, t, **kw: transformer.forward(p, t, cfg, **kw),
            prefill=lambda p, t, cap, **kw: transformer.prefill(p, t, cfg, cap, **kw),
            decode_step=lambda p, tok, cache, n: transformer.decode_step(p, tok, cache, n, cfg),
            cache_schema=lambda b, cap: transformer.cache_schema(cfg, b, cap),
        )
    if cfg.num_encoder_layers:
        return ModelAPI(
            cfg=cfg,
            schema=encdec.encdec_schema(cfg),
            loss=lambda p, b: encdec.loss_fn(p, b, cfg),
            forward=lambda p, frames, tokens: encdec.forward(p, frames, tokens, cfg),
            decode_step=lambda p, tok, cache, n: encdec.decode_step(p, tok, cache, n, cfg),
            cache_schema=lambda b, cap: encdec.cache_schema(cfg, b, cap),
        )
    if cfg.block_type == "mamba2":
        return ModelAPI(
            cfg=cfg,
            schema=hybrid.hybrid_schema(cfg),
            loss=lambda p, b: hybrid.loss_fn(p, b, cfg),
            forward=lambda p, t: hybrid.forward(p, t, cfg),
            decode_step=lambda p, tok, cache, n: hybrid.decode_step(p, tok, cache, n, cfg),
            cache_schema=lambda b, cap: hybrid.cache_schema(cfg, b, cap),
        )
    if cfg.block_type == "rwkv6":
        return ModelAPI(
            cfg=cfg,
            schema=rwkv_lm.rwkv_lm_schema(cfg),
            loss=lambda p, b: rwkv_lm.loss_fn(p, b, cfg),
            forward=lambda p, t: rwkv_lm.forward(p, t, cfg),
            decode_step=lambda p, tok, cache, n: rwkv_lm.decode_step(p, tok, cache, n, cfg),
            cache_schema=lambda b, cap: rwkv_lm.cache_schema(cfg, b, cap),
        )
    raise ValueError(f"no model for {cfg.name} ({cfg.block_type})")


# ---------------------------------------------------------------------------
# Input specs (dry-run) + concrete batches (smoke tests / examples)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for one ``loss``-mode batch."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.num_encoder_layers:  # enc-dec: frames in, tokens out
        S_dec = max(S // encdec.DEC_RATIO, 16)
        return {
            "frames": _sds((B, S, cfg.d_model), cfg.dtype),
            "tokens": _sds((B, S_dec), I32),
            "labels": _sds((B, S_dec), I32),
        }
    if cfg.frontend == "vision":
        S_img = min(transformer.VISION_PREFIX, S // 4)
        S_txt = S - S_img
        return {
            "tokens": _sds((B, S_txt), I32),
            "labels": _sds((B, S_txt), I32),
            "patch_embeds": _sds((B, S_img, cfg.d_model), cfg.dtype),
        }
    return {"tokens": _sds((B, S), I32), "labels": _sds((B, S), I32)}


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for one ``decode_step`` call with a full cache."""
    from repro.models.layers import is_spec, ParamSpec

    B, cap = shape.global_batch, shape.seq_len
    api = get_model(cfg)
    cache = jax.tree.map(
        lambda s: _sds(s.shape, s.dtype), api.cache_schema(B, cap),
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
    return {
        "token": _sds((B, 1), I32),
        "cache": cache,
        "cache_len": _sds((), I32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    if shape.kind == "decode":
        return decode_input_specs(cfg, shape)
    if shape.kind == "prefill":
        # prefill lowers the full-sequence forward (loss-free): same inputs
        spec = train_input_specs(cfg, shape)
        spec.pop("labels", None)
        return spec
    return train_input_specs(cfg, shape)


def make_batch(rng: jax.Array, cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Concrete random batch at smoke scale."""
    kt, kl, kf = jax.random.split(rng, 3)
    V = cfg.vocab_size
    if cfg.num_encoder_layers:
        S_dec = max(seq // encdec.DEC_RATIO, 8)
        return {
            "frames": jax.random.normal(kf, (batch, seq, cfg.d_model), jnp.float32)
            .astype(jnp.dtype(cfg.dtype)),
            "tokens": jax.random.randint(kt, (batch, S_dec), 0, V, I32),
            "labels": jax.random.randint(kl, (batch, S_dec), 0, V, I32),
        }
    if cfg.frontend == "vision":
        S_img = max(seq // 4, 4)
        S_txt = seq - S_img
        return {
            "tokens": jax.random.randint(kt, (batch, S_txt), 0, V, I32),
            "labels": jax.random.randint(kl, (batch, S_txt), 0, V, I32),
            "patch_embeds": jax.random.normal(kf, (batch, S_img, cfg.d_model), jnp.float32)
            .astype(jnp.dtype(cfg.dtype)),
        }
    return {
        "tokens": jax.random.randint(kt, (batch, seq), 0, V, I32),
        "labels": jax.random.randint(kl, (batch, seq), 0, V, I32),
    }
