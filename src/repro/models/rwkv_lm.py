"""RWKV-6 LM stack (rwkv6-1.6b). Attention-free; O(1) decode state."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, rms_norm, softmax_xent, stack_schema
from repro.models.rwkv6 import (
    rwkv6_channel_mix,
    rwkv6_schema,
    rwkv6_time_mix,
)
from repro.models.transformer import embed_tokens, unembed
from repro.dist import fsdp


def _layer_schema(cfg) -> dict:
    D = cfg.d_model
    return {
        "ln1": ParamSpec((D,), ("norm",), init="zeros"),
        "ln2": ParamSpec((D,), ("norm",), init="zeros"),
        "mix": rwkv6_schema(cfg),
    }


def rwkv_lm_schema(cfg) -> dict:
    D, Vp = cfg.d_model, cfg.padded_vocab
    layer = {
        "ln1": ParamSpec((D,), ("norm",), init="zeros"),
        "ln2": ParamSpec((D,), ("norm",), init="zeros"),
        "mix": rwkv6_schema(cfg),
    }
    return {
        "embed": ParamSpec((Vp, D), ("vocab", "embed"), init="embed"),
        "layers": stack_schema(layer, cfg.num_layers),
        "final_norm": ParamSpec((D,), ("norm",), init="zeros"),
        "lm_head": ParamSpec((D, Vp), ("embed", "vocab")),
    }


def _block(lp, h, cfg, decode=False, states=None):
    lp = fsdp.gather(lp, _layer_schema(cfg))
    tm_in = rms_norm(h, lp["ln1"], cfg.norm_eps)
    if decode:
        wkv_state, tm_shift, cm_shift = states
        tm_out, wkv_new, tm_last = rwkv6_time_mix(
            lp["mix"], tm_in, cfg, state=wkv_state, decode=True,
            shift_state=tm_shift,
        )
    else:
        tm_out, wkv_new, tm_last = rwkv6_time_mix(lp["mix"], tm_in, cfg)
    h = h + tm_out
    cm_in = rms_norm(h, lp["ln2"], cfg.norm_eps)
    if decode:
        cm_out, cm_last = rwkv6_channel_mix(lp["mix"], cm_in, shift_state=cm_shift)
    else:
        cm_out, cm_last = rwkv6_channel_mix(lp["mix"], cm_in)
    h = h + cm_out
    return h, (wkv_new, tm_last, cm_last)


def hidden_states(params: dict, tokens: jax.Array, cfg):
    h = embed_tokens(params, tokens, cfg)

    blk = (
        jax.checkpoint(lambda lp, hh: _block(lp, hh, cfg))
        if cfg.remat_policy != "none"
        else (lambda lp, hh: _block(lp, hh, cfg))
    )

    def body(hh, lp):
        hh, _ = blk(lp, hh)
        return hh, None

    h, _ = jax.lax.scan(body, h, params["layers"])
    return h


def forward(params: dict, tokens: jax.Array, cfg) -> jax.Array:
    return unembed(params, hidden_states(params, tokens, cfg), cfg)


def loss_fn(params: dict, batch: dict, cfg):
    logits = forward(params, batch["tokens"], cfg)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    xent = softmax_xent(logits, jnp.maximum(labels, 0), mask)
    return xent, {"loss": xent, "xent": xent}


def cache_schema(cfg, batch: int, capacity: int) -> dict:
    """O(1) state — `capacity` is ignored (kept for API uniformity)."""
    H, hd, D, L = cfg.num_heads, cfg.d_head, cfg.d_model, cfg.num_layers
    return {
        "wkv": ParamSpec(
            (L, batch, H, hd, hd),
            ("layers", "act_batch", "heads", "head_dim", "head_dim2"),
            init="zeros", dtype="float32",
        ),
        "tm_shift": ParamSpec(
            (L, batch, D), ("layers", "act_batch", "act_embed"), init="zeros",
            dtype=cfg.dtype,
        ),
        "cm_shift": ParamSpec(
            (L, batch, D), ("layers", "act_batch", "act_embed"), init="zeros",
            dtype=cfg.dtype,
        ),
    }


def decode_step(params: dict, token: jax.Array, cache: dict, cache_len: jax.Array, cfg):
    del cache_len  # O(1) state — position-free
    h = embed_tokens(params, token, cfg)

    def body(hh, xs):
        lp, wkv, tms, cms = xs
        hh, (wkv_new, tm_last, cm_last) = _block(
            lp, hh, cfg, decode=True, states=(wkv, tms.astype(hh.dtype), cms.astype(hh.dtype))
        )
        return hh, (wkv_new, tm_last.astype(tms.dtype), cm_last.astype(cms.dtype))

    h, (wkv, tms, cms) = jax.lax.scan(
        body, h, (params["layers"], cache["wkv"], cache["tm_shift"], cache["cm_shift"])
    )
    logits = unembed(params, h, cfg)[:, 0]
    return logits, {"wkv": wkv, "tm_shift": tms, "cm_shift": cms}
