"""Encoder-decoder stack (seamless-m4t-large-v2 backbone).

The speech frontend is a STUB per the assignment: inputs are precomputed
frame embeddings (B, S_enc, D).  Encoder: bidirectional self-attention.
Decoder: causal self-attention + cross-attention over encoder output.

Shape mapping (DESIGN.md §4): for train/prefill cells the encoder consumes
seq_len frames and the decoder seq_len // DEC_RATIO tokens; decode cells run
one decoder step against a decoder KV cache of seq_len with a cached encoder
memory of ENC_MEMORY frames.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models.layers import (
    ParamSpec,
    apply_rope,
    attention_schema,
    cast,
    mlp_apply,
    mlp_schema,
    out_project,
    qkv_project,
    rms_norm,
    softmax_xent,
    stack_schema,
)
from repro.models.transformer import embed_tokens, unembed
from repro.dist import fsdp

DEC_RATIO = 4       # decoder length = encoder length // 4 for train/prefill
ENC_MEMORY = 4096   # encoder memory length at decode shapes


def encoder_block_schema(cfg) -> dict:
    D = cfg.d_model
    return {
        "ln1": ParamSpec((D,), ("norm",), init="zeros"),
        "ln2": ParamSpec((D,), ("norm",), init="zeros"),
        "attn": attention_schema(cfg),
        "mlp": mlp_schema(cfg),
    }


def decoder_block_schema(cfg) -> dict:
    D = cfg.d_model
    return {
        "ln1": ParamSpec((D,), ("norm",), init="zeros"),
        "lnx": ParamSpec((D,), ("norm",), init="zeros"),
        "ln2": ParamSpec((D,), ("norm",), init="zeros"),
        "self_attn": attention_schema(cfg),
        "cross_attn": attention_schema(cfg),
        "mlp": mlp_schema(cfg),
    }


def encdec_schema(cfg) -> dict:
    D, Vp = cfg.d_model, cfg.padded_vocab
    return {
        "frontend_proj": ParamSpec((D, D), ("embed", "embed_out")),
        "embed": ParamSpec((Vp, D), ("vocab", "embed"), init="embed"),
        "enc_layers": stack_schema(encoder_block_schema(cfg), cfg.num_encoder_layers),
        "dec_layers": stack_schema(decoder_block_schema(cfg), cfg.num_layers),
        "enc_norm": ParamSpec((D,), ("norm",), init="zeros"),
        "final_norm": ParamSpec((D,), ("norm",), init="zeros"),
        "lm_head": ParamSpec((D, Vp), ("embed", "vocab")),
    }


def _enc_block(lp, h, positions, cfg):
    lp = fsdp.gather(lp, encoder_block_schema(cfg))
    a_in = rms_norm(h, lp["ln1"], cfg.norm_eps)
    q, k, v = qkv_project(lp["attn"], a_in, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    h = h + out_project(lp["attn"], attn_lib.attend(q, k, v, causal=False))
    m_in = rms_norm(h, lp["ln2"], cfg.norm_eps)
    return h + mlp_apply(lp["mlp"], m_in)


def encode(params: dict, frames: jax.Array, cfg) -> jax.Array:
    """frames: (B, S_enc, D) precomputed frame embeddings (frontend stub)."""
    dt = jnp.dtype(cfg.dtype)
    fp = fsdp.gather_leaf(params["frontend_proj"], ("embed", "embed_out"))
    h = jnp.einsum("bsd,de->bse", frames.astype(dt), cast(fp, dt))
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    blk = (
        jax.checkpoint(lambda lp, hh: _enc_block(lp, hh, positions, cfg))
        if cfg.remat_policy != "none"
        else (lambda lp, hh: _enc_block(lp, hh, positions, cfg))
    )

    def body(hh, lp):
        return blk(lp, hh), None

    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def _cross_attend(lp, h, enc_kv, positions, cfg):
    """Cross-attention: q from decoder h, k/v precomputed from encoder."""
    a_in = rms_norm(h, lp["lnx"], cfg.norm_eps)
    dt = h.dtype
    q = jnp.einsum("bsd,dhk->bshk", a_in, cast(lp["cross_attn"]["wq"], dt))
    k, v = enc_kv
    return h + out_project(
        lp["cross_attn"], attn_lib.attend(q, k, v, causal=False)
    )


def _enc_kv(lp, enc_out, cfg):
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, cast(lp["cross_attn"]["wk"], dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, cast(lp["cross_attn"]["wv"], dt))
    return k, v


def _dec_block(lp, h, enc_out, positions, cfg):
    lp = fsdp.gather(lp, decoder_block_schema(cfg))
    a_in = rms_norm(h, lp["ln1"], cfg.norm_eps)
    q, k, v = qkv_project(lp["self_attn"], a_in, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    h = h + out_project(lp["self_attn"], attn_lib.attend(q, k, v, causal=True))
    h = _cross_attend(lp, h, _enc_kv(lp, enc_out, cfg), positions, cfg)
    m_in = rms_norm(h, lp["ln2"], cfg.norm_eps)
    return h + mlp_apply(lp["mlp"], m_in)


def forward(params: dict, frames: jax.Array, tokens: jax.Array, cfg) -> jax.Array:
    enc_out = encode(params, frames, cfg)
    h = embed_tokens(params, tokens, cfg)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    blk = (
        jax.checkpoint(lambda lp, hh: _dec_block(lp, hh, enc_out, positions, cfg))
        if cfg.remat_policy != "none"
        else (lambda lp, hh: _dec_block(lp, hh, enc_out, positions, cfg))
    )

    def body(hh, lp):
        return blk(lp, hh), None

    h, _ = jax.lax.scan(body, h, params["dec_layers"])
    return unembed(params, h, cfg)


def loss_fn(params: dict, batch: dict, cfg):
    logits = forward(params, batch["frames"], batch["tokens"], cfg)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    xent = softmax_xent(logits, jnp.maximum(labels, 0), mask)
    return xent, {"loss": xent, "xent": xent}


def cache_schema(cfg, batch: int, capacity: int) -> dict:
    KV, hd, L = cfg.num_kv_heads, cfg.d_head, cfg.num_layers
    kv = ParamSpec(
        (L, batch, capacity, KV, hd),
        ("layers", "act_batch", "act_kv_seq", "kv_heads", "head_dim"),
        init="zeros", dtype=cfg.dtype,
    )
    enc_kv = ParamSpec(
        (L, batch, ENC_MEMORY, KV, hd),
        ("layers", "act_batch", "act_kv_seq", "kv_heads", "head_dim"),
        init="zeros", dtype=cfg.dtype,
    )
    return {"k": kv, "v": kv, "enc_k": enc_kv, "enc_v": enc_kv}


def decode_step(params: dict, token: jax.Array, cache: dict, cache_len: jax.Array, cfg):
    """One decoder step; encoder memory K/V precomputed in the cache."""
    h = embed_tokens(params, token, cfg)

    def body(hh, xs):
        lp, c = xs
        lp = fsdp.gather(lp, decoder_block_schema(cfg))
        positions = jnp.full((hh.shape[0], 1), cache_len, dtype=jnp.int32)
        a_in = rms_norm(hh, lp["ln1"], cfg.norm_eps)
        q, k, v = qkv_project(lp["self_attn"], a_in, cfg)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(c["k"], k.astype(c["k"].dtype), cache_len, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(c["v"], v.astype(c["v"].dtype), cache_len, 1)
        hh = hh + out_project(
            lp["self_attn"],
            attn_lib.decode_attention(q, kc.astype(q.dtype), vc.astype(q.dtype), cache_len + 1),
        )
        # cross-attention over full encoder memory
        x_in = rms_norm(hh, lp["lnx"], cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", x_in, cast(lp["cross_attn"]["wq"], hh.dtype))
        hh = hh + out_project(
            lp["cross_attn"],
            attn_lib.decode_attention(
                qx, c["enc_k"].astype(qx.dtype), c["enc_v"].astype(qx.dtype),
                jnp.int32(ENC_MEMORY),
            ),
        )
        m_in = rms_norm(hh, lp["ln2"], cfg.norm_eps)
        hh = hh + mlp_apply(lp["mlp"], m_in)
        return hh, {"k": kc, "v": vc, "enc_k": c["enc_k"], "enc_v": c["enc_v"]}

    h, new_cache = jax.lax.scan(body, h, (params["dec_layers"], cache))
    logits = unembed(params, h, cfg)[:, 0]
    return logits, new_cache
