"""zamba2 hybrid stack: Mamba-2 backbone + ONE shared attention block.

The shared block's weights are used at every ``attn_every``-th layer (weight
sharing across invocations — the zamba2 signature).  Its input is
concat(hidden, first-layer embedding) (2·d_model), attention output projects
back to d_model, followed by a gated MLP.  [arXiv:2411.15242]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models.layers import (
    ParamSpec,
    apply_rope,
    cast,
    mlp_apply,
    mlp_schema,
    rms_norm,
    softmax_xent,
    stack_schema,
)
from repro.models.mamba2 import mamba2_apply, mamba2_schema
from repro.dist import fsdp
from repro.models.transformer import embed_tokens, unembed


def shared_block_schema(cfg) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    D2 = 2 * D
    return {
        "ln_in": ParamSpec((D2,), ("norm",), init="zeros"),
        "wq": ParamSpec((D2, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((D2, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((D2, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, hd, D), ("heads", "head_dim", "embed")),
        "ln_mlp": ParamSpec((D,), ("norm",), init="zeros"),
        "mlp": mlp_schema(cfg),
    }


def hybrid_schema(cfg) -> dict:
    D, Vp = cfg.d_model, cfg.padded_vocab
    layer = {
        "ln": ParamSpec((D,), ("norm",), init="zeros"),
        "mamba": mamba2_schema(cfg),
    }
    schema = {
        "embed": ParamSpec((Vp, D), ("vocab", "embed"), init="embed"),
        "layers": stack_schema(layer, cfg.num_layers),
        "shared": shared_block_schema(cfg),
        "final_norm": ParamSpec((D,), ("norm",), init="zeros"),
    }
    return schema


def n_shared_invocations(cfg) -> int:
    return (cfg.num_layers + cfg.attn_every - 1) // cfg.attn_every


def _shared_qkv(sp: dict, xcat: jax.Array, positions: jax.Array, cfg):
    dt = xcat.dtype
    a_in = rms_norm(xcat, sp["ln_in"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", a_in, cast(sp["wq"], dt))
    k = jnp.einsum("bsd,dhk->bshk", a_in, cast(sp["wk"], dt))
    v = jnp.einsum("bsd,dhk->bshk", a_in, cast(sp["wv"], dt))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def shared_block(sp: dict, h: jax.Array, h0: jax.Array, positions: jax.Array, cfg):
    xcat = jnp.concatenate([h, h0], axis=-1)
    q, k, v = _shared_qkv(sp, xcat, positions, cfg)
    attn_out = attn_lib.attend(q, k, v, causal=True, window=cfg.sliding_window)
    h = h + jnp.einsum("bshk,hkd->bsd", attn_out, cast(sp["wo"], h.dtype))
    m_in = rms_norm(h, sp["ln_mlp"], cfg.norm_eps)
    return h + mlp_apply(sp["mlp"], m_in)


def hidden_states(params: dict, tokens: jax.Array, cfg):
    h = embed_tokens(params, tokens, cfg)
    h0 = h
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    sp = fsdp.gather(params["shared"], shared_block_schema(cfg))
    lschema = {"ln": ParamSpec((cfg.d_model,), ("norm",), init="zeros"),
               "mamba": mamba2_schema(cfg)}

    def block(lp_idx, hh):
        lp, idx = lp_idx
        lp = fsdp.gather(lp, lschema)
        m_in = rms_norm(hh, lp["ln"], cfg.norm_eps)
        m_out, _ = mamba2_apply(lp["mamba"], m_in, cfg)
        hh = hh + m_out
        hh = jax.lax.cond(
            idx % cfg.attn_every == 0,
            lambda x: shared_block(sp, x, h0, positions, cfg),
            lambda x: x,
            hh,
        )
        return hh

    blk = jax.checkpoint(block) if cfg.remat_policy != "none" else block

    def body(hh, xs):
        return blk(xs, hh), None

    idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)
    h, _ = jax.lax.scan(body, h, (params["layers"], idxs))
    return h


def forward(params: dict, tokens: jax.Array, cfg) -> jax.Array:
    return unembed(params, hidden_states(params, tokens, cfg), cfg)


def loss_fn(params: dict, batch: dict, cfg):
    logits = forward(params, batch["tokens"], cfg)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    xent = softmax_xent(logits, jnp.maximum(labels, 0), mask)
    return xent, {"loss": xent, "xent": xent}


# ---------------------------------------------------------------------------
# Decode (serving): Mamba states per layer + shared-block KV caches per
# invocation + the cached first-layer embedding h0 for the concat input.
# ---------------------------------------------------------------------------


def cache_schema(cfg, batch: int, capacity: int) -> dict:
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    KV, hd = cfg.num_kv_heads, cfg.d_head
    L, NS = cfg.num_layers, n_shared_invocations(cfg)
    return {
        "ssm": ParamSpec(
            (L, batch, H, P, N), ("layers", "act_batch", "heads", "head_dim", "ssm_state"),
            init="zeros", dtype="float32",
        ),
        "conv": ParamSpec(
            (L, batch, cfg.ssm_conv - 1, cfg.ssm_d_inner),
            ("layers", "act_batch", "conv_k", "ssm_inner"),
            init="zeros", dtype=cfg.dtype,
        ),
        "k": ParamSpec(
            (NS, batch, capacity, KV, hd),
            ("layers", "act_batch", "act_kv_seq", "kv_heads", "head_dim"),
            init="zeros", dtype=cfg.dtype,
        ),
        "v": ParamSpec(
            (NS, batch, capacity, KV, hd),
            ("layers", "act_batch", "act_kv_seq", "kv_heads", "head_dim"),
            init="zeros", dtype=cfg.dtype,
        ),
    }


def _shared_block_decode(sp, h, h0, k_all, v_all, slot, cache_len, cfg):
    """One shared-attention invocation at decode time. k_all/v_all stacked
    (NS, B, cap, KV, hd); slot selects the invocation's cache."""
    positions = jnp.full((h.shape[0], 1), cache_len, dtype=jnp.int32)
    xcat = jnp.concatenate([h, h0], axis=-1)
    q, k, v = _shared_qkv(sp, xcat, positions, cfg)
    kc = jax.lax.dynamic_index_in_dim(k_all, slot, 0, keepdims=False)
    vc = jax.lax.dynamic_index_in_dim(v_all, slot, 0, keepdims=False)
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), cache_len, 1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), cache_len, 1)
    attn_out = attn_lib.decode_attention(
        q, kc.astype(q.dtype), vc.astype(q.dtype), cache_len + 1, window=cfg.sliding_window
    )
    h = h + jnp.einsum("bshk,hkd->bsd", attn_out, cast(sp["wo"], h.dtype))
    m_in = rms_norm(h, sp["ln_mlp"], cfg.norm_eps)
    h = h + mlp_apply(sp["mlp"], m_in)
    k_all = jax.lax.dynamic_update_index_in_dim(k_all, kc, slot, 0)
    v_all = jax.lax.dynamic_update_index_in_dim(v_all, vc, slot, 0)
    return h, k_all, v_all


def decode_step(params: dict, token: jax.Array, cache: dict, cache_len: jax.Array, cfg):
    h = embed_tokens(params, token, cfg)
    # the shared block's concat input uses the CURRENT position's embedding
    # (matches forward(), where h0[t] = embed(tokens[t]))
    h0 = h
    sp = fsdp.gather(params["shared"], shared_block_schema(cfg))
    lschema = {"ln": ParamSpec((cfg.d_model,), ("norm",), init="zeros"),
               "mamba": mamba2_schema(cfg)}

    def body(carry, xs):
        hh, k_all, v_all = carry
        lp, idx, ssm_state, conv_state = xs
        lp = fsdp.gather(lp, lschema)
        m_in = rms_norm(hh, lp["ln"], cfg.norm_eps)
        m_out, (new_state, new_conv) = mamba2_apply(
            lp["mamba"], m_in, cfg, state=(ssm_state, conv_state), decode=True)
        hh = hh + m_out

        def with_attn(args):
            hh, k_all, v_all = args
            return _shared_block_decode(
                sp, hh, h0, k_all, v_all, idx // cfg.attn_every, cache_len, cfg
            )

        hh, k_all, v_all = jax.lax.cond(
            idx % cfg.attn_every == 0, with_attn, lambda a: a, (hh, k_all, v_all)
        )
        return (hh, k_all, v_all), (new_state, new_conv.astype(conv_state.dtype))

    idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)
    (h, k_all, v_all), (ssm_new, conv_new) = jax.lax.scan(
        body, (h, cache["k"], cache["v"]),
        (params["layers"], idxs, cache["ssm"], cache["conv"]),
    )
    logits = unembed(params, h, cfg)[:, 0]
    new_cache = {"ssm": ssm_new, "conv": conv_new, "k": k_all, "v": v_all}
    return logits, new_cache
