"""Layer-graph representation consumed by the burst-parallel planner.

A graph is a *chain* of elements; an element is either a ``LayerNode`` or a
``ParallelBlock`` whose branches are themselves chains (possibly nested) —
exactly the branch/join structure the paper's graph-reduction algorithm
(Fig 7) handles.

Each node carries analytical cost descriptors; ``core/profiler.py`` turns
them into the paper's ``comp(i, g)`` tables through the hardware model.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Union

from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.vgg16 import ConvSpec, DenseSpec, VGGConfig


@dataclass(frozen=True)
class LayerNode:
    name: str
    flops: float            # fwd FLOPs for the full global batch
    param_bytes: float      # parameter bytes (gradient-sync payload)
    act_out_bytes: float    # output activation bytes (resharding payload)
    parallel_units: int     # max useful sample-dimension split
    seq_flops: float = 0.0  # inherently sequential FLOPs (scan steps etc.)
    bwd_mult: float = 2.0   # bwd = bwd_mult × fwd flops
    kind: str = "generic"
    sync_groups: int = 1    # params sharded over this many groups (TP/EP):
                            # grad sync runs per group over g/sync_groups
                            # replicas with 1/sync_groups of the bytes


@dataclass(frozen=True)
class ParallelBlock:
    name: str
    branches: tuple  # tuple of chains; each chain is a tuple of elements


@dataclass(frozen=True)
class EncDecGraph:
    """Two-chain DAG: encoder chain + decoder chain joined by a cross-edge.

    The decoder's cross-attention consumes the encoder's output memory, so
    the planner (core/planner.plan_encdec) plans the two chains jointly: the
    encoder's exit scale becomes the decoder's pinned entry scale and the
    cross-edge pays a resharding join of ``cross_act_bytes``.
    """

    name: str
    encoder: tuple  # LayerGraph chain
    decoder: tuple  # LayerGraph chain
    cross_act_bytes: float


GraphElem = Union[LayerNode, ParallelBlock]
LayerGraph = List[GraphElem]  # a chain


def flatten_nodes(graph) -> list:
    out = []
    for el in graph:
        if isinstance(el, LayerNode):
            out.append(el)
        else:
            for br in el.branches:
                out.extend(flatten_nodes(list(br)))
    return out


def total_fwd_flops(graph) -> float:
    return sum(n.flops + n.seq_flops for n in flatten_nodes(graph))


# ---------------------------------------------------------------------------
# Builders — LM architectures
# ---------------------------------------------------------------------------

_BYTES = 2  # activations in bf16


def build_lm_graph(cfg: ModelConfig, shape: ShapeConfig, tp: int = 16) -> LayerGraph:
    """Per-layer chain for the assigned LM architectures. Costs are for one
    iteration at the global batch of `shape` (train) or one decode step.
    `tp` = model-axis width: params are TP/EP-sharded over it, so gradient
    sync spans only g/tp replicas with 1/tp of the bytes (dist/sharding.py
    layout)."""
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    S_kv = shape.seq_len
    D, Hh, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    T = B * S  # tokens per iteration
    act = T * D * _BYTES
    g: LayerGraph = []

    g.append(
        LayerNode(
            "embed",
            flops=2.0 * T * D,  # gather ~ bytes-bound; count copy flops
            param_bytes=cfg.padded_vocab * D * 4,
            act_out_bytes=act,
            parallel_units=T,
            kind="embed",
            sync_groups=tp,
        )
    )

    def attn_node(i: int) -> LayerNode:
        proj = 2.0 * T * D * (cfg.attn_dim + 2 * cfg.kv_dim) + 2.0 * T * cfg.attn_dim * D
        window = min(cfg.sliding_window or S_kv, S_kv)
        if shape.kind == "decode":
            score = 2.0 * B * Hh * hd * window * 2  # qk + pv
        else:
            score = 2.0 * B * Hh * hd * S * min(window, S)  # causal ≈ /2 applied below
            score = score  # keep full-window upper bound; masks don't save on MXU
        pb = (D * (cfg.attn_dim + 2 * cfg.kv_dim) + cfg.attn_dim * D) * 4
        return LayerNode(
            f"attn_{i}", flops=proj + score, param_bytes=pb, act_out_bytes=act,
            parallel_units=T, kind="attention", sync_groups=tp,
        )

    def ffn_node(i: int) -> LayerNode:
        if cfg.is_moe:
            fl = 6.0 * T * D * cfg.moe_d_ff * cfg.experts_per_tok
            pb = cfg.num_experts * 3 * D * cfg.moe_d_ff * 4
            return LayerNode(
                f"moe_{i}", flops=fl, param_bytes=pb, act_out_bytes=act,
                parallel_units=T, kind="moe", sync_groups=tp,
            )
        fl = 6.0 * T * D * cfg.d_ff
        return LayerNode(
            f"mlp_{i}", flops=fl, param_bytes=3 * D * cfg.d_ff * 4,
            act_out_bytes=act, parallel_units=T, kind="mlp", sync_groups=tp,
        )

    def mamba_node(i: int) -> LayerNode:
        din, N, Hm = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
        proj = 2.0 * T * D * (2 * din + 2 * N + Hm) + 2.0 * T * din * D
        chunk = 128
        ssd = 2.0 * B * max(S // chunk, 1) * chunk * chunk * (Hm + 2 * N)  # intra-chunk
        seq = 2.0 * B * max(S // chunk, 1) * Hm * cfg.ssm_head_dim * N  # inter-chunk scan
        pb = (D * (2 * din + 2 * N + Hm) + din * D) * 4
        return LayerNode(
            f"mamba_{i}", flops=proj + ssd, seq_flops=seq, param_bytes=pb,
            act_out_bytes=act, parallel_units=B * max(S // chunk, 1), kind="ssm",
            sync_groups=tp,
        )

    def rwkv_node(i: int) -> LayerNode:
        chunk = 64
        proj = 2.0 * T * D * (5 * D)
        wkv = 2.0 * B * max(S // chunk, 1) * chunk * chunk * D
        seq = 2.0 * B * max(S // chunk, 1) * D * hd
        cmix = 6.0 * T * D * cfg.d_ff
        return LayerNode(
            f"rwkv_{i}", flops=proj + wkv + cmix, seq_flops=seq,
            param_bytes=(6 * D * D + 2 * D * cfg.d_ff) * 4,
            act_out_bytes=act, parallel_units=B * max(S // chunk, 1), kind="ssm",
            sync_groups=tp,
        )

    for i in range(cfg.num_layers):
        if cfg.block_type == "mamba2":
            g.append(mamba_node(i))
            if cfg.attn_every and i % cfg.attn_every == 0:
                g.append(attn_node(i))
                g.append(ffn_node(i))
        elif cfg.block_type == "rwkv6":
            g.append(rwkv_node(i))
        else:
            g.append(attn_node(i))
            g.append(ffn_node(i))

    g.append(
        LayerNode(
            "lm_head",
            flops=2.0 * T * D * cfg.padded_vocab,
            param_bytes=cfg.padded_vocab * D * 4,
            act_out_bytes=T * cfg.padded_vocab * _BYTES,
            parallel_units=T,
            kind="head",
            sync_groups=tp,
        )
    )
    return g


# ---------------------------------------------------------------------------
# Builders — encoder-decoder two-chain DAG (seamless-m4t class, encdec.py)
# ---------------------------------------------------------------------------


def build_encdec_graph(cfg: ModelConfig, shape: ShapeConfig, tp: int = 16) -> EncDecGraph:
    """Two-chain DAG for an encoder-decoder LM (models/encdec.py shapes):
    encoder over ``seq_len`` frames, decoder over ``seq_len // 4`` tokens
    (encdec.DEC_RATIO), cross-attention joining them.  The cross-edge payload
    is the encoder output memory each decoder device must hold."""
    dec_ratio = 4  # encdec.DEC_RATIO; literal avoids importing jax here
    B = shape.global_batch
    S_enc = shape.seq_len
    S_dec = max(S_enc // dec_ratio, 1)
    D, Hh, hd = cfg.d_model, cfg.num_heads, cfg.d_head
    T_e, T_d = B * S_enc, B * S_dec
    act_e = T_e * D * _BYTES
    act_d = T_d * D * _BYTES

    enc: List[LayerNode] = [
        LayerNode(
            "frontend_proj", flops=2.0 * T_e * D * D, param_bytes=D * D * 4,
            act_out_bytes=act_e, parallel_units=T_e, kind="embed", sync_groups=tp,
        )
    ]
    attn_pb = (D * (cfg.attn_dim + 2 * cfg.kv_dim) + cfg.attn_dim * D) * 4
    for i in range(cfg.num_encoder_layers):
        proj = 2.0 * T_e * D * (cfg.attn_dim + 2 * cfg.kv_dim) + 2.0 * T_e * cfg.attn_dim * D
        score = 2.0 * B * Hh * hd * S_enc * S_enc  # bidirectional
        enc.append(
            LayerNode(
                f"enc_attn_{i}", flops=proj + score, param_bytes=attn_pb,
                act_out_bytes=act_e, parallel_units=T_e, kind="attention",
                sync_groups=tp,
            )
        )
        enc.append(
            LayerNode(
                f"enc_mlp_{i}", flops=6.0 * T_e * D * cfg.d_ff,
                param_bytes=3 * D * cfg.d_ff * 4, act_out_bytes=act_e,
                parallel_units=T_e, kind="mlp", sync_groups=tp,
            )
        )

    dec: List[LayerNode] = [
        LayerNode(
            "embed", flops=2.0 * T_d * D, param_bytes=cfg.padded_vocab * D * 4,
            act_out_bytes=act_d, parallel_units=T_d, kind="embed", sync_groups=tp,
        )
    ]
    for i in range(cfg.num_layers):
        proj = 2.0 * T_d * D * (cfg.attn_dim + 2 * cfg.kv_dim) + 2.0 * T_d * cfg.attn_dim * D
        score = 2.0 * B * Hh * hd * S_dec * S_dec
        dec.append(
            LayerNode(
                f"dec_self_attn_{i}", flops=proj + score, param_bytes=attn_pb,
                act_out_bytes=act_d, parallel_units=T_d, kind="attention",
                sync_groups=tp,
            )
        )
        # cross-attention: q from T_d decoder tokens, k/v projected from the
        # T_e-frame encoder memory, scores over S_dec × S_enc
        x_proj = (
            2.0 * T_d * D * cfg.attn_dim
            + 2.0 * T_e * D * 2 * cfg.kv_dim
            + 2.0 * T_d * cfg.attn_dim * D
        )
        x_score = 2.0 * B * Hh * hd * S_dec * S_enc * 2  # qk + pv
        dec.append(
            LayerNode(
                f"dec_cross_attn_{i}", flops=x_proj + x_score, param_bytes=attn_pb,
                act_out_bytes=act_d, parallel_units=T_d, kind="attention",
                sync_groups=tp,
            )
        )
        dec.append(
            LayerNode(
                f"dec_mlp_{i}", flops=6.0 * T_d * D * cfg.d_ff,
                param_bytes=3 * D * cfg.d_ff * 4, act_out_bytes=act_d,
                parallel_units=T_d, kind="mlp", sync_groups=tp,
            )
        )
    dec.append(
        LayerNode(
            "lm_head", flops=2.0 * T_d * D * cfg.padded_vocab,
            param_bytes=cfg.padded_vocab * D * 4,
            act_out_bytes=T_d * cfg.padded_vocab * _BYTES,
            parallel_units=T_d, kind="head", sync_groups=tp,
        )
    )
    return EncDecGraph(
        name=cfg.name, encoder=tuple(enc), decoder=tuple(dec),
        cross_act_bytes=float(act_e),
    )


# ---------------------------------------------------------------------------
# Builders — paper's CNNs (VGG-16 + a synthetic Inception-style graph)
# ---------------------------------------------------------------------------


def build_vgg_graph(vcfg: VGGConfig, global_batch: int) -> LayerGraph:
    g: LayerGraph = []
    for spec in vcfg.layers:
        if isinstance(spec, ConvSpec):
            hw = spec.spatial * spec.spatial
            fl = 2.0 * global_batch * hw * spec.kernel ** 2 * spec.in_ch * spec.out_ch
            pb = spec.kernel ** 2 * spec.in_ch * spec.out_ch * 4
            ab = global_batch * hw * spec.out_ch * _BYTES
            g.append(
                LayerNode(spec.name, flops=fl, param_bytes=pb, act_out_bytes=ab,
                          parallel_units=global_batch, kind="conv")
            )
        else:
            fl = 2.0 * global_batch * spec.in_dim * spec.out_dim
            g.append(
                LayerNode(spec.name, flops=fl, param_bytes=spec.in_dim * spec.out_dim * 4,
                          act_out_bytes=global_batch * spec.out_dim * _BYTES,
                          parallel_units=global_batch, kind="dense")
            )
    return g


def build_wrn_graph(global_batch: int, image_size: int = 400) -> LayerGraph:
    """WideResNet-101-2 (paper Table 1: 105 layers, 3×400×400, intense conv).
    Bottleneck stages [3, 4, 23, 3], width factor 2."""
    g: LayerGraph = []
    hw = image_size // 2

    def conv(name, cin, cout, k, sp):
        fl = 2.0 * global_batch * sp * sp * k * k * cin * cout
        g.append(
            LayerNode(name, flops=fl, param_bytes=k * k * cin * cout * 4,
                      act_out_bytes=global_batch * sp * sp * cout * _BYTES,
                      parallel_units=global_batch, kind="conv")
        )

    conv("stem", 3, 64, 7, hw)
    hw //= 2
    cin = 64
    for si, (blocks, planes) in enumerate(zip((3, 4, 23, 3), (128, 256, 512, 1024))):
        cout = planes * 4 // 2  # expansion 4, post-width normalization
        for b in range(blocks):
            conv(f"s{si}b{b}_1x1a", cin, planes, 1, hw)
            conv(f"s{si}b{b}_3x3", planes, planes, 3, hw)
            conv(f"s{si}b{b}_1x1b", planes, cout, 1, hw)
            cin = cout
        hw = max(hw // 2, 7)
    g.append(LayerNode("fc", flops=2.0 * global_batch * cin * 1000,
                       param_bytes=cin * 1000 * 4,
                       act_out_bytes=global_batch * 1000 * _BYTES,
                       parallel_units=global_batch, kind="dense"))
    return g


def build_inception_like_graph(global_batch: int, n_blocks: int = 9) -> LayerGraph:
    """Synthetic multi-branch graph (Inception-v3 shape class): exercises the
    paper's graph-reduction algorithm. Each block: 4 parallel branches of
    1–3 convs joined by concat."""
    g: LayerGraph = []
    ch, hw = 32, 149
    g.append(LayerNode("stem", flops=2.0 * global_batch * hw * hw * 9 * 3 * ch,
                       param_bytes=9 * 3 * ch * 4,
                       act_out_bytes=global_batch * hw * hw * ch * _BYTES,
                       parallel_units=global_batch, kind="conv"))
    for b in range(n_blocks):
        hwb = max(8, hw // (1 + b // 3))
        chb = ch * (1 + b // 3)
        branches = []
        for j, depth in enumerate((1, 2, 3, 1)):
            chain = tuple(
                LayerNode(
                    f"b{b}_br{j}_conv{k}",
                    flops=2.0 * global_batch * hwb * hwb * (1 if k == 0 else 9) * chb * chb // 4,
                    param_bytes=(1 if k == 0 else 9) * chb * chb // 4 * 4,
                    act_out_bytes=global_batch * hwb * hwb * chb // 4 * _BYTES,
                    parallel_units=global_batch,
                    kind="conv",
                )
                for k in range(depth)
            )
            branches.append(chain)
        g.append(ParallelBlock(f"block{b}", tuple(branches)))
    g.append(LayerNode("classifier", flops=2.0 * global_batch * 2048 * 1000,
                       param_bytes=2048 * 1000 * 4,
                       act_out_bytes=global_batch * 1000 * _BYTES,
                       parallel_units=global_batch, kind="dense"))
    return g
