"""RWKV-6 "Finch" block — attention-free, data-dependent decay.
[arXiv:2404.05892]

Time-mix: per-head linear recurrence S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t with
*data-dependent* per-channel decay w_t (the Finch hallmark, produced by a
low-rank projection), read out as o_t = r_t · (S_{t-1} + diag(u) k_t ⊗ v_t).

Training uses the chunked parallel form (intra-chunk O(L²) matmuls +
inter-chunk state recurrence — same TPU-native structure as SSD); decode
carries the (B, H, dk, dv) state.  kernels/wkv6.py is the Pallas version of
the chunk inner loop; kernels/ref.py holds the sequential oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, cast, rms_norm

DECAY_RANK = 64


def rwkv6_schema(cfg) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    H, hd = cfg.num_heads, cfg.d_head
    return {
        # time-mix
        "mix_r": ParamSpec((D,), ("norm",), init="zeros"),
        "mix_k": ParamSpec((D,), ("norm",), init="zeros"),
        "mix_v": ParamSpec((D,), ("norm",), init="zeros"),
        "mix_w": ParamSpec((D,), ("norm",), init="zeros"),
        "mix_g": ParamSpec((D,), ("norm",), init="zeros"),
        "wr": ParamSpec((D, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((D, H, hd), ("embed", "heads", "head_dim")),
        "wv": ParamSpec((D, H, hd), ("embed", "heads", "head_dim")),
        "wg": ParamSpec((D, H, hd), ("embed", "heads", "head_dim")),
        "w_lora_a": ParamSpec((D, DECAY_RANK), ("embed", "norm"), init="small_normal"),
        "w_lora_b": ParamSpec((DECAY_RANK, D), ("norm", "embed"), init="small_normal"),
        "w0": ParamSpec((D,), ("norm",), init="zeros"),
        "u_bonus": ParamSpec((H, hd), ("heads", "head_dim"), init="small_normal"),
        "ln_x": ParamSpec((D,), ("norm",), init="zeros"),
        "wo": ParamSpec((H, hd, D), ("heads", "head_dim", "embed")),
        # channel-mix
        "cmix_k": ParamSpec((D,), ("norm",), init="zeros"),
        "cmix_r": ParamSpec((D,), ("norm",), init="zeros"),
        "cw_k": ParamSpec((D, F), ("embed", "mlp")),
        "cw_v": ParamSpec((F, D), ("mlp", "embed")),
        "cw_r": ParamSpec((D, D), ("embed", "embed_out")),
    }


def token_shift(x: jax.Array, prev: jax.Array = None) -> jax.Array:
    """x: (B,S,D) -> previous token's features (zeros / `prev` at position 0)."""
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev)
    return shifted


def _mix(x, xs, mu):
    return x + (xs - x) * jax.nn.sigmoid(mu)[None, None, :].astype(x.dtype)


def wkv6_chunked(
    r: jax.Array,  # (B, S, H, K)
    k: jax.Array,  # (B, S, H, K)
    v: jax.Array,  # (B, S, H, V)
    w: jax.Array,  # (B, S, H, K)  per-channel decay in (0,1)
    u: jax.Array,  # (H, K) bonus
    chunk: int = 64,
    init_state=None,  # (B, H, K, V)
):
    """Chunked parallel WKV-6. Returns (o (B,S,H,V), final_state)."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    f32 = jnp.float32
    rc = r.astype(f32).reshape(B, nc, chunk, H, K)
    kc = k.astype(f32).reshape(B, nc, chunk, H, K)
    vc = v.astype(f32).reshape(B, nc, chunk, H, V)
    lw = jnp.log(jnp.clip(w.astype(f32), 1e-6, 1.0)).reshape(B, nc, chunk, H, K)
    cs = jnp.cumsum(lw, axis=2)  # inclusive cumsum within chunk (B,nc,L,H,K)

    # intra-chunk: A[t,j] = r_t · (k_j ⊙ exp(cs_{t-1} - cs_j)) for j<t; diag uses u
    r_dec = rc * jnp.exp(cs - lw)  # r_t ⊙ exp(cs_{t-1})  (cs_{t-1} = cs_t - lw_t)
    k_dec = kc * jnp.exp(-cs)  # k_j ⊙ exp(-cs_j)
    A = jnp.einsum("bclhk,bcmhk->bchlm", r_dec, k_dec)  # (B,nc,H,L,L)
    L_idx = jnp.arange(chunk)
    strict = (L_idx[:, None] > L_idx[None, :])  # j < t
    A = A * strict[None, None, None, :, :]
    diag = jnp.einsum("bclhk,hk,bclhk->bclh", rc, u.astype(f32), kc)  # (B,nc,L,H)
    o_intra = jnp.einsum("bchlm,bcmhv->bclhv", A, vc)
    o_intra = o_intra + diag[..., None] * vc

    # chunk state summaries: sum_j (k_j ⊙ exp(cs_L - cs_j)) ⊗ v_j
    cs_last = cs[:, :, -1:]  # (B,nc,1,H,K)
    k_tail = kc * jnp.exp(cs_last - cs)
    chunk_states = jnp.einsum("bclhk,bclhv->bchkv", k_tail, vc)
    chunk_decay = jnp.exp(cs_last[:, :, 0])  # (B,nc,H,K)

    s0 = (
        jnp.zeros((B, H, K, V), f32) if init_state is None else init_state.astype(f32)
    )

    def body(s_prev, inp):
        st, dec = inp  # (B,H,K,V), (B,H,K)
        return s_prev * dec[..., None] + st, s_prev

    final_state, prev_states = jax.lax.scan(
        body,
        s0,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,nc,H,K,V)

    # inter-chunk: o_t += (r_t ⊙ exp(cs_{t-1})) · S_prev
    o_inter = jnp.einsum("bclhk,bchkv->bclhv", r_dec, prev_states)
    o = (o_intra + o_inter).reshape(B, S, H, V)
    return o.astype(r.dtype), final_state


def wkv6_decode_step(r, k, v, w, u, state):
    """Single-token step. r/k/v/w: (B,1,H,*); state (B,H,K,V) fp32."""
    f32 = jnp.float32
    r0, k0, v0, w0 = (a.astype(f32)[:, 0] for a in (r, k, v, w))
    kv = jnp.einsum("bhk,bhv->bhkv", k0, v0)
    o = jnp.einsum("bhk,bhkv->bhv", r0, state + u.astype(f32)[None, :, :, None] * kv)
    state = state * w0[..., None] + kv
    return o[:, None].astype(r.dtype), state


def rwkv6_time_mix(p: dict, x: jax.Array, cfg, state=None, decode: bool = False,
                   shift_state=None):
    dt_c = x.dtype
    B, S, D = x.shape
    H, hd = cfg.num_heads, cfg.d_head
    xs = token_shift(x, shift_state)
    xr = _mix(x, xs, p["mix_r"])
    xk = _mix(x, xs, p["mix_k"])
    xv = _mix(x, xs, p["mix_v"])
    xw = _mix(x, xs, p["mix_w"])
    xg = _mix(x, xs, p["mix_g"])
    r = jnp.einsum("bsd,dhk->bshk", xr, cast(p["wr"], dt_c))
    k = jnp.einsum("bsd,dhk->bshk", xk, cast(p["wk"], dt_c))
    v = jnp.einsum("bsd,dhk->bshk", xv, cast(p["wv"], dt_c))
    g = jnp.einsum("bsd,dhk->bshk", xg, cast(p["wg"], dt_c))
    # data-dependent decay (Finch): w = exp(-exp(w0 + lora(xw)))
    dec = p["w0"].astype(jnp.float32)[None, None, :] + jnp.einsum(
        "bsr,rd->bsd",
        jnp.tanh(jnp.einsum("bsd,dr->bsr", xw.astype(jnp.float32), p["w_lora_a"].astype(jnp.float32))),
        p["w_lora_b"].astype(jnp.float32),
    )
    w = jnp.exp(-jnp.exp(dec)).reshape(B, S, H, hd)
    if decode:
        o, new_state = wkv6_decode_step(r, k, v, w.astype(dt_c), p["u_bonus"], state)
    else:
        chunk = 64 if S % 64 == 0 else S
        o, new_state = wkv6_chunked(r, k, v, w.astype(dt_c), p["u_bonus"], chunk=chunk,
                                    init_state=state)
    o = o.reshape(B, S, D)
    o = rms_norm(o, p["ln_x"], cfg.norm_eps) * jax.nn.silu(g).reshape(B, S, D)
    out = jnp.einsum("bshk,hkd->bsd", o.reshape(B, S, H, hd), cast(p["wo"], dt_c))
    return out, new_state, x[:, -1]


def rwkv6_channel_mix(p: dict, x: jax.Array, shift_state=None):
    dt_c = x.dtype
    xs = token_shift(x, shift_state)
    xk = _mix(x, xs, p["cmix_k"])
    xr = _mix(x, xs, p["cmix_r"])
    k = jnp.einsum("bsd,df->bsf", xk, cast(p["cw_k"], dt_c))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, cast(p["cw_v"], dt_c))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, cast(p["cw_r"], dt_c)))
    return r * kv, x[:, -1]
