"""Core layers + parameter-schema machinery (pure functional, no flax).

A model is described by a *schema*: a pytree of ``ParamSpec`` leaves.  From
one schema we derive
  - ``init_params``      : random arrays (jit-friendly),
  - ``abstract_params``  : ShapeDtypeStructs (dry-run, no allocation),
  - ``logical_axes``     : pytree of logical-axis-name tuples, which
                           dist.sharding maps to mesh PartitionSpecs.

Logical axis vocabulary (mapping decided per-config in dist/sharding.py):
  'layers'    leading stacked-layer axis (scan dim)           -> never sharded
  'embed'     d_model dim of weights                          -> FSDP ('data')
  'heads'     query-head dim                                  -> TP ('model')
  'kv_heads'  kv-head dim                                     -> TP or replicated
  'head_dim'  per-head feature dim                            -> never sharded
  'mlp'       d_ff dim                                        -> TP ('model')
  'vocab'     vocabulary dim                                  -> TP ('model')
  'expert'    MoE expert dim                                  -> EP ('model') or None
  'moe_mlp'   per-expert d_ff dim                             -> TP for grok-style
  'ssm_inner' mamba inner dim                                 -> TP ('model')
  'ssm_state' SSM state dim                                   -> never sharded
  'norm'      norm scales / biases / small vectors            -> replicated
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Param schema
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple  # logical axis names, len == len(shape)
    init: str = "normal"  # 'normal' | 'zeros' | 'ones' | 'embed' | 'small_normal'
    dtype: str = "float32"
    scale: Optional[float] = None  # override init std

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _fan_in(shape: tuple) -> int:
    if len(shape) == 1:
        return shape[0]
    # weights laid out (in..., out...) — use product of all but last dim group;
    # we approximate fan_in as prod(shape[:-1]) capped for 3d head layouts.
    return int(max(1, math.prod(shape[:-1])))


def init_leaf(key: jax.Array, spec: ParamSpec) -> jax.Array:
    dt = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "embed":
        std = spec.scale if spec.scale is not None else 0.02
        return (jax.random.normal(key, spec.shape) * std).astype(dt)
    std = spec.scale if spec.scale is not None else 1.0 / math.sqrt(_fan_in(spec.shape))
    if spec.init == "small_normal":
        std = spec.scale if spec.scale is not None else 0.02
    return (jax.random.normal(key, spec.shape) * std).astype(dt)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def init_params(rng: jax.Array, schema: Any) -> Any:
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(treedef, [init_leaf(k, s) for k, s in zip(keys, leaves)])


def abstract_params(schema: Any) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)), schema, is_leaf=is_spec
    )


def logical_axes(schema: Any) -> Any:
    return jax.tree.map(lambda s: s.axes, schema, is_leaf=is_spec)


def stack_schema(schema: Any, n: int) -> Any:
    """Prepend a stacked 'layers' axis to every spec (scan-over-layers)."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.dtype, s.scale),
        schema,
        is_leaf=is_spec,
    )


def param_count(schema: Any) -> int:
    leaves, _ = jax.tree.flatten(schema, is_leaf=is_spec)
    return sum(math.prod(s.shape) for s in leaves)


# ---------------------------------------------------------------------------
# Numerics helpers
# ---------------------------------------------------------------------------


def cast(x: jax.Array, dtype: str) -> jax.Array:
    return x.astype(jnp.dtype(dtype))


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n_heads, d_head); positions: (..., S) int32."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)  # (d_head/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Schema builders for common sub-modules
# ---------------------------------------------------------------------------


def attention_schema(cfg) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    s: dict = {
        "wq": ParamSpec((D, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((D, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((D, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, hd, D), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((H, hd), ("heads", "head_dim"), init="zeros")
        s["bk"] = ParamSpec((KV, hd), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = ParamSpec((KV, hd), ("kv_heads", "head_dim"), init="zeros")
    return s


def mlp_schema(cfg) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "wg": ParamSpec((D, F), ("embed", "mlp")),
        "wu": ParamSpec((D, F), ("embed", "mlp")),
        "wd": ParamSpec((F, D), ("mlp", "embed")),
    }


def qkv_project(p: dict, x: jax.Array, cfg) -> tuple:
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, cast(p["wq"], dt))
    k = jnp.einsum("bsd,dhk->bshk", x, cast(p["wk"], dt))
    v = jnp.einsum("bsd,dhk->bshk", x, cast(p["wv"], dt))
    if cfg.qkv_bias:
        q = q + cast(p["bq"], dt)
        k = k + cast(p["bk"], dt)
        v = v + cast(p["bv"], dt)
    return q, k, v


def out_project(p: dict, attn_out: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", attn_out, cast(p["wo"], attn_out.dtype))


def mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, cast(p["wg"], dt))
    u = jnp.einsum("bsd,df->bsf", x, cast(p["wu"], dt))
    return jnp.einsum("bsf,fd->bsd", swiglu(g, u), cast(p["wd"], dt))


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def softmax_xent(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None):
    """logits: (B, S, V) any float dtype; labels int32 (B, S). fp32 reduction."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
