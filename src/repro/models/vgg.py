"""VGG-16 in JAX — the paper's own evaluation model.

Used by the paper-reproduction benchmarks (Fig 1/3/5/9/10, Table 3) and the
burst-planner end-to-end demo. NHWC layout, lax conv.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.vgg16 import ConvSpec, DenseSpec, VGGConfig
from repro.models.layers import ParamSpec, init_params, is_spec, softmax_xent


def vgg_schema(vcfg: VGGConfig) -> dict:
    schema: dict = {}
    for spec in vcfg.layers:
        if isinstance(spec, ConvSpec):
            schema[spec.name] = {
                "w": ParamSpec(
                    (spec.kernel, spec.kernel, spec.in_ch, spec.out_ch),
                    ("norm", "norm", "embed", "mlp"),
                ),
                "b": ParamSpec((spec.out_ch,), ("mlp",), init="zeros"),
            }
        else:
            schema[spec.name] = {
                "w": ParamSpec((spec.in_dim, spec.out_dim), ("embed", "mlp")),
                "b": ParamSpec((spec.out_dim,), ("mlp",), init="zeros"),
            }
    return schema


def forward(params: dict, images: jax.Array, vcfg: VGGConfig) -> jax.Array:
    """images: (B, H, W, 3) -> logits (B, num_classes)."""
    h = images
    for spec in vcfg.layers:
        p = params[spec.name]
        if isinstance(spec, ConvSpec):
            h = jax.lax.conv_general_dilated(
                h, p["w"], window_strides=(1, 1), padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            h = jax.nn.relu(h + p["b"])
            if spec.pool_after:
                h = jax.lax.reduce_window(
                    h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
                )
        else:
            if h.ndim == 4:
                h = h.reshape(h.shape[0], -1)
            h = h @ p["w"] + p["b"]
            if spec.name != vcfg.layers[-1].name:
                h = jax.nn.relu(h)
    return h


def loss_fn(params: dict, batch: dict, vcfg: VGGConfig):
    logits = forward(params, batch["images"], vcfg)
    xent = softmax_xent(logits[:, None, :], batch["labels"][:, None])
    return xent, {"loss": xent}


def init(rng: jax.Array, vcfg: VGGConfig):
    return init_params(rng, vgg_schema(vcfg))
