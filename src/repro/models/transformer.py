"""Decoder-only LM stack (dense + MoE variants) with scan-over-layers.

Covers: qwen2-72b, qwen2-1.5b, minicpm-2b, llama3-8b, pixtral-12b (backbone),
grok-1-314b, qwen3-moe-30b-a3b.  Layer stacks use ``jax.lax.scan`` over
stacked per-layer params with a configurable remat policy so the 80-layer
configs compile quickly and activation memory stays bounded.

Modes:
  forward(params, tokens)                        -> logits     (teacher forcing)
  prefill(params, tokens, cache_capacity)        -> (last-position logits, cache)
  decode_step(params, token, cache, cache_len)   -> (logits, cache)
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models.layers import (
    ParamSpec,
    apply_rope,
    attention_schema,
    cast,
    mlp_apply,
    mlp_schema,
    out_project,
    qkv_project,
    rms_norm,
    softmax_xent,
    stack_schema,
)
from repro.models.moe import moe_apply, moe_schema
from repro.dist import fsdp

VISION_PREFIX = 1024  # pixtral: number of precomputed patch-embedding positions


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------


def block_schema(cfg) -> dict:
    D = cfg.d_model
    s = {
        "ln1": ParamSpec((D,), ("norm",), init="zeros"),
        "ln2": ParamSpec((D,), ("norm",), init="zeros"),
        "attn": attention_schema(cfg),
    }
    if cfg.block_type == "moe":
        s["moe"] = moe_schema(cfg)
    else:
        s["mlp"] = mlp_schema(cfg)
    return s


def lm_schema(cfg) -> dict:
    D, Vp = cfg.d_model, cfg.padded_vocab
    schema = {
        "embed": ParamSpec((Vp, D), ("vocab", "embed"), init="embed"),
        "layers": stack_schema(block_schema(cfg), cfg.num_layers),
        "final_norm": ParamSpec((D,), ("norm",), init="zeros"),
    }
    if not cfg.tie_embeddings:
        schema["lm_head"] = ParamSpec((D, Vp), ("embed", "vocab"))
    if cfg.frontend == "vision":
        schema["frontend_proj"] = ParamSpec((D, D), ("embed", "embed_out"))
    return schema


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def decoder_block(p: dict, h: jax.Array, positions: jax.Array, cfg) -> tuple:
    """Full-sequence (train/prefill) block. Returns (h, (k, v), aux)."""
    a_in = rms_norm(h, p["ln1"], cfg.norm_eps)
    q, k, v = qkv_project(p["attn"], a_in, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    attn_out = attn_lib.attend(
        q, k, v, causal=True, window=cfg.sliding_window, softcap=cfg.attn_logit_softcap
    )
    h = h + out_project(p["attn"], attn_out)
    m_in = rms_norm(h, p["ln2"], cfg.norm_eps)
    if cfg.block_type == "moe":
        mlp_out, aux = moe_apply(p["moe"], m_in, cfg)
    else:
        mlp_out, aux = mlp_apply(p["mlp"], m_in), jnp.zeros((), jnp.float32)
    return h + mlp_out, (k, v), aux


def decoder_block_decode(
    p: dict,
    h: jax.Array,  # (B, 1, D)
    k_cache: jax.Array,  # (B, cap, KV, hd)
    v_cache: jax.Array,
    cache_len: jax.Array,  # scalar int32, or (B,) per-row lengths
    cfg,
) -> tuple:
    B = h.shape[0]
    per_row = jnp.ndim(cache_len) == 1  # continuous batching: ragged lanes
    positions = jnp.broadcast_to(
        jnp.reshape(cache_len, (-1, 1)).astype(jnp.int32), (B, 1)
    )
    a_in = rms_norm(h, p["ln1"], cfg.norm_eps)
    q, k, v = qkv_project(p["attn"], a_in, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if per_row:
        # each lane appends at its own length (one scatter per row)
        rows = jnp.arange(B)
        k_cache = k_cache.at[rows, cache_len].set(k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[rows, cache_len].set(v[:, 0].astype(v_cache.dtype))
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), cache_len, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), cache_len, 1)
    attn_out = attn_lib.decode_attention(
        q,
        k_cache.astype(q.dtype),
        v_cache.astype(q.dtype),
        cache_len + 1,
        window=cfg.sliding_window,
        softcap=cfg.attn_logit_softcap,
    )
    h = h + out_project(p["attn"], attn_out)
    m_in = rms_norm(h, p["ln2"], cfg.norm_eps)
    if cfg.block_type == "moe":
        mlp_out, _ = moe_apply(p["moe"], m_in, cfg)
    else:
        mlp_out = mlp_apply(p["mlp"], m_in)
    return h + mlp_out, k_cache, v_cache


def _maybe_remat(fn, cfg):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)  # 'full': save only layer boundaries


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------


def embed_tokens(params: dict, tokens: jax.Array, cfg) -> jax.Array:
    emb = fsdp.gather_leaf(params["embed"], ("vocab", "embed"))
    return cast(emb, jnp.dtype(cfg.dtype))[tokens]


def unembed(params: dict, h: jax.Array, cfg) -> jax.Array:
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        w = cast(fsdp.gather_leaf(params["embed"], ("vocab", "embed")), h.dtype)
        return jnp.einsum("bsd,vd->bsv", h, w)
    w = cast(fsdp.gather_leaf(params["lm_head"], ("embed", "vocab")), h.dtype)
    return jnp.einsum("bsd,dv->bsv", h, w)


def hidden_states(params: dict, tokens: jax.Array, cfg, patch_embeds=None):
    """Embed (+ optional vision prefix) and run the layer stack."""
    h = embed_tokens(params, tokens, cfg)
    if cfg.frontend == "vision" and patch_embeds is not None:
        fp = fsdp.gather_leaf(params["frontend_proj"], ("embed", "embed_out"))
        pe = jnp.einsum("bsd,de->bse", patch_embeds.astype(h.dtype), cast(fp, h.dtype))
        h = jnp.concatenate([pe, h], axis=1)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    bschema = block_schema(cfg)
    blk = _maybe_remat(
        lambda lp, hh: decoder_block(fsdp.gather(lp, bschema), hh, positions, cfg), cfg
    )

    def body(carry, lp):
        hh, aux = carry
        hh, _, a = blk(lp, hh)
        return (hh, aux + a), None

    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), params["layers"])
    return h, aux / cfg.num_layers


def forward(params: dict, tokens: jax.Array, cfg, patch_embeds=None) -> jax.Array:
    h, _ = hidden_states(params, tokens, cfg, patch_embeds)
    return unembed(params, h, cfg)


def loss_fn(params: dict, batch: dict, cfg):
    """batch: tokens (B,S) int32, labels (B,S) int32 (-1 = masked).
    Returns (loss, metrics)."""
    h, aux = hidden_states(params, batch["tokens"], cfg, batch.get("patch_embeds"))
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        h = h[:, batch["patch_embeds"].shape[1]:]  # loss over text positions only
    logits = unembed(params, h, cfg)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    xent = softmax_xent(logits, jnp.maximum(labels, 0), mask)
    loss = xent + 0.01 * aux
    return loss, {"loss": loss, "xent": xent, "moe_aux": aux}


def prefill(params: dict, tokens: jax.Array, cfg, cache_capacity: int, patch_embeds=None):
    """Returns (last-position logits (B, V), cache)."""
    h = embed_tokens(params, tokens, cfg)
    if cfg.frontend == "vision" and patch_embeds is not None:
        fp = fsdp.gather_leaf(params["frontend_proj"], ("embed", "embed_out"))
        pe = jnp.einsum("bsd,de->bse", patch_embeds.astype(h.dtype), cast(fp, h.dtype))
        h = jnp.concatenate([pe, h], axis=1)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    bschema = block_schema(cfg)

    def body(hh, lp):
        hh, (k, v), _ = decoder_block(fsdp.gather(lp, bschema), hh, positions, cfg)
        pad = cache_capacity - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return hh, {"k": kc.astype(jnp.dtype(cfg.dtype)), "v": vc.astype(jnp.dtype(cfg.dtype))}

    h, cache = jax.lax.scan(body, h, params["layers"])
    logits = unembed(params, h[:, -1:], cfg)[:, 0]
    return logits, cache


def decode_step(params: dict, token: jax.Array, cache: dict, cache_len: jax.Array, cfg):
    """token: (B, 1) int32; cache: {'k','v'} stacked (L, B, cap, KV, hd).
    ``cache_len`` is a scalar (all lanes aligned) or a (B,) vector of
    per-lane lengths (continuous batching: lanes decode at ragged
    positions).  Returns (logits (B, V), new cache)."""
    h = embed_tokens(params, token, cfg)

    bschema = block_schema(cfg)

    def body(hh, xs):
        lp, c = xs
        lp = fsdp.gather(lp, bschema)
        hh, kc, vc = decoder_block_decode(lp, hh, c["k"], c["v"], cache_len, cfg)
        return hh, {"k": kc, "v": vc}

    h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))
    logits = unembed(params, h, cfg)[:, 0]
    return logits, new_cache


def cache_schema(cfg, batch: int, capacity: int) -> dict:
    """Abstract KV-cache layout (used by input_specs + serving engine)."""
    KV, hd, L = cfg.num_kv_heads, cfg.d_head, cfg.num_layers
    spec = ParamSpec(
        (L, batch, capacity, KV, hd),
        ("layers", "act_batch", "act_kv_seq", "kv_heads", "head_dim"),
        init="zeros",
        dtype=cfg.dtype,
    )
    return {"k": spec, "v": spec}
