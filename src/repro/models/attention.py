"""Attention execution paths.

Three paths, selected by shape (and backend — see kernels/ops.py):
  - ``full_attention``    : materializes (Sq, Skv) scores. Smoke scale only.
  - ``blocked_attention`` : lax.scan over query blocks; memory bounded by
                            block_q × Skv. The pure-XLA production path for
                            long sequences (the Pallas flash kernel replaces
                            it on real TPUs; see kernels/flash_attention.py).
  - ``decode_attention``  : single-query attention against a KV cache.

All paths implement GQA natively (no KV head repetition) plus causal,
sliding-window masking and grok-style logit soft-capping.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def _softcap(scores: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0.0:
        return cap * jnp.tanh(scores / cap)
    return scores


def _mask_bias(
    q_pos: jax.Array,  # (Sq,) absolute positions of queries
    k_pos: jax.Array,  # (Skv,) absolute positions of keys
    causal: bool,
    window: int,
) -> jax.Array:
    """Additive mask (Sq, Skv) in fp32."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window and window > 0:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF)


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B,Sq,KV,G,hd), k: (B,Skv,KV,hd) -> (B,KV,G,Sq,Skv) fp32."""
    return jnp.einsum("bsngh,btnh->bngst", q, k, preferred_element_type=jnp.float32)


def _gqa_out(p: jax.Array, v: jax.Array) -> jax.Array:
    """p: (B,KV,G,Sq,Skv) fp32, v: (B,Skv,KV,hd) -> (B,Sq,KV,G,hd)."""
    return jnp.einsum("bngst,btnh->bsngh", p, v.astype(p.dtype))


def full_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, KV, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd) * (1.0 / math.sqrt(hd))
    scores = _gqa_scores(qg, k)
    scores = _softcap(scores, softcap)
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(k.shape[1])
    scores = scores + _mask_bias(q_pos, k_pos, causal, window)
    p = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(p, v)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def blocked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 512,
) -> jax.Array:
    """Scan over query blocks; each block softmaxes over its full (masked)
    key row, so no online-softmax state is needed and peak memory is
    O(block_q × Skv) per head group."""
    B, Sq, H, hd = q.shape
    if Sq % block_q != 0 or Sq <= block_q:
        return full_attention(q, k, v, causal=causal, window=window, softcap=softcap)
    KV = k.shape[2]
    G = H // KV
    nblk = Sq // block_q
    qg = q.reshape(B, nblk, block_q, KV, G, hd) * (1.0 / math.sqrt(hd))
    qg = jnp.moveaxis(qg, 1, 0)  # (nblk, B, block_q, KV, G, hd)
    k_pos = jnp.arange(k.shape[1])

    def body(carry, inp):
        blk_idx, qb = inp
        scores = _gqa_scores(qb, k)
        scores = _softcap(scores, softcap)
        q_pos = blk_idx * block_q + jnp.arange(block_q)
        ok = jnp.ones((block_q, k.shape[1]), dtype=bool)
        if causal:
            ok &= k_pos[None, :] <= q_pos[:, None]
        if window and window > 0:
            ok &= k_pos[None, :] > (q_pos[:, None] - window)
        scores = scores + jnp.where(ok, 0.0, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        ob = _gqa_out(p, v).astype(q.dtype)  # (B, block_q, KV, G, hd)
        return carry, ob

    _, out = jax.lax.scan(body, None, (jnp.arange(nblk), qg))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, hd)
    return out


def decode_attention(
    q: jax.Array,  # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, Skv, KV, hd)
    v_cache: jax.Array,
    cache_len: jax.Array,  # (B,) or scalar — number of valid cache entries
    *,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    """Single new query attends over the valid prefix of the cache."""
    B, _, H, hd = q.shape
    Skv, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd) * (1.0 / math.sqrt(hd))
    scores = _gqa_scores(qg, k_cache)  # (B,KV,G,1,Skv)
    scores = _softcap(scores, softcap)
    k_pos = jnp.arange(Skv)
    valid = k_pos[None, :] < jnp.reshape(cache_len, (-1, 1))  # (B, Skv)
    if window and window > 0:
        valid &= k_pos[None, :] >= (jnp.reshape(cache_len, (-1, 1)) - window)
    bias = jnp.where(valid, 0.0, NEG_INF)[:, None, None, None, :]
    p = jax.nn.softmax(scores + bias, axis=-1)
    out = _gqa_out(p, v_cache)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def attend(
    q, k, v, *, causal=True, window=0, softcap=0.0, block_q=512, min_blocked_len=2048
):
    """Shape-dispatching attention used by the model forward passes."""
    if q.shape[1] >= min_blocked_len:
        return blocked_attention(
            q, k, v, causal=causal, window=window, softcap=softcap, block_q=block_q
        )
    return full_attention(q, k, v, causal=causal, window=window, softcap=softcap)
