"""Mamba-2 (SSD — state space duality) block. [arXiv:2405.21060]

TPU adaptation (DESIGN.md §2): the CUDA selective-scan becomes the *chunked*
SSD algorithm — intra-chunk work is dense MXU matmuls, inter-chunk state is a
short recurrence over n_chunks (a lax.scan over S/chunk steps).  The Pallas
kernel (kernels/ssd_scan.py) implements the intra-chunk part with explicit
VMEM tiling; this module is the XLA path + the block plumbing.

Layout: x (B, S, H, P) heads; B/C projections shared across heads
(ngroups=1), state size N; per-head scalar decay A and dt.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, cast, rms_norm


def mamba2_schema(cfg) -> dict:
    D, din = cfg.d_model, cfg.ssm_d_inner
    N, H = cfg.ssm_state, cfg.ssm_heads
    K = cfg.ssm_conv
    return {
        "wx": ParamSpec((D, din), ("embed", "ssm_inner")),
        "wz": ParamSpec((D, din), ("embed", "ssm_inner")),
        "wB": ParamSpec((D, N), ("embed", "ssm_state")),
        "wC": ParamSpec((D, N), ("embed", "ssm_state")),
        "wdt": ParamSpec((D, H), ("embed", "heads")),
        "dt_bias": ParamSpec((H,), ("heads",), init="zeros"),
        "A_log": ParamSpec((H,), ("heads",), init="zeros"),
        "D_skip": ParamSpec((H,), ("heads",), init="ones"),
        "conv_w": ParamSpec((K, din), ("norm", "ssm_inner"), init="small_normal"),
        "conv_b": ParamSpec((din,), ("ssm_inner",), init="zeros"),
        "gate_norm": ParamSpec((din,), ("ssm_inner",), init="zeros"),
        "wo": ParamSpec((din, D), ("ssm_inner", "embed")),
    }


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq. x: (B,S,C), w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):  # K is tiny (4); unrolled adds, no conv primitive needed
        out = out + pad[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return jax.nn.silu(out + b[None, None, :])


def _segsum(logd: jax.Array) -> jax.Array:
    """logd: (..., L). Returns (..., L, L) M[i,j] = sum_{k=j+1..i} logd_k for
    j <= i, -inf above diagonal (stable segment-sum trick from the SSD paper)."""
    L = logd.shape[-1]
    c = jnp.cumsum(logd, axis=-1)
    diff = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), dtype=bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P) inputs (pre-multiplied by nothing; dt applied here)
    dt: jax.Array,  # (B, S, H) softplus'd step sizes
    A: jax.Array,  # (H,) negative decay rates
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    chunk: int = 128,
    init_state=None,  # (B, H, P, N) or None
):
    """Chunked SSD. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    f32 = jnp.float32

    xb = (x.astype(f32) * dt.astype(f32)[..., None]).reshape(Bsz, nc, chunk, H, P)
    dA = (dt.astype(f32) * A.astype(f32)[None, None, :]).reshape(Bsz, nc, chunk, H)
    Bc = Bm.astype(f32).reshape(Bsz, nc, chunk, N)
    Cc = Cm.astype(f32).reshape(Bsz, nc, chunk, N)

    # --- intra-chunk (diagonal blocks): Y = (C B^T ⊙ L) X̄
    dAh = jnp.moveaxis(dA, -1, 2)  # (B, nc, H, L)
    L = jnp.exp(_segsum(dAh))  # (B, nc, H, L, L)
    CB = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)  # (B, nc, L, L)
    y_diag = jnp.einsum("bchlm,bclm,bcmhp->bclhp", L, CB, xb)

    # --- chunk summaries: state contribution of each chunk
    cum = jnp.cumsum(dAh, axis=-1)  # (B, nc, H, L)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)  # (B, nc, H, L)
    states = jnp.einsum("bchl,bcln,bclhp->bchpn", decay_to_end, Bc, xb)

    # --- inter-chunk recurrence over nc (short scan)
    chunk_decay = jnp.exp(cum[..., -1])  # (B, nc, H)
    s0 = (
        jnp.zeros((Bsz, H, P, N), f32)
        if init_state is None
        else init_state.astype(f32)
    )

    def body(s_prev, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    sc = jnp.moveaxis(states, 1, 0)  # (nc, B, H, P, N)
    dc = jnp.moveaxis(chunk_decay, 1, 0)  # (nc, B, H)
    final_state, prev_states = jax.lax.scan(body, s0, (sc, dc))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B, nc, H, P, N)

    # --- off-diagonal contribution: Y += (C ⊙ decay_from_start) · state_prev
    decay_from_start = jnp.exp(cum)  # (B, nc, H, L)
    y_off = jnp.einsum("bcln,bchl,bchpn->bclhp", Cc, decay_from_start, prev_states)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), final_state


def ssd_decode_step(
    x: jax.Array,  # (B, 1, H, P)
    dt: jax.Array,  # (B, 1, H)
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, 1, N)
    Cm: jax.Array,  # (B, 1, N)
    state: jax.Array,  # (B, H, P, N) fp32
):
    f32 = jnp.float32
    xb = x.astype(f32)[:, 0] * dt.astype(f32)[:, 0, :, None]  # (B,H,P)
    dec = jnp.exp(dt.astype(f32)[:, 0] * A.astype(f32)[None, :])  # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn", xb, Bm.astype(f32)[:, 0])
    state = state * dec[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(f32)[:, 0])
    return y[:, None].astype(x.dtype), state


def mamba2_apply(p: dict, u: jax.Array, cfg, state=None, decode: bool = False):
    """u: (B, S, D). Returns (out (B,S,D), new_state or None).

    Decode carries state = (ssm_state (B,H,P,N) fp32, conv_state (B,K-1,din))
    — the conv window tail, so decode matches the training conv exactly."""
    dt_c = u.dtype
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    B, S, D = u.shape
    x = jnp.einsum("bsd,di->bsi", u, cast(p["wx"], dt_c))
    z = jnp.einsum("bsd,di->bsi", u, cast(p["wz"], dt_c))
    if decode:
        ssm_state, conv_state = state
        window = jnp.concatenate([conv_state.astype(dt_c), x], axis=1)  # (B,K,din)
        w = cast(p["conv_w"], dt_c)
        xc = jnp.einsum("bki,ki->bi", window, w)[:, None, :]
        x = jax.nn.silu(xc + p["conv_b"].astype(dt_c)[None, None, :])
        new_conv_state = window[:, 1:]
        state = ssm_state
    else:
        x = causal_conv1d(x, cast(p["conv_w"], dt_c), cast(p["conv_b"], dt_c))
    Bm = jnp.einsum("bsd,dn->bsn", u, cast(p["wB"], dt_c))
    Cm = jnp.einsum("bsd,dn->bsn", u, cast(p["wC"], dt_c))
    dtv = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", u, cast(p["wdt"], dt_c)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = x.reshape(B, S, H, P)
    if decode:
        y, new_state = ssd_decode_step(xh, dtv, A, Bm, Cm, state)
    else:
        chunk = 128 if S % 128 == 0 else (64 if S % 64 == 0 else S)
        y, new_state = ssd_chunked(xh, dtv, A, Bm, Cm, chunk=chunk, init_state=state)
    y = y + xh * p["D_skip"].astype(dt_c)[None, None, :, None]
    y = y.reshape(B, S, H * P)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, cast(p["wo"], dt_c))
    if decode:
        return out, (new_state, new_conv_state)
    return out, new_state
