"""Model zoo: pure-functional JAX models for every assigned architecture."""
from repro.models.api import ModelAPI, get_model, input_specs, make_batch  # noqa: F401
