"""KV-cache allocation + sharding for the serving engine."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import param_shardings
from repro.models.layers import ParamSpec, is_spec


def init_cache(api, batch: int, capacity: int, mesh=None, rules=None) -> Any:
    """Concrete zeroed cache, optionally sharded."""
    schema = api.cache_schema(batch, capacity)
    if mesh is not None and rules is not None:
        sh = param_shardings(schema, rules, mesh)
        return jax.tree.map(
            lambda s, d: jax.device_put(jnp.zeros(s.shape, jnp.dtype(s.dtype)), d),
            schema, sh, is_leaf=is_spec,
        )
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)), schema, is_leaf=is_spec
    )


def cache_bytes(api, batch: int, capacity: int) -> int:
    schema = api.cache_schema(batch, capacity)
    total = 0
    for s in jax.tree.leaves(schema, is_leaf=is_spec):
        n = 1
        for d in s.shape:
            n *= d
        total += n * jnp.dtype(s.dtype).itemsize
    return total
