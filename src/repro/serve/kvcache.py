"""KV-cache allocation + sharding for the serving engine.

Two layers:

- ``init_cache``/``cache_bytes`` — the seed contiguous cache: one
  (L, batch, capacity, KV, hd) block per k/v leaf, optionally sharded by the
  same rules engine that shards parameters.  ``ServingEngine`` (fixed-batch)
  decodes against it directly.

- Paged serving (continuous batching): the physical store is a *page pool* —
  the very same ``init_cache`` schema instantiated with ``batch=n_pages`` and
  ``capacity=page_tokens``, so every sharding rule that applies to the
  contiguous cache applies unchanged to the pool, and capacity accounting is
  literally ``cache_bytes(api, n_pages, page_tokens)``.  ``PageAllocator``
  hands out pages to requests (per-request page tables, alloc on admit, free
  on finish; no page is ever owned by two live requests), and the gather /
  scatter helpers materialize a contiguous per-lane view for ``decode_step``
  and write the appended token's KV back through the page table.

Page 0 is reserved as a scratch page: batch lanes with no live request keep
decoding (the batch shape is static under jit) and their KV write is
redirected there via an all-zero page-table row, so a dead lane can never
corrupt a live request's pages.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.dist.sharding import param_shardings
from repro.models.layers import ParamSpec, is_spec


def init_cache(api, batch: int, capacity: int, mesh=None, rules=None) -> Any:
    """Concrete zeroed cache, optionally sharded."""
    schema = api.cache_schema(batch, capacity)
    if mesh is not None and rules is not None:
        sh = param_shardings(schema, rules, mesh)
        return jax.tree.map(
            lambda s, d: jax.device_put(jnp.zeros(s.shape, jnp.dtype(s.dtype)), d),
            schema, sh, is_leaf=is_spec,
        )
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)), schema, is_leaf=is_spec
    )


def cache_bytes(api, batch: int, capacity: int) -> int:
    schema = api.cache_schema(batch, capacity)
    total = 0
    for s in jax.tree.leaves(schema, is_leaf=is_spec):
        n = 1
        for d in s.shape:
            n *= d
        total += n * jnp.dtype(s.dtype).itemsize
    return total


# ---------------------------------------------------------------------------
# Paged pool (continuous batching)
# ---------------------------------------------------------------------------


SCRATCH_PAGE = 0  # never allocated; dead-lane writes land here


def init_paged_cache(api, n_pages: int, page_tokens: int, mesh=None, rules=None):
    """Physical page pool: the ``init_cache`` schema at ``batch=n_pages``,
    ``capacity=page_tokens`` — leaves (L, n_pages, page_tokens, KV, hd)."""
    return init_cache(api, n_pages, page_tokens, mesh, rules)


class PageAllocator:
    """Fixed-size-page allocator with per-request page tables.

    Pages are integer ids into the pool's page axis; ``alloc(req, n_tokens)``
    reserves ``ceil(n_tokens / page_tokens)`` pages for ``req`` (returning
    None — request stays queued — when the pool can't satisfy it), and
    ``free(req)`` returns every page to the free list on finish.  Page
    ``SCRATCH_PAGE`` is reserved and never handed out.
    """

    def __init__(self, n_pages: int, page_tokens: int):
        if n_pages < 2:
            raise ValueError("paged pool needs >= 2 pages (page 0 is scratch)")
        if page_tokens < 1:
            raise ValueError(f"page_tokens must be >= 1, got {page_tokens}")
        self.n_pages = n_pages
        self.page_tokens = page_tokens
        # LIFO free list: a just-freed request's pages are reused first,
        # which keeps the working set of hot pages small
        self._free: List[int] = list(range(n_pages - 1, SCRATCH_PAGE, -1))
        self.tables: Dict[Any, List[int]] = {}

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(1, int(n_tokens)) // self.page_tokens)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    def can_alloc(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= len(self._free)

    def alloc(self, req_id, n_tokens: int) -> Optional[List[int]]:
        """Reserve pages covering ``n_tokens`` for ``req_id``; None when the
        pool is exhausted (the caller queues the request, never drops it)."""
        if req_id in self.tables:
            raise ValueError(f"request {req_id!r} already holds pages")
        k = self.pages_for(n_tokens)
        if k > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(k)]
        self.tables[req_id] = pages
        return list(pages)

    def grow(self, req_id, n_tokens: int) -> Optional[List[int]]:
        """Extend ``req_id``'s table to cover ``n_tokens`` total; returns the
        full table, or None (caller must retire or wait) on exhaustion."""
        held = self.tables.get(req_id)
        if held is None:
            raise KeyError(f"request {req_id!r} holds no pages")
        need = self.pages_for(n_tokens) - len(held)
        if need <= 0:
            return list(held)
        if need > len(self._free):
            return None
        held.extend(self._free.pop() for _ in range(need))
        return list(held)

    def free(self, req_id) -> int:
        """Return ``req_id``'s pages to the pool; returns the count freed."""
        pages = self.tables.pop(req_id)
        self._free.extend(pages)
        return len(pages)

    def check_invariants(self) -> None:
        """No page owned twice, none leaked, scratch never handed out."""
        owned: List[int] = [p for t in self.tables.values() for p in t]
        assert len(owned) == len(set(owned)), "page owned by two live requests"
        assert SCRATCH_PAGE not in owned, "scratch page handed out"
        assert SCRATCH_PAGE not in self._free, "scratch page in free list"
        assert len(owned) + len(self._free) == self.n_pages - 1, "pages leaked"
        assert not (set(owned) & set(self._free)), "page both free and owned"


# -- pure gather/scatter (jit-friendly; leaves are (L, P, pt, ...) blocks) --


def gather_view(pool, tables: jax.Array):
    """Materialize a contiguous per-lane cache view from the page pool.

    ``tables`` is (B, max_pages) int32 — lane b's pages in order, padded with
    ``SCRATCH_PAGE`` (padded positions are masked by the lane's length).
    Leaves (L, P, pt, ...) -> (L, B, max_pages*pt, ...), the exact layout
    ``decode_step`` expects.
    """
    B, maxp = tables.shape

    def g(x):
        v = x[:, tables]  # (L, B, maxp, pt, ...)
        return v.reshape(v.shape[0], B, maxp * x.shape[2], *x.shape[3:])

    return jax.tree.map(g, pool)


def scatter_token(pool, view, tables: jax.Array, lens: jax.Array):
    """Write the KV entry each lane appended at position ``lens[b]`` of the
    gathered ``view`` back into that lane's page in the pool.  Lanes whose
    table row is all-``SCRATCH_PAGE`` (no live request) write to scratch."""
    B = tables.shape[0]
    rows = jnp.arange(B)

    def s(x, v):
        pt = x.shape[2]
        page = tables[rows, lens // pt]
        off = lens % pt
        new = v[:, rows, lens]  # (L, B, ...)
        return x.at[:, page, off].set(new.astype(x.dtype))

    return jax.tree.map(s, pool, view)


def cache_to_pages(cache, page_tokens: int):
    """Split one request's contiguous prefill cache (leaves (L, 1, cap, ...),
    ``cap`` a page multiple) into page chunks (L, cap/pt, pt, ...)."""

    def f(x):
        L, B, cap = x.shape[:3]
        assert B == 1, f"cache_to_pages expects a single-request cache, got B={B}"
        assert cap % page_tokens == 0, (cap, page_tokens)
        return x[:, 0].reshape(L, cap // page_tokens, page_tokens, *x.shape[3:])

    return jax.tree.map(f, cache)


def write_pages(pool, page_ids: Sequence[int], chunks):
    """Insert page chunks (leaves (L, k, pt, ...)) into pool pages
    ``page_ids`` — the prefill->decode handoff's final scatter."""
    ids = jnp.asarray(page_ids, jnp.int32)

    def w(x, c):
        return x.at[:, ids].set(c.astype(x.dtype))

    return jax.tree.map(w, pool, chunks)
