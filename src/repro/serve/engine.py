"""Batched serving engine: prefill + greedy decode with a sharded KV cache.

Continuous-batching-lite: requests are grouped into a fixed batch; finished
sequences are masked out (EOS) while the batch keeps stepping.  Decode steps
are jitted once (cache donated) — the XLA-executable analogue of the paper's
CUDA-graph serving path.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import get_model
from repro.serve.kvcache import init_cache


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_steps: int = 0
    decode_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.decode_steps / self.decode_s if self.decode_s else 0.0


class ServingEngine:
    def __init__(self, cfg, params, batch: int, capacity: int, mesh=None, rules=None):
        self.cfg = cfg
        self.api = get_model(cfg)
        self.params = params
        self.batch = batch
        self.capacity = capacity
        self.mesh = mesh
        self.stats = ServeStats()
        self._decode = jax.jit(self.api.decode_step, donate_argnums=(2,))
        self._cache = init_cache(self.api, batch, capacity, mesh, rules)
        self._len = jnp.int32(0)

    def prefill(self, prompts: np.ndarray) -> jax.Array:
        """prompts: (batch, prompt_len) int32. Feeds tokens one step at a
        time through decode_step (cache-building path shared with decode;
        models with a fused prefill use it when available)."""
        t0 = time.perf_counter()
        B, P = prompts.shape
        assert B == self.batch
        last_logits = None
        if self.api.prefill is not None and self.cfg.block_type in ("attn_mlp", "moe"):
            last_logits, cache = jax.jit(
                lambda p, t: self.api.prefill(p, t, self.capacity)
            )(self.params, jnp.asarray(prompts, jnp.int32))
            self._cache = cache
            self._len = jnp.int32(P)
        else:
            for i in range(P):
                tok = jnp.asarray(prompts[:, i : i + 1], jnp.int32)
                last_logits, self._cache = self._decode(
                    self.params, tok, self._cache, self._len
                )
                self._len = self._len + 1
        jax.block_until_ready(last_logits)
        self.stats.prefill_s += time.perf_counter() - t0
        return last_logits

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 eos_id: Optional[int] = None) -> np.ndarray:
        logits = self.prefill(prompts)
        out: List[np.ndarray] = []
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        done = np.zeros((self.batch,), bool)
        t0 = time.perf_counter()
        for _ in range(max_new_tokens):
            out.append(np.asarray(tok)[:, 0])
            if eos_id is not None:
                done |= out[-1] == eos_id
                if done.all():
                    break
            logits, self._cache = self._decode(self.params, tok, self._cache, self._len)
            self._len = self._len + 1
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            self.stats.decode_steps += 1
        jax.block_until_ready(tok)
        self.stats.decode_s += time.perf_counter() - t0
        return np.stack(out, axis=1)
