"""Serving engines: fixed-batch prefill+decode, and continuous batching.

``ServingEngine`` is the fixed-batch engine: requests are grouped into one
batch; finished sequences are masked to EOS (output and fed-back token)
while the batch keeps stepping.  Decode steps are jitted once (cache
donated) — the XLA-executable analogue of the paper's CUDA-graph serving
path — and the fused prefill is jitted once per prompt length, cached on
the engine.

``ContinuousBatchingEngine`` (ISSUE 9 tentpole) serves a request *stream*:
a paged KV pool (``serve/kvcache.py``) replaces the contiguous per-batch
cache, each batch lane holds one live request with its own page table and
length, finished lanes are retired and refilled mid-decode, and — when
built over ``split_mesh_for_serving`` submeshes — prefill and decode run
on disjoint device carvings with an explicit page handoff between them.
``serve/scheduler.py`` drives it over a request trace.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.models.api import get_model
from repro.serve.kvcache import (
    SCRATCH_PAGE,
    PageAllocator,
    cache_to_pages,
    gather_view,
    init_cache,
    init_paged_cache,
    scatter_token,
    write_pages,
)


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    prefills: int = 0
    decode_steps: int = 0    # batch steps dispatched
    decode_tokens: int = 0   # tokens actually produced (live lanes per step)
    decode_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        """Decode throughput in *tokens* (live lanes x steps), comparable
        across batch sizes — not batch steps."""
        return self.decode_tokens / self.decode_s if self.decode_s else 0.0

    @property
    def steps_per_s(self) -> float:
        return self.decode_steps / self.decode_s if self.decode_s else 0.0


class ServingEngine:
    def __init__(self, cfg, params, batch: int, capacity: int, mesh=None, rules=None):
        self.cfg = cfg
        self.api = get_model(cfg)
        self.params = params
        self.batch = batch
        self.capacity = capacity
        self.mesh = mesh
        self.rules = rules
        self.stats = ServeStats()
        self.prefill_compiles = 0  # bumped at trace time, not per call
        self._decode = jax.jit(self.api.decode_step, donate_argnums=(2,))
        self._prefill_fn: Optional[Callable] = None
        self.reset()

    def reset(self) -> None:
        """Fresh KV state: every batch decodes against its own cache, never
        a predecessor's leftover entries."""
        self._cache = init_cache(self.api, self.batch, self.capacity,
                                 self.mesh, self.rules)
        self._len = jnp.int32(0)

    def _fused_prefill(self) -> Callable:
        """The jitted fused prefill, built once and cached on the engine —
        per-call ``jax.jit(lambda ...)`` would recompile every batch."""
        if self._prefill_fn is None:
            def f(p, t):
                # runs at trace time only: counts compiles, not calls
                self.prefill_compiles += 1
                return self.api.prefill(p, t, self.capacity)

            self._prefill_fn = jax.jit(f)
        return self._prefill_fn

    def prefill(self, prompts: np.ndarray) -> jax.Array:
        """prompts: (batch, prompt_len) int32. Feeds tokens one step at a
        time through decode_step (cache-building path shared with decode;
        models with a fused prefill use it when available)."""
        t0 = time.perf_counter()
        B, P = prompts.shape
        assert B == self.batch
        last_logits = None
        if self.api.prefill is not None and self.cfg.block_type in ("attn_mlp", "moe"):
            last_logits, cache = self._fused_prefill()(
                self.params, jnp.asarray(prompts, jnp.int32)
            )
            self._cache = cache
            self._len = jnp.int32(P)
        else:
            for i in range(P):
                tok = jnp.asarray(prompts[:, i : i + 1], jnp.int32)
                last_logits, self._cache = self._decode(
                    self.params, tok, self._cache, self._len
                )
                self._len = self._len + 1
        jax.block_until_ready(last_logits)
        self.stats.prefill_s += time.perf_counter() - t0
        self.stats.prefills += 1
        return last_logits

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 eos_id: Optional[int] = None) -> np.ndarray:
        self.reset()
        logits = self.prefill(prompts)
        out: List[np.ndarray] = []
        tok = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        done = np.zeros((self.batch,), bool)
        t0 = time.perf_counter()
        for i in range(max_new_tokens):
            cur = tok.copy()
            if eos_id is not None:
                # finished rows emit EOS, not the garbage their lane keeps
                # argmax-ing, and keep feeding it back (frozen)
                cur[done] = eos_id
                done |= cur == eos_id
            out.append(cur)
            if done.all() or i + 1 == max_new_tokens:
                # the last emitted token needs no further decode: logits
                # would be discarded, so neither compute nor count the step
                break
            live = int((~done).sum()) if eos_id is not None else self.batch
            feed = jnp.asarray(cur[:, None], jnp.int32)
            logits, self._cache = self._decode(
                self.params, feed, self._cache, self._len
            )
            self._len = self._len + 1
            tok = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            self.stats.decode_steps += 1
            self.stats.decode_tokens += live
        jax.block_until_ready(self._len)
        self.stats.decode_s += time.perf_counter() - t0
        return np.stack(out, axis=1)


# ---------------------------------------------------------------------------
# Continuous batching over the paged pool (ISSUE 9 tentpole)
# ---------------------------------------------------------------------------


def _replicate(tree, mesh):
    sh = NamedSharding(mesh, PartitionSpec())
    return jax.tree.map(lambda x: jax.device_put(x, sh), tree)


class ContinuousBatchingEngine:
    """Request-stream serving: paged KV, per-lane lengths, lane reuse.

    ``lanes`` batch slots decode together under one jitted step; each live
    lane holds one request, its page-table row and its own length (the
    ``(B,)`` ``cache_len`` path of ``decode_step``).  Dead lanes keep
    stepping — the batch shape is static under jit — with an all-scratch
    table row, so their writes land in the reserved scratch page and their
    logits are discarded.  On ``admit`` a request is prefilled (exact
    prompt length, page-multiple cache capacity), its cache is split into
    pages and written into the pool, and the prefill's last-position
    argmax becomes its first generated token; ``step`` advances every live
    lane one token; ``retire`` frees the lane and returns its pages.

    With ``submeshes`` (``split_mesh_for_serving``), prefill runs on the
    prefill carving and decode on the disjoint decode carving: params are
    replicated onto both, the pool lives on the decode mesh, and the admit
    handoff reshards the prefilled page chunks across carvings before
    writing them into the pool.
    """

    def __init__(self, cfg, params, *, lanes: int, n_pages: int,
                 page_tokens: int = 16, lane_capacity: int = 128,
                 submeshes=None, debug_checks: bool = False):
        if cfg.block_type not in ("attn_mlp", "moe"):
            raise ValueError(
                f"paged serving needs a KV-cache family, got {cfg.block_type}"
            )
        self.cfg = cfg
        self.api = get_model(cfg)
        self.lanes = lanes
        self.page_tokens = page_tokens
        self.max_pages = -(-lane_capacity // page_tokens)
        self.lane_capacity = self.max_pages * page_tokens
        self.alloc = PageAllocator(n_pages, page_tokens)
        # page-accounting invariants re-checked after every mutating op
        # (admit/step/retire/reset) — cheap O(pages) sets, off by default,
        # on in tests and the bench smoke lane
        self.debug_checks = debug_checks
        self.submeshes = submeshes
        if submeshes is not None:
            self.params_prefill = _replicate(params, submeshes.prefill_mesh)
            self.params_decode = _replicate(params, submeshes.decode_mesh)
            self.pool = _replicate(
                init_paged_cache(self.api, n_pages, page_tokens),
                submeshes.decode_mesh,
            )
        else:
            self.params_prefill = self.params_decode = params
            self.pool = init_paged_cache(self.api, n_pages, page_tokens)
        self.tables = np.full((lanes, self.max_pages), SCRATCH_PAGE, np.int32)
        self.lens = np.zeros((lanes,), np.int32)
        self.lane_tok = np.zeros((lanes,), np.int32)
        self.lane_req: List[Optional[object]] = [None] * lanes
        self.stats = ServeStats()
        self.prefill_compiles = 0
        self._prefill_fns: Dict[int, Callable] = {}
        self._decode = self._make_decode()

    def _make_decode(self) -> Callable:
        api = self.api

        def step(params, tok, pool, tables, lens):
            view = gather_view(pool, tables)
            logits, new_view = api.decode_step(params, tok, view, lens)
            return logits, scatter_token(pool, new_view, tables, lens)

        return jax.jit(step, donate_argnums=(2,))

    def reset(self) -> None:
        """Fresh serving state (pool, tables, allocator, stats); the jitted
        decode/prefill executables are kept — warmup survives a reset."""
        n_pages = self.alloc.n_pages
        self.alloc = PageAllocator(n_pages, self.page_tokens)
        pool = init_paged_cache(self.api, n_pages, self.page_tokens)
        if self.submeshes is not None:
            pool = _replicate(pool, self.submeshes.decode_mesh)
        self.pool = pool
        self.tables[:] = SCRATCH_PAGE
        self.lens[:] = 0
        self.lane_tok[:] = 0
        self.lane_req = [None] * self.lanes
        self.stats = ServeStats()
        self._debug_check()

    def _debug_check(self) -> None:
        if self.debug_checks:
            self.alloc.check_invariants()

    # -- capacity ----------------------------------------------------------

    def live_count(self) -> int:
        return sum(1 for r in self.lane_req if r is not None)

    def has_free_lane(self) -> bool:
        return any(r is None for r in self.lane_req)

    def can_fit(self, req, check: bool = False) -> bool:
        """Whether ``req`` can *ever* run here (lane capacity + pool size);
        ``check=True`` raises — an oversize request is a config error, not
        a transient full-pool condition."""
        need = self.alloc.pages_for(req.total_tokens)
        ok = (req.total_tokens <= self.lane_capacity
              and need <= self.alloc.n_pages - 1)
        if check and not ok:
            raise ValueError(
                f"request {req.rid!r} needs {req.total_tokens} tokens "
                f"({need} pages); engine lanes hold {self.lane_capacity} "
                f"tokens over a {self.alloc.n_pages - 1}-page pool"
            )
        return ok

    # -- prefill (per prompt length, jitted once each) ---------------------

    def _prefill_fn(self, prompt_len: int) -> Callable:
        fn = self._prefill_fns.get(prompt_len)
        if fn is None:
            cap = self.alloc.pages_for(prompt_len) * self.page_tokens

            def f(p, t):
                self.prefill_compiles += 1  # trace-time: counts compiles
                return self.api.prefill(p, t, cap)

            fn = self._prefill_fns[prompt_len] = jax.jit(f)
        return fn

    # -- scheduler-facing ops ----------------------------------------------

    def admit(self, req) -> bool:
        """Prefill ``req`` into a free lane.  False when the page pool
        can't hold it right now (caller keeps it queued)."""
        lane = next(
            (i for i, r in enumerate(self.lane_req) if r is None), None
        )
        if lane is None:
            return False
        pages = self.alloc.alloc(req.rid, req.total_tokens)
        if pages is None:
            return False
        t0 = time.perf_counter()
        P = req.prompt_len
        logits, cache = self._prefill_fn(P)(
            self.params_prefill, jnp.asarray(req.prompt[None, :], jnp.int32)
        )
        first = int(jnp.argmax(logits[0]))
        chunks = cache_to_pages(cache, self.page_tokens)
        if self.submeshes is not None:
            # the disaggregation handoff: reshard the prefilled pages from
            # the prefill carving onto the decode carving, then scatter
            chunks = _replicate(chunks, self.submeshes.decode_mesh)
        n_pf = self.alloc.pages_for(P)
        self.pool = write_pages(self.pool, pages[:n_pf], chunks)
        self.stats.prefill_s += time.perf_counter() - t0
        self.stats.prefills += 1
        row = np.full((self.max_pages,), SCRATCH_PAGE, np.int32)
        row[: len(pages)] = pages
        self.tables[lane] = row
        self.lens[lane] = P
        self.lane_tok[lane] = first
        self.lane_req[lane] = req
        req.tokens.append(first)
        self._debug_check()
        return True

    def step(self) -> List[object]:
        """One decode tick over every live lane; returns newly finished
        requests (their lanes already retired)."""
        live = [i for i, r in enumerate(self.lane_req) if r is not None]
        if not live:
            return []
        t0 = time.perf_counter()
        logits, self.pool = self._decode(
            self.params_decode,
            jnp.asarray(self.lane_tok[:, None], jnp.int32),
            self.pool,
            jnp.asarray(self.tables),
            jnp.asarray(self.lens),
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.stats.decode_s += time.perf_counter() - t0
        self.stats.decode_steps += 1
        finished: List[object] = []
        for lane in live:
            req = self.lane_req[lane]
            self.lens[lane] += 1
            tok = int(nxt[lane])
            req.tokens.append(tok)
            self.lane_tok[lane] = tok
            self.stats.decode_tokens += 1
            if req.decoding_done():
                finished.append(req)
                self._retire_lane(lane)
        self._debug_check()
        return finished

    def retire(self, req) -> None:
        """Free ``req``'s lane and pages (instant-finish path: a request
        whose prefill already satisfied it)."""
        for lane, r in enumerate(self.lane_req):
            if r is req:
                self._retire_lane(lane)
                return
        raise KeyError(f"request {req.rid!r} holds no lane")

    def _retire_lane(self, lane: int) -> None:
        req = self.lane_req[lane]
        self.alloc.free(req.rid)
        self.tables[lane] = SCRATCH_PAGE
        self.lens[lane] = 0
        self.lane_tok[lane] = 0
        self.lane_req[lane] = None
        self._debug_check()
