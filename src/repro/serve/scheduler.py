"""Continuous-batching scheduler + request-level admission (ISSUE 9).

The scheduler owns request bookkeeping and the serving timeline; the engine
(``serve/engine.py``) owns device state.  Per tick it (1) moves trace
arrivals into the ready queue, (2) runs the admission sweep —
``ServingAdmission`` casts serving as a one-stage ``serving_plan`` and
reuses ``Collocator.admit()`` with the TTFT SLO as the slowdown bound, so
decode requests pack into the prefill stage's burst gap exactly like
training tenants pack into a foreground plan's gaps — (3) slots admitted
requests into freed batch lanes (prefill + page alloc), and (4) advances
every live lane one decode step, retiring finished requests so their lanes
and pages free up mid-decode.

Requests an admission sweep or page exhaustion defers stay queued — they
are never dropped — and time is a *virtual clock* advanced by the measured
wall duration of each engine operation, so a trace replays deterministically
against real compute costs without wall-clock sleeps.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.multiplex import (
    AdmissionDecision,
    BgTenant,
    Collocator,
    InterferenceModel,
    MultiplexConfig,
)
from repro.core.plan import serving_plan


@dataclass
class Request:
    """One serving request: a prompt, a decode budget, and its timeline.

    ``arrival`` is trace time (seconds).  The scheduler fills the
    ``admitted_at``/``first_token_at``/``finished_at`` marks and the engine
    appends generated token ids to ``tokens``.
    """

    rid: Any
    prompt: np.ndarray  # (P,) int32 token ids
    max_new_tokens: int
    arrival: float = 0.0
    eos_id: Optional[int] = None
    # filled during serving
    tokens: List[int] = field(default_factory=list)
    admitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError(f"request {self.rid!r}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid!r}: max_new_tokens < 1")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)

    @property
    def total_tokens(self) -> int:
        """Upper bound on KV positions this request ever occupies."""
        return self.prompt_len + self.max_new_tokens

    @property
    def done(self) -> bool:
        return self.finished_at is not None

    def decoding_done(self) -> bool:
        """Token budget exhausted or EOS emitted (engine finish condition)."""
        if len(self.tokens) >= self.max_new_tokens:
            return True
        return (self.eos_id is not None and len(self.tokens) > 0
                and self.tokens[-1] == self.eos_id)

    @property
    def latency(self) -> float:
        """Arrival -> last token (inf while unfinished)."""
        if self.finished_at is None:
            return float("inf")
        return self.finished_at - self.arrival

    @property
    def ttft(self) -> float:
        """Arrival -> first token (inf while unstarted)."""
        if self.first_token_at is None:
            return float("inf")
        return self.first_token_at - self.arrival


class VirtualClock:
    """Serving timeline advanced by measured op durations (no sleeps)."""

    def __init__(self, now: float = 0.0):
        self.now = float(now)

    def advance(self, dt: float) -> None:
        if dt < 0.0:
            raise ValueError(f"clock can't run backwards (dt={dt})")
        self.now += dt

    def advance_to(self, t: float) -> None:
        self.now = max(self.now, float(t))


class ServingAdmission:
    """Request-level admission: ``Collocator.admit()`` over a serving plan.

    The serving plan casts prefill as the latency-critical foreground
    (``n_prefill`` of ``n_devices``) and the decode carving as its burst
    gap; every candidate decode request becomes a ``BgTenant`` packed into
    that gap.  The QoS bound is the latency SLO expressed as allowed
    prefill inflation — ``ttft_slo / prefill_time`` — the serving analogue
    of the paper's 1.33x training bound: admit the largest request roster
    whose predicted interference keeps time-to-first-token inside the SLO.
    The Collocator is built once and re-rostered per sweep via
    ``set_tenants`` (keeping its calibrated interference model), and its
    ``density_slope`` is what lets the sweep reject the *marginal* request
    rather than all-or-nothing.
    """

    def __init__(self, n_devices: int, n_prefill: int, *,
                 prefill_time: float, decode_step_time: float,
                 ttft_slo: float,
                 interference: Optional[InterferenceModel] = None,
                 max_inflight: int = 8):
        if ttft_slo < prefill_time:
            raise ValueError(
                f"ttft_slo {ttft_slo:g}s is below the isolated prefill "
                f"latency {prefill_time:g}s — no roster can meet it"
            )
        self.plan = serving_plan(n_devices, n_prefill, prefill_time)
        self.bound = ttft_slo / prefill_time
        cfg = MultiplexConfig(
            bg_step_time=decode_step_time,
            bg_min_step_time=min(decode_step_time, 0.25e-3),
            max_inflight=max_inflight,
        )
        self.collocator = Collocator(
            self.plan, cfg,
            interference=interference or InterferenceModel(),
        )

    @staticmethod
    def fit_interference(
        prefill_iso: float,
        measured: Sequence[Tuple[float, float]],
    ) -> InterferenceModel:
        """Fit (gap_inflation, density_slope) from measured prefill
        latencies under load: ``measured`` is (decode-tenant density,
        prefill latency) pairs.  base = mean inflation at density 1; slope
        = mean of ``((t_d/iso - 1)/(base - 1) - 1)/(d - 1)`` over d > 1.
        """
        iso = max(prefill_iso, 1e-12)
        at1 = [t / iso for d, t in measured if d <= 1.0]
        base = max(1.0, float(np.mean(at1))) if at1 else 1.0
        slope = 0.0
        if base > 1.0 + 1e-9:
            rest = [
                ((t / iso - 1.0) / (base - 1.0) - 1.0) / (d - 1.0)
                for d, t in measured if d > 1.0
            ]
            if rest:
                slope = float(np.clip(np.mean(rest), 0.0, 10.0))
        return InterferenceModel(gap_inflation=base, density_slope=slope)

    def max_concurrent(self, n_candidates: int) -> AdmissionDecision:
        """How many of ``n_candidates`` requests may run concurrently."""
        n = max(0, int(n_candidates))
        self.collocator.set_tenants(
            BgTenant(f"req{i}") for i in range(n)
        )
        return self.collocator.admit(max_fg_slowdown=self.bound)


@dataclass
class ServeReport:
    """Outcome of one trace replay: per-request records + aggregates."""

    completed: List[Request]
    makespan: float
    stats: Any  # engine ServeStats
    admission_deferrals: int = 0
    page_deferrals: int = 0

    def latencies(self) -> np.ndarray:
        return np.array([r.latency for r in self.completed], np.float64)

    def latency_percentile(self, q: float) -> float:
        lat = self.latencies()
        return float(np.percentile(lat, q)) if lat.size else 0.0

    def goodput(self, slo: float) -> float:
        """SLO-satisfying completed requests per second of makespan."""
        if self.makespan <= 0.0:
            return 0.0
        good = sum(1 for r in self.completed if r.latency <= slo)
        return good / self.makespan

    def tokens_out(self) -> int:
        return sum(len(r.tokens) for r in self.completed)


class ContinuousScheduler:
    """Drives an engine over a request trace with continuous batching.

    The engine contract (see ``ContinuousBatchingEngine``):
      ``has_free_lane()``, ``live_count()``, ``can_fit(req)``,
      ``admit(req) -> bool`` (False = pages exhausted, request stays
      queued), ``step() -> list[Request]`` (one decode tick over all live
      lanes; returns newly finished, already retired).
    """

    def __init__(self, engine, admission: Optional[ServingAdmission] = None,
                 clock: Optional[VirtualClock] = None):
        self.engine = engine
        self.admission = admission
        self.clock = clock or VirtualClock()
        self.last_decision: Optional[AdmissionDecision] = None
        self.admission_deferrals = 0
        self.page_deferrals = 0

    def _admit_budget(self, n_ready: int) -> int:
        """Concurrency headroom this tick under the admission sweep."""
        if self.admission is None or n_ready == 0:
            return n_ready
        live = self.engine.live_count()
        # candidates beyond the engine's lane count can't run concurrently
        # anyway — capping keeps the admit() sweep O(lanes), not O(queue)
        cap = getattr(self.engine, "lanes", None)
        n_cand = live + n_ready if cap is None else min(live + n_ready, cap)
        dec = self.admission.max_concurrent(n_cand)
        self.last_decision = dec
        allow = max(0, dec.n_admitted - live)
        if allow == 0 and live == 0:
            # an idle engine must make progress: with nothing running there
            # is no foreground to protect, so the SLO bound is moot
            allow = 1
        return allow

    def run(self, requests: Sequence[Request]) -> ServeReport:
        pending = deque(sorted(requests, key=lambda r: (r.arrival, str(r.rid))))
        for r in pending:
            self.engine.can_fit(r, check=True)  # oversize prompt = config error
        ready: deque = deque()
        completed: List[Request] = []
        clk = self.clock
        while pending or ready or self.engine.live_count():
            while pending and pending[0].arrival <= clk.now + 1e-12:
                ready.append(pending.popleft())
            allow = self._admit_budget(len(ready))
            if ready and allow < len(ready):
                self.admission_deferrals += len(ready) - allow
            while ready and allow > 0 and self.engine.has_free_lane():
                req = ready[0]
                t0 = time.perf_counter()
                ok = self.engine.admit(req)
                dt = time.perf_counter() - t0
                if not ok:
                    self.page_deferrals += 1
                    break  # pool exhausted: wait for a retirement
                clk.advance(dt)
                ready.popleft()
                allow -= 1
                req.admitted_at = clk.now
                req.first_token_at = clk.now  # prefill emits the first token
                if req.decoding_done():
                    req.finished_at = clk.now
                    completed.append(req)
                    self.engine.retire(req)
            if self.engine.live_count():
                t0 = time.perf_counter()
                finished = self.engine.step()
                clk.advance(time.perf_counter() - t0)
                for req in finished:
                    req.finished_at = clk.now
                    completed.append(req)
            elif not ready and pending:
                clk.advance_to(pending[0].arrival)  # idle until next arrival
            elif ready:
                # nothing live, nothing admitted (pages exhausted with zero
                # live lanes can't resolve itself)
                raise RuntimeError(
                    "scheduler stalled: ready requests but no lane/page "
                    "capacity and nothing running"
                )
        return ServeReport(
            completed=completed,
            makespan=clk.now,
            stats=self.engine.stats,
            admission_deferrals=self.admission_deferrals,
            page_deferrals=self.page_deferrals,
        )
