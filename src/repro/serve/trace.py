"""Request-arrival traces for the serving benchmark.

A serving trace is a seeded, replayable stream of requests: Poisson
arrivals at a base QPS, each with a prompt length and a decode budget.
Prompt *token ids* are not stored — they are re-derived deterministically
from ``(seed, request id)`` at load time, so the committed JSON stays tiny
while replays are bit-identical.  ``load_requests`` can rescale the
arrival process to a different QPS (the benchmark sweeps load by replaying
one committed trace at increasing QPS), which preserves the arrival
*pattern* while compressing or stretching the timeline.

JSON schema (see ``benchmarks/traces/README.md``):

    {"name": "...", "seed": 7, "qps": 20.0, "vocab_size": 512,
     "requests": [{"id": 0, "t": 0.031, "prompt_len": 6, "max_new": 8}, ...]}
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.serve.scheduler import Request


@dataclass
class RequestTrace:
    name: str
    seed: int
    qps: float
    vocab_size: int
    requests: List[dict] = field(default_factory=list)  # schema rows

    def to_json(self) -> dict:
        return {
            "name": self.name, "seed": self.seed, "qps": self.qps,
            "vocab_size": self.vocab_size, "requests": self.requests,
        }


def generate_request_trace(
    n_requests: int, *, seed: int = 7, qps: float = 20.0,
    vocab_size: int = 512,
    prompt_len: Tuple[int, int] = (4, 12),
    max_new: Tuple[int, int] = (4, 12),
    name: str = "requests",
) -> RequestTrace:
    """Seeded trace: exponential inter-arrivals at ``qps``, uniform prompt
    lengths and decode budgets."""
    rng = np.random.default_rng(seed)
    t = 0.0
    rows = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / qps))
        rows.append({
            "id": i,
            "t": round(t, 6),
            "prompt_len": int(rng.integers(prompt_len[0], prompt_len[1] + 1)),
            "max_new": int(rng.integers(max_new[0], max_new[1] + 1)),
        })
    return RequestTrace(name=name, seed=seed, qps=qps,
                        vocab_size=vocab_size, requests=rows)


def save_request_trace(trace: RequestTrace, path: str) -> None:
    with open(path, "w") as f:
        json.dump(trace.to_json(), f, indent=1)
        f.write("\n")


def load_request_trace(path: str) -> RequestTrace:
    with open(path) as f:
        d = json.load(f)
    return RequestTrace(name=d["name"], seed=int(d["seed"]),
                        qps=float(d["qps"]), vocab_size=int(d["vocab_size"]),
                        requests=list(d["requests"]))


def _prompt_for(trace: RequestTrace, rid: int, length: int,
                vocab_size: int) -> np.ndarray:
    """Deterministic prompt ids from (trace seed, request id)."""
    rng = np.random.default_rng((trace.seed, rid))
    return rng.integers(0, vocab_size, (length,), dtype=np.int32)


def materialize_requests(
    trace: RequestTrace, *, qps: Optional[float] = None,
    vocab_size: Optional[int] = None,
    eos_id: Optional[int] = None,
) -> List[Request]:
    """Turn a trace into scheduler ``Request``s.

    ``qps`` rescales the arrival timeline (same pattern, different load);
    ``vocab_size`` overrides the trace's vocab (prompts must stay inside
    the serving model's vocab).
    """
    scale = trace.qps / qps if qps else 1.0
    V = vocab_size if vocab_size is not None else trace.vocab_size
    return [
        Request(
            rid=r["id"],
            prompt=_prompt_for(trace, r["id"], r["prompt_len"], V),
            max_new_tokens=r["max_new"],
            arrival=r["t"] * scale,
            eos_id=eos_id,
        )
        for r in trace.requests
    ]


def load_requests(path: str, *, qps: Optional[float] = None,
                  vocab_size: Optional[int] = None,
                  eos_id: Optional[int] = None) -> List[Request]:
    return materialize_requests(load_request_trace(path), qps=qps,
                                vocab_size=vocab_size, eos_id=eos_id)
