"""Trace-driven cluster simulation at deployment scale.

Replays seeded or hand-written cluster event traces (job churn, device
failures, elastic rejoins, heartbeat losses — JSON schema in
``repro.sim.trace``) through the real control plane: ``ClusterCoordinator``
on a virtual clock, the vectorized matrix-DP planner for every re-plan,
``Collocator.admit()`` under the measurement-calibrated
``InterferenceModel``, the ``ExecutableCache`` via the prediction-only
collocation path, and the live transport consumption loop
(``repro.dist.transport.CoordinatorLoop`` detecting silenced devices from
missing beats) — no accelerator or compilation anywhere, so 1024 simulated
devices replay in seconds on a laptop.

CLI::

    PYTHONPATH=src python benchmarks/bench_cluster_sim.py --smoke --record

emits the cluster-goodput-vs-scale curve (128/512/1024 devices, burst
multi-task vs single-task data parallelism) into BENCH_cluster_sim.json
and checks replay determinism; traces live under ``benchmarks/traces/``.
"""
from repro.sim.cluster_sim import ClusterSim, Segment, SimReport
from repro.sim.trace import (
    Trace,
    TraceEvent,
    generate_failure_storm,
    generate_heartbeat_loss,
    generate_lease_churn,
    generate_trace,
    load_trace,
    save_trace,
)

__all__ = [
    "ClusterSim",
    "Segment",
    "SimReport",
    "Trace",
    "TraceEvent",
    "generate_failure_storm",
    "generate_heartbeat_loss",
    "generate_lease_churn",
    "generate_trace",
    "load_trace",
    "save_trace",
]
