"""Seeded cluster event traces: generation, JSON (de)serialization.

A trace is the replayable input of the cluster simulator
(``repro.sim.cluster_sim``): a device count plus a time-ordered list of
events drawn from six kinds —

  job_arrival     a background job enters the cluster
                  (fields: job, priority, weight, quantum)
  job_departure   a background job finishes / leaves (field: job)
  device_failure  one device dies fail-stop, announced to the coordinator
                  directly (field: device)
  device_join     a device (re)joins the pool (field: device)
  heartbeat_loss  a device goes *silent* at t — its heartbeats stop but
                  nothing announces the loss (field: device).  The
                  simulator replays this through the live control plane:
                  the device keeps beating until t, then the coordinator's
                  ``CoordinatorLoop`` must *detect* the loss from missing
                  beats (``HeartbeatMonitor.failed()`` at t + hb_timeout)
                  and fire ``handle_failure`` itself — the same
                  consumption path the live train loop runs.
  lease_churn     the worker currently holding the coordinator lease dies
                  at t (no ``device`` field — the victim is resolved at
                  replay time, it is whoever holds the lease then).  The
                  simulator replays this through the real election path:
                  the holder goes silent, its lease renewals stop, and at
                  t + lease_timeout the lowest surviving worker claims the
                  next lease epoch, reconstructs coordinator state from
                  the topic log (``CoordinatorLoop.bootstrap_from_log``)
                  and resumes pumping; the dead ex-holder is then
                  *detected* from missing beats like any other loss.

Trace JSON schema (version 1)::

    {
      "version": 1,
      "n_devices": 128,
      "seed": 7,                      # null for hand-written traces
      "horizon": 600.0,               # virtual seconds the trace spans
      "events": [
        {"t": 3.25, "kind": "job_arrival", "job": "bg000",
         "priority": 1, "weight": 1.0, "quantum": 1},
        {"t": 41.0, "kind": "device_failure", "device": 17},
        {"t": 55.5, "kind": "device_join", "device": 17},
        {"t": 90.1, "kind": "job_departure", "job": "bg000"}
      ]
    }

``generate_trace`` is fully deterministic in its arguments (it draws only
from ``random.Random(seed)``), and ``save_trace``/``load_trace`` round-trip
bit-identically: generate -> save -> load -> simulate gives the same report
as simulating the in-memory trace (pinned by tests/test_cluster_sim.py).
"""
from __future__ import annotations

import json
import random
from dataclasses import asdict, dataclass, field
from typing import List, Optional

EVENT_KINDS = ("job_arrival", "job_departure", "device_failure",
               "device_join", "heartbeat_loss", "lease_churn")


@dataclass(frozen=True)
class TraceEvent:
    """One timestamped cluster event.  Unused payload fields stay None and
    are dropped from the JSON form (schema above)."""

    t: float
    kind: str
    job: Optional[str] = None
    priority: Optional[int] = None
    weight: Optional[float] = None
    quantum: Optional[int] = None
    device: Optional[int] = None

    def to_json(self) -> dict:
        return {k: v for k, v in asdict(self).items() if v is not None}

    @classmethod
    def from_json(cls, d: dict) -> "TraceEvent":
        if d.get("kind") not in EVENT_KINDS:
            raise ValueError(f"unknown trace event kind: {d.get('kind')!r}")
        return cls(**{k: d.get(k) for k in
                      ("t", "kind", "job", "priority", "weight", "quantum",
                       "device")})


@dataclass
class Trace:
    n_devices: int
    events: List[TraceEvent] = field(default_factory=list)
    seed: Optional[int] = None
    horizon: float = 0.0
    version: int = 1

    def to_json(self) -> dict:
        return {
            "version": self.version,
            "n_devices": self.n_devices,
            "seed": self.seed,
            "horizon": self.horizon,
            "events": [e.to_json() for e in self.events],
        }

    @classmethod
    def from_json(cls, d: dict) -> "Trace":
        if d.get("version") != 1:
            raise ValueError(f"unsupported trace version: {d.get('version')!r}")
        return cls(
            n_devices=int(d["n_devices"]),
            events=[TraceEvent.from_json(e) for e in d["events"]],
            seed=d.get("seed"),
            horizon=float(d.get("horizon", 0.0)),
        )


def _sorted(events: List[TraceEvent]) -> List[TraceEvent]:
    """Deterministic replay order: by time, ties broken by emission order
    (Python's sort is stable, so equal-t events keep generator order)."""
    return sorted(events, key=lambda e: e.t)


def generate_trace(
    n_devices: int,
    seed: int = 0,
    *,
    horizon: float = 600.0,
    arrival_rate: float = 0.05,
    mean_job_lifetime: float = 120.0,
    failure_rate: float = 0.0003,
    mean_repair_time: float = 60.0,
    max_dead_fraction: float = 0.25,
) -> Trace:
    """Seeded generator of job-churn + device-failure traces.

    Poisson job arrivals (``arrival_rate`` jobs / virtual second) with
    exponential lifetimes emit matched arrival/departure pairs; Poisson
    device failures pick a uniformly random currently-healthy device and
    schedule its rejoin after an exponential repair time.  The dead set is
    capped at ``max_dead_fraction`` of the pool (a failure drawn while at
    the cap is skipped), so the foreground keeps a plannable pool.
    Identical arguments produce an identical trace, bit for bit.
    """
    rng = random.Random(seed)
    events: List[TraceEvent] = []
    # -- job churn ----------------------------------------------------------
    t, n_jobs = 0.0, 0
    while True:
        t += rng.expovariate(arrival_rate)
        if t >= horizon:
            break
        name = f"bg{n_jobs:03d}"
        n_jobs += 1
        events.append(TraceEvent(
            t=round(t, 6), kind="job_arrival", job=name,
            priority=rng.choice((1, 1, 1, 2)),
            weight=float(rng.choice((1.0, 1.0, 2.0))),
            quantum=rng.choice((1, 1, 2)),
        ))
        depart = t + rng.expovariate(1.0 / mean_job_lifetime)
        if depart < horizon:
            events.append(TraceEvent(t=round(depart, 6),
                                     kind="job_departure", job=name))
    # -- device failures / repairs -----------------------------------------
    t = 0.0
    dead: dict = {}  # device -> rejoin time
    max_dead = max(1, int(n_devices * max_dead_fraction))
    while True:
        t += rng.expovariate(failure_rate * n_devices)
        if t >= horizon:
            break
        for dev, back in sorted(dead.items()):
            if back <= t:
                del dead[dev]
        if len(dead) >= max_dead:
            continue
        alive = [d for d in range(n_devices) if d not in dead]
        dev = rng.choice(alive)
        events.append(TraceEvent(t=round(t, 6), kind="device_failure",
                                 device=dev))
        back = t + rng.expovariate(1.0 / mean_repair_time)
        dead[dev] = back
        if back < horizon:
            events.append(TraceEvent(t=round(back, 6), kind="device_join",
                                     device=dev))
    return Trace(n_devices=n_devices, events=_sorted(events), seed=seed,
                 horizon=horizon)


def generate_failure_storm(
    n_devices: int,
    seed: int = 0,
    *,
    horizon: float = 120.0,
    dead_fraction: float = 0.25,
) -> Trace:
    """A failure-storm trace: ``dead_fraction`` of the pool dies in a burst
    early in the trace (no rejoin), with a couple of background jobs around
    to exercise cache eviction + admission under the shrunken pool."""
    rng = random.Random(seed)
    events: List[TraceEvent] = [
        TraceEvent(t=1.0, kind="job_arrival", job="bg000", priority=1,
                   weight=1.0, quantum=1),
        TraceEvent(t=2.0, kind="job_arrival", job="bg001", priority=1,
                   weight=1.0, quantum=1),
    ]
    n_dead = max(1, int(n_devices * dead_fraction))
    victims = rng.sample(range(n_devices), n_dead)
    t = horizon * 0.1
    for dev in victims:
        t += rng.expovariate(n_dead / (horizon * 0.4))
        events.append(TraceEvent(t=round(min(t, horizon * 0.6), 6),
                                 kind="device_failure", device=dev))
    return Trace(n_devices=n_devices, events=_sorted(events), seed=seed,
                 horizon=horizon)


def generate_heartbeat_loss(
    n_devices: int,
    seed: int = 0,
    *,
    horizon: float = 120.0,
    n_losses: int = 3,
    n_jobs: int = 2,
) -> Trace:
    """A heartbeat-loss trace: ``n_losses`` distinct devices go silent
    (their beats stop, nothing announces the loss) spread over the middle
    of the horizon, with ``n_jobs`` background jobs around so the
    continuous-admission re-sweep has a roster to re-decide after each
    detected loss.  The losses are never rejoined — the final healthy pool
    is exactly ``n_devices - n_losses``, which pins the detection path:
    every loss must be *detected* from missing beats for the pool to get
    there."""
    rng = random.Random(seed)
    events: List[TraceEvent] = [
        TraceEvent(t=float(1 + i), kind="job_arrival", job=f"bg{i:03d}",
                   priority=1, weight=1.0, quantum=1)
        for i in range(n_jobs)
    ]
    victims = rng.sample(range(n_devices), n_losses)
    for i, dev in enumerate(victims):
        t = horizon * (0.2 + 0.5 * i / max(1, n_losses - 1)
                       if n_losses > 1 else 0.3)
        t += rng.uniform(0.0, horizon * 0.05)
        events.append(TraceEvent(t=round(t, 6), kind="heartbeat_loss",
                                 device=dev))
    return Trace(n_devices=n_devices, events=_sorted(events), seed=seed,
                 horizon=horizon)


def generate_lease_churn(
    n_devices: int,
    seed: int = 0,
    *,
    horizon: float = 120.0,
    n_churns: int = 3,
    n_jobs: int = 2,
) -> Trace:
    """A lease-churn trace: the coordinator host dies ``n_churns`` times.

    Each ``lease_churn`` event kills whichever worker holds the lease at
    replay time (the events carry no device — churn 2 kills whoever won
    the failover after churn 1), so ``n_churns`` successive failovers each
    elect the lowest survivor and shrink the pool by one.  Churns are
    spread evenly with a small seeded jitter, leaving room between them
    for the failover (lease timeout) *and* the subsequent detection of the
    dead ex-holder (heartbeat timeout) to complete; ``n_jobs`` background
    jobs give the rebuilt admission state a roster to re-decide."""
    rng = random.Random(seed)
    events: List[TraceEvent] = [
        TraceEvent(t=float(1 + i), kind="job_arrival", job=f"bg{i:03d}",
                   priority=1, weight=1.0, quantum=1)
        for i in range(n_jobs)
    ]
    for i in range(n_churns):
        t = horizon * (0.15 + 0.6 * i / max(1, n_churns - 1)
                       if n_churns > 1 else 0.3)
        t += rng.uniform(0.0, horizon * 0.02)
        events.append(TraceEvent(t=round(t, 6), kind="lease_churn"))
    return Trace(n_devices=n_devices, events=_sorted(events), seed=seed,
                 horizon=horizon)


def save_trace(trace: Trace, path) -> None:
    with open(path, "w") as f:
        json.dump(trace.to_json(), f, indent=1, sort_keys=True)
        f.write("\n")


def load_trace(path) -> Trace:
    with open(path) as f:
        return Trace.from_json(json.load(f))
