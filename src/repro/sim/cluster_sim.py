"""Trace-driven cluster simulator: the real control plane, a virtual clock.

Replays a ``repro.sim.trace.Trace`` (job arrivals / departures, device
failures / rejoins, heartbeat losses) through the REAL coordination stack
— no stubs:

  * the live transport consumption path: every simulated device publishes
    heartbeats over an ``InProcessBus`` and a ``CoordinatorLoop`` pumps
    them at every boundary — a ``heartbeat_loss`` event silences a device
    and the loss must be *detected* (``HeartbeatMonitor.failed()`` at
    ``t + hb_timeout``) before ``handle_failure`` fires, exactly the code
    path the live train loop runs (the simulator is the regression bed
    for the control plane before hardware),
  * ``ClusterCoordinator`` with an injected virtual clock and
    ``virtual_devices=True`` (device ids are the simulated healthy indices,
    so a 1024-device cluster runs on a 0-accelerator host),
  * the vectorized matrix-DP planner for every elasticity re-plan
    (failures / joins re-plan onto the exact surviving pool, non-pow2
    included),
  * ``Collocator.admit()`` — the predict-before-compile admission sweep
    under the measurement-calibrated ``InterferenceModel`` — and
    ``Collocator.predict()`` for the operating point of each epoch,
  * ``MultiplexSim`` as a per-epoch discrete-event cross-check of the
    foreground slowdown,
  * the coordinator's ``ExecutableCache``, touched through
    ``Collocator.predicted_cache_keys`` so compile reuse, the LRU bound and
    post-failure ``evict_stale`` behave exactly as in a real deployment.

Between consecutive trace events the cluster state is constant (an
*epoch*); the simulator integrates goodput over each epoch and re-derives
the operating point after every event.  Goodput is reported in
single-device equivalents (one unit = one device running the job
standalone, the paper's speedup axis):

  fg goodput rate = plan.speedup / predicted fg slowdown
  bg goodput rate = sum_t steps/iter x step_time x chunk_width x eff(t)
                    / collocated iteration time,
                    eff = (step_time / bg_step_time) ** 0.25

(the ``eff`` factor discounts granularity-reduced background steps: a
tenant forced to tiny steps by small gaps does proportionally less useful
work per device-second).  The cluster-throughput-vs-scale curve from
``benchmarks/bench_cluster_sim.py`` compares total goodput against the
single-task data-parallel baseline ``plan_data_parallel(G).speedup``.

Everything is deterministic: traces are seeded, the replay draws no
randomness, and ``SimReport.to_json()`` round-trips bit-identically
(pinned by tests/test_cluster_sim.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.coordinator import ClusterCoordinator, Job, QOS_SLOWDOWN_BOUND
from repro.core.costmodel import Hardware
from repro.core.multiplex import (
    Collocator,
    InterferenceModel,
    MultiplexConfig,
    MultiplexSim,
    QoSMonitor,
)
from repro.dist.faults import HeartbeatMonitor, MitigationLog
from repro.dist.transport import (
    HEARTBEAT_TOPIC,
    RECONFIG_TOPIC,
    CoordinatorLease,
    CoordinatorLoop,
    InProcessBus,
    WorkerClient,
)
from repro.sim.trace import Trace


def _bg_factory(mesh):  # pragma: no cover - never dispatched in simulation
    return lambda: None


@dataclass
class Segment:
    """One constant-state epoch: [t0, t1) between consecutive trace events."""

    t0: float
    t1: float
    n_healthy: int
    plan_gpus: int
    n_tenants: int
    n_admitted: int
    fg_slowdown: float
    sim_fg_slowdown: float  # MultiplexSim cross-check (single-tenant DES)
    fg_rate: float          # single-device equivalents / virtual second
    bg_rate: float
    jain: float

    def to_json(self) -> dict:
        d = dict(self.__dict__)
        for k, v in d.items():
            if isinstance(v, float):
                d[k] = round(v, 9)
        return d


@dataclass
class SimReport:
    """Aggregated outcome of one trace replay."""

    n_devices: int
    horizon: float
    n_events: int
    n_replans: int          # planner invocations from failures/joins
    n_epochs: int
    admitted_total: int     # tenant-epochs admitted
    rejected_total: int     # tenant-epochs refused by the QoS bound
    fg_goodput: float       # time-integrated, in device x seconds
    bg_goodput: float
    cache_hits: int
    cache_misses: int
    cache_evictions: int
    cache_final_size: int
    jain_time_avg: float    # time-weighted schedule-level Jain index
    jain_service: float     # Jain over per-job accumulated weighted service
    mean_fg_slowdown: float  # time-weighted
    per_job_service: Dict[str, float] = field(default_factory=dict)
    # per-kind counts from the live control plane's MitigationLog
    # (failure_detected / replan / straggler_worker / ...): non-empty only
    # when the trace carries heartbeat_loss events, and deterministic —
    # the CI gate pins the counts across replays
    mitigations: Dict[str, int] = field(default_factory=dict)
    # coordinator failovers replayed through the real election path
    # (lease_churn traces) and the per-topic retained log sizes at the end
    # of the replay — with gc_every set these stay bounded across churns
    n_failovers: int = 0
    topic_backlog: Dict[str, int] = field(default_factory=dict)
    segments: List[Segment] = field(default_factory=list)

    @property
    def total_goodput(self) -> float:
        return self.fg_goodput + self.bg_goodput

    @property
    def mean_goodput_rate(self) -> float:
        """Cluster throughput in single-device equivalents (curve y-axis)."""
        return self.total_goodput / max(self.horizon, 1e-30)

    def to_json(self, *, with_segments: bool = False) -> dict:
        d = {
            k: (round(v, 9) if isinstance(v, float) else v)
            for k, v in self.__dict__.items()
            if k not in ("segments", "per_job_service")
        }
        d["per_job_service"] = {
            k: round(v, 9) for k, v in sorted(self.per_job_service.items())
        }
        d["total_goodput"] = round(self.total_goodput, 9)
        d["mean_goodput_rate"] = round(self.mean_goodput_rate, 9)
        if with_segments:
            d["segments"] = [s.to_json() for s in self.segments]
        return d


class ClusterSim:
    """Replay a trace through the real coordinator / admission stack.

    ``graph`` is the foreground job's layer graph (planned by the matrix-DP
    planner at every pool size the trace visits); ``interference`` seeds
    the calibrated model used by admission + prediction — pass the fit from
    measured collocation records so the simulation carries measured
    hardware behavior instead of optimism.
    """

    def __init__(
        self,
        trace: Trace,
        graph,
        *,
        hw: Optional[Hardware] = None,
        amp_limit: float = 2.0,
        mcfg: Optional[MultiplexConfig] = None,
        interference: Optional[InterferenceModel] = None,
        qos_bound: float = QOS_SLOWDOWN_BOUND,
        fg_job: str = "fg",
        hb_timeout: float = 5.0,
        lease_timeout: float = 2.0,
        gc_every: int = 0,
    ):
        self.trace = trace
        self.graph = graph
        self.hw = hw or Hardware()
        self.amp_limit = amp_limit
        # virtual replay never dispatches async work, so the pacing bound
        # models steady-state gap occupancy rather than a real in-flight
        # window: leave it wide and let gap duration / step time cap steps
        self.mcfg = mcfg or MultiplexConfig(max_inflight=10 ** 6)
        self.interference = interference or InterferenceModel()
        self.qos_bound = qos_bound
        self.fg_job = fg_job
        # heartbeat_loss detection latency: a silenced device is declared
        # failed by the CoordinatorLoop hb_timeout virtual seconds after
        # its last beat (a synthetic detection boundary is inserted there)
        self.hb_timeout = hb_timeout
        # lease-churn traces run the real election: the coordinator role
        # moves to the lowest survivor lease_timeout after the holder dies,
        # and with gc_every > 0 each holder compacts the topics every
        # that-many pumps (the backlog stays bounded across churns)
        self.lease_timeout = lease_timeout
        self.gc_every = gc_every
        self._lease_mode = any(e.kind == "lease_churn" for e in trace.events)
        self._t = 0.0
        self._silent: set = set()
        self._holder: Optional[int] = None

    # -- replay -------------------------------------------------------------

    def run(self, *, keep_segments: bool = True) -> SimReport:
        tr = self.trace
        self._t = 0.0
        self._silent = set()
        self._holder = None
        coord = ClusterCoordinator(
            tr.n_devices, self.hw, clock=lambda: self._t,
            virtual_devices=True,
        )
        coord.interference = self.interference
        coord.submit_foreground(
            Job(self.fg_job, "foreground", self.graph,
                amp_limit=self.amp_limit)
        )
        horizon = tr.horizon or (tr.events[-1].t if tr.events else 0.0)
        # the live control plane: every simulated device beats over the bus
        # at each boundary; the CoordinatorLoop pumps the same consumption
        # path the train loop runs (detection -> handle_failure -> replan).
        # Admission stays with _epoch (the sweep below is richer: it feeds
        # the cache-traffic and goodput accounting), so the loop's own
        # readmit hook is off.
        bus = InProcessBus()
        monitor = HeartbeatMonitor(tr.n_devices, timeout=self.hb_timeout,
                                   clock=lambda: self._t)
        mlog = MitigationLog()
        cloop = CoordinatorLoop(bus, monitor, coordinator=coord, log=mlog,
                                gc_every=self.gc_every)
        workers = {w: WorkerClient(bus, w) for w in range(tr.n_devices)}
        # lease mode (the trace carries lease_churn events): the real
        # election protocol arbitrates who pumps — every live worker ticks
        # its CoordinatorLease each boundary, only the holder's loop runs.
        # Worker 0 seeds the initial claim (lowest id, same as production).
        leases: Dict[int, CoordinatorLease] = {}
        n_failovers = 0
        if self._lease_mode:
            leases = {
                w: CoordinatorLease(bus, w, timeout=self.lease_timeout,
                                    clock=lambda: self._t)
                for w in range(tr.n_devices)
            }
            assert leases[0].tick(), "worker 0 must win the seed election"
            self._holder = 0
        # synthetic detection boundaries: a silenced device's loss becomes
        # visible exactly hb_timeout after its last beat; a dead lease
        # holder triggers a failover boundary at t + lease_timeout and its
        # own detection one hb_timeout after that (the new holder re-joined
        # it with a fresh grace period during bootstrap).  Merged stably
        # (time, then trace order, events before detections at equal t) so
        # the replay stays deterministic.
        entries = [(e.t, 0, i, e) for i, e in enumerate(tr.events)]
        for i, e in enumerate(tr.events):
            if e.kind == "heartbeat_loss" and e.t + self.hb_timeout < horizon:
                entries.append((e.t + self.hb_timeout, 1, i, None))
            elif e.kind == "lease_churn":
                for dt in (self.lease_timeout,
                           self.lease_timeout + self.hb_timeout):
                    if e.t + dt < horizon:
                        entries.append((e.t + dt, 1, i, None))
        entries.sort(key=lambda x: (x[0], x[1], x[2]))
        segments: List[Segment] = []
        per_job: Dict[str, float] = {}
        n_replans = 0
        admitted_total = rejected_total = 0
        epoch = self._epoch(coord)
        t_prev = 0.0
        beat_round = 0
        for t_ev, _phase, _i, ev in entries + [(horizon, 2, -1, None)]:
            t_ev = min(max(t_ev, t_prev), horizon)
            if t_ev > t_prev:
                seg = self._integrate(epoch, t_prev, t_ev, per_job)
                segments.append(seg)
                admitted_total += seg.n_admitted
                rejected_total += seg.n_tenants - seg.n_admitted
                t_prev = t_ev
            if _phase == 2:
                break
            self._t = t_ev
            # live beats from every healthy, non-silent device, then pump:
            # a silenced device's age crosses hb_timeout exactly at its
            # synthetic boundary and the loop fires handle_failure itself
            beat_round += 1
            for w in sorted(coord.healthy - self._silent):
                if w in monitor.last:
                    # consume pending reconfigs first so the beat carries a
                    # current ack — the cursor aggregation GC feeds on these
                    workers[w].poll_reconfig()
                    workers[w].beat(beat_round)
            live_replans: List[dict] = []
            if self._lease_mode:
                # election-gated pumping: ticking in id order means the
                # live holder renews before anyone checks staleness, and
                # after a churn the lowest survivor claims first and wins
                for w in sorted(coord.healthy - self._silent):
                    if not leases[w].tick():
                        continue
                    if leases[w].acquired and w != self._holder:
                        # failover: a fresh loop on the new holder rebuilds
                        # monitor/ack state from the topic log — adopting
                        # (never re-firing) the old holder's mitigations
                        monitor = HeartbeatMonitor(
                            0, timeout=self.hb_timeout, clock=lambda: self._t
                        )
                        cloop = CoordinatorLoop(
                            bus, monitor, coordinator=coord, log=mlog,
                            gc_every=self.gc_every,
                        )
                        cloop.bootstrap_from_log()
                        self._holder = w
                        n_failovers += 1
                    live_replans = cloop.pump()
            else:
                live_replans = cloop.pump()
            n_replans += len(live_replans)
            changed = bool(live_replans)
            if ev is not None:
                ev_changed, replanned = self._apply(coord, monitor, ev)
                n_replans += replanned
                changed = changed or ev_changed
            if changed:
                epoch = self._epoch(coord)
        total_t = sum(s.t1 - s.t0 for s in segments) or 1e-30
        jain_avg = sum(s.jain * (s.t1 - s.t0) for s in segments) / total_t
        slow_avg = sum(
            s.fg_slowdown * (s.t1 - s.t0) for s in segments) / total_t
        return SimReport(
            n_devices=tr.n_devices,
            horizon=horizon,
            n_events=len(tr.events),
            n_replans=n_replans,
            n_epochs=len(segments),
            admitted_total=admitted_total,
            rejected_total=rejected_total,
            fg_goodput=sum(s.fg_rate * (s.t1 - s.t0) for s in segments),
            bg_goodput=sum(s.bg_rate * (s.t1 - s.t0) for s in segments),
            cache_hits=coord.exec_cache.hits,
            cache_misses=coord.exec_cache.misses,
            cache_evictions=coord.exec_cache.evictions,
            cache_final_size=len(coord.exec_cache),
            jain_time_avg=jain_avg,
            jain_service=_jain(list(per_job.values())),
            mean_fg_slowdown=slow_avg,
            per_job_service=per_job,
            mitigations={k: mlog.count(k) for k in sorted(
                {e["kind"] for e in mlog.events})},
            n_failovers=n_failovers,
            topic_backlog={t: bus.backlog(t) for t in
                           (HEARTBEAT_TOPIC, RECONFIG_TOPIC)},
            segments=segments if keep_segments else [],
        )

    # -- event application --------------------------------------------------

    def _apply(self, coord: ClusterCoordinator, monitor: HeartbeatMonitor,
               ev) -> Tuple[bool, int]:
        """Returns (state_changed, n_replans)."""
        if ev.kind == "job_arrival":
            coord.submit_background(Job(
                ev.job, "background", [], priority=ev.priority or 1,
                step_fn_factory=_bg_factory,
                weight=ev.weight if ev.weight is not None else 1.0,
                quantum=ev.quantum,
            ))
            return True, 0
        if ev.kind == "job_departure":
            return coord.handle_departure(ev.job), 0
        if ev.kind == "device_failure":
            if ev.device not in coord.healthy or len(coord.healthy) <= 1:
                return False, 0
            # fail-stop: the loss is ANNOUNCED (not detected) — handled
            # directly, and the monitor stops tracking the device so the
            # heartbeat path can't double-report it later
            monitor.forget(ev.device)
            self._silent.discard(ev.device)
            coord.handle_failure(ev.device)
            return True, 1
        if ev.kind == "device_join":
            if ev.device in coord.healthy:
                return False, 0
            monitor.join(ev.device)
            self._silent.discard(ev.device)
            coord.handle_join([ev.device])
            return True, 1
        if ev.kind == "heartbeat_loss":
            # the device goes silent NOW; nothing else happens until the
            # CoordinatorLoop detects the missing beats hb_timeout later
            # (the synthetic detection boundary pumps it)
            if ev.device not in coord.healthy or ev.device in self._silent:
                return False, 0
            self._silent.add(ev.device)
            return False, 0
        if ev.kind == "lease_churn":
            # the coordinator host dies NOW: its beats *and* lease renewals
            # stop.  Election (lowest survivor claims) happens at the
            # t + lease_timeout synthetic boundary; the dead ex-holder's
            # device loss is detected one hb_timeout after the new holder
            # re-joined it during bootstrap
            h = self._holder
            if h is None or h in self._silent or h not in coord.healthy:
                return False, 0
            self._silent.add(h)
            return False, 0
        raise ValueError(f"unknown trace event kind: {ev.kind!r}")

    # -- per-epoch operating point ------------------------------------------

    def _epoch(self, coord: ClusterCoordinator) -> dict:
        """Re-derive the operating point for the current cluster state:
        admission sweep + prediction + executable-cache traffic."""
        fg = coord.foreground()
        plan = fg.plan
        roster = coord.background_tenants(_bg_factory)
        # fresh monitor per epoch: predictions carry no measured QoS bans
        col = Collocator(plan, self.mcfg, monitor=QoSMonitor(),
                         tenants=roster, interference=self.interference)
        k = 0
        if roster:
            decision = col.admit(max_fg_slowdown=self.qos_bound)
            coord.last_admission = decision
            k = decision.n_admitted
        pred = col.predict(k)
        # prediction-only collocation path: the cache keys this schedule
        # would compile.  Positional device ids come from the sorted healthy
        # set — exactly what run_executable's submeshes would use.
        ids = sorted(coord.healthy)
        for key in col.predicted_cache_keys(k, device_ids=ids):
            assert set(key[1]) <= coord.healthy, (key, coord.healthy)
            coord.exec_cache.get_or_build(key, object)
        des = MultiplexSim(plan, self.mcfg, self.interference,
                           monitor=QoSMonitor()).run(iterations=8)
        fg_rate = plan.speedup / max(pred.fg_slowdown, 1e-30)
        # exact per-chunk bg busy from the schedule rows (per-tenant rows
        # only carry the max chunk width, which overstates multi-gap work)
        busy: Dict[int, float] = {}
        for _si, slot, _pos, (cs, ce), nsteps, bg_t in (
                col._schedule_detail(k) if k > 0 else []):
            eff = min(1.0, bg_t / self.mcfg.bg_step_time) ** 0.25
            busy[slot] = busy.get(slot, 0.0) + nsteps * bg_t * (ce - cs) * eff
        bg_rate = 0.0
        job_rates: Dict[str, float] = {}
        for slot, t in enumerate(pred.tenants[:k]):
            rate = busy.get(slot, 0.0) / max(pred.fg_iter_time, 1e-30)
            bg_rate += rate
            job_rates[t.job] = rate / max(t.weight, 1e-30)
        job_rates[self.fg_job] = fg_rate
        return {
            "n_healthy": len(coord.healthy),
            "plan_gpus": plan.num_gpus,
            "n_tenants": len(roster),
            "n_admitted": k,
            "fg_slowdown": pred.fg_slowdown,
            "sim_fg_slowdown": des.fg_slowdown,
            "fg_rate": fg_rate,
            "bg_rate": bg_rate,
            "jain": pred.jain_index,
            "job_rates": job_rates,
        }

    def _integrate(self, epoch: dict, t0: float, t1: float,
                   per_job: Dict[str, float]) -> Segment:
        dt = t1 - t0
        for job, rate in epoch["job_rates"].items():
            per_job[job] = per_job.get(job, 0.0) + rate * dt
        return Segment(
            t0=t0, t1=t1,
            n_healthy=epoch["n_healthy"],
            plan_gpus=epoch["plan_gpus"],
            n_tenants=epoch["n_tenants"],
            n_admitted=epoch["n_admitted"],
            fg_slowdown=epoch["fg_slowdown"],
            sim_fg_slowdown=epoch["sim_fg_slowdown"],
            fg_rate=epoch["fg_rate"],
            bg_rate=epoch["bg_rate"],
            jain=epoch["jain"],
        )


def _jain(xs: List[float]) -> float:
    xs = [x for x in xs if x > 0]
    if not xs:
        return 1.0
    return sum(xs) ** 2 / (len(xs) * sum(x * x for x in xs))
