"""Schema validator for the committed benchmark traces.

The cluster simulator and the serving benchmark replay JSON traces from
``benchmarks/traces/``; a malformed committed trace fails *silently* (an
unknown field is dropped by ``TraceEvent.from_json``, a mis-typed one
crashes replay long after checkout).  This pass validates every committed
trace against hand-rolled schemas (the container has no ``jsonschema`` —
the rules live here, next to the checks):

**Cluster trace v1** (``trace_*.json``, ``failure_storm_*.json``,
``heartbeat_loss_*.json``, ``lease_churn_*.json`` — any file with a
top-level ``events`` list):

  - top level: ``version == 1``, ``n_devices`` int >= 1, ``events`` list;
    optional ``seed`` (int) and ``horizon`` (number >= 0); nothing else;
  - every event: ``t`` number >= 0 and ``kind`` from the simulator's
    vocabulary, time-sorted, inside the horizon when one is declared;
  - kind-specific payloads: ``job_arrival`` carries job/priority/weight/
    quantum, ``job_departure`` carries job, the device events
    (``device_failure``/``device_join``/``heartbeat_loss``) carry
    ``device`` in ``[0, n_devices)``, and ``lease_churn`` carries no
    payload at all (the sim kills whichever worker holds the lease);
    fields from the wrong group are violations — ``from_json`` would
    accept and silently mis-replay them.

**Request trace** (``requests_smoke.json`` — any file with a top-level
``requests`` list): ``name``/``seed``/``qps``/``vocab_size`` plus rows of
``id``/``t``/``prompt_len``/``max_new``; ids dense from 0, arrival times
non-decreasing.

Run as ``python -m repro.analysis.tracecheck benchmarks/traces``
(exit 1 on violations).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional, Sequence

from repro.analysis.verify import Violation

EVENT_KINDS = frozenset({"job_arrival", "job_departure", "device_failure",
                         "device_join", "heartbeat_loss", "lease_churn"})
JOB_FIELDS = {"job", "priority", "weight", "quantum"}
# required payload fields per kind (beyond t/kind); everything else from
# the payload universe is forbidden for that kind
EVENT_FIELDS = {
    "job_arrival": {"job", "priority", "weight", "quantum"},
    "job_departure": {"job"},
    "device_failure": {"device"},
    "device_join": {"device"},
    "heartbeat_loss": {"device"},
    "lease_churn": set(),
}
PAYLOAD_UNIVERSE = JOB_FIELDS | {"device"}


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _is_int(v) -> bool:
    return isinstance(v, int) and not isinstance(v, bool)


def check_cluster_trace(doc: dict, where: str) -> List[Violation]:
    out: List[Violation] = []
    if doc.get("version") != 1:
        out.append(Violation("trace-version", where,
                             f"version {doc.get('version')!r}, want 1"))
    n_devices = doc.get("n_devices")
    if not (_is_int(n_devices) and n_devices >= 1):
        out.append(Violation("trace-top", where,
                             f"n_devices {n_devices!r} is not a positive int"))
        n_devices = None
    seed = doc.get("seed")
    if seed is not None and not _is_int(seed):
        out.append(Violation("trace-top", where,
                             f"seed {seed!r} is not an int"))
    horizon = doc.get("horizon", 0.0)
    if not (_is_num(horizon) and horizon >= 0):
        out.append(Violation("trace-top", where,
                             f"horizon {horizon!r} is not a number >= 0"))
        horizon = 0.0
    extra = set(doc) - {"version", "n_devices", "seed", "horizon", "events"}
    if extra:
        out.append(Violation("trace-top", where,
                             f"unknown top-level fields {sorted(extra)}"))
    events = doc.get("events")
    if not isinstance(events, list):
        out.append(Violation("trace-top", where,
                             f"events is {type(events).__name__}, want list"))
        return out

    prev_t = None
    for i, ev in enumerate(events):
        ew = f"{where} events[{i}]"
        if not isinstance(ev, dict):
            out.append(Violation("trace-event", ew,
                                 f"{type(ev).__name__}, want object"))
            continue
        t = ev.get("t")
        if not (_is_num(t) and t >= 0):
            out.append(Violation("trace-event", ew,
                                 f"t {t!r} is not a number >= 0"))
            t = None
        kind = ev.get("kind")
        if kind not in EVENT_KINDS:
            out.append(Violation("trace-event-kind", ew,
                                 f"unknown kind {kind!r} "
                                 f"(vocabulary: {sorted(EVENT_KINDS)})"))
            continue
        required = EVENT_FIELDS[kind]
        present = set(ev) & PAYLOAD_UNIVERSE
        missing = required - present
        forbidden = present - required
        if missing:
            out.append(Violation(
                "trace-field", ew,
                f"{kind} missing {sorted(missing)}"))
        if forbidden:
            out.append(Violation(
                "trace-field", ew,
                f"{kind} carries {sorted(forbidden)} — wrong payload group "
                f"(from_json would silently mis-replay it)"))
        extra = set(ev) - PAYLOAD_UNIVERSE - {"t", "kind"}
        if extra:
            out.append(Violation("trace-field", ew,
                                 f"unknown fields {sorted(extra)}"))
        if "job" in required and not isinstance(ev.get("job"), str):
            out.append(Violation("trace-field", ew,
                                 f"job {ev.get('job')!r} is not a string"))
        if kind == "job_arrival":
            if not _is_int(ev.get("priority")):
                out.append(Violation(
                    "trace-field", ew,
                    f"priority {ev.get('priority')!r} is not an int"))
            if not (_is_num(ev.get("weight")) and ev.get("weight") > 0):
                out.append(Violation(
                    "trace-field", ew,
                    f"weight {ev.get('weight')!r} is not a number > 0"))
            if not (_is_int(ev.get("quantum")) and ev.get("quantum") >= 1):
                out.append(Violation(
                    "trace-field", ew,
                    f"quantum {ev.get('quantum')!r} is not an int >= 1"))
        if "device" in required and "device" in ev:
            d = ev["device"]
            if not _is_int(d):
                out.append(Violation("trace-field", ew,
                                     f"device {d!r} is not an int"))
            elif n_devices is not None and not (0 <= d < n_devices):
                out.append(Violation(
                    "trace-device-range", ew,
                    f"device {d} outside [0, {n_devices})"))
        if t is not None:
            if prev_t is not None and t < prev_t:
                out.append(Violation(
                    "trace-order", ew,
                    f"t {t} before previous event at {prev_t} — replay "
                    f"requires time-sorted events"))
            prev_t = t
            if horizon and t > horizon:
                out.append(Violation(
                    "trace-horizon", ew,
                    f"t {t} beyond the declared horizon {horizon}"))
    return out


def check_request_trace(doc: dict, where: str) -> List[Violation]:
    out: List[Violation] = []
    if not isinstance(doc.get("name"), str):
        out.append(Violation("req-top", where,
                             f"name {doc.get('name')!r} is not a string"))
    if not _is_int(doc.get("seed")):
        out.append(Violation("req-top", where,
                             f"seed {doc.get('seed')!r} is not an int"))
    if not (_is_num(doc.get("qps")) and doc.get("qps") > 0):
        out.append(Violation("req-top", where,
                             f"qps {doc.get('qps')!r} is not a number > 0"))
    if not (_is_int(doc.get("vocab_size")) and doc.get("vocab_size") >= 2):
        out.append(Violation(
            "req-top", where,
            f"vocab_size {doc.get('vocab_size')!r} is not an int >= 2"))
    extra = set(doc) - {"name", "seed", "qps", "vocab_size", "requests"}
    if extra:
        out.append(Violation("req-top", where,
                             f"unknown top-level fields {sorted(extra)}"))
    rows = doc.get("requests")
    if not isinstance(rows, list):
        out.append(Violation("req-top", where,
                             f"requests is {type(rows).__name__}, want list"))
        return out
    prev_t = None
    for i, row in enumerate(rows):
        rw = f"{where} requests[{i}]"
        if not isinstance(row, dict):
            out.append(Violation("req-row", rw,
                                 f"{type(row).__name__}, want object"))
            continue
        extra = set(row) - {"id", "t", "prompt_len", "max_new"}
        if extra:
            out.append(Violation("req-row", rw,
                                 f"unknown fields {sorted(extra)}"))
        if row.get("id") != i:
            out.append(Violation(
                "req-id", rw,
                f"id {row.get('id')!r}, want dense ids from 0 (= {i})"))
        t = row.get("t")
        if not (_is_num(t) and t >= 0):
            out.append(Violation("req-row", rw,
                                 f"t {t!r} is not a number >= 0"))
        else:
            if prev_t is not None and t < prev_t:
                out.append(Violation(
                    "req-order", rw,
                    f"arrival t {t} before previous {prev_t}"))
            prev_t = t
        for f in ("prompt_len", "max_new"):
            if not (_is_int(row.get(f)) and row.get(f) >= 1):
                out.append(Violation(
                    "req-row", rw,
                    f"{f} {row.get(f)!r} is not an int >= 1"))
    return out


def check_trace_file(path: Path, display: Optional[str] = None,
                     ) -> List[Violation]:
    where = display or str(path)
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [Violation("trace-json", where, f"unreadable: {e}")]
    if not isinstance(doc, dict):
        return [Violation("trace-kind", where,
                          f"top level is {type(doc).__name__}, want object")]
    if "requests" in doc:
        return check_request_trace(doc, where)
    if "events" in doc:
        return check_cluster_trace(doc, where)
    return [Violation("trace-kind", where,
                      "neither 'events' (cluster trace) nor 'requests' "
                      "(request trace) at top level")]


def check_paths(paths: Sequence[str]) -> List[Violation]:
    files: List[Path] = []
    for p in paths:
        pp = Path(p)
        files.extend(sorted(pp.glob("*.json")) if pp.is_dir() else [pp])
    out: List[Violation] = []
    for f in files:
        out.extend(check_trace_file(f, display=f.as_posix()))
    if not files:
        out.append(Violation("trace-json", ", ".join(paths),
                             "no .json files found"))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.tracecheck",
        description="schema validator for committed benchmark traces "
                    "(cluster trace v1 and request traces)")
    ap.add_argument("paths", nargs="+",
                    help="trace files or directories of *.json")
    args = ap.parse_args(argv)
    violations = check_paths(args.paths)
    for v in violations:
        print(v)
    n_files = sum(1 for p in args.paths for _ in (
        sorted(Path(p).glob('*.json')) if Path(p).is_dir() else [Path(p)]))
    print(f"tracecheck: {n_files} file(s), {len(violations)} violation(s)",
          file=sys.stderr)
    return 1 if violations else 0


if __name__ == "__main__":
    raise SystemExit(main())
