"""AST linter for the JAX hazards this repo has actually shipped.

Four rules, each a bug class from a past PR:

- ``JH001`` jit-in-hot-path: ``jax.jit(...)`` immediately invoked, built
  inside a loop, or built inside a per-step/per-request function body
  without being cached on an attribute/subscript — the PR 9 prefill
  retracing bug (every ``generate()`` call recompiled the prefill).
  Factories (``make_*``/``build_*``/``jit_*``) and cached-assignment
  idioms (``self._fn = jax.jit(...)``, ``cache[k] = jax.jit(...)``,
  ``return jax.jit(...)``) are exempt.
- ``JH002`` wall-clock-in-virtual-clock-module: ``time.time``/
  ``time.sleep`` in modules that run on a virtual clock (``sim/``,
  ``serve/scheduler.py``) — a single wall-clock read desynchronizes a
  deterministic replay.  ``time.perf_counter`` is allowed: the serving
  scheduler *measures* op durations to advance its virtual clock.
- ``JH003`` assert-on-traced: Python ``assert`` over ``jnp``/``jax``
  expressions — under ``jit`` the test is a tracer, so the assert either
  fails at trace time or silently passes on the abstract value.
- ``JH004`` pspec-unknown-axis: string axis names in
  ``PartitionSpec``/``P`` constructors outside the declared mesh-axis
  vocabulary {pod, data, model} — a typo'd axis silently replicates.

Intentional sites live in the committed allowlist
(``lint_allowlist.txt`` next to this module): one line per site,
``RULE  path-suffix  qualname  # justification``.  Run as::

    python -m repro.analysis.lint src/

Exit status 1 when any unallowlisted finding remains.
"""
from __future__ import annotations

import argparse
import ast
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Iterable, List, Optional, Sequence, Tuple

MESH_AXIS_VOCAB = frozenset({"pod", "data", "model"})

# modules that must never read the wall clock (deterministic replay)
VIRTUAL_CLOCK_PARTS = ("sim",)
VIRTUAL_CLOCK_FILES = ("serve/scheduler.py",)

# function-name markers for per-step/per-request hot paths
HOT_MARKERS = ("step", "generate", "admit", "pump", "decode", "prefill",
               "serve", "handle_", "retire", "tick")
# factory prefixes: functions that exist to build a jitted callable once
FACTORY_PREFIXES = ("make_", "build_", "_make_", "_build_", "jit_")


@dataclass(frozen=True)
class LintFinding:
    path: str        # posix path as given on the command line
    line: int
    col: int
    rule: str
    message: str
    qualname: str    # innermost enclosing function ('' at module level)

    def __str__(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"{self.message}")


# -- allowlist --------------------------------------------------------------


@dataclass(frozen=True)
class AllowEntry:
    rule: str
    path_suffix: str
    qualname: str
    justification: str


def load_allowlist(path: Optional[Path] = None) -> List[AllowEntry]:
    if path is None:
        path = Path(__file__).with_name("lint_allowlist.txt")
    if not path.exists():
        return []
    out = []
    for raw in path.read_text().splitlines():
        line, _, comment = raw.partition("#")
        fields = line.split()
        if not fields:
            continue
        if len(fields) != 3:
            raise ValueError(
                f"{path}: malformed allowlist line {raw!r} "
                f"(want: RULE path-suffix qualname  # justification)")
        out.append(AllowEntry(fields[0], fields[1], fields[2],
                              comment.strip()))
    return out


def _allowed(f: LintFinding, allow: Sequence[AllowEntry]) -> bool:
    p = Path(f.path).as_posix()
    return any(
        a.rule == f.rule and p.endswith(a.path_suffix)
        and a.qualname == f.qualname
        for a in allow
    )


# -- AST helpers ------------------------------------------------------------


def _attach_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._lint_parent = node  # type: ignore[attr-defined]


def _parents(node: ast.AST) -> Iterable[ast.AST]:
    cur = getattr(node, "_lint_parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "_lint_parent", None)


def _enclosing_funcs(node: ast.AST) -> List[ast.AST]:
    return [p for p in _parents(node)
            if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef))]


def _qualname(node: ast.AST) -> str:
    names = [f.name for f in _enclosing_funcs(node)]
    for p in _parents(node):
        if isinstance(p, ast.ClassDef):
            names.append(p.name)
            break
    return ".".join(reversed(names))


def _is_jax_jit(call: ast.Call) -> bool:
    f = call.func
    return (isinstance(f, ast.Attribute) and f.attr == "jit"
            and isinstance(f.value, ast.Name) and f.value.id == "jax")


def _string_leaves(node: ast.AST) -> Iterable[Tuple[ast.AST, str]]:
    for n in ast.walk(node):
        if isinstance(n, ast.Constant) and isinstance(n.value, str):
            yield n, n.value


# -- rules ------------------------------------------------------------------


def _check_jit(tree: ast.AST, path: str) -> List[LintFinding]:
    out = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and _is_jax_jit(node)):
            continue
        parent = getattr(node, "_lint_parent", None)
        qn = _qualname(node)

        # jax.jit(f)(x): compiled object thrown away after one call
        if isinstance(parent, ast.Call) and parent.func is node:
            out.append(LintFinding(
                path, node.lineno, node.col_offset, "JH001",
                "jax.jit(...) immediately invoked — the compiled callable "
                "is discarded and every call retraces", qn))
            continue

        # cached-assignment idioms are safe anywhere
        if isinstance(parent, ast.Return):
            continue
        if isinstance(parent, ast.Assign) and any(
                isinstance(t, (ast.Attribute, ast.Subscript))
                for t in parent.targets):
            continue

        funcs = _enclosing_funcs(node)
        in_loop = any(
            isinstance(p, (ast.For, ast.While, ast.AsyncFor))
            for p in _parents(node)
        )
        innermost = funcs[0].name if funcs else ""
        is_factory = innermost.startswith(FACTORY_PREFIXES)
        is_hot = (not is_factory and any(
            m in innermost.lower() for m in HOT_MARKERS))
        if in_loop:
            out.append(LintFinding(
                path, node.lineno, node.col_offset, "JH001",
                "jax.jit(...) inside a loop without an attribute/subscript "
                "cache — recompiles every iteration", qn))
        elif is_hot:
            out.append(LintFinding(
                path, node.lineno, node.col_offset, "JH001",
                f"jax.jit(...) in per-step/per-request function "
                f"{innermost!r} without an attribute/subscript cache — "
                f"retraces on every call", qn))
    return out


def _is_virtual_clock_module(path: str) -> bool:
    p = Path(path).as_posix()
    if any(p.endswith(f) for f in VIRTUAL_CLOCK_FILES):
        return True
    return any(part in VIRTUAL_CLOCK_PARTS for part in Path(p).parts)


def _check_wallclock(tree: ast.AST, path: str) -> List[LintFinding]:
    if not _is_virtual_clock_module(path):
        return []
    out = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Attribute)
                and node.attr in ("time", "sleep")
                and isinstance(node.value, ast.Name)
                and node.value.id == "time"):
            out.append(LintFinding(
                path, node.lineno, node.col_offset, "JH002",
                f"time.{node.attr} in a virtual-clock module — wall-clock "
                f"reads desynchronize deterministic replay "
                f"(time.perf_counter for measured durations is fine)",
                _qualname(node)))
        if isinstance(node, ast.ImportFrom) and node.module == "time":
            bad = [a.name for a in node.names
                   if a.name in ("time", "sleep")]
            if bad:
                out.append(LintFinding(
                    path, node.lineno, node.col_offset, "JH002",
                    f"from time import {', '.join(bad)} in a virtual-clock "
                    f"module", _qualname(node)))
    return out


def _check_traced_assert(tree: ast.AST, path: str) -> List[LintFinding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assert):
            continue
        for n in ast.walk(node.test):
            if isinstance(n, ast.Name) and n.id in ("jnp", "jax"):
                out.append(LintFinding(
                    path, node.lineno, node.col_offset, "JH003",
                    "assert over a jax/jnp expression — under jit the test "
                    "is a tracer; use checkify or a host callback",
                    _qualname(node)))
                break
    return out


def _check_pspec_axes(tree: ast.AST, path: str) -> List[LintFinding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        name = (f.id if isinstance(f, ast.Name)
                else f.attr if isinstance(f, ast.Attribute) else None)
        if name not in ("P", "PartitionSpec"):
            continue
        for leaf, s in _string_leaves(
                ast.Tuple(elts=list(node.args), ctx=ast.Load())):
            if s not in MESH_AXIS_VOCAB:
                out.append(LintFinding(
                    path, leaf.lineno, leaf.col_offset, "JH004",
                    f"pspec axis {s!r} outside the mesh-axis vocabulary "
                    f"{sorted(MESH_AXIS_VOCAB)} — an unknown axis silently "
                    f"replicates", _qualname(node)))
    return out


RULES = (_check_jit, _check_wallclock, _check_traced_assert,
         _check_pspec_axes)


# -- driver -----------------------------------------------------------------


def lint_file(path: Path, display: Optional[str] = None) -> List[LintFinding]:
    src = path.read_text()
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:
        return [LintFinding(display or str(path), e.lineno or 0, 0,
                            "JH000", f"syntax error: {e.msg}", "")]
    _attach_parents(tree)
    out: List[LintFinding] = []
    for rule in RULES:
        out.extend(rule(tree, display or str(path)))
    return out


def lint_paths(paths: Sequence[str],
               allowlist: Optional[Sequence[AllowEntry]] = None,
               ) -> Tuple[List[LintFinding], List[LintFinding]]:
    """Lint files/trees; returns (findings, suppressed-by-allowlist)."""
    allow = load_allowlist() if allowlist is None else list(allowlist)
    files: List[Path] = []
    for p in paths:
        pp = Path(p)
        files.extend(sorted(pp.rglob("*.py")) if pp.is_dir() else [pp])
    findings: List[LintFinding] = []
    suppressed: List[LintFinding] = []
    for f in files:
        for hit in lint_file(f, display=f.as_posix()):
            (suppressed if _allowed(hit, allow) else findings).append(hit)
    return findings, suppressed


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="JAX-hazard linter (jit retracing, wall-clock in "
                    "virtual-clock modules, traced asserts, unknown pspec "
                    "axes)")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--allowlist", type=Path, default=None,
                    help="override the committed allowlist file")
    args = ap.parse_args(argv)
    allow = (load_allowlist(args.allowlist) if args.allowlist
             else load_allowlist())
    findings, suppressed = lint_paths(args.paths, allowlist=allow)
    for f in findings:
        print(f)
    if suppressed:
        print(f"({len(suppressed)} allowlisted finding(s) suppressed)",
              file=sys.stderr)
    if findings:
        print(f"{len(findings)} finding(s)", file=sys.stderr)
        return 1
    print(f"lint clean ({len(suppressed)} allowlisted)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
