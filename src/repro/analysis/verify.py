"""Static plan verifier: prove burst-plan invariants from pure metadata.

The planner, the collocator, and the serving carving all rely on the same
family of invariants — per stage, the foreground window, each tenant's bg
chunk, every parallel ``BranchPlacement`` window, and the prefill/decode
carving occupy *disjoint* device-index ranges that stay inside the pool —
but the runtime only checks them piecemeal (``submesh_from_range`` bounds,
the serving ``disjoint()`` probe).  A violation anywhere silently burns
cluster throughput instead of crashing: two tenants sharing a device look
like "interference", a branch window leaking into a bg chunk looks like a
slow background step.

``verify_plan`` checks a ``BurstPlan`` in O(layers + stages·branches) with
no jax import and no devices, so the coordinator can run it on every
installed or re-planned plan (debug-gated in hot paths) and CI can sweep
it over randomized plans plus every committed golden plan.  Violations are
structured ``Violation`` records, never asserts — callers decide whether
to raise (``verify_plan_or_raise``) or report.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.plan import (
    BurstPlan,
    StageSharding,
    complement_ranges,
    merge_ranges,
    normalize_quanta,
    pack_ranges,
)

# matches the planner's soft-limit contract (tests/test_plan_regression.py):
# the aggregate amplification honors amp_limit exactly; a single layer may
# exceed it by <= 10% when the soft-limit fallback admits it
EPS = 1e-9
LAYER_AMP_SLACK = 1.1


@dataclass(frozen=True)
class Violation:
    """One broken invariant: a machine-readable check code, the locus
    (layer/stage/slot), and a human-readable detail string."""

    check: str
    where: str
    detail: str

    def __str__(self) -> str:
        return f"[{self.check}] {self.where}: {self.detail}"


class PlanVerificationError(AssertionError):
    """Raised by ``verify_plan_or_raise`` — carries the violation list."""

    def __init__(self, violations: Sequence[Violation], context: str = "plan"):
        self.violations = list(violations)
        lines = "\n".join(f"  {v}" for v in self.violations)
        super().__init__(
            f"{context} failed static verification "
            f"({len(self.violations)} violation(s)):\n{lines}"
        )


# -- range helpers ----------------------------------------------------------


def _span(ranges) -> int:
    return sum(e - s for s, e in ranges)


def _disjoint(ranges) -> bool:
    """True when no two [start, end) ranges overlap."""
    return _span(merge_ranges(ranges)) == _span(
        [(s, e) for s, e in ranges if e > s]
    )


def _overlap(a: Tuple[int, int], b: Tuple[int, int]) -> bool:
    return a[0] < b[1] and b[0] < a[1]


# -- the plan verifier ------------------------------------------------------


def verify_plan(plan: BurstPlan, *,
                pool_size: Optional[int] = None,
                strict_layer_amp: bool = False) -> List[Violation]:
    """All invariants a ``BurstPlan`` must satisfy, as structured reports.

    ``pool_size`` is the surviving device pool the plan was built for; when
    given, the plan must target *exactly* that many devices (the non-pow2
    survivor-pool contract from PR 6: 7 survivors plan as 7, never 4).

    ``strict_layer_amp`` additionally enforces the per-layer soft limit
    (``amp <= amp_limit * 1.1``).  That bound is a property of the *chain*
    planner's transition rule, not of BurstPlan itself — the joint enc-dec
    planner only bounds per-chain aggregates (a tiny decoder embed layer
    may amplify more at the jointly-chosen scale), and block-folding layers
    carry a whole ParallelBlock's gpu-sec — so it is opt-in, used by the
    chain-graph regression sweep.
    """
    out: List[Violation] = []
    if not plan.layers:
        return [Violation("plan-empty", "plan", "no layers")]
    n = plan.num_gpus
    if n < 1:
        out.append(Violation("plan-pool", "plan", f"num_gpus={n} < 1"))
        return out

    # layers that fold a whole ParallelBlock into their time carry the
    # block's aggregate gpu-sec, so the per-layer amp contract does not
    # apply to them (only the aggregate limit does); unknown provenance
    # (layer_index < 0) disables the per-layer check plan-wide
    folded = {
        getattr(p, "layer_index", -1)
        for v in plan.block_details.values() if isinstance(v, tuple)
        for p in v
    }
    skip_layer_amp = -1 in folded

    # layer bounds + per-layer amp (soft-limit contract)
    for l in plan.layers:
        loc = f"layer {l.index} ({l.name})"
        if not 1 <= l.gpus <= n:
            out.append(Violation(
                "layer-bounds", loc,
                f"gpus={l.gpus} outside [1, {n}]"))
        for fname in ("time", "comp", "sync", "comm_in"):
            v = getattr(l, fname)
            if not (math.isfinite(v) and v >= 0.0):
                out.append(Violation(
                    "layer-bounds", loc, f"{fname}={v!r} not finite >= 0"))
        if not (math.isfinite(l.amp) and l.amp >= 0.0):
            out.append(Violation(
                "layer-amp", loc, f"amp={l.amp!r} not finite >= 0"))
        elif (strict_layer_amp and not skip_layer_amp
              and l.index not in folded
              and l.amp > plan.amp_limit * LAYER_AMP_SLACK + EPS):
            out.append(Violation(
                "layer-amp", loc,
                f"amp={l.amp:g} > amp_limit*{LAYER_AMP_SLACK:g}="
                f"{plan.amp_limit * LAYER_AMP_SLACK:g}"))

    # aggregate amp limit
    if plan.amplification > plan.amp_limit + EPS:
        out.append(Violation(
            "plan-amp", "plan",
            f"amplification={plan.amplification:g} > "
            f"amp_limit={plan.amp_limit:g}"))

    # pool exactness (non-pow2 survivor contract)
    if pool_size is not None and n != pool_size:
        out.append(Violation(
            "pool-exact", "plan",
            f"plan targets {n} devices but the pool has {pool_size} — "
            f"survivors must be planned exactly"))

    # stages partition the layer list contiguously, with matching scales
    stages = plan.stages()
    expect_first = 0
    for si, st in enumerate(stages):
        loc = f"stage {si}"
        if st.first != expect_first or st.last < st.first:
            out.append(Violation(
                "stage-cover", loc,
                f"layers [{st.first}, {st.last}] break the contiguous "
                f"partition (expected first={expect_first})"))
            break
        expect_first = st.last + 1
        for li in range(st.first, min(st.last + 1, len(plan.layers))):
            if plan.layers[li].gpus != st.gpus:
                out.append(Violation(
                    "stage-cover", loc,
                    f"layer {li} has gpus={plan.layers[li].gpus} != "
                    f"stage gpus={st.gpus}"))
    else:
        if stages and expect_first != len(plan.layers):
            out.append(Violation(
                "stage-cover", f"stage {len(stages) - 1}",
                f"stages end at layer {expect_first - 1}, plan has "
                f"{len(plan.layers)} layers"))

    # gap windows must mirror their stage
    for g in plan.gaps():
        loc = f"gap@stage {g.stage_index}"
        if not 0 <= g.stage_index < len(stages):
            out.append(Violation(
                "gap-stage", loc, "stage_index out of range"))
            continue
        st = stages[g.stage_index]
        if g.free_gpus != n - st.gpus:
            out.append(Violation(
                "gap-stage", loc,
                f"free_gpus={g.free_gpus} != num_gpus - stage.gpus="
                f"{n - st.gpus}"))

    # branch placements: bounds, then disjointness at the true concurrency
    # granularity — the chain executes layer by layer, so two *different*
    # blocks are never live at once (they may legally reuse the same device
    # window); only parallel non-critical branches of the SAME block run
    # concurrently with each other and with that block's critical branch in
    # [0, stage.gpus).  Demoted/sequential branches time-multiplex the
    # critical range and occupy nothing extra.
    for block, v in plan.block_details.items():
        if not isinstance(v, tuple):
            continue
        par = [
            p for p in v
            if getattr(p, "parallel", False)
            and not getattr(p, "critical", False)
        ]
        for p in par:
            loc = f"branch {p.block}[{p.branch}]"
            if not 0 <= p.device_start < p.device_end <= n:
                out.append(Violation(
                    "branch-bounds", loc,
                    f"devices [{p.device_start}, {p.device_end}) outside "
                    f"[0, {n})"))
        # the fg window while this block executes: the stage containing the
        # block's fold layer (unknown provenance -> check every stage the
        # busy-range logic would exclude it from, i.e. all of them)
        for p in par:
            li = getattr(p, "layer_index", -1)
            hosts = [
                st for st in stages
                if li < 0 or st.first <= li <= st.last
            ]
            for st in hosts:
                if _overlap((0, st.gpus), p.devices):
                    out.append(Violation(
                        "branch-overlap", f"block {block}",
                        f"branch [{p.branch}] devices {p.devices} overlap "
                        f"the fg window [0, {st.gpus}) of its host stage"))
        for i, a in enumerate(par):
            for b in par[i + 1:]:
                if _overlap(a.devices, b.devices):
                    out.append(Violation(
                        "branch-overlap", f"block {block}",
                        f"branches [{a.branch}] {a.devices} and "
                        f"[{b.branch}] {b.devices} overlap"))

    # free/busy must partition the pool exactly, every stage
    for si in range(len(stages)):
        busy = plan.busy_device_ranges(si)
        free = plan.free_device_ranges(si)
        loc = f"stage {si}"
        if not _disjoint(list(busy) + list(free)):
            out.append(Violation(
                "free-busy", loc, f"free {free} overlaps busy {busy}"))
        if _span(merge_ranges(list(busy) + list(free))) != n or \
                _span(busy) + _span(free) != n:
            out.append(Violation(
                "free-busy", loc,
                f"free {free} + busy {busy} do not cover [0, {n}) exactly"))
    return out


def verify_plan_or_raise(plan: BurstPlan, *,
                         pool_size: Optional[int] = None,
                         context: str = "plan") -> None:
    vs = verify_plan(plan, pool_size=pool_size)
    if vs:
        raise PlanVerificationError(vs, context=context)


# -- the bg carving (pure ranges — mirrors split_mesh_for_plan, no meshes) --


def verify_carving(plan: BurstPlan, *, tenants: int = 1,
                   bg_model: int = 1,
                   tenant_quanta: Optional[Sequence[int]] = None,
                   ) -> List[Violation]:
    """Re-derive the per-gap tenant carving from ranges alone and check it.

    This is the same ``pack_ranges`` call ``split_mesh_for_plan`` makes,
    verified against the invariants the collocator assumes: chunks pairwise
    disjoint, each inside one free range (never touching the fg window or a
    branch placement), every chunk quantum-aligned to its slot, and never
    more chunks than tenants.  Because it never builds a Mesh it runs on a
    plan for 1024 devices in microseconds.
    """
    out: List[Violation] = []
    quanta = (normalize_quanta(tenant_quanta, tenants)
              if tenant_quanta is not None else [bg_model] * tenants)
    for gap in plan.gaps():
        si = gap.stage_index
        free = plan.free_device_ranges(si)
        chunks = pack_ranges(
            free, tenants,
            quantum=(normalize_quanta(tenant_quanta, tenants)
                     if tenant_quanta is not None else bg_model))
        live = [c for c in chunks if c is not None]
        loc = f"carving@stage {si}"
        if len(chunks) > tenants:
            out.append(Violation(
                "carve-count", loc,
                f"{len(chunks)} chunks for {tenants} tenants"))
        if not _disjoint(live):
            out.append(Violation(
                "carve-overlap", loc, f"chunks overlap: {live}"))
        for slot, c in enumerate(chunks):
            if c is None:
                continue
            s, e = c
            q = quanta[slot] if tenant_quanta is not None else bg_model
            if e <= s:
                out.append(Violation(
                    "carve-bounds", f"{loc} slot {slot}",
                    f"empty chunk {c}"))
                continue
            if (e - s) % q:
                out.append(Violation(
                    "carve-quantum", f"{loc} slot {slot}",
                    f"chunk {c} size {e - s} not a multiple of "
                    f"quantum {q}"))
            if not any(fs <= s and e <= fe for fs, fe in free):
                out.append(Violation(
                    "carve-free", f"{loc} slot {slot}",
                    f"chunk {c} escapes the free ranges {free} — it "
                    f"touches the fg window or a branch placement"))
    return out


# -- real carved submeshes (PlanSubmeshes / ServingSubmeshes) ---------------


def verify_submeshes(plan: BurstPlan, submeshes) -> List[Violation]:
    """Check a carved ``PlanSubmeshes`` against its plan.

    Works on positional ranges and mesh *shapes* only — never touches the
    device objects — so it holds for real, forced-host, and virtual device
    sets alike.
    """
    out: List[Violation] = []
    n = plan.num_gpus
    stages = plan.stages()
    fs, fe = submeshes.fg_range
    peak = max(s.gpus for s in stages)
    if (fs, fe) != (0, peak):
        out.append(Violation(
            "submesh-fg", "fg", f"fg_range {(fs, fe)} != (0, peak={peak})"))
    if submeshes.fg_mesh is not None and \
            int(submeshes.fg_mesh.devices.size) != fe - fs:
        out.append(Violation(
            "submesh-size", "fg",
            f"fg mesh has {int(submeshes.fg_mesh.devices.size)} devices, "
            f"range {(fs, fe)} spans {fe - fs}"))
    for si, slots in submeshes.bg_tenants.items():
        if not 0 <= si < len(stages):
            out.append(Violation(
                "submesh-stage", f"stage {si}", "not a plan stage"))
            continue
        busy = plan.busy_device_ranges(si)
        live = [c for c, _mesh in (s for s in slots if s is not None)]
        loc = f"submesh@stage {si}"
        if not _disjoint(live):
            out.append(Violation(
                "submesh-overlap", loc, f"tenant ranges overlap: {live}"))
        for slot, hit in enumerate(slots):
            if hit is None:
                continue
            (s, e), mesh = hit
            sloc = f"{loc} slot {slot}"
            if not 0 <= s < e <= n:
                out.append(Violation(
                    "submesh-bounds", sloc,
                    f"range {(s, e)} outside [0, {n})"))
            for b in busy:
                if _overlap((s, e), b):
                    out.append(Violation(
                        "submesh-overlap", sloc,
                        f"tenant range {(s, e)} overlaps busy range {b} "
                        f"(fg window or branch placement)"))
            if mesh is not None and int(mesh.devices.size) != e - s:
                out.append(Violation(
                    "submesh-size", sloc,
                    f"mesh has {int(mesh.devices.size)} devices, range "
                    f"{(s, e)} spans {e - s}"))
        hit = submeshes.bg.get(si)
        if hit is not None and all(
                hit[0] != c for c, _m in
                (s for s in slots if s is not None)):
            out.append(Violation(
                "submesh-slot0", loc,
                f"bg range {hit[0]} is not one of the tenant slots"))
    return out


def verify_serving_submeshes(sub, n_devices: int) -> List[Violation]:
    """Check a ``ServingSubmeshes`` prefill/decode carving."""
    out: List[Violation] = []
    (ps, pe), (ds, de) = sub.prefill_range, sub.decode_range
    for name, (s, e) in (("prefill", (ps, pe)), ("decode", (ds, de))):
        if not 0 <= s < e <= n_devices:
            out.append(Violation(
                "serving-bounds", name,
                f"range {(s, e)} outside [0, {n_devices})"))
    if _overlap((ps, pe), (ds, de)):
        out.append(Violation(
            "serving-overlap", "prefill/decode",
            f"prefill {(ps, pe)} overlaps decode {(ds, de)}"))
    for name, mesh, (s, e) in (
            ("prefill", sub.prefill_mesh, (ps, pe)),
            ("decode", sub.decode_mesh, (ds, de))):
        if mesh is not None and int(mesh.devices.size) != e - s:
            out.append(Violation(
                "serving-size", name,
                f"mesh has {int(mesh.devices.size)} devices, range "
                f"{(s, e)} spans {e - s}"))
    return out


# -- stage shardings (map_plan_to_mesh output) ------------------------------


_MESH_AXIS_VOCAB = ("pod", "data", "model")


def verify_stage_shardings(plan: BurstPlan,
                           shardings: Sequence[StageSharding],
                           mesh_axes: Dict[str, int]) -> List[Violation]:
    """Check ``map_plan_to_mesh`` output against its plan and mesh."""
    out: List[Violation] = []
    stages = plan.stages()
    if len(shardings) != len(stages):
        out.append(Violation(
            "sharding-count", "plan",
            f"{len(shardings)} stage shardings for {len(stages)} stages"))
    for si, sh in enumerate(shardings):
        loc = f"sharding@stage {si}"
        for ax in sh.batch_axes:
            if ax not in _MESH_AXIS_VOCAB:
                out.append(Violation(
                    "sharding-axis", loc,
                    f"batch axis {ax!r} outside the mesh vocabulary "
                    f"{_MESH_AXIS_VOCAB}"))
            elif ax not in mesh_axes:
                out.append(Violation(
                    "sharding-axis", loc,
                    f"batch axis {ax!r} not on this mesh "
                    f"(axes: {sorted(mesh_axes)})"))
        if not sh.batch_axes:
            out.append(Violation(
                "sharding-axis", loc, "no batch axes — samples unplaced"))
        if si < len(stages):
            expect = tuple(plan.free_device_ranges(si))
            if tuple(sh.free_ranges) != expect:
                out.append(Violation(
                    "sharding-free", loc,
                    f"free_ranges {sh.free_ranges} != plan's {expect}"))
    return out
