"""Static analysis passes over the burst-parallel runtime (ISSUE 10).

Four passes, all runnable with zero accelerators:

- ``verify``     — pure-metadata plan/submesh verifier (device-range
                   disjointness, coverage, quantum alignment, amp limits).
- ``shardcheck`` — sharding-rule sweep over every config x every mesh shape
                   reachable by ``largest_pow2_mesh`` after a failure.
- ``protocheck`` — bounded-interleaving model checker for the transport
                   control plane (lease election, cursor safety, GC).
- ``lint``       — AST linter for the JAX hazards this repo has shipped
                   (per-call jit, wall-clock in virtual-clock modules,
                   asserts on traced values, unknown pspec axes).

Each pass is a module with a ``main()`` CLI (``python -m
repro.analysis.<pass>``) and a library entry point returning structured
``Violation`` reports; the ``static-analysis`` CI job runs all four.
"""
from repro.analysis.verify import (  # noqa: F401
    PlanVerificationError,
    Violation,
    verify_carving,
    verify_plan,
    verify_plan_or_raise,
    verify_serving_submeshes,
    verify_stage_shardings,
    verify_submeshes,
)
