"""Bounded-interleaving model checker for the transport control plane.

PR 7–8 hand-tested the live control plane's safety properties with scripted
scenarios; this pass checks them *mechanically* by driving the real
protocol objects — ``InProcessBus`` + ``WorkerClient`` +
``CoordinatorLease`` + ``CoordinatorLoop`` + ``HeartbeatMonitor`` — through
every interleaving of a small action alphabet (exhaustive to a bounded
depth, plus seeded-random longer schedules) on a virtual clock.  Only the
planner is abstracted away (``ModelCoordinator`` stub): the properties are
about the protocol, not the plan contents.

Safety properties, asserted after every action of every schedule:

- **cursor safety** — each worker's delivered reconfig sequence is
  strictly consecutive (never skips, never re-reads), and no consumer
  cursor ever falls below a topic's compacted ``low_water`` mark;
- **lease uniqueness** — per epoch, at most one worker ever *settles* as
  holder (believes it holds after consuming the entire lease log);
- **mitigation-once** — each device failure is mitigated (re-planned) at
  most once across arbitrary coordinator failovers:
  ``bootstrap_from_log`` adopts the pool-of-record instead of re-firing;
- **pool-of-record survival** — once any reconfig event was published,
  the newest one survives every GC schedule (it is what a failover
  restores from).

Seeded mutants demonstrate the checker's power by re-introducing the real
PR 7–8 bug classes; each must be re-detected (see MUTANTS):

- ``cursor-reread``   — worker ack cursor off-by-one (re-reads the tail);
- ``adopt-skip``      — failover skips pool adoption (double-fires the
  old holder's mitigations);
- ``gc-head``         — GC compacts the reconfig log without retaining
  the newest event (loses the failover pool-of-record).

Run as ``python -m repro.analysis.protocheck`` (exit 1 on violations, or —
with ``--mutant NAME`` — exit 1 when the mutant is NOT detected).
"""
from __future__ import annotations

import argparse
import itertools
import random
import sys
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis.verify import Violation
from repro.dist.faults import HeartbeatMonitor
from repro.dist.transport import (
    HEARTBEAT_TOPIC,
    LEASE_TOPIC,
    RECONFIG_TOPIC,
    CoordinatorLease,
    CoordinatorLoop,
    InProcessBus,
    WorkerClient,
)

HB_TIMEOUT = 2.0        # small timeouts shrink the temporal diameter of the
LEASE_TIMEOUT = 3.0     # state space: every interesting pattern (failure
DT_SMALL = 1.0          # detection, lease expiry, failover) is reachable in
DT_BIG = 3.5            # fewer actions.  DT_SMALL is under both timeouts;
                        # DT_BIG expires both at once.


class RecordingBus(InProcessBus):
    """InProcessBus that remembers every published seq per topic (so the
    checker knows the newest reconfig independently of the log state)."""

    def __init__(self):
        super().__init__()
        self.published: Dict[str, List[int]] = {}

    def publish(self, topic: str, payload: dict) -> int:
        seq = super().publish(topic, payload)
        self.published.setdefault(topic, []).append(seq)
        return seq


class RecordingWorkerClient(WorkerClient):
    """WorkerClient that records the seq of every delivered reconfig."""

    def __init__(self, transport, worker_id: int):
        super().__init__(transport, worker_id)
        self.delivered: List[int] = []

    def poll_reconfig(self) -> List[dict]:
        msgs = sorted(
            self.transport.poll(RECONFIG_TOPIC, self._seen_reconfig),
            key=lambda sp: sp[0])
        out = []
        for seq, p in msgs:
            if seq < self._seen_reconfig:
                continue
            self.delivered.append(seq)
            self._seen_reconfig = seq + 1
            out.append(p)
        return out


@dataclass
class FakePlan:
    num_gpus: int


class ModelCoordinator:
    """Planner-free coordinator stub with the exact surface
    ``CoordinatorLoop`` touches (healthy / handle_failure / handle_join /
    restore_pool / readmit).  Mitigation counters live in ``shared`` so
    they survive coordinator failovers — each new lease holder builds a
    fresh instance (like the real train loop) but the *cluster truth* of
    which failures were already mitigated is global."""

    def __init__(self, n_devices: int, shared: Dict[int, int]):
        self.healthy = set(range(n_devices))
        self.failure_mitigations = shared  # device -> times re-planned

    def handle_failure(self, device_id: int) -> Optional[FakePlan]:
        self.healthy.discard(device_id)
        self.failure_mitigations[device_id] = \
            self.failure_mitigations.get(device_id, 0) + 1
        return FakePlan(len(self.healthy))

    def handle_join(self, device_ids) -> Optional[FakePlan]:
        new = set(int(d) for d in device_ids) - self.healthy
        if not new:
            return None
        self.healthy.update(new)
        for d in new:  # a re-join starts a new life: the next failure is a
            self.failure_mitigations.pop(d, None)  # new event, not a re-fire
        return FakePlan(len(self.healthy))

    def restore_pool(self, devices) -> None:
        self.healthy = set(int(d) for d in devices)

    def readmit(self, *a, **kw) -> None:
        return None


# -- mutants (seeded bug re-introductions) ----------------------------------


class MutantRereadClient(RecordingWorkerClient):
    """PR 7 bug class: ack cursor off-by-one — the consumer sets its cursor
    *to* the delivered seq instead of past it, re-reading the tail event on
    every later poll."""

    def poll_reconfig(self) -> List[dict]:
        msgs = sorted(
            self.transport.poll(RECONFIG_TOPIC, self._seen_reconfig),
            key=lambda sp: sp[0])
        out = []
        for seq, p in msgs:
            self.delivered.append(seq)
            self._seen_reconfig = seq  # BUG: should be seq + 1
            out.append(p)
        return out


class MutantAdoptSkipCoordinator(ModelCoordinator):
    """PR 8 bug class: a fresh lease holder that does not adopt the old
    holder's pool-of-record — the already-mitigated dead worker is back in
    ``healthy``, so the next detection double-fires the mitigation."""

    def restore_pool(self, devices) -> None:
        pass  # BUG: bootstrap adoption skipped


class MutantGCHeadLoop(CoordinatorLoop):
    """PR 8 bug class: reconfig GC driven purely by consumer acks, without
    retaining the newest event — once every live worker acked, the
    failover pool-of-record is compacted away."""

    def gc(self) -> Tuple[int, int]:
        hb_lw = self.transport.compact(HEARTBEAT_TOPIC, self._seen_beats)
        live_acks = [a for w, a in self._acks.items()
                     if w in self.monitor.last]
        rc_lw = self.transport.low_water(RECONFIG_TOPIC)
        if live_acks and len(live_acks) == len(self.monitor.last):
            rc_lw = self.transport.compact(
                RECONFIG_TOPIC, min(live_acks))  # BUG: no head-1 retention
        return hb_lw, rc_lw


@dataclass
class Mutant:
    name: str
    bug_class: str
    client_cls: type = RecordingWorkerClient
    coordinator_cls: type = ModelCoordinator
    loop_cls: type = CoordinatorLoop


MUTANTS: Dict[str, Mutant] = {
    m.name: m for m in (
        Mutant("cursor-reread", "cursor re-read",
               client_cls=MutantRereadClient),
        Mutant("adopt-skip", "double-fired mitigation",
               coordinator_cls=MutantAdoptSkipCoordinator),
        Mutant("gc-head", "lost pool-of-record",
               loop_cls=MutantGCHeadLoop),
    )
}


# -- the model --------------------------------------------------------------


class ProtocolModel:
    """One fresh control-plane universe: N workers over one bus, driven by
    named actions on a virtual clock, with the safety properties checked
    after every action."""

    def __init__(self, n_workers: int = 2, mutant: Optional[Mutant] = None):
        m = mutant or Mutant("none", "none")
        self.now = 0.0
        self.clock = lambda: self.now
        self.bus = RecordingBus()
        self.n_workers = n_workers
        self.mitigations: Dict[int, int] = {}
        self._coordinator_cls = m.coordinator_cls
        self._loop_cls = m.loop_cls
        self.alive = {w: True for w in range(n_workers)}
        self.steps = {w: 0 for w in range(n_workers)}
        self.clients = {
            w: m.client_cls(self.bus, w) for w in range(n_workers)}
        self.leases = {
            w: CoordinatorLease(self.bus, w, timeout=LEASE_TIMEOUT,
                                clock=self.clock)
            for w in range(n_workers)}
        self.loops: Dict[int, CoordinatorLoop] = {}
        # epoch -> workers that settled as holder of that epoch
        self.settled: Dict[int, set] = {}
        # workers ever declared dead + re-planned away: excluded from the
        # cursor-safety property for good (their old cursor may straddle a
        # compaction; the protocol makes them bootstrap, not continue)
        self.ever_mitigated: set = set()
        self.violations: List[Violation] = []

    # -- actions ------------------------------------------------------------

    def act_beat(self, w: int) -> None:
        if not self.alive[w]:
            return
        self.clients[w].poll_reconfig()
        self.steps[w] += 1
        self.clients[w].beat(self.steps[w])

    def _ensure_loop(self, w: int) -> CoordinatorLoop:
        loop = self.loops.get(w)
        if loop is None:
            loop = self._loop_cls(
                self.bus,
                HeartbeatMonitor(self.n_workers, HB_TIMEOUT,
                                 clock=self.clock),
                coordinator=self._coordinator_cls(
                    self.n_workers, self.mitigations),
            )
            loop.bootstrap_from_log()
            self.loops[w] = loop
        return loop

    def act_tick(self, w: int) -> None:
        if not self.alive[w]:
            return
        if self.leases[w].tick() and self.leases[w].acquired:
            self.loops.pop(w, None)   # fresh holder: fresh coordinator
            self._ensure_loop(w)

    def act_pump(self, w: int) -> None:
        if not self.alive[w]:
            return
        lease = self.leases[w]
        if not lease.tick():
            return
        if lease.acquired:
            self.loops.pop(w, None)
        self._ensure_loop(w).pump()

    def act_gc(self, w: int) -> None:
        if not self.alive[w]:
            return
        lease = self.leases[w]
        if lease.holder == w and w in self.loops:
            self.loops[w].gc()

    def act_silence(self, w: int) -> None:
        self.alive[w] = False  # beats, ticks and pumps stop forever

    def act_advance(self, dt: float) -> None:
        self.now += dt

    def actions(self) -> Dict[str, Callable[[], None]]:
        acts: Dict[str, Callable[[], None]] = {}
        for w in range(self.n_workers):
            acts[f"beat{w}"] = lambda w=w: self.act_beat(w)
            acts[f"tick{w}"] = lambda w=w: self.act_tick(w)
            acts[f"pump{w}"] = lambda w=w: self.act_pump(w)
            acts[f"gc{w}"] = lambda w=w: self.act_gc(w)
        # silencing worker 0 (the deterministic first lease winner) is the
        # coordinator-failover case; higher workers dying is the plain
        # worker-loss case — include both, but keep the alphabet small by
        # silencing only the extremes
        acts["silence0"] = lambda: self.act_silence(0)
        acts[f"silence{self.n_workers - 1}"] = \
            lambda: self.act_silence(self.n_workers - 1)
        acts["adv"] = lambda: self.act_advance(DT_SMALL)
        acts["ADV"] = lambda: self.act_advance(DT_BIG)
        return acts

    # -- properties ---------------------------------------------------------

    def check(self, where: str) -> None:
        v = self.violations
        rc_lw = self.bus.low_water(RECONFIG_TOPIC)
        hb_lw = self.bus.low_water(HEARTBEAT_TOPIC)
        lease_head = max(self.bus.published.get(LEASE_TOPIC, [-1])) + 1
        self.ever_mitigated.update(
            d for d, c in self.mitigations.items() if c > 0)
        # cursor safety is guaranteed only while the control plane considers
        # the worker live: once a failure was mitigated for it (declared
        # dead, re-planned away, acks dropped from GC aggregation) it must
        # bootstrap, not continue its cursor — exclude it from P1
        for w, c in self.clients.items():
            if w in self.ever_mitigated:
                continue
            seqs = c.delivered
            for a, b in zip(seqs, seqs[1:]):
                if b != a + 1:
                    kind = ("re-read" if b <= a else "skipped")
                    v.append(Violation(
                        "proto-cursor", f"{where} worker {w}",
                        f"delivered reconfig seqs {seqs} — {kind} "
                        f"(consecutive delivery violated)"))
                    break
            if self.alive[w] and c._seen_reconfig < rc_lw:
                v.append(Violation(
                    "proto-gc-cursor", f"{where} worker {w}",
                    f"live consumer cursor {c._seen_reconfig} below the "
                    f"compacted low-water {rc_lw} — GC passed a live ack"))
        # the hb-cursor bound holds for the *acting* holder only: a deposed
        # coordinator's loop legitimately falls behind once the new holder
        # compacts, and the lease gate keeps it from ever pumping again
        for w, loop in self.loops.items():
            lease = self.leases[w]
            if (self.alive[w] and lease.holder == w
                    and lease._cursor >= lease_head
                    and loop._seen_beats < hb_lw):
                v.append(Violation(
                    "proto-gc-cursor", f"{where} holder {w}",
                    f"beat cursor {loop._seen_beats} below hb low-water "
                    f"{hb_lw}"))
        # lease: a worker is *settled* when it believes it holds after
        # consuming the full lease log; per epoch at most one may ever
        for w, lease in self.leases.items():
            if (self.alive[w] and lease.holder == w
                    and lease._cursor >= lease_head):
                self.settled.setdefault(lease.epoch, set()).add(w)
        for epoch, holders in self.settled.items():
            if len(holders) > 1:
                v.append(Violation(
                    "proto-lease", where,
                    f"epoch {epoch} settled holders {sorted(holders)} — "
                    f"split brain"))
        for dev, count in self.mitigations.items():
            if count > 1:
                v.append(Violation(
                    "proto-mitigation", where,
                    f"device {dev} mitigated {count} times — a failover "
                    f"re-fired an adopted mitigation"))
        published = self.bus.published.get(RECONFIG_TOPIC, [])
        if published:
            newest = published[-1]
            retained = [s for s, _ in self.bus.poll(RECONFIG_TOPIC, rc_lw)]
            if newest not in retained:
                v.append(Violation(
                    "proto-pool-of-record", where,
                    f"newest reconfig seq {newest} compacted away "
                    f"(retained: {retained}) — a failover would restore a "
                    f"stale pool"))

    def run_schedule(self, schedule: Sequence[str]) -> List[Violation]:
        acts = self.actions()
        for i, name in enumerate(schedule):
            try:
                acts[name]()
            except Exception as e:  # a replay crash is itself a finding
                self.violations.append(Violation(
                    "proto-crash", f"step {i} ({name})",
                    f"{type(e).__name__}: {e} "
                    f"[schedule: {' '.join(schedule[:i + 1])}]"))
                return self.violations
            self.check(f"after {' '.join(schedule[:i + 1])}")
            if self.violations:
                return self.violations
        return self.violations


# -- the explorer -----------------------------------------------------------


@dataclass
class CheckReport:
    schedules: int = 0
    violations: List[Violation] = field(default_factory=list)
    failing_schedule: Optional[Tuple[str, ...]] = None

    @property
    def ok(self) -> bool:
        return not self.violations


def _action_weight(name: str) -> int:
    """Sampling weights for the random-walk phase.  ``silence`` is
    permanent (a silenced worker never returns), so uniform sampling kills
    every worker within a few dozen steps and the walk explores nothing —
    keep deaths rare and ordinary protocol activity common."""
    if name.startswith("silence"):
        return 1
    if name in ("adv", "ADV"):
        return 10
    return 8


def explore(n_workers: int = 2, depth: int = 4, samples: int = 2000,
            sample_len: int = 40, seed: int = 0,
            mutant: Optional[str] = None,
            stop_on_first: bool = True) -> CheckReport:
    """Exhaustive schedules to ``depth``, then ``samples`` seeded random
    walks of ``sample_len`` weighted actions (long walks reach the deep
    temporal patterns — mitigate, fail over, re-detect — that bounded
    exhaustion cannot).  Deterministic for fixed parameters — no wall
    clock, no global RNG."""
    mut = MUTANTS[mutant] if mutant else None
    names = sorted(ProtocolModel(n_workers, mut).actions())
    weights = [_action_weight(n) for n in names]
    report = CheckReport()

    def run(schedule: Tuple[str, ...]) -> bool:
        report.schedules += 1
        vs = ProtocolModel(n_workers, mut).run_schedule(schedule)
        if vs:
            report.violations.extend(vs)
            report.failing_schedule = schedule
            return True
        return False

    for d in range(1, depth + 1):
        for schedule in itertools.product(names, repeat=d):
            if run(schedule) and stop_on_first:
                return report
    rng = random.Random(seed)
    for _ in range(samples):
        schedule = tuple(rng.choices(names, weights=weights, k=sample_len))
        if run(schedule) and stop_on_first:
            return report
    return report


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.protocheck",
        description="bounded-interleaving model checker for the transport "
                    "control plane")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--depth", type=int, default=4,
                    help="exhaustive interleaving depth")
    ap.add_argument("--samples", type=int, default=2000,
                    help="seeded-random longer schedules")
    ap.add_argument("--sample-len", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mutant", choices=sorted(MUTANTS), default=None,
                    help="run against a seeded bug; exit 1 if NOT detected")
    args = ap.parse_args(argv)
    report = explore(args.workers, args.depth, args.samples,
                     args.sample_len, args.seed, mutant=args.mutant)
    if args.mutant:
        m = MUTANTS[args.mutant]
        if report.ok:
            print(f"mutant {args.mutant} ({m.bug_class}) NOT detected "
                  f"after {report.schedules} schedules", file=sys.stderr)
            return 1
        print(f"mutant {args.mutant} ({m.bug_class}) detected after "
              f"{report.schedules} schedules:", file=sys.stderr)
        for v in report.violations[:3]:
            print(f"  {v}", file=sys.stderr)
        print(f"  schedule: {' '.join(report.failing_schedule)}",
              file=sys.stderr)
        return 0
    for v in report.violations:
        print(v)
    if report.failing_schedule:
        print(f"failing schedule: {' '.join(report.failing_schedule)}",
              file=sys.stderr)
    print(f"protocheck: {report.schedules} schedules, "
          f"{len(report.violations)} violation(s)", file=sys.stderr)
    return 1 if report.violations else 0


if __name__ == "__main__":
    raise SystemExit(main())
