"""Static sharding sweep: every config x every post-failure mesh shape.

``sharding_rules``/``pspec`` are pure functions of ``mesh.axis_names`` and
``mesh.devices.shape``, so the whole rule surface can be validated against
abstract mesh stand-ins — no devices, no compilation.  This catches the
"config only breaks after a 7-device re-carve" class statically: the sweep
enumerates every (data, model) shape ``largest_pow2_mesh``/
``remesh_for_pool`` can produce for pool sizes 1–64 (the shapes the elastic
control plane actually re-carves onto after failures) and, for every
registered config and shape kind, checks the produced ``PartitionSpec``
trees uphold the engine's three invariants *by construction output*, not by
trusting the derivation:

  - every sharded dim is divisible by its mesh-axes product;
  - no mesh axis shards two dims of one array;
  - specs never exceed the array rank, and only name axes on the mesh.

It also cross-checks the vocabulary in both directions: rules may only map
to declared mesh axes ({pod, data, model}), and every logical axis named by
a model schema must be known to the rules engine (a typo'd logical axis
silently replicates).  Divisibility *drops* recorded by ``RuleReport`` are
expected degradation (the guard working), reported as statistics, not
violations.

Run as ``python -m repro.analysis.shardcheck`` (exit 1 on violations).
"""
from __future__ import annotations

import argparse
import math
import sys
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.verify import Violation

MESH_AXIS_VOCAB = frozenset({"pod", "data", "model"})
DEFAULT_POOL_RANGE = range(1, 65)


class AbstractMesh:
    """Stand-in with the only two attributes the rules engine reads."""

    def __init__(self, shape: Tuple[int, ...],
                 axis_names: Tuple[str, ...] = ("data", "model")):
        assert len(shape) == len(axis_names)
        self.axis_names = tuple(axis_names)
        # int8 keeps the stand-in tiny; only .shape is ever read
        self.devices = np.empty(shape, dtype=np.int8)

    def __repr__(self) -> str:
        return "x".join(
            f"{a}={n}" for a, n in zip(self.axis_names, self.devices.shape))


def reachable_mesh_shapes(
        pool_sizes: Iterable[int] = DEFAULT_POOL_RANGE,
) -> List[Tuple[int, int]]:
    """Every (data, model) shape the elastic re-carve can produce."""
    from repro.launch.mesh import pow2_mesh_shape

    return sorted({pow2_mesh_shape(n) for n in pool_sizes})


def _spec_entries(spec) -> List[Tuple[int, Tuple[str, ...]]]:
    """(dim_index, mesh_axes) for each sharded dim of a PartitionSpec."""
    out = []
    for i, part in enumerate(tuple(spec)):
        if part is None:
            continue
        axes = (part,) if isinstance(part, str) else tuple(part)
        out.append((i, axes))
    return out


def check_spec(spec, shape: Sequence[int], sizes: Dict[str, int],
               where: str) -> List[Violation]:
    """Validate one produced PartitionSpec against the engine invariants."""
    out: List[Violation] = []
    entries = _spec_entries(spec)
    if len(tuple(spec)) > len(shape):
        out.append(Violation(
            "shard-rank", where,
            f"spec {spec} has {len(tuple(spec))} entries for rank-"
            f"{len(shape)} array"))
        return out
    used: List[str] = []
    for dim_idx, axes in entries:
        for a in axes:
            if a not in sizes:
                out.append(Violation(
                    "shard-axis", where,
                    f"spec {spec} names mesh axis {a!r} not on the mesh "
                    f"(axes: {sorted(sizes)})"))
            elif a in used:
                out.append(Violation(
                    "shard-reuse", where,
                    f"mesh axis {a!r} shards two dims of one array "
                    f"(spec {spec})"))
            used.append(a)
        total = int(math.prod(sizes.get(a, 1) for a in axes))
        if total > 1 and shape[dim_idx] % total != 0:
            out.append(Violation(
                "shard-divisibility", where,
                f"dim {dim_idx} (size {shape[dim_idx]}) sharded over "
                f"{axes} (product {total}) without dividing"))
    return out


def check_cell(cfg, shape_cfg, mesh) -> Tuple[List[Violation], int]:
    """One (config, shape kind, mesh shape) cell.

    Returns (violations, n_dropped) — drops are the divisibility guard
    declining to shard, which is expected degradation at odd pool sizes.
    """
    import jax

    from repro.dist.sharding import (RuleReport, batch_pspecs,
                                     mesh_axis_sizes, pspec, sharding_rules)
    from repro.models.api import get_model, input_specs
    from repro.models.layers import is_spec

    sizes = mesh_axis_sizes(mesh)
    rules = sharding_rules(cfg, mesh, shape_cfg)
    kind = shape_cfg.kind if shape_cfg is not None else "train"
    cell = f"{cfg.name}/{kind}@{mesh!r}"
    out: List[Violation] = []

    # rule vocabulary: only declared mesh axes may appear on the RHS
    for logical, axes in rules.items():
        for a in axes:
            if a not in MESH_AXIS_VOCAB:
                out.append(Violation(
                    "shard-vocab", f"{cell} rule {logical!r}",
                    f"maps to undeclared mesh axis {a!r}"))

    api = get_model(cfg)
    report = RuleReport()

    def check_tree(tree, label: str) -> None:
        leaves_with_paths = jax.tree_util.tree_flatten_with_path(
            tree, is_leaf=is_spec)[0]
        for path, s in leaves_with_paths:
            if not is_spec(s):
                continue
            where = f"{cell} {label}{jax.tree_util.keystr(path)}"
            for ax in s.axes:
                if ax is not None and ax not in rules:
                    out.append(Violation(
                        "shard-logical", where,
                        f"schema names unknown logical axis {ax!r} — it "
                        f"would silently replicate"))
            spec = pspec(s.axes, s.shape, rules, mesh, report)
            out.extend(check_spec(spec, s.shape, sizes, where))

    check_tree(api.schema, "params")

    # model inputs (and, for decode, the paged cache schema) go through the
    # same machinery batch_pspecs uses at jit time
    if shape_cfg is not None:
        specs = input_specs(cfg, shape_cfg)
        bspecs = batch_pspecs(cfg, shape_cfg, rules, mesh, specs, report)
        flat = jax.tree_util.tree_flatten_with_path(bspecs)[0]
        spec_shapes = {
            jax.tree_util.keystr(p): v.shape
            for p, v in jax.tree_util.tree_flatten_with_path(specs)[0]
        }
        if "cache" in specs:
            schema = api.cache_schema(shape_cfg.global_batch,
                                      shape_cfg.seq_len)
            for p, s in jax.tree_util.tree_flatten_with_path(
                    schema, is_leaf=is_spec)[0]:
                spec_shapes[f"['cache']{jax.tree_util.keystr(p)}"] = s.shape
        for path, spec in flat:
            key = jax.tree_util.keystr(path)
            shp = spec_shapes.get(key)
            if shp is None:
                continue
            out.extend(check_spec(
                spec, shp, sizes, f"{cell} inputs{key}"))
    return out, len(report.dropped)


def sweep(config_names: Optional[Sequence[str]] = None,
          pool_sizes: Iterable[int] = DEFAULT_POOL_RANGE,
          ) -> Tuple[List[Violation], Dict[str, int]]:
    """The full static sweep.  Returns (violations, stats)."""
    from repro.configs import get_config, list_configs, shapes_for

    names = list(config_names) if config_names else list_configs()
    shapes = reachable_mesh_shapes(pool_sizes)
    violations: List[Violation] = []
    stats = {"cells": 0, "dropped": 0, "mesh_shapes": len(shapes),
             "configs": len(names)}
    for name in names:
        cfg = get_config(name)
        shape_cfgs = [None] + list(shapes_for(cfg))
        for (data, model) in shapes:
            mesh = AbstractMesh((data, model))
            for sc in shape_cfgs:
                vs, dropped = check_cell(cfg, sc, mesh)
                violations.extend(vs)
                stats["cells"] += 1
                stats["dropped"] += dropped
    return violations, stats


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.shardcheck",
        description="static sharding sweep over every config x every "
                    "post-failure mesh shape (1-64 devices)")
    ap.add_argument("--configs", nargs="*", default=None,
                    help="subset of config names (default: all registered)")
    ap.add_argument("--max-pool", type=int, default=64)
    args = ap.parse_args(argv)
    violations, stats = sweep(args.configs,
                              pool_sizes=range(1, args.max_pool + 1))
    for v in violations:
        print(v)
    print(
        f"shardcheck: {stats['configs']} configs x "
        f"{stats['mesh_shapes']} mesh shapes, {stats['cells']} cells, "
        f"{stats['dropped']} divisibility drops (expected degradation), "
        f"{len(violations)} violation(s)", file=sys.stderr)
    return 1 if violations else 0


if __name__ == "__main__":
    raise SystemExit(main())
