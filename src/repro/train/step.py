"""train_step / eval_step factories with pjit shardings.

``make_train_step`` builds the full step: fwd + bwd + gradient clipping +
optimizer update (+ optional PowerSGD gradient compression and burst-plan
activation constraints).  ``jit_train_step`` closes it over mesh shardings —
this is exactly what launch/dryrun.py lowers.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist import fsdp
from repro.dist.sharding import (
    batch_pspecs,
    param_pspecs,
    sharding_rules,
)
from repro.train.state import state_pspecs, state_schema


def make_train_step(api, optimizer, grad_transform: Optional[Callable] = None):
    def train_step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(api.loss, has_aux=True)(
            state["params"], batch
        )
        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt = optimizer.update(grads, state["opt"], state["params"])
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def make_forward(api):
    """Full-sequence forward (prefill benchmark shape)."""

    def fwd(params, batch):
        if "frames" in batch:
            return api.forward(params, batch["frames"], batch["tokens"])
        if "patch_embeds" in batch:
            return api.forward(params, batch["tokens"], patch_embeds=batch["patch_embeds"])
        return api.forward(params, batch["tokens"])

    return fwd


def make_decode_step(api):
    def step(params, batch):
        return api.decode_step(params, batch["token"], batch["cache"], batch["cache_len"])

    return step


def jit_train_step(api, optimizer, mesh, shape: ShapeConfig, donate: bool = True,
                   rules: Optional[dict] = None, report=None):
    """Returns (jitted_fn, state_shardings, batch_shardings)."""
    from repro.models.api import input_specs

    cfg = api.cfg
    rules = rules or sharding_rules(cfg, mesh, shape)
    st_specs = state_pspecs(api, optimizer, rules, mesh, report)
    bt_specs = batch_pspecs(cfg, shape, rules, mesh, input_specs(cfg, shape), report)
    st_sh = jax.tree.map(lambda p: NamedSharding(mesh, p), st_specs,
                         is_leaf=lambda x: isinstance(x, P))
    bt_sh = jax.tree.map(lambda p: NamedSharding(mesh, p), bt_specs,
                         is_leaf=lambda x: isinstance(x, P))
    step = make_train_step(api, optimizer)

    def step_with_fsdp(state, batch):
        with fsdp.context(mesh, rules):
            return step(state, batch)

    fn = jax.jit(
        step_with_fsdp,
        in_shardings=(st_sh, bt_sh),
        out_shardings=(st_sh, None),
        donate_argnums=(0,) if donate else (),
    )
    return fn, st_sh, bt_sh


def bg_step_factory(arch: str = "qwen2-1.5b", *, batch: int = 4, seq: int = 8,
                    seed: int = 0, on_loss: Optional[Callable] = None,
                    per_device_batch: Optional[int] = None):
    """``make_bg_step_fn`` for executable gap collocation
    (``Collocator.run_executable``): returns a callable that, given a gap
    submesh, jits a REAL tiny-LM training step onto it with a private state
    replica and dispatches one step per call.  ``on_loss`` observes each
    step's (device-resident) loss.  Shared by bench_collocation,
    multiplex_demo and the training entrypoint's --bg-arch path.

    ``per_device_batch`` sizes the tenant's step to its own chunk width
    (the per-tenant bg step quantum): each jitted step uses
    ``per_device_batch * mesh.devices.size`` samples, so a tenant holding a
    wide gap chunk trains a proportionally bigger global batch instead of
    everyone running the batch sized for the global gap minimum.  Without
    it, ``batch`` is the fixed global batch (legacy behavior).

    The returned factory carries a ``signature`` attribute
    (``"{arch}-b{batch}-s{seq}-r{seed}"``, or ``-pdb{n}-`` in
    per-device-batch mode) identifying the compiled executable for
    ``ExecutableCache`` reuse across re-plans: two tenants built from
    factories with equal signatures and landing on the same gap submesh
    share one jitted step.  (The cache key also carries the submesh device
    ids/shape, so per-device sizing never aliases across chunk widths.)
    """
    import dataclasses

    from repro.configs import TRAIN_4K, get_config
    from repro.models.api import get_model, make_batch
    from repro.optim.optimizer import make_optimizer
    from repro.train.state import init_state

    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    opt = make_optimizer(cfg)

    def make_bg_step_fn(mesh):
        b_global = batch
        if per_device_batch is not None:
            b_global = max(1, per_device_batch * int(mesh.devices.size))
        shape = dataclasses.replace(TRAIN_4K, seq_len=seq,
                                    global_batch=b_global, name="bg")
        raw = make_batch(jax.random.PRNGKey(seed + 1), cfg, b_global, seq)
        fn, st_sh, bt_sh = jit_train_step(api, opt, mesh, shape, donate=False)
        holder = {
            "state": jax.device_put(
                init_state(jax.random.PRNGKey(seed), api, opt), st_sh
            )
        }
        b = jax.device_put(raw, bt_sh)

        def step():
            holder["state"], metrics = fn(holder["state"], b)
            if on_loss is not None:
                on_loss(metrics["loss"])
            return metrics["loss"]

        return step

    if per_device_batch is not None:
        make_bg_step_fn.signature = f"{arch}-pdb{per_device_batch}-s{seq}-r{seed}"
    else:
        make_bg_step_fn.signature = f"{arch}-b{batch}-s{seq}-r{seed}"
    return make_bg_step_fn


def jit_forward(api, mesh, shape: ShapeConfig, rules: Optional[dict] = None, report=None):
    from repro.dist.sharding import param_shardings
    from repro.models.api import input_specs

    cfg = api.cfg
    rules = rules or sharding_rules(cfg, mesh, shape)
    p_sh = param_shardings(api.schema, rules, mesh, report)
    specs = input_specs(cfg, shape)
    bt_specs = batch_pspecs(cfg, shape, rules, mesh, specs, report)
    bt_sh = jax.tree.map(lambda p: NamedSharding(mesh, p), bt_specs,
                         is_leaf=lambda x: isinstance(x, P))
    fwd = make_forward(api)

    def fwd_with_fsdp(params, batch):
        with fsdp.context(mesh, rules):
            return fwd(params, batch)

    fn = jax.jit(fwd_with_fsdp, in_shardings=(p_sh, bt_sh))
    return fn, p_sh, bt_sh


def jit_decode_step(api, mesh, shape: ShapeConfig, rules: Optional[dict] = None,
                    donate: bool = True, report=None):
    from repro.dist.sharding import param_shardings
    from repro.models.api import input_specs

    cfg = api.cfg
    rules = rules or sharding_rules(cfg, mesh, shape)
    p_sh = param_shardings(api.schema, rules, mesh, report)
    specs = input_specs(cfg, shape)
    bt_specs = batch_pspecs(cfg, shape, rules, mesh, specs, report)
    bt_sh = jax.tree.map(lambda p: NamedSharding(mesh, p), bt_specs,
                         is_leaf=lambda x: isinstance(x, P))

    def step(params, batch):
        with fsdp.context(mesh, rules):
            return api.decode_step(params, batch["token"], batch["cache"], batch["cache_len"])

    fn = jax.jit(
        step,
        in_shardings=(p_sh, bt_sh),
        out_shardings=(None, bt_sh["cache"]),
        donate_argnums=() if not donate else (1,),
    )
    return fn, p_sh, bt_sh
