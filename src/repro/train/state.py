"""TrainState: a plain pytree (dict) + schema/sharding derivation."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.dist.sharding import param_pspecs, param_shardings
from repro.models.layers import ParamSpec, abstract_params, init_params


def state_schema(api, optimizer) -> Dict[str, Any]:
    return {
        "params": api.schema,
        "opt": optimizer.state_schema(api.schema),
        "step": ParamSpec((), (), init="zeros", dtype="int32"),
    }


def init_state(rng: jax.Array, api, optimizer) -> Dict[str, Any]:
    params = init_params(rng, api.schema)
    return {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_state(api, optimizer):
    return abstract_params(state_schema(api, optimizer))


def state_pspecs(api, optimizer, rules, mesh, report=None):
    return param_pspecs(state_schema(api, optimizer), rules, mesh, report)


def state_shardings(api, optimizer, rules, mesh, report=None):
    return param_shardings(state_schema(api, optimizer), rules, mesh, report)
