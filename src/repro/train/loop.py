"""Fault-tolerant training loop.

Integrates every substrate: data pipeline, jitted train step, async
checkpointing with restart, straggler detection (EMA deadlines), elastic
re-plan hooks, and the DeepPool multiplexer (background steps dispatched
into burst-plan gaps with pacing + the slowdown feedback loop).

The loop runs in *mesh generations*: with ``apply_reconfig`` set, a
reconfiguration event the coordinator pushed back (a re-plan after a
failure or join) is not just logged — at the next epoch boundary the
worker re-carves its mesh onto the surviving pool
(``launch.mesh.remesh_for_pool``), re-shards the training state onto the
new carving, and resumes.  The jitted step for each carving goes through
an ``ExecutableCache`` (the coordinator's, when wired), so churning back
to a previously-seen pool reuses the compiled step instead of re-jitting.

On a real cluster this runs once per host; in this repo it runs end-to-end
on CPU at smoke scale (examples/train_lm.py) and under forced host-device
counts in the integration tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.multiplex import (
    Collocator,
    ExecutableCache,
    MultiplexConfig,
    QoSMonitor,
)
from repro.data.pipeline import SyntheticLMData
from repro.dist.faults import HeartbeatMonitor, MitigationLog, StepTimer
from repro.dist.transport import WorkerClient
from repro.launch.mesh import remesh_for_pool
from repro.models.api import get_model
from repro.optim.optimizer import make_optimizer
from repro.train.state import init_state
from repro.train.step import jit_train_step


@dataclass
class TrainConfig:
    steps: int = 20
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 10
    keep: int = 3
    seed: int = 0
    log_every: int = 5
    max_failures: int = 3
    straggler_factor: float = 3.0
    bg_step_fn: Optional[Callable] = None  # multiplexed background work
    multiplex: MultiplexConfig = field(default_factory=MultiplexConfig)
    # elastic re-planning: when set, failures are reported to the
    # coordinator (which re-plans the foreground job on the surviving
    # power-of-two subset) and each step beats the heartbeat monitor
    coordinator: Optional[Any] = None  # ClusterCoordinator
    heartbeat: Optional[HeartbeatMonitor] = None
    worker_id: int = 0
    # live control plane: with `transport` set, beats go over the wire
    # (WorkerClient) instead of directly into `heartbeat`, and the worker
    # applies reconfiguration events the coordinator pushes back; with
    # `control_loop` set (single-process runs host the coordinator side
    # in the same loop), every step pumps the consumption path so
    # HeartbeatMonitor.failed()/stragglers() drive handle_failure +
    # MitigationLog from live beats.  `admit_every` > 0 re-sweeps tenant
    # admission (coordinator.readmit) every that-many steps — the
    # continuous-admission epoch cadence
    transport: Optional[Any] = None  # worker-side Transport endpoint
    control_loop: Optional[Any] = None  # CoordinatorLoop (co-hosted)
    admit_every: int = 0
    # applied reconfiguration: re-carve this worker's mesh onto the
    # surviving pool at the epoch boundary after a replan event arrives
    # (instead of logging the event and continuing on the stale mesh)
    apply_reconfig: bool = False
    # coordinator election: with `lease` set (CoordinatorLease), the
    # co-hosted control loop only pumps while this worker holds the lease;
    # on acquiring it (failover), the loop bootstraps coordinator state
    # from the topic log before its first pump
    lease: Optional[Any] = None


@dataclass
class TrainReport:
    steps_done: int = 0
    restarts: int = 0
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)
    mitigations: MitigationLog = field(default_factory=MitigationLog)
    bg_steps: int = 0
    remeshes: int = 0  # applied reconfigurations (mesh actually re-carved)


def _mesh_identity(mesh) -> tuple:
    return (tuple(d.id for d in mesh.devices.flat), tuple(mesh.devices.shape))


def train(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    tc: TrainConfig,
    fault_injector: Optional[Callable[[int], None]] = None,
) -> TrainReport:
    """Run `tc.steps` steps with checkpoint/restart + straggler monitoring.
    `fault_injector(step)` may raise to simulate failures (tests)."""
    api = get_model(cfg)
    opt = make_optimizer(cfg, total_steps=tc.steps)
    report = TrainReport()
    timer = StepTimer(deadline_factor=tc.straggler_factor)
    monitor = QoSMonitor()
    # compiled fg steps per mesh carving: re-carving back onto a pool seen
    # before (join after failure) reuses the jitted step through the same
    # bounded LRU the bg tenants use (the coordinator's, when wired)
    exec_cache: ExecutableCache = (
        tc.coordinator.exec_cache if tc.coordinator is not None
        else ExecutableCache()
    )

    worker_client = (WorkerClient(tc.transport, tc.worker_id)
                     if tc.transport is not None else None)
    if tc.control_loop is not None and tc.control_loop.log is None:
        tc.control_loop.log = report.mitigations

    failures = 0
    step = 0
    inflight_bg = 0
    flagged_stragglers: set = set()
    admitted: Optional[tuple] = None
    state = None
    data_state: Optional[dict] = None
    pending_reconfig: Optional[dict] = None
    first_generation = True

    while True:  # one iteration per mesh generation (re-carved on reconfig)
        with mesh:
            key = ExecutableCache.key(
                f"fg-train-{cfg.name}-{shape.name}-s{tc.seed}", mesh
            )
            step_fn, st_sh, bt_sh = exec_cache.get_or_build(
                key, lambda: jit_train_step(api, opt, mesh, shape)
            )

            def fresh_state():
                s = init_state(jax.random.PRNGKey(tc.seed), api, opt)
                return jax.device_put(s, st_sh)

            data = SyntheticLMData(cfg, shape.global_batch, shape.seq_len,
                                   seed=tc.seed, shardings=bt_sh)
            if first_generation:
                first_generation = False
                if tc.ckpt_dir and ckpt_lib.latest_step(tc.ckpt_dir) is not None:
                    state, meta = ckpt_lib.restore(tc.ckpt_dir, fresh_state(),
                                                   shardings=st_sh)
                    step = meta["step"]
                    data.restore(meta.get("data",
                                          {"seed": tc.seed, "step": step}))
                    report.restarts += 1
                else:
                    state = fresh_state()
            else:
                # new carving: re-shard the live state + resume the data
                # cursor exactly where the previous generation stopped
                state = jax.device_put(state, st_sh)
                if data_state is not None:
                    data.restore(data_state)

            while step < tc.steps:
                try:
                    if fault_injector is not None:
                        fault_injector(step)
                    batch = next(data)
                    t0 = time.perf_counter()
                    state, metrics = step_fn(state, batch)
                    # multiplexing: dispatch paced background steps while the
                    # foreground step is in flight (async dispatch)
                    if tc.bg_step_fn is not None:
                        while inflight_bg < tc.multiplex.max_inflight:
                            tc.bg_step_fn()
                            inflight_bg += 1
                            report.bg_steps += 1
                        inflight_bg = 0
                    loss = float(jax.block_until_ready(metrics["loss"]))
                    dt = time.perf_counter() - t0
                    timer.record(dt)
                    if timer.is_straggler_step(dt):
                        report.mitigations.log("straggler", step=step, dt=dt)
                    report.losses.append(loss)
                    report.step_times.append(dt)
                    step += 1
                    report.steps_done += 1
                    if worker_client is not None:
                        # live path: the beat goes over the transport; the
                        # co-hosted CoordinatorLoop (or a remote coordinator)
                        # consumes it — detection, handle_failure, straggler
                        # logging and continuous admission all happen on the
                        # consumption side, not here
                        worker_client.beat(step)
                    elif tc.heartbeat is not None:
                        tc.heartbeat.beat(tc.worker_id, step)
                    if tc.control_loop is not None:
                        if tc.lease is not None:
                            # election-gated coordination: pump only while
                            # holding the lease; a fresh acquisition
                            # (failover) bootstraps from the topic log so
                            # mitigations the dead holder already fired
                            # are adopted, never re-fired
                            if tc.lease.tick():
                                if tc.lease.acquired:
                                    tc.control_loop.bootstrap_from_log()
                                tc.control_loop.pump()
                        else:
                            tc.control_loop.pump()
                    elif tc.heartbeat is not None:
                        # legacy in-process path (no transport): classify
                        # stragglers directly off the monitor
                        lagging = set(tc.heartbeat.stragglers())
                        for w in sorted(lagging - flagged_stragglers):
                            report.mitigations.log("straggler_worker",
                                                   step=step, worker=w)
                        flagged_stragglers = lagging  # recovered ones re-arm
                    if worker_client is not None:
                        # epoch-boundary reconfiguration: apply re-plans the
                        # coordinator pushed back since the last step
                        for ev in worker_client.poll_reconfig():
                            report.mitigations.log(
                                "reconfig", step=step,
                                **{k: v for k, v in ev.items()
                                   if k != "kind"}
                            )
                            if (tc.apply_reconfig
                                    and ev.get("action") == "replan"
                                    and ev.get("devices")):
                                pending_reconfig = ev  # latest event wins
                    if (tc.admit_every > 0 and tc.coordinator is not None
                            and step % tc.admit_every == 0):
                        # continuous admission: re-sweep the tenant roster at
                        # the epoch cadence (churn events re-sweep via the
                        # control loop); log only when the admitted set
                        # changed
                        decision = tc.coordinator.readmit(reason="epoch")
                        if decision is not None:
                            now = tuple(t.job for t in decision.admitted)
                            if admitted is not None and now != admitted:
                                report.mitigations.log(
                                    "admission", step=step,
                                    admitted=list(now),
                                    rejected=[t.job
                                              for t in decision.rejected],
                                )
                            admitted = now
                    if tc.ckpt_dir and step % tc.ckpt_every == 0:
                        ckpt_lib.save(tc.ckpt_dir, state, step, keep=tc.keep,
                                      extra_meta={"data": data.state()},
                                      async_=False)
                    if pending_reconfig is not None:
                        break  # epoch boundary: re-carve before next step
                except (RuntimeError, ValueError, FloatingPointError) as e:
                    failures += 1
                    report.mitigations.log("failure", step=step,
                                           err=repr(e)[:200])
                    if failures > tc.max_failures:
                        raise
                    # fail-stop semantics (paper §3.2): a wired coordinator
                    # treats a step failure as loss of this worker's device.
                    # Report it once — repeats of the same worker would only
                    # re-run an identical planner search.
                    if (tc.coordinator is not None
                            and tc.worker_id in tc.coordinator.healthy):
                        new_plan = tc.coordinator.handle_failure(tc.worker_id)
                        if new_plan is not None:
                            report.mitigations.log("replan", step=step,
                                                   gpus=new_plan.num_gpus)
                    # restart from last checkpoint (or fresh if none)
                    if tc.ckpt_dir and \
                            ckpt_lib.latest_step(tc.ckpt_dir) is not None:
                        state, meta = ckpt_lib.restore(
                            tc.ckpt_dir, fresh_state(), shardings=st_sh
                        )
                        step = meta["step"]
                        data.restore(meta.get("data", {"seed": tc.seed,
                                                       "step": step}))
                    else:
                        state = fresh_state()
                        step = 0
                    report.restarts += 1
            data_state = data.state()
            data.close()
        if step >= tc.steps or pending_reconfig is None:
            break
        # -- applied reconfig: re-carve onto the surviving pool -------------
        ev, pending_reconfig = pending_reconfig, None
        new_mesh = remesh_for_pool(ev["devices"])
        if _mesh_identity(new_mesh) == _mesh_identity(mesh):
            continue  # this host's carving is unchanged (event logged above)
        mesh = new_mesh
        report.remeshes += 1
        report.mitigations.log(
            "reconfig_applied", step=step, gpus=ev.get("gpus"),
            mesh_devices=len(new_mesh.devices.flat),
            reason=ev.get("reason"),
        )
    return report
