"""Live control-plane transport (paper §3.2: failures/joins as live events).

The elasticity half of the paper assumes cluster-membership changes reach
the coordinator as *live events*: workers publish heartbeats, the
coordinator consumes them, detection (``HeartbeatMonitor.failed()`` /
``stragglers()``) drives ``ClusterCoordinator.handle_failure`` /
``handle_join``, and re-plan results flow back to the workers as
epoch-boundary reconfiguration events.  This module is that transport.

Transport contract
------------------

A transport is an append-only, per-topic message log with at-least-once
delivery and a deterministic total order per topic:

  - ``publish(topic, payload) -> seq`` appends one JSON-serializable dict
    and returns its sequence number (monotone per topic).  A disconnected
    endpoint may silently drop the publish (returns -1) — exactly how a
    partitioned worker's beats die.
  - ``poll(topic, since) -> [(seq, payload), ...]`` returns every message
    with ``seq >= since`` in ascending seq order.  Consumers track their
    own cursor; polling never consumes destructively, so any number of
    readers (every worker polls the reconfig topic) can share one topic.
  - ``compact(topic, upto) -> int`` garbage-collects the log prefix below
    ``upto`` and returns the new low-water mark (``low_water(topic)``).
    Compaction is monotone (``upto`` below the current mark is a no-op)
    and must only be driven from an aggregated consumer-ack cursor: a
    consumer polling below the mark would silently miss messages, which
    the fake CI transport turns into a hard error.  Without compaction a
    long job's heartbeat topic grows without bound — one beat per worker
    per step, forever.

Three implementations, one contract:

  - ``InProcessBus`` — plain shared-memory topic lists; the reference
    implementation for single-process tests and the trace-driven cluster
    simulator (``repro.sim.cluster_sim`` replays heartbeat-loss traces
    through the exact consumption path below).
  - ``fake_transport_pair()`` — two distinct endpoint views over one bus
    that force every payload through JSON (catching payloads a real
    multi-host KV store could not carry) and support ``disconnect()``
    (beat loss injection for CI).
  - ``KVStoreTransport`` — the multi-host implementation, backed by the
    ``jax.distributed`` coordination-service key-value store.  Keys are
    ``{ns}/{topic}/{counter:012d}.{uid}`` so a lexicographic directory
    listing is a deterministic global order across publishers.

Protocol layer
--------------

``WorkerClient`` (worker side) publishes beats on the heartbeat topic and
polls the reconfig topic; ``CoordinatorLoop`` (coordinator side) drains
beats into a ``HeartbeatMonitor``, fires ``handle_failure`` on beat
timeout, treats beats from unknown worker ids as explicit joins
(``monitor.join`` + idempotent ``handle_join``), logs stragglers, and
publishes every re-plan back as a reconfiguration event.  Beats carry the
worker's consumed reconfig cursor as an *ack*, and the coordinator
aggregates the acks of live workers into the low-water mark it compacts
the topics to (``gc_every``) — the key log stays bounded across a long
job without any consumer ever losing a message.

``CoordinatorLease`` elects the coordinator itself: an epoch-numbered,
heartbeat-renewed lease record on its own topic.  When the holder dies its
renewals stop; any worker that observes the lease stale past its timeout
claims the next epoch, with epoch ties broken toward the lowest worker id
so concurrent claimants converge without a CAS.  A fresh holder calls
``CoordinatorLoop.bootstrap_from_log()`` to reconstruct monitor +
coordinator state from the topic logs — mitigations the previous holder
already fired are adopted, not re-fired.
"""
from __future__ import annotations

import json
import os
import socket
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

HEARTBEAT_TOPIC = "hb"
RECONFIG_TOPIC = "reconfig"
LEASE_TOPIC = "lease"


class InProcessBus:
    """Reference transport: per-topic append-only lists in process memory.

    Sequence numbers are absolute list indices (compaction shifts the
    storage but never renumbers), so ``poll(topic, since)`` is a
    constant-time slice and replay is trivially deterministic.
    """

    def __init__(self):
        self._topics: Dict[str, List[dict]] = {}
        self._base: Dict[str, int] = {}  # per-topic low-water mark

    def publish(self, topic: str, payload: dict) -> int:
        log = self._topics.setdefault(topic, [])
        log.append(payload)
        return self._base.get(topic, 0) + len(log) - 1

    def poll(self, topic: str, since: int = 0) -> List[Tuple[int, dict]]:
        log = self._topics.get(topic, ())
        base = self._base.get(topic, 0)
        return [(i, log[i - base])
                for i in range(max(since, base), base + len(log))]

    def low_water(self, topic: str) -> int:
        return self._base.get(topic, 0)

    def backlog(self, topic: str) -> int:
        """Messages currently retained (published minus compacted) — the
        quantity GC must keep bounded on a long-running job."""
        return len(self._topics.get(topic, ()))

    def compact(self, topic: str, upto: int) -> int:
        """Drop messages with seq < ``upto``.  Monotone and clamped to the
        log head; surviving messages keep their sequence numbers."""
        log = self._topics.get(topic)
        base = self._base.get(topic, 0)
        if log is None:
            return base
        upto = min(upto, base + len(log))
        if upto > base:
            del log[: upto - base]
            self._base[topic] = upto
            base = upto
        return base


class FakeTransportEndpoint:
    """One endpoint of the fake two-endpoint transport (CI implementation).

    Wraps a shared ``InProcessBus`` but forces every payload through a JSON
    round-trip on both publish and poll — a payload that would not survive
    a real multi-host KV store (arbitrary objects, non-string keys) fails
    here too, in-process, where the test can see it.  ``disconnect()``
    models a partitioned/crashed endpoint: its publishes are silently
    dropped (returns -1), which is exactly how a worker's heartbeats die in
    the live-failure tests.

    Compaction safety is *asserted* here: polling from a cursor below the
    topic's low-water mark means the consumer would silently miss
    compacted messages on a real KV store — the fake raises instead, so a
    GC driver that compacts past a live consumer's ack fails in CI, not in
    production.  (A fresh consumer that intends to start at the compacted
    head polls from ``low_water(topic)``.)
    """

    def __init__(self, bus: InProcessBus, name: str):
        self.bus = bus
        self.name = name
        self.connected = True
        self.dropped = 0

    def publish(self, topic: str, payload: dict) -> int:
        wire = json.loads(json.dumps(payload))  # serialization enforced
        if not self.connected:
            self.dropped += 1
            return -1
        return self.bus.publish(topic, wire)

    def poll(self, topic: str, since: int = 0) -> List[Tuple[int, dict]]:
        if not self.connected:
            return []
        lw = self.bus.low_water(topic)
        if since < lw:
            raise RuntimeError(
                f"{self.name}: poll({topic!r}, since={since}) reads below "
                f"the compacted low-water mark {lw} — the consumer ack "
                f"aggregation compacted past a live cursor"
            )
        return [(seq, json.loads(json.dumps(p)))
                for seq, p in self.bus.poll(topic, since)]

    def low_water(self, topic: str) -> int:
        return self.bus.low_water(topic)

    def compact(self, topic: str, upto: int) -> int:
        return self.bus.compact(topic, upto)

    def disconnect(self) -> None:
        self.connected = False

    def reconnect(self) -> None:
        self.connected = True


def fake_transport_pair() -> Tuple[FakeTransportEndpoint, FakeTransportEndpoint]:
    """(worker_end, coordinator_end) over one shared in-process bus, with
    JSON serialization enforced at both endpoints (the CI stand-in for the
    multi-host KV-store transport)."""
    bus = InProcessBus()
    return FakeTransportEndpoint(bus, "worker"), \
        FakeTransportEndpoint(bus, "coordinator")


class KVStoreTransport:
    """Multi-host transport over the ``jax.distributed`` key-value store.

    The coordination service every multi-host jax job already runs
    (``jax.distributed.initialize()``) exposes a string KV store — the only
    cross-host channel jax ships without extra dependencies.  Messages are
    stored under ``{namespace}/{topic}/{counter:012d}.{uid}`` where
    ``counter`` is this publisher's local per-topic counter and ``uid``
    identifies the publisher (host-pid by default): the zero-padded counter
    makes the lexicographic directory listing a deterministic total order,
    with publisher uid breaking counter ties stably.

    ``client`` injects any object with the ``DistributedRuntimeClient``
    surface (``key_value_set(key, value)``,
    ``key_value_dir_get(prefix) -> [(key, value), ...]`` and
    ``key_value_delete(key)``) — tests pass a dict-backed fake; real runs
    default to jax's global client and raise ``RuntimeError`` when
    ``jax.distributed`` was never initialized (use ``InProcessBus`` /
    ``fake_transport_pair`` for single-process runs).

    Sequence numbers are assigned *per consumer instance*, stably: the
    first poll seeds the numbering at the topic's persisted low-water mark,
    and every later poll numbers only keys it has not seen before (in
    lexicographic order among the new ones).  A key that lands "in the
    middle" of the lexicographic order after a slow publisher flushes (its
    counter is small, so it sorts before keys another consumer already
    numbered) therefore gets the *next* sequence number instead of
    renumbering — and shifting — everything behind it.  Cursors stay
    monotone: a consumer never skips and never re-reads a key, which is
    the delivery contract ``CoordinatorLoop.pump`` relies on (it still
    sorts by seq defensively, see the pump docstring).

    ``compact(topic, upto)`` deletes the first ``upto - low_water`` keys in
    lexicographic order and persists the new mark under
    ``{ns}/.lw/{topic}`` (outside the message prefix, so directory polls
    never see it).
    """

    def __init__(self, namespace: str = "reproctl", *,
                 client: Optional[Any] = None, uid: Optional[str] = None):
        if client is None:
            client = _global_kv_client()
            if client is None:
                raise RuntimeError(
                    "KVStoreTransport needs jax.distributed.initialize() "
                    "(no coordination-service KV client is active); use "
                    "InProcessBus or fake_transport_pair() for "
                    "single-process runs"
                )
        self._client = client
        self._ns = namespace.strip("/")
        self._uid = uid if uid is not None else \
            f"{socket.gethostname()}-{os.getpid()}"
        self._counters: Dict[str, int] = {}
        self._key_seq: Dict[str, Dict[str, int]] = {}  # topic -> key -> seq
        self._next_seq: Dict[str, int] = {}

    def publish(self, topic: str, payload: dict) -> int:
        n = self._counters.get(topic, 0)
        self._counters[topic] = n + 1
        key = f"{self._ns}/{topic}/{n:012d}.{self._uid}"
        self._client.key_value_set(key, json.dumps(payload, sort_keys=True))
        return n

    def _dir(self, topic: str) -> List[Tuple[str, str]]:
        try:
            entries = self._client.key_value_dir_get(f"{self._ns}/{topic}/")
        except Exception:  # empty directory raises on some jax versions
            return []
        return sorted(entries, key=lambda kv: kv[0])

    def _numbered(self, topic: str) -> List[Tuple[int, str, str]]:
        """Current directory listing as stable (seq, key, value) triples,
        ascending seq (= this consumer's arrival order, lexicographic
        within one poll)."""
        entries = self._dir(topic)
        amap = self._key_seq.setdefault(topic, {})
        nxt = self._next_seq.get(topic)
        if nxt is None:
            nxt = self.low_water(topic)
        for k, _v in entries:
            if k not in amap:
                amap[k] = nxt
                nxt += 1
        self._next_seq[topic] = nxt
        return sorted((amap[k], k, v) for k, v in entries)

    def poll(self, topic: str, since: int = 0) -> List[Tuple[int, dict]]:
        return [(seq, json.loads(v))
                for seq, _k, v in self._numbered(topic) if seq >= since]

    def low_water(self, topic: str) -> int:
        try:
            entries = self._client.key_value_dir_get(f"{self._ns}/.lw/")
        except Exception:
            return 0
        for k, v in entries:
            if k == f"{self._ns}/.lw/{topic}":
                return int(v)
        return 0

    def compact(self, topic: str, upto: int) -> int:
        lw = self.low_water(topic)
        numbered = self._numbered(topic)
        upto = min(upto, lw + len(numbered))
        if upto <= lw:
            return lw
        doomed = [(seq, k) for seq, k, _v in numbered if seq < upto]
        for _seq, key in doomed:
            self._client.key_value_delete(key)
            self._key_seq[topic].pop(key, None)
        # the coordination-service KV store is write-once by default: the
        # low-water mark is the one key we mutate, so it needs the explicit
        # overwrite flag (message keys are never rewritten)
        self._client.key_value_set(f"{self._ns}/.lw/{topic}", str(upto),
                                   allow_overwrite=True)
        return upto


def _global_kv_client():
    try:
        from jax._src import distributed

        return distributed.global_state.client
    except Exception:
        return None


# ---------------------------------------------------------------------------
# Protocol layer: heartbeat publication + coordinator-side consumption
# ---------------------------------------------------------------------------


class WorkerClient:
    """Worker-side protocol endpoint: publish beats, poll reconfig events.

    One per worker process.  ``beat(step)`` publishes this worker's
    liveness + progress, carrying the worker's consumed reconfig cursor as
    an *ack* — the coordinator aggregates live workers' acks into the
    low-water mark it compacts the reconfig topic to, so no worker ever
    loses an event to GC.  ``poll_reconfig()`` returns the reconfiguration
    events (re-plan results the coordinator pushed back) published since
    the last poll — the worker applies them at its next epoch boundary.
    """

    def __init__(self, transport, worker_id: int):
        self.transport = transport
        self.worker_id = worker_id
        self._seen_reconfig = 0

    def beat(self, step: int) -> int:
        return self.transport.publish(
            HEARTBEAT_TOPIC, {"worker": self.worker_id, "step": step,
                              "ack": self._seen_reconfig}
        )

    def poll_reconfig(self) -> List[dict]:
        msgs = sorted(self.transport.poll(RECONFIG_TOPIC, self._seen_reconfig),
                      key=lambda sp: sp[0])
        out = []
        for seq, p in msgs:
            if seq < self._seen_reconfig:  # at-least-once: drop re-delivery
                continue
            self._seen_reconfig = seq + 1
            out.append(p)
        return out


class CoordinatorLoop:
    """Coordinator-side consumption: beats in, failure handling, reconfig out.

    ``pump()`` is the whole live control plane for one tick:

      1. drain new beats into the ``HeartbeatMonitor`` — a beat from an
         unknown worker id is an explicit *join* (``monitor.join`` +
         idempotent ``ClusterCoordinator.handle_join``, so re-delivered
         announcements for an already-healthy device are no-ops),
      2. every worker ``monitor.failed()`` reports is acknowledged
         (``monitor.forget`` — detection fires once per loss, not every
         tick), logged, and driven through ``handle_failure`` — the
         foreground re-plans onto the exact surviving pool,
      3. each re-plan is published on the reconfig topic so workers pick it
         up at their next epoch boundary (``WorkerClient.poll_reconfig``),
      4. newly lagging workers are logged as stragglers (recovered workers
         re-arm),
      5. when ``admission_bound`` is set, every churn event triggers a
         continuous-admission re-sweep (``ClusterCoordinator.readmit``) —
         the DeepPool requirement that admission runs continuously, not
         once at submesh-carving time,
      6. with ``gc_every`` > 0, every that-many pumps the topics are
         compacted: the heartbeat topic up to the loop's own consumed
         cursor (it is the only hb consumer), the reconfig topic up to the
         minimum ack carried in live workers' beats (acks of dead/forgotten
         workers are dropped, or one crashed worker would pin the log
         forever).

    Beat consumption is ordered and de-duplicated: polled messages are
    sorted by sequence id and any seq below the consumed cursor is skipped
    before it reaches the monitor.  A transport whose poll returns
    overlapping or out-of-arrival-order batches (the KV store merges
    per-publisher counters lexicographically, and at-least-once delivery
    may repeat a tail) would otherwise replay old beats — resurrecting a
    worker the loop already declared dead and double-firing the mitigation
    on the next timeout.

    ``log`` is a ``MitigationLog`` (attached lazily by the train loop when
    None).  Returns the reconfiguration events published this pump.
    """

    def __init__(self, transport, monitor, coordinator=None, log=None, *,
                 admission_bound: Optional[float] = None,
                 allow_joins: bool = True,
                 on_replan: Optional[Callable] = None,
                 gc_every: int = 0):
        self.transport = transport
        self.monitor = monitor
        self.coordinator = coordinator
        self.log = log
        self.admission_bound = admission_bound
        self.allow_joins = allow_joins
        self.on_replan = on_replan
        self.gc_every = gc_every
        self._seen_beats = 0
        self._flagged: set = set()
        self._acks: Dict[int, int] = {}  # worker -> consumed reconfig cursor
        self._pumps = 0

    # -- helpers ------------------------------------------------------------

    def _log(self, kind: str, **info) -> None:
        if self.log is not None:
            self.log.log(kind, **info)

    def _publish_replan(self, plan, *, reason: str, worker: int) -> dict:
        ev = {
            "action": "replan",
            "reason": reason,
            "worker": worker,
            "gpus": plan.num_gpus,
            "devices": sorted(self.coordinator.healthy),
        }
        self.transport.publish(RECONFIG_TOPIC, ev)
        self._log("replan", reason=reason, worker=worker, gpus=plan.num_gpus)
        if self.on_replan is not None:
            self.on_replan(ev)
        return ev

    def _readmit(self, reason: str) -> None:
        if self.admission_bound is not None and self.coordinator is not None:
            self.coordinator.readmit(self.admission_bound, reason=reason)

    # -- the consumption path ----------------------------------------------

    def pump(self) -> List[dict]:
        out: List[dict] = []
        msgs = sorted(self.transport.poll(HEARTBEAT_TOPIC, self._seen_beats),
                      key=lambda sp: sp[0])
        for seq, m in msgs:
            if seq < self._seen_beats:  # re-delivered tail: already consumed
                continue
            self._seen_beats = seq + 1
            w, step = int(m["worker"]), int(m.get("step", 0))
            if "ack" in m:
                self._acks[w] = max(self._acks.get(w, 0), int(m["ack"]))
            if w not in self.monitor.last:
                if not self.allow_joins:
                    continue
                self.monitor.join(w)
                self._log("join", worker=w)
                if self.coordinator is not None:
                    new_plan = self.coordinator.handle_join([w])
                    if new_plan is not None:  # idempotent: None = no-op join
                        out.append(self._publish_replan(
                            new_plan, reason="join", worker=w
                        ))
                        self._readmit("join")
            self.monitor.beat(w, step)
        for w in self.monitor.failed():
            self.monitor.forget(w)  # ack: one detection per loss
            self._acks.pop(w, None)  # a dead worker's ack must not pin GC
            self._log("failure_detected", worker=w)
            self._flagged.discard(w)
            if self.coordinator is not None and w in self.coordinator.healthy:
                new_plan = self.coordinator.handle_failure(w)
                if new_plan is not None:
                    out.append(self._publish_replan(
                        new_plan, reason="failure", worker=w
                    ))
                self._readmit("failure")
        lagging = set(self.monitor.stragglers())
        for w in sorted(lagging - self._flagged):
            self._log("straggler_worker", worker=w)
        self._flagged = lagging  # recovered workers re-arm
        self._pumps += 1
        if self.gc_every > 0 and self._pumps % self.gc_every == 0:
            self.gc()
        return out

    def gc(self) -> Tuple[int, int]:
        """Compact the topics to the aggregated consumer cursors: the hb
        topic up to this loop's consumed cursor, the reconfig topic up to
        the minimum ack among live (monitored) workers.  The newest
        reconfiguration event is always retained even when every worker has
        acked it — it is the pool of record ``bootstrap_from_log`` restores
        the coordinator from after a failover; compacting it away would
        reset a new holder to the full initial pool and re-fire every
        mitigation the old holder already handled.  Returns the two new
        low-water marks."""
        hb_lw = self.transport.compact(HEARTBEAT_TOPIC, self._seen_beats)
        live_acks = [a for w, a in self._acks.items() if w in self.monitor.last]
        rc_lw = self.transport.low_water(RECONFIG_TOPIC)
        if live_acks and len(live_acks) == len(self.monitor.last):
            # only compact once every live worker has acked (a worker that
            # never beat with an ack could still be at an older cursor)
            tail = self.transport.poll(RECONFIG_TOPIC, rc_lw)
            head = max((s for s, _ in tail), default=rc_lw - 1) + 1
            rc_lw = self.transport.compact(
                RECONFIG_TOPIC, min(min(live_acks), head - 1)
            )
        return hb_lw, rc_lw

    def bootstrap_from_log(self) -> dict:
        """Reconstruct coordinator-side state from the topic logs after
        winning the lease (coordinator failover).

        Mitigations the previous holder already fired must not re-fire: the
        surviving pool is adopted from the last reconfiguration event still
        in the log (``ClusterCoordinator.restore_pool`` re-plans silently
        when needed), so a worker the old coordinator already re-planned
        away is neither re-joined nor re-detected.  Every worker of the
        restored pool is (re)joined with a fresh grace period — workers
        that died *around* the failover stop beating and are detected by
        the normal ``pump()`` path one heartbeat timeout later.  The beat
        cursor fast-forwards to the log tail (old beats are membership
        evidence, not progress), and worker acks are re-seeded from the
        beat tail so GC can resume.  Returns a summary dict (logged as a
        ``coordinator_failover`` mitigation).
        """
        rc_lw = self.transport.low_water(RECONFIG_TOPIC)
        reconfigs = sorted(self.transport.poll(RECONFIG_TOPIC, rc_lw),
                           key=lambda sp: sp[0])
        pool: Optional[List[int]] = None
        for _seq, ev in reconfigs:
            if "devices" in ev:
                pool = [int(d) for d in ev["devices"]]
        if self.coordinator is not None and pool is not None:
            self.coordinator.restore_pool(pool)
        hb_lw = self.transport.low_water(HEARTBEAT_TOPIC)
        # never leave the cursor below the compacted low-water mark: if the
        # old holder compacted every beat and none arrived since, the first
        # pump() after failover would poll below low-water (a strict
        # transport raises) — flushed out by repro.analysis.protocheck
        self._seen_beats = max(self._seen_beats, hb_lw)
        beats = sorted(self.transport.poll(HEARTBEAT_TOPIC, self._seen_beats),
                       key=lambda sp: sp[0])
        seen: Dict[int, int] = {}
        for seq, m in beats:
            self._seen_beats = max(self._seen_beats, seq + 1)
            w = int(m["worker"])
            seen[w] = max(seen.get(w, 0), int(m.get("ack", 0)))
        members = (sorted(self.coordinator.healthy)
                   if self.coordinator is not None else sorted(seen))
        for w in members:
            self.monitor.join(w)  # idempotent; fresh grace period
        self._acks = {w: a for w, a in seen.items() if w in self.monitor.last}
        info = {"pool": members, "replayed_beats": len(beats),
                "replayed_reconfigs": len(reconfigs)}
        self._log("coordinator_failover", **info)
        return info


class CoordinatorLease:
    """Coordinator election over the transport: an epoch-numbered,
    heartbeat-renewed lease record.

    The coordinator role must not die with worker 0 (PR 7 co-hosted it
    there, a single point of failure).  The lease lives on its own topic as
    append-only claim/renewal messages ``{"worker", "epoch"}``; no
    compare-and-swap is needed because the total order per topic plus a
    deterministic tie-break does the arbitration:

      - the *holder* is the worker of the highest epoch seen, with epoch
        ties broken toward the **lowest** worker id — two workers that
        claim the same epoch concurrently both observe both claims and
        converge on the lower id without coordination,
      - the holder republishes its claim every ``renew_every`` seconds; a
        lease not renewed for ``timeout`` is *stale*,
      - any worker that observes a stale (or absent) lease claims
        ``epoch + 1``.  A partitioned claimant's publish is dropped by the
        transport (returns -1), so it cannot win while unreachable.

    ``tick()`` drives the whole protocol and returns True while this
    worker holds the lease — the train loop gates ``pump()`` on it, and a
    worker that just acquired the lease must ``bootstrap_from_log()``
    before its first pump.  ``acquired`` flags that transition exactly
    once per acquisition.
    """

    def __init__(self, transport, worker_id: int, *, timeout: float = 5.0,
                 renew_every: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.transport = transport
        self.worker_id = worker_id
        self.timeout = timeout
        self.renew_every = renew_every if renew_every is not None \
            else timeout / 3.0
        self.clock = clock
        self.epoch = 0
        self.holder: Optional[int] = None
        self.acquired = False  # set by the tick that won the lease
        self._cursor = 0
        self._last_seen = clock()   # local receipt time of holder activity
        self._last_renew = -float("inf")

    def _consume(self) -> None:
        msgs = sorted(self.transport.poll(LEASE_TOPIC, self._cursor),
                      key=lambda sp: sp[0])
        for seq, m in msgs:
            if seq < self._cursor:
                continue
            self._cursor = seq + 1
            w, e = int(m["worker"]), int(m["epoch"])
            if e > self.epoch or self.holder is None:
                self.epoch, self.holder = e, w
                self._last_seen = self.clock()
            elif e == self.epoch:
                if w < self.holder:  # tie-break: lowest id wins the epoch
                    self.holder = w
                    self._last_seen = self.clock()
                elif w == self.holder:  # renewal
                    self._last_seen = self.clock()

    def stale(self) -> bool:
        return (self.holder is not None
                and self.clock() - self._last_seen >= self.timeout)

    def claim(self) -> None:
        """Publish a claim for the next epoch (used for seeding an initial
        holder deterministically in tests/harnesses; ``tick`` claims
        automatically once the lease goes stale).  Local state is NOT
        mutated here — adoption happens in ``_consume`` when the claim
        comes back through the log, so a dropped publish (partitioned
        endpoint) simply never wins."""
        self.transport.publish(
            LEASE_TOPIC, {"worker": self.worker_id, "epoch": self.epoch + 1}
        )
        self._last_renew = self.clock()

    def tick(self) -> bool:
        """Advance the protocol one step; True while this worker holds the
        lease (after consuming any competing claims)."""
        was_holder = self.holder == self.worker_id
        self._consume()
        now = self.clock()
        if self.holder == self.worker_id:
            if now - self._last_renew >= self.renew_every:
                self.transport.publish(
                    LEASE_TOPIC,
                    {"worker": self.worker_id, "epoch": self.epoch}
                )
                self._last_renew = now
            self.acquired = not was_holder
            return True
        if self.holder is None or now - self._last_seen >= self.timeout:
            self.claim()
            self._consume()  # a concurrent lower-id claim wins immediately
            if self.holder == self.worker_id:
                self.acquired = True
                return True
        self.acquired = False
        return False
