"""repro.dist — sharded execution substrate (rules, FSDP, fault detection).

This package turns planner output and model schemas into executable
GSPMD layouts and keeps them healthy at runtime.  Public API:

``repro.dist.sharding``
    - ``pspec(dims, shape, rules, mesh, report=None)`` — logical axes ->
      ``PartitionSpec`` with divisibility guard (drops recorded in
      ``RuleReport``), no mesh-axis reuse, trailing-``None`` trimming.
    - ``sharding_rules(cfg, mesh, shape_cfg=None)`` — per-(arch, mesh,
      shape) rule set: attention-head / kv-head TP, MLP TP, MoE expert vs
      tensor parallelism, FSDP on 'embed' (serving drops it for small
      models), decode kv-sequence fallbacks (GQA + long-context).
    - ``param_pspecs / param_shardings(schema, rules, mesh, report=None)``
      — ParamSpec trees -> PartitionSpec / NamedSharding trees.
    - ``batch_pspecs(cfg, shape, rules, mesh, specs, report=None)`` —
      input-spec dicts (incl. decode KV caches) -> PartitionSpec trees.

``repro.dist.fsdp``
    - ``context(mesh, rules)`` — activate a layout for the hooks below;
      all hooks are identity functions outside a context.
    - ``gather(tree, schema)`` / ``gather_leaf(x, axes)`` — use-site
      all-gather of FSDP-sharded weights (ZeRO-3 inside scan-over-layers).
    - ``constrain(x, axes)`` — activation sharding constraint via rules.
    - ``group_count(axis)`` — shard count of a logical axis (MoE capacity).

``repro.dist.faults``
    - ``StepTimer`` — EMA-deadline straggler-step detection.
    - ``HeartbeatMonitor`` — per-worker timeout (failure) + step-lag
      (straggler) classification with an injectable clock.
    - ``MitigationLog`` — append-only mitigation record; feeds
      ``ClusterCoordinator.handle_failure`` elastic re-planning.
"""
from repro.dist import fsdp  # noqa: F401
from repro.dist.faults import HeartbeatMonitor, MitigationLog, StepTimer  # noqa: F401
from repro.dist.sharding import (  # noqa: F401
    RuleReport,
    batch_pspecs,
    param_pspecs,
    param_shardings,
    pspec,
    sharding_rules,
)
