"""repro.dist — sharded execution substrate (rules, FSDP, fault detection).

This package turns planner output and model schemas into executable
GSPMD layouts and keeps them healthy at runtime.  Public API:

``repro.dist.sharding``
    - ``pspec(dims, shape, rules, mesh, report=None)`` — logical axes ->
      ``PartitionSpec`` with divisibility guard (drops recorded in
      ``RuleReport``), no mesh-axis reuse, trailing-``None`` trimming.
    - ``sharding_rules(cfg, mesh, shape_cfg=None)`` — per-(arch, mesh,
      shape) rule set: attention-head / kv-head TP, MLP TP, MoE expert vs
      tensor parallelism, FSDP on 'embed' (serving drops it for small
      models), decode kv-sequence fallbacks (GQA + long-context).
    - ``param_pspecs / param_shardings(schema, rules, mesh, report=None)``
      — ParamSpec trees -> PartitionSpec / NamedSharding trees.
    - ``batch_pspecs(cfg, shape, rules, mesh, specs, report=None)`` —
      input-spec dicts (incl. decode KV caches) -> PartitionSpec trees.

``repro.dist.fsdp``
    - ``context(mesh, rules)`` — activate a layout for the hooks below;
      all hooks are identity functions outside a context.
    - ``gather(tree, schema)`` / ``gather_leaf(x, axes)`` — use-site
      all-gather of FSDP-sharded weights (ZeRO-3 inside scan-over-layers).
    - ``constrain(x, axes)`` — activation sharding constraint via rules.
    - ``group_count(axis)`` — shard count of a logical axis (MoE capacity).

``repro.dist.faults``
    - ``StepTimer`` — EMA-deadline straggler-step detection (over-deadline
      samples excluded from the EMA so one slow step can't mask the next).
    - ``HeartbeatMonitor`` — per-worker timeout (failure) + step-lag
      (straggler) classification with an injectable clock and explicit
      ``join``/``forget`` membership semantics.
    - ``MitigationLog`` — append-only mitigation record; feeds
      ``ClusterCoordinator.handle_failure`` elastic re-planning.

``repro.dist.transport``  (the live control plane)
    Transport contract: ``publish(topic, payload) -> seq`` appends one
    JSON-serializable dict to a per-topic append-only log;
    ``poll(topic, since) -> [(seq, payload), ...]`` returns everything at
    or after ``since`` in a deterministic per-topic total order, without
    consuming (readers keep their own cursors).  Implementations:

    - ``InProcessBus`` — reference implementation (tests + simulator).
    - ``fake_transport_pair()`` — two endpoints over one bus with JSON
      round-trip enforcement and ``disconnect()`` beat-loss injection
      (the CI stand-in for multi-host).
    - ``KVStoreTransport`` — multi-host, over the ``jax.distributed``
      coordination-service KV store (injectable client for tests).

    Protocol layer: ``WorkerClient`` (beat + poll_reconfig) and
    ``CoordinatorLoop.pump()`` (beats -> HeartbeatMonitor -> live
    ``handle_failure``/``handle_join`` -> reconfig events back out, plus
    continuous-admission re-sweeps on churn).
"""
from repro.dist import fsdp  # noqa: F401
from repro.dist.faults import HeartbeatMonitor, MitigationLog, StepTimer  # noqa: F401
from repro.dist.transport import (  # noqa: F401
    HEARTBEAT_TOPIC,
    RECONFIG_TOPIC,
    CoordinatorLoop,
    FakeTransportEndpoint,
    InProcessBus,
    KVStoreTransport,
    WorkerClient,
    fake_transport_pair,
)
from repro.dist.sharding import (  # noqa: F401
    RuleReport,
    batch_pspecs,
    param_pspecs,
    param_shardings,
    pspec,
    sharding_rules,
)
