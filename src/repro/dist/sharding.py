"""Mesh-shape-driven sharding-rules engine.

A *rule set* maps logical axis names (the vocabulary documented in
models/layers.py) to tuples of mesh axis names.  ``sharding_rules`` derives
one rule set per (ModelConfig, mesh, ShapeConfig) cell; ``pspec`` turns a
(logical-axes, shape) pair into a ``PartitionSpec`` under three invariants:

  1. divisibility guard — a dim whose size is not divisible by the product
     of its mesh axes is left unsharded, and the drop is recorded in a
     ``RuleReport`` so dry-runs can surface layout regressions;
  2. no mesh-axis reuse — one mesh axis shards at most one dim per array
     (GSPMD rejects duplicated axes); later occurrences are dropped;
  3. trailing-``None`` trimming — specs are canonical (``P('data')``, never
     ``P('data', None, None)``) so tests and goldens compare cleanly.

Everything here reads only ``mesh.axis_names`` and ``mesh.devices.shape``,
so rule derivation works on abstract mesh stand-ins (tests) and never
touches jax device state.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.layers import is_spec

# Mesh axes that carry the sample/FSDP dimension (ordered: outermost first).
DP_AXES = ("pod", "data")

# Per-device weight-byte budget above which serving cells keep FSDP on the
# 'embed' dim (small models replicate their weights instead — the all-gather
# would dominate decode latency).
SERVE_FSDP_BYTES = 2e9


@dataclass
class RuleReport:
    """Record of sharding rules dropped by the divisibility guard.

    ``dropped`` entries are ``(logical_axis, dim_size, mesh_axes_product)``.
    """

    dropped: List[Tuple[str, int, int]] = field(default_factory=list)

    def note_dropped(self, axis: str, dim: int, total: int) -> None:
        self.dropped.append((axis, dim, total))


def mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(tuple(mesh.axis_names), tuple(mesh.devices.shape)))


def dp_axes(mesh) -> Tuple[str, ...]:
    sizes = mesh_axis_sizes(mesh)
    return tuple(a for a in DP_AXES if a in sizes)


def pspec(dims, shape, rules, mesh, report: Optional[RuleReport] = None) -> P:
    """PartitionSpec for an array with logical ``dims`` and concrete ``shape``.

    ``dims`` entries may be ``None`` (dimension never sharded).  Rules map
    each logical axis to a tuple of mesh axes; missing rules mean replicated.
    """
    sizes = mesh_axis_sizes(mesh)
    used: set = set()
    parts: list = []
    for name, dim in zip(dims, shape):
        axes = tuple(rules.get(name, ())) if name is not None else ()
        axes = tuple(a for a in axes if a in sizes)
        if not axes or any(a in used for a in axes):
            parts.append(None)
            continue
        total = int(math.prod(sizes[a] for a in axes))
        if total > 1 and dim % total != 0:
            if report is not None:
                report.note_dropped(name, dim, total)
            parts.append(None)
            continue
        used.update(axes)
        parts.append(axes[0] if len(axes) == 1 else axes)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def sharding_rules(cfg, mesh, shape_cfg=None) -> Dict[str, Tuple[str, ...]]:
    """Derive the logical-axis -> mesh-axes rule set for one benchmark cell.

    ``shape_cfg=None`` means the training layout (the elastic checkpoint
    path re-derives rules mesh-by-mesh without a shape).  All decisions are
    pure functions of (cfg, mesh shape, shape kind) — no device state.
    """
    sizes = mesh_axis_sizes(mesh)
    model = sizes.get("model", 1)
    dp = dp_axes(mesh)
    dp_size = int(math.prod(sizes[a] for a in dp)) if dp else 1
    kind = shape_cfg.kind if shape_cfg is not None else "train"
    batch = shape_cfg.global_batch if shape_cfg is not None else None

    def tp(enabled: bool, n: int) -> Tuple[str, ...]:
        return ("model",) if (enabled and n and n % model == 0) else ()

    heads = tp(cfg.attn_tp, cfg.num_heads)
    kv_heads = tp(cfg.kv_tp, cfg.num_kv_heads)
    expert_par = bool(
        cfg.is_moe and cfg.moe_parallelism == "expert" and cfg.num_experts % model == 0
    )

    # FSDP on 'embed': always during training; in serving only when the
    # per-device weight bytes (post-TP) exceed the serving budget.
    if kind == "train":
        embed = dp
    else:
        per_dev = cfg.n_params() * np.dtype(cfg.dtype).itemsize / max(model, 1)
        embed = dp if per_dev > SERVE_FSDP_BYTES else ()

    # Activation batch/token dims ride the DP axes when divisible.
    act_batch = dp if (batch is None or (dp_size and batch % dp_size == 0)) else ()
    act_tokens = dp if (
        shape_cfg is None or (dp_size and shape_cfg.tokens % dp_size == 0)
    ) else ()

    # KV-cache sequence dim (decode): recover parallelism lost elsewhere —
    # DP axes when the batch cannot shard (long-context batch=1), the model
    # axis when the kv heads cannot shard (GQA kv < model-axis width).
    act_kv_seq: list = []
    if kind == "decode":
        if not act_batch:
            act_kv_seq += list(dp)
        if not kv_heads:
            act_kv_seq.append("model")

    rules: Dict[str, Tuple[str, ...]] = {
        # -- weights --------------------------------------------------------
        "layers": (),
        "norm": (),
        "head_dim": (),
        "head_dim2": (),
        "conv_k": (),
        "ssm_state": (),
        "embed": embed,
        "embed_out": tp(True, cfg.d_model),
        "heads": heads,
        "kv_heads": kv_heads,
        "mlp": tp(True, cfg.d_ff),
        "vocab": tp(True, cfg.padded_vocab),
        "expert": ("model",) if expert_par else (),
        "moe_mlp": tp(cfg.is_moe and not expert_par, cfg.moe_d_ff),
        "ssm_inner": tp(bool(cfg.ssm_state), cfg.ssm_d_inner),
        # -- activations ----------------------------------------------------
        "act_batch": act_batch,
        "act_tokens": act_tokens,
        "act_seq": ("model",) if cfg.sequence_parallel else (),
        "act_embed": (),
        "act_kv_seq": tuple(act_kv_seq),
        "act_expert": ("model",) if expert_par else (),
        "act_moe_ff": tp(cfg.is_moe and not expert_par, cfg.moe_d_ff),
    }
    return rules


# ---------------------------------------------------------------------------
# Schema trees -> PartitionSpec / NamedSharding trees
# ---------------------------------------------------------------------------


def param_pspecs(schema, rules, mesh, report: Optional[RuleReport] = None):
    """Map a ParamSpec tree to a PartitionSpec tree under ``rules``."""
    import jax

    return jax.tree.map(
        lambda s: pspec(s.axes, s.shape, rules, mesh, report), schema, is_leaf=is_spec
    )


def param_shardings(schema, rules, mesh, report: Optional[RuleReport] = None):
    import jax

    return jax.tree.map(
        lambda s: NamedSharding(mesh, pspec(s.axes, s.shape, rules, mesh, report)),
        schema,
        is_leaf=is_spec,
    )


# Logical axes of the non-cache model inputs, by input name.
_INPUT_AXES: Dict[str, Tuple[str, ...]] = {
    "tokens": ("act_batch", "act_seq"),
    "labels": ("act_batch", "act_seq"),
    "frames": ("act_batch", "act_seq", "act_embed"),
    "patch_embeds": ("act_batch", "act_seq", "act_embed"),
    "token": ("act_batch", "act_seq"),
    "cache_len": (),
}


def batch_pspecs(cfg, shape_cfg, rules, mesh, specs,
                 report: Optional[RuleReport] = None):
    """PartitionSpec tree matching an ``input_specs`` dict.

    Plain inputs are mapped by name via ``_INPUT_AXES``; the decode ``cache``
    subtree re-derives its logical axes from the model's cache schema (the
    input specs carry only ShapeDtypeStructs).
    """
    import jax

    out: Dict[str, Any] = {}
    for key, spec in specs.items():
        if key == "cache":
            from repro.models.api import get_model

            schema = get_model(cfg).cache_schema(
                shape_cfg.global_batch, shape_cfg.seq_len
            )
            out[key] = jax.tree.map(
                lambda s: pspec(s.axes, s.shape, rules, mesh, report),
                schema,
                is_leaf=is_spec,
            )
            continue
        axes = _INPUT_AXES.get(key, ())
        ndim = len(spec.shape)
        axes = tuple(axes[:ndim]) + (None,) * (ndim - len(axes))
        out[key] = pspec(axes, spec.shape, rules, mesh, report)
    return out
