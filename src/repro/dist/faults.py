"""Fault & straggler detection (paper §3.2: fault-tolerant re-planning).

Three small, injectable-clock primitives the training loop and the cluster
coordinator compose:

  - ``StepTimer``: per-step deadline from an EMA of observed step times —
    a step slower than ``deadline_factor x EMA`` is a straggler step.
  - ``HeartbeatMonitor``: per-worker liveness (timeout => failed) and
    step-lag (behind the front-runner => straggler) classification.
  - ``MitigationLog``: append-only record of mitigations taken, consumed by
    TrainReport and the coordinator event stream.

Detection feeds ``ClusterCoordinator.handle_failure`` /
``handle_join`` which re-plan the foreground job on the surviving
power-of-two device subset (elastic scaling falls out of the planner).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


class StepTimer:
    """EMA-deadline straggler detection over observed step durations."""

    def __init__(self, deadline_factor: float = 2.0, warmup_steps: int = 3,
                 ema_alpha: float = 0.2):
        assert deadline_factor > 1.0
        self.deadline_factor = deadline_factor
        self.warmup_steps = warmup_steps
        self.ema_alpha = ema_alpha
        self.ema: Optional[float] = None
        self.n = 0

    def record(self, dt: float) -> None:
        # Over-deadline (straggler) samples are excluded from the EMA:
        # folding them in would inflate the deadline after one slow step
        # and mask a persistently slow worker from then on.
        if not self.is_straggler_step(dt):
            self.ema = dt if self.ema is None else (
                (1 - self.ema_alpha) * self.ema + self.ema_alpha * dt
            )
        self.n += 1

    def deadline(self) -> Optional[float]:
        if self.ema is None or self.n < self.warmup_steps:
            return None
        return self.deadline_factor * self.ema

    def is_straggler_step(self, dt: float) -> bool:
        deadline = self.deadline()
        return deadline is not None and dt > deadline


class HeartbeatMonitor:
    """Per-worker heartbeat tracking with timeout + step-lag classification.

    ``clock`` is injectable for tests.  A worker is *failed* once its last
    beat is older than ``timeout``; a live worker more than ``lag`` steps
    behind the front-runner is a *straggler*.
    """

    def __init__(self, n_workers: int, timeout: float, lag: int = 1,
                 clock: Callable[[], float] = time.monotonic):
        self.n_workers = n_workers
        self.timeout = timeout
        self.lag = lag
        self.clock = clock
        t0 = clock()
        self.last: Dict[int, Tuple[float, int]] = {
            w: (t0, 0) for w in range(n_workers)
        }

    def beat(self, worker: int, step: int) -> None:
        # Unknown worker ids must go through join(): silently accepting
        # them grows `last` past n_workers with no join semantics and the
        # coordinator never learns a device appeared.
        if worker not in self.last:
            raise KeyError(
                f"beat from unknown worker {worker}; call join({worker}) first"
            )
        self.last[worker] = (self.clock(), step)

    def join(self, worker: int) -> bool:
        """Register a worker (explicit join semantics).

        Returns True if the worker was new; re-joining a tracked worker is
        a no-op (False) so re-delivered join announcements are idempotent.
        """
        if worker in self.last:
            return False
        self.last[worker] = (self.clock(), 0)
        self.n_workers = len(self.last)
        return True

    def forget(self, worker: int) -> bool:
        """Stop tracking a worker (acknowledge a detected failure).

        Without this, ``failed()`` re-reports the same dead worker every
        poll; the consumption loop forgets each failure it acts on so
        detection fires exactly once per loss.
        """
        if worker not in self.last:
            return False
        del self.last[worker]
        self.n_workers = len(self.last)
        return True

    def failed(self) -> List[int]:
        now = self.clock()
        return sorted(w for w, (t, _) in self.last.items()
                      if now - t >= self.timeout)

    def stragglers(self) -> List[int]:
        dead = set(self.failed())
        live = {w: s for w, (_, s) in self.last.items() if w not in dead}
        if not live:
            return []
        front = max(live.values())
        return sorted(w for w, s in live.items() if front - s > self.lag)


@dataclass
class MitigationLog:
    """Append-only record of mitigations (straggler/failure/replan/...)."""

    events: List[dict] = field(default_factory=list)

    def log(self, kind: str, **info) -> None:
        self.events.append({"kind": kind, **info})

    def count(self, kind: str) -> int:
        return sum(1 for e in self.events if e["kind"] == kind)

    def __len__(self) -> int:
        return len(self.events)
