"""FSDP-style parameter gathering + activation constraints (GSPMD).

The models call three trace-time hooks:

  - ``gather(tree, schema)`` / ``gather_leaf(x, axes)``: force the per-use
    all-gather of FSDP-sharded weights by constraining them to a TP-only
    layout (DP axes stripped).  Inside ``jax.lax.scan`` over layers this
    yields ZeRO-3 behaviour: each layer's weights materialize just before
    use and are released after.
  - ``constrain(x, axes)``: ``with_sharding_constraint`` through the active
    rule set (MoE dispatch relies on this to keep scatters local).
  - ``group_count(axis)``: number of shards the active rules give a logical
    axis (1 outside any context) — used for group-local capacity math.

All hooks are identity functions outside a ``context(mesh, rules)`` block,
so single-device smoke tests run the exact same model code unsharded.
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from typing import Any, Optional, Tuple

from repro.dist.sharding import DP_AXES, mesh_axis_sizes, pspec
from repro.models.layers import is_spec

# Stack of (mesh, rules) — trace-time only, LIFO so contexts nest.
_CTX: list = []


@contextmanager
def context(mesh, rules):
    """Activate (mesh, rules) for gather/constrain/group_count."""
    _CTX.append((mesh, rules))
    try:
        yield
    finally:
        _CTX.pop()


def active() -> Optional[tuple]:
    return _CTX[-1] if _CTX else None


def group_count(axis: str) -> int:
    """Shard count of a logical axis under the active rules (1 if inactive)."""
    ctx = active()
    if ctx is None:
        return 1
    mesh, rules = ctx
    sizes = mesh_axis_sizes(mesh)
    return int(math.prod(sizes.get(a, 1) for a in rules.get(axis, ()))) or 1


def _tp_only_rules(rules) -> dict:
    """The rule set with DP/FSDP axes stripped (what a gathered weight keeps)."""
    dp = set(DP_AXES)
    return {
        k: tuple(a for a in v if a not in dp)
        for k, v in rules.items()
        if isinstance(v, (tuple, list))
    }


def _constrain(x, spec, mesh):
    import jax
    from jax.sharding import NamedSharding

    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def gather_leaf(x, axes: Tuple[str, ...]):
    """All-gather an FSDP-sharded leaf at its use site (identity unsharded)."""
    ctx = active()
    if ctx is None:
        return x
    mesh, rules = ctx
    return _constrain(x, pspec(axes, x.shape, _tp_only_rules(rules), mesh), mesh)


def gather(params: Any, schema: Any) -> Any:
    """``gather_leaf`` over a param subtree, axes taken from its schema."""
    ctx = active()
    if ctx is None:
        return params
    import jax

    return jax.tree.map(
        lambda s, x: gather_leaf(x, s.axes), schema, params, is_leaf=is_spec
    )


def constrain(x, axes: Tuple[Optional[str], ...]):
    """Sharding-constrain an activation via the active rules (identity if none)."""
    ctx = active()
    if ctx is None:
        return x
    mesh, rules = ctx
    return _constrain(x, pspec(axes, x.shape, rules, mesh), mesh)
