"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state.  The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
smoke tests and benches see the real (1-device) platform.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(data: int, model: int, pod: int = 1):
    """Arbitrary mesh (tests / small-scale demos on host devices)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def largest_pow2_mesh(n_devices: int):
    """Elastic re-mesh: biggest power-of-two (data, model) mesh that fits
    n_devices, favoring the data axis 4:1 (used after failures)."""
    g = 1
    while g * 2 <= n_devices:
        g *= 2
    model = 1
    while model * model * 4 <= g:
        model *= 2
    data = g // model
    return make_mesh(data, model)


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
