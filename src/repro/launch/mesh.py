"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state.  The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
smoke tests and benches see the real (1-device) platform.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.plan import normalize_quanta, pack_ranges, pow2_floor, serving_plan


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(data: int, model: int, pod: int = 1, devices: Optional[Sequence] = None):
    """Arbitrary mesh (tests / small-scale demos on host devices).

    ``devices`` restricts the mesh to an explicit device subset (elastic
    re-mesh over survivors, submesh demos); default is the process devices.
    """
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             devices=devices)
    return jax.make_mesh((data, model), ("data", "model"), devices=devices)


def pow2_mesh_shape(n_devices: int) -> Tuple[int, int]:
    """The (data, model) shape ``largest_pow2_mesh`` would build — pure
    arithmetic, no jax device state, so the static sharding sweep
    (``repro.analysis.shardcheck``) can enumerate every mesh shape reachable
    after a failure without constructing a single device."""
    if n_devices < 1:
        raise ValueError(f"need at least one device, got {n_devices}")
    cap = 1
    while cap * cap * 4 <= pow2_floor(n_devices):
        cap *= 2
    candidates = []
    m = 1
    while m <= cap:
        candidates.append(m)
        m *= 2
    # widest model axis within the 4:1 bound that maximizes device coverage
    model = max(candidates, key=lambda m: (n_devices // m * m, m))
    return n_devices // model, model


def largest_pow2_mesh(n_devices: int, devices: Optional[Sequence] = None):
    """Elastic re-mesh: the largest (data, model) mesh that fits n_devices,
    favoring the data axis 4:1 (used after failures).  The model axis stays
    a power of two — sharding rules genuinely need it to divide head/hidden
    dims — but the data axis is just a batch split, so a non-power-of-two
    survivor count keeps every device the model width allows (7 survivors
    -> 7x1, not 4x1; the planner's scale set covers non-pow2 pools too).
    Only a sub-``model`` remainder is ever left out of the mesh, and only
    when a narrower model axis would not cover more devices."""
    data, model = pow2_mesh_shape(n_devices)
    if devices is not None:
        devices = list(devices)[: data * model]
    return make_mesh(data, model, devices=devices)


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def remesh_for_pool(device_ids, *, devices: Optional[Sequence] = None) -> Mesh:
    """Re-carve a mesh onto a surviving device pool (applied reconfig).

    ``device_ids`` is the healthy pool from a reconfiguration event
    (``CoordinatorLoop`` publishes the coordinator's sorted healthy set).
    Ids map positionally onto the process device list — the same
    positional contract ``submesh_from_range`` and the executable-cache
    eviction use — and ids beyond it (devices hosted by other processes,
    or virtual ids above the local pool) are skipped: each host re-carves
    over *its* survivors.  The carving itself is ``largest_pow2_mesh``, so
    a non-pow2 survivor count keeps every device the model width allows.
    """
    devs = list(devices) if devices is not None else jax.devices()
    local = [devs[int(i)] for i in device_ids if 0 <= int(i) < len(devs)]
    if not local:
        raise ValueError(
            f"reconfig pool {sorted(int(i) for i in device_ids)} has no "
            f"local devices (process has {len(devs)})"
        )
    return largest_pow2_mesh(len(local), devices=local)


# ---------------------------------------------------------------------------
# Plan-driven submeshes (executable gap collocation — paper §5, TPU mode)
# ---------------------------------------------------------------------------


def submesh_from_range(start: int, end: int, *, model: int = 1,
                       devices: Optional[Sequence] = None) -> Mesh:
    """A (data, model) Mesh over the device-index range [start, end).

    Devices are taken positionally from ``devices`` (default: the process
    device list), so two non-overlapping index ranges always yield disjoint
    submeshes — the invariant the collocator relies on.
    """
    devs = list(devices) if devices is not None else jax.devices()
    n = end - start
    if n <= 0:
        raise ValueError(f"empty device range [{start}, {end})")
    if start < 0 or end > len(devs):
        raise ValueError(
            f"device range [{start}, {end}) outside the {len(devs)}-device set"
        )
    if n % model:
        raise ValueError(f"range size {n} not divisible by model={model}")
    arr = np.array(devs[start:end], dtype=object).reshape(n // model, model)
    return Mesh(arr, ("data", "model"))


@dataclass(frozen=True)
class PlanSubmeshes:
    """Disjoint fg/bg submeshes for one BurstPlan.

    ``fg_range``/``fg_mesh`` span the plan's peak foreground device usage;
    ``bg`` maps each gap stage to the largest free device range (after
    excluding parallel-branch placements active in that stage) and its Mesh.
    ``bg_tenants`` maps each gap stage to the per-tenant carving: up to
    ``tenants`` disjoint (range, Mesh) slots in priority order (slot 0 =
    largest chunk = highest-priority tenant); ``bg`` is always slot 0.
    ``stage_fg_range`` gives the foreground's *actual* device window per
    stage — during a gap stage the fg occupies a strict prefix of
    ``fg_range``, and every bg range is disjoint from it.
    """

    fg_range: Tuple[int, int]
    fg_mesh: Mesh
    bg: Dict[int, Tuple[Tuple[int, int], Mesh]]
    stage_fg_range: Dict[int, Tuple[int, int]]
    bg_tenants: Dict[int, Tuple[Optional[Tuple[Tuple[int, int], Mesh]], ...]] = field(
        default_factory=dict
    )

    def bg_mesh(self, stage_index: int) -> Optional[Mesh]:
        hit = self.bg.get(stage_index)
        return hit[1] if hit else None

    def tenant_mesh(self, stage_index: int, slot: int) -> Optional[Mesh]:
        slots = self.bg_tenants.get(stage_index, ())
        if slot >= len(slots) or slots[slot] is None:
            return None
        return slots[slot][1]


def split_mesh_for_plan(plan, *, devices: Optional[Sequence] = None,
                        fg_model: int = 1, bg_model: int = 1,
                        tenants: int = 1,
                        tenant_quanta: Optional[Sequence[int]] = None,
                        ) -> PlanSubmeshes:
    """Carve the device set into the plan's fg submesh + per-gap bg submeshes.

    For each ``GapWindow`` the free set is ``plan.free_device_ranges(stage)``
    — the gap's idle devices minus any ``BranchPlacement`` ranges hosting
    parallel block branches *during that stage* — packed into up to
    ``tenants`` disjoint ``bg_model``-aligned chunks (``pack_ranges``,
    largest chunk first for the highest-priority tenant).  Raises when the
    process has fewer devices than the plan assumes.

    ``tenant_quanta`` switches to the slot-aware per-tenant mode: slot *i*'s
    chunk is aligned to (and its submesh model width is) ``tenant_quanta[i]``
    instead of the global ``bg_model``; a slot whose quantum no chunk can
    satisfy gets ``None`` in ``bg_tenants`` (the tenant is dropped from that
    gap — admission control / the starvation rotation decide what to do).
    """
    devs = list(devices) if devices is not None else jax.devices()
    if len(devs) < plan.num_gpus:
        raise ValueError(
            f"plan wants {plan.num_gpus} devices, process has {len(devs)}"
        )
    stages = plan.stages()
    fg_peak = max(s.gpus for s in stages)
    if fg_peak % fg_model:
        fg_model = 1
    fg_mesh = submesh_from_range(0, fg_peak, model=fg_model, devices=devs)
    bg: Dict[int, Tuple[Tuple[int, int], Mesh]] = {}
    bg_tenants: Dict[int, Tuple[Optional[Tuple[Tuple[int, int], Mesh]], ...]] = {}
    stage_fg: Dict[int, Tuple[int, int]] = {
        i: (0, s.gpus) for i, s in enumerate(stages)
    }
    quanta = (normalize_quanta(tenant_quanta, tenants)
              if tenant_quanta is not None else None)
    for gap in plan.gaps():
        free = plan.free_device_ranges(gap.stage_index)
        chunks = pack_ranges(free, tenants,
                             quantum=quanta if quanta is not None else bg_model)
        if not chunks or all(c is None for c in chunks):
            continue
        slots = tuple(
            None if c is None else (
                c, submesh_from_range(
                    c[0], c[1],
                    model=quanta[slot] if quanta is not None else bg_model,
                    devices=devs,
                )
            )
            for slot, c in enumerate(chunks)
        )
        bg_tenants[gap.stage_index] = slots
        bg[gap.stage_index] = next(s for s in slots if s is not None)
    return PlanSubmeshes(fg_range=(0, fg_peak), fg_mesh=fg_mesh, bg=bg,
                         stage_fg_range=stage_fg, bg_tenants=bg_tenants)


# ---------------------------------------------------------------------------
# Serving submeshes (prefill/decode disaggregation — ISSUE 9)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ServingSubmeshes:
    """Disjoint prefill/decode submeshes for a disaggregated serving engine.

    Built by carving a ``serving_plan`` with ``split_mesh_for_plan``:
    prefill is the plan's foreground stage on [0, n_prefill), decode is the
    gap's largest bg chunk.  The ranges are positional device indices, so
    ``disjoint`` is checkable without touching the device objects.
    """

    prefill_range: Tuple[int, int]
    prefill_mesh: Mesh
    decode_range: Tuple[int, int]
    decode_mesh: Mesh

    def disjoint(self) -> bool:
        (ps, pe), (ds, de) = self.prefill_range, self.decode_range
        return pe <= ds or de <= ps

    def device_sets_disjoint(self) -> bool:
        """The ground-truth check: no physical device in both meshes."""
        p = {d.id for d in self.prefill_mesh.devices.flat}
        q = {d.id for d in self.decode_mesh.devices.flat}
        return not (p & q)


def split_mesh_for_serving(n_prefill: int, *,
                           devices: Optional[Sequence] = None,
                           prefill_model: int = 1,
                           decode_model: int = 1) -> ServingSubmeshes:
    """Carve the device set into disjoint prefill + decode submeshes.

    Reuses the ``split_mesh_for_plan`` carving over a ``serving_plan`` —
    prefill as the foreground stage, decode as its burst gap — so the
    positional-disjointness invariant of ``submesh_from_range`` carries
    over: the two submeshes can never share a device.
    """
    devs = list(devices) if devices is not None else jax.devices()
    plan = serving_plan(len(devs), n_prefill)
    split = split_mesh_for_plan(plan, devices=devs, fg_model=prefill_model,
                                bg_model=decode_model)
    hit = split.bg.get(0)
    if hit is None:
        raise ValueError(
            f"no decode carving: {len(devs) - n_prefill} free devices can't "
            f"fit a decode submesh with model={decode_model}"
        )
    (ds, de), dmesh = hit
    return ServingSubmeshes(
        prefill_range=split.fg_range, prefill_mesh=split.fg_mesh,
        decode_range=(ds, de), decode_mesh=dmesh,
    )
