"""HLO text analysis: trip-aware FLOPs / bytes / collective traffic.

``compiled.cost_analysis()`` counts each while-loop body ONCE — with
scan-over-layers that understates FLOPs by ~num_layers×.  This module parses
the post-SPMD HLO text instead:

  - every computation gets a *multiplier* = sum over call-chains of
    while-loop trip counts (``known_trip_count`` annotation when present,
    caller-supplied default otherwise — the dry-run passes num_layers);
  - ``dot`` op FLOPs     = 2 × |result| × |contracting dims|  (per device)
  - result-buffer bytes  ≈ bytes written (×2 ≈ bytes accessed)
  - collective operand bytes per kind (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute)

Shapes in post-SPMD HLO are per-device, so all outputs are per-device per
step; the roofline multiplies by chip count per its formulas.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9]+m[0-9]+(?:fn)?)?|pred)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_WHILE_BODY = re.compile(r"body=%?([\w.\-]+)")
_WHILE_COND = re.compile(r"condition=%?([\w.\-]+)")
_CALLS = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
# dot operands may carry inline types ("dot(f32[16,16]{1,0} %x, ...)" —
# newer XLA text) or be bare names ("dot(%x, ...)"); capture both forms.
_DOT_RE = re.compile(
    r"=\s*([a-z0-9]+\[[0-9,]*\])\{?[^=]*?\bdot\(\s*"
    r"(?:([a-z0-9]+\[[0-9,]*\])(?:\{[0-9,]*\})?\s+)?%?([\w.\-]+)"
)
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[a-z0-9]+\[[0-9,]*\])")
_OPERAND_NAMES = re.compile(r"%([\w.\-]+)")


def _dims(shape_txt: str):
    m = _SHAPE_RE.search(shape_txt)
    if not m:
        return None, []
    dt = m.group(1)
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return dt, dims


def _nbytes(shape_txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in (dims.split(",") if dims else []):
            n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


class HloCosts:
    def __init__(self):
        self.dot_flops = 0.0
        self.bytes_written = 0.0
        self.collectives: Dict[str, float] = defaultdict(float)
        self.diag: Dict[str, int] = {}

    @property
    def collective_bytes(self) -> float:
        return float(sum(self.collectives.values()))


_CONST_RE = re.compile(r"constant\((\d+)\)")
_ALIAS_OP = re.compile(
    r"\b(?:tuple|get-tuple-element|bitcast|bitcast-convert|parameter|constant|"
    r"while|conditional|after-all|iota)\(")


def analyze_hlo(hlo_text: str, default_trip_count: int = 1) -> HloCosts:
    lines = hlo_text.splitlines()

    # --- pass 1: computations, call edges, while ops, per-comp constants
    comp_of_line: Dict[int, str] = {}
    current = "<module>"
    called_by: Dict[str, list] = defaultdict(list)  # callee -> [(caller, mult)]
    fusion_comps: set = set()
    const_max: Dict[str, int] = defaultdict(int)  # comp -> max int constant
    whiles: list = []  # (caller_comp, body, cond, known_trip)
    n_while = 0
    for i, line in enumerate(lines):
        st = line.strip()
        if st.endswith("{") and ("->" in st) and not st.startswith(("%constant", "ROOT")):
            hdr = _COMP_HDR.match(st)
            if hdr:
                current = hdr.group(1)
        comp_of_line[i] = current
        for m in _CONST_RE.finditer(st):
            const_max[current] = max(const_max[current], int(m.group(1)))
        if " while(" in st:
            n_while += 1
            trip = None
            mt = _TRIP.search(st)
            if mt:
                trip = int(mt.group(1))
            body = cond = None
            mb = _WHILE_BODY.search(st)
            mc = _WHILE_COND.search(st)
            if mb:
                body = mb.group(1)
            if mc:
                cond = mc.group(1)
            whiles.append((current, body, cond, trip))
        else:
            for m in _CALLS.finditer(st):
                called_by[m.group(1)].append((current, 1))
                fusion_comps.add(m.group(1))  # fusion/reduction bodies: ops
                # stay in registers/VMEM — not HBM traffic
            mb = _BRANCHES.search(st)
            if mb:
                for name in mb.group(1).split(","):
                    called_by[name.strip().lstrip("%")].append((current, 1))

    # resolve trip counts: known_trip_count > condition-bound constant > default
    for caller, body, cond, trip in whiles:
        if trip is None and cond is not None and const_max.get(cond, 0) >= 2:
            trip = const_max[cond]
        t = trip if trip else default_trip_count
        if body:
            called_by[body].append((caller, t))
        if cond:
            called_by[cond].append((caller, t))

    memo: Dict[str, float] = {}

    def mult(comp: str, depth: int = 0) -> float:
        if comp in memo:
            return memo[comp]
        if depth > 64:
            return 1.0
        callers = called_by.get(comp)
        if not callers:
            memo[comp] = 1.0
            return 1.0
        memo[comp] = 0.0  # cycle guard
        total = 0.0
        for caller, m in callers:
            if caller == comp:
                continue
            total += m * mult(caller, depth + 1)
        memo[comp] = total if total > 0 else 1.0
        return memo[comp]

    # --- pass 1.5: symbol table (op name -> first shape dims + bytes)
    sym_dims: Dict[str, list] = {}
    sym_bytes: Dict[str, int] = {}
    for line in lines:
        st = line.strip()
        md = _DEF_RE.match(st)
        if md:
            name, shp = md.group(1), md.group(2)
            _, dims = _dims(shp)
            sym_dims[name] = dims
            sym_bytes[name] = _nbytes(shp)

    # --- pass 2: per-op costs × multiplier
    out = HloCosts()
    n_dots = 0
    n_coll = 0
    for i, line in enumerate(lines):
        st = line.strip()
        if not (st.startswith("%") or st.startswith("ROOT")):
            continue
        k = mult(comp_of_line[i])

        md = _DOT_RE.search(st)
        if md:
            _, rdims = _dims(md.group(1))
            if md.group(2):
                _, ldims = _dims(md.group(2))
            else:
                ldims = sym_dims.get(md.group(3), [])
            mc = _LHS_CONTRACT.search(st)
            contract = 1
            if mc and mc.group(1):
                for ci in mc.group(1).split(","):
                    ci = int(ci)
                    if ci < len(ldims):
                        contract *= ldims[ci]
            res = 1
            for d in rdims:
                res *= d
            out.dot_flops += 2.0 * res * contract * k
            n_dots += 1

        # result bytes (bytes written) — top-level/while/branch ops only;
        # fusion-internal results never touch HBM, and alias/metadata ops
        # (tuple plumbing, bitcasts, parameters, the while carry itself)
        # move no bytes
        if comp_of_line[i] not in fusion_comps and not _ALIAS_OP.search(st):
            eq = st.find("= ")
            if eq > 0:
                head = st[eq + 2:]
                par = head.find("(")
                out.bytes_written += _nbytes(head[: par if par > 0 else len(head)]) * k

        for kind in _COLLECTIVES:
            if re.search(r"\b%s(?:-start)?[\w.\-]*\(" % kind, st):
                if f"{kind}-done" in st:
                    break
                m = re.search(
                    r"(?:%s)(?:-start)?[\w.\-]*\(([^)]*)\)" % kind, st
                )
                b = 0
                if m:
                    # operand bytes via symbol table (no inline types in HLO)
                    for opname in _OPERAND_NAMES.findall(m.group(1)):
                        b += sym_bytes.get(opname, 0)
                    if b == 0:
                        b = _nbytes(m.group(1))
                if b == 0:
                    # fall back to result bytes
                    mr = _DEF_RE.match(st)
                    if mr:
                        b = sym_bytes.get(mr.group(1), 0)
                if b:
                    out.collectives[kind] += b * k
                    n_coll += 1
                break

    out.diag = {"n_dots": n_dots, "n_collective_ops": n_coll, "n_while": n_while}
    return out


def analyze_collectives(
    hlo_text: str, default_trip_count: int = 1
) -> Tuple[Dict[str, float], Dict[str, int]]:
    """Back-compat wrapper: ({kind: per-device bytes}, diagnostics)."""
    c = analyze_hlo(hlo_text, default_trip_count)
    return dict(c.collectives), c.diag
