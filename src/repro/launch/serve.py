"""Serving entrypoint.

  python -m repro.launch.serve --arch qwen2-1.5b [--batch 4] [--new-tokens 16]

Runs the reduced config on host devices: batched prefill + greedy decode
through the sharded KV cache.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--capacity", type=int, default=64)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models.api import get_model
    from repro.serve.engine import ServingEngine

    cfg = get_config(args.arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, args.batch, args.capacity)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32
    )
    out = eng.generate(prompts, args.new_tokens)
    print(f"generated {out.shape} tokens")
    print(f"prefill {eng.stats.prefill_s*1e3:.1f} ms, "
          f"decode {eng.stats.tokens_per_s:.1f} steps/s")


if __name__ == "__main__":
    main()
