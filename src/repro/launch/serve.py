"""Serving entrypoint.

  python -m repro.launch.serve --arch qwen2-1.5b [--batch 4] [--new-tokens 16]
  python -m repro.launch.serve --arch qwen2-1.5b --continuous [--qps 20]

Default mode runs the fixed-batch engine on host devices: batched prefill +
greedy decode through the sharded KV cache.  ``--continuous`` serves a
generated Poisson request trace through the paged continuous-batching
engine (lanes refilled mid-decode, pages allocated/freed per request) and
reports per-request latency percentiles.
"""
from __future__ import annotations

import argparse


def _run_fixed(args):
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models.api import get_model
    from repro.serve.engine import ServingEngine

    cfg = get_config(args.arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, args.batch, args.capacity)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32
    )
    out = eng.generate(prompts, args.new_tokens)
    print(f"generated {out.shape} tokens")
    print(f"prefill {eng.stats.prefill_s*1e3:.1f} ms, "
          f"decode {eng.stats.tokens_per_s:.1f} tokens/s "
          f"({eng.stats.steps_per_s:.1f} steps/s)")


def _run_continuous(args):
    import jax

    from repro.configs import get_config
    from repro.models.api import get_model
    from repro.serve.engine import ContinuousBatchingEngine
    from repro.serve.scheduler import ContinuousScheduler
    from repro.serve.trace import generate_request_trace, materialize_requests

    cfg = get_config(args.arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(
        cfg, params, lanes=args.batch, n_pages=args.pages,
        page_tokens=args.page_tokens, lane_capacity=args.capacity,
    )
    trace = generate_request_trace(
        args.requests, seed=7, qps=args.qps,
        vocab_size=min(512, cfg.vocab_size),
        prompt_len=(4, args.prompt_len),
        max_new=(4, args.new_tokens), name="cli",
    )
    reqs = materialize_requests(trace)
    rep = ContinuousScheduler(eng).run(reqs)
    print(f"served {len(rep.completed)} requests in {rep.makespan:.2f}s "
          f"virtual ({rep.tokens_out()} tokens, "
          f"{eng.stats.tokens_per_s:.1f} tokens/s decode)")
    print(f"latency p50 {rep.latency_percentile(50)*1e3:.1f} ms, "
          f"p99 {rep.latency_percentile(99)*1e3:.1f} ms; "
          f"deferrals: {rep.page_deferrals} page")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4,
                    help="batch rows (fixed) / decode lanes (continuous)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--capacity", type=int, default=64,
                    help="KV capacity per row/lane (tokens)")
    ap.add_argument("--continuous", action="store_true",
                    help="serve a Poisson request trace with continuous batching")
    ap.add_argument("--qps", type=float, default=20.0)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--pages", type=int, default=33)
    ap.add_argument("--page-tokens", type=int, default=8)
    args = ap.parse_args()
    if args.continuous:
        _run_continuous(args)
    else:
        _run_fixed(args)


if __name__ == "__main__":
    main()
