import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch × shape × mesh) cell.

The two lines above run before ANY other import (jax locks the device count
on first init).  For each cell we build the production mesh, jit the real
step function (train_step incl. optimizer for train shapes; full-sequence
forward for prefill; decode_step for decode shapes), lower against
ShapeDtypeStruct inputs (zero allocation), compile, and record:

  - memory_analysis()    (proves the cell fits per-device HBM)
  - cost_analysis()      (FLOPs / bytes for §Roofline)
  - collective bytes     (parsed from the post-SPMD HLO, scan-aware)

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out results.jsonl]

--all runs each cell in a fresh subprocess (compile caches don't accumulate;
one failing cell doesn't kill the sweep) and appends JSONL records.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback


def _record(arch: str, shape_name: str, mesh_kind: str, rules_override=None,
            cfg_override=None) -> dict:
    import dataclasses

    import jax
    from repro.configs import SHAPES, get_config, shapes_for
    from repro.dist.sharding import RuleReport, sharding_rules
    from repro.launch.hlo_analysis import analyze_collectives
    from repro.launch.mesh import make_production_mesh
    from repro.models import get_model
    from repro.models.api import input_specs
    from repro.optim.optimizer import make_optimizer
    from repro.train.state import abstract_state
    from repro.train.step import jit_decode_step, jit_forward, jit_train_step

    cfg = get_config(arch)
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    report = RuleReport()
    rules = sharding_rules(cfg, mesh, shape)
    if rules_override:
        rules.update({k: tuple(v) for k, v in rules_override.items()})
    api = get_model(cfg)
    specs = input_specs(cfg, shape)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            opt = make_optimizer(cfg)
            fn, st_sh, bt_sh = jit_train_step(api, opt, mesh, shape, rules=rules,
                                              report=report)
            args = (abstract_state(api, opt), specs)
        elif shape.kind == "prefill":
            fn, p_sh, bt_sh = jit_forward(api, mesh, shape, rules=rules, report=report)
            from repro.models.layers import abstract_params

            args = (abstract_params(api.schema), specs)
        else:  # decode
            fn, p_sh, bt_sh = jit_decode_step(api, mesh, shape, rules=rules,
                                              report=report)
            from repro.models.layers import abstract_params

            args = (abstract_params(api.schema), specs)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax < 0.5 returns [dict] per program
        cost = cost[0] if cost else {}
    trip = max(cfg.num_layers, cfg.num_encoder_layers)
    from repro.launch.hlo_analysis import analyze_hlo

    hc = analyze_hlo(compiled.as_text(), default_trip_count=trip)
    coll, diag = dict(hc.collectives), hc.diag

    print(f"=== {arch} × {shape_name} × {mesh_kind} ({n_dev} chips) ===")
    print(f"memory_analysis: args={mem.argument_size_in_bytes/1e9:.3f} GB "
          f"out={mem.output_size_in_bytes/1e9:.3f} GB "
          f"temp={mem.temp_size_in_bytes/1e9:.3f} GB per device")
    print(f"cost_analysis: flops={cost.get('flops', 0):.3e} "
          f"bytes={cost.get('bytes accessed', 0):.3e}")
    print(f"collectives (per-device bytes/step): "
          f"{ {k: f'{v:.3e}' for k, v in coll.items()} }")

    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "n_devices": int(n_dev),
        "kind": shape.kind,
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "peak_bytes": int(mem.argument_size_in_bytes + mem.temp_size_in_bytes
                          + mem.output_size_in_bytes),
        "hlo_flops_raw": float(cost.get("flops", 0.0)),
        "hlo_bytes_raw": float(cost.get("bytes accessed", 0.0)),
        "hlo_dot_flops": float(hc.dot_flops),  # trip-aware, per device
        "hlo_bytes_written": float(hc.bytes_written),  # trip-aware, per device
        "collective_bytes": {k: float(v) for k, v in coll.items()},
        "collective_bytes_total": float(sum(coll.values())),
        "collective_diag": diag,
        "scan_trip_count": trip,
        "n_params": int(cfg.n_params()),
        "dropped_rules": [list(map(str, d)) for d in report.dropped],
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "rules_override": rules_override or {},
        "cfg_override": cfg_override or {},
    }


def run_cell(arch, shape_name, mesh_kind, out_path=None, rules_override=None,
             cfg_override=None):
    rec = _record(arch, shape_name, mesh_kind, rules_override, cfg_override)
    if out_path:
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return rec


def enumerate_cells(mesh_kinds):
    from repro.configs import ASSIGNED_ARCHS, get_config, shapes_for

    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            for mk in mesh_kinds:
                yield arch, shape.name, mk


def _done_cells(out_path):
    done = set()
    try:
        with open(out_path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass
    except FileNotFoundError:
        pass
    return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun.jsonl")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--rules-override", default=None,
                    help="JSON dict of logical-axis rule overrides (hillclimb)")
    ap.add_argument("--cfg-override", default=None,
                    help="JSON dict of ModelConfig field overrides (hillclimb)")
    args = ap.parse_args()

    if args.all:
        mesh_kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        done = _done_cells(args.out)
        cells = [c for c in enumerate_cells(mesh_kinds) if c not in done]
        print(f"dry-run sweep: {len(cells)} cells to go ({len(done)} done)")
        failures = []
        for arch, shape, mk in cells:
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                   "--shape", shape, "--mesh", mk, "--out", args.out]
            print(f"--> {arch} × {shape} × {mk}", flush=True)
            try:
                r = subprocess.run(cmd, timeout=args.timeout, capture_output=True,
                                   text=True)
                if r.returncode != 0:
                    failures.append((arch, shape, mk, r.stderr[-2000:]))
                    print(f"FAILED: {arch} × {shape} × {mk}\n{r.stderr[-2000:]}",
                          flush=True)
                else:
                    print(r.stdout.strip().splitlines()[-3] if r.stdout.strip() else "",
                          flush=True)
            except subprocess.TimeoutExpired:
                failures.append((arch, shape, mk, "timeout"))
                print(f"TIMEOUT: {arch} × {shape} × {mk}", flush=True)
        print(f"sweep done: {len(cells) - len(failures)} ok, {len(failures)} failed")
        for f in failures:
            print("FAIL:", f[0], f[1], f[2])
        sys.exit(1 if failures else 0)
    else:
        assert args.arch and args.shape
        override = json.loads(args.rules_override) if args.rules_override else None
        cfg_over = json.loads(args.cfg_override) if args.cfg_override else None
        run_cell(args.arch, args.shape, args.mesh, args.out, override, cfg_over)


if __name__ == "__main__":
    main()
