"""Training entrypoint.

  python -m repro.launch.train --arch llama3-8b [--smoke] [--steps N]
      [--data N --model N] [--ckpt-dir DIR] [--bg-arch qwen2-1.5b]

--smoke uses the arch's reduced config on the host devices; the full config
is exercised via the dry-run (AOT only) per the assignment.  --bg-arch
enables DeepPool multiplexing: a background job's steps are paced into the
foreground plan's gaps.
"""
from __future__ import annotations

import argparse
import dataclasses


def _bg_submesh(fg_devices: int, amp_limit: float, hw, cfg):
    """Largest plan-gap submesh disjoint from the foreground training mesh.

    The production plan assumes 256 devices, so the foreground graph is
    re-planned at the host device count and its gaps carved into submeshes
    (``split_mesh_for_plan``); the biggest free range that clears the fg
    mesh's device prefix [0, fg_devices) wins.  Falls back to the raw spare
    devices when the host plan leaves no usable gap, and to None (plain
    same-device jit) when every device belongs to the fg mesh."""
    import jax

    from repro.configs import TRAIN_4K
    from repro.core.plan import pow2_floor
    from repro.core.planner import plan as make_plan
    from repro.launch.mesh import split_mesh_for_plan, submesh_from_range
    from repro.models.graph import build_lm_graph

    n_dev = len(jax.devices())
    if n_dev <= fg_devices:
        return None
    host_plan = make_plan(build_lm_graph(cfg, TRAIN_4K), pow2_floor(n_dev),
                          amp_limit, hw)
    best = None
    for rng, _mesh in split_mesh_for_plan(host_plan).bg.values():
        lo, hi = max(rng[0], fg_devices), rng[1]
        if hi - lo > 0 and (best is None or hi - lo > best[1] - best[0]):
            best = (lo, hi)
    if best is None:
        best = (fg_devices, n_dev)
    return submesh_from_range(best[0], best[1])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--bg-arch", default=None)
    ap.add_argument("--amp-limit", type=float, default=2.0)
    args = ap.parse_args()

    import jax

    from repro.configs import TRAIN_4K, get_config
    from repro.core.coordinator import ClusterCoordinator, Job
    from repro.launch.mesh import make_mesh
    from repro.models.graph import build_lm_graph
    from repro.train.loop import TrainConfig, TrainReport, train

    cfg = get_config(args.arch)
    shape = dataclasses.replace(
        TRAIN_4K, seq_len=args.seq, global_batch=args.batch, name="cli"
    )
    run_cfg = cfg.reduced() if args.smoke else cfg
    mesh = make_mesh(args.data, args.model)

    # burst-parallel plan for the FULL config (what production would run)
    coord = ClusterCoordinator(256)
    plan = coord.submit_foreground(
        Job(args.arch, "foreground", build_lm_graph(cfg, TRAIN_4K),
            amp_limit=args.amp_limit)
    )
    print(plan.summary())

    bg_fn = None
    if args.bg_arch:
        bg_mesh = _bg_submesh(args.data * args.model, args.amp_limit,
                              coord.hw, cfg)
        if bg_mesh is not None:
            # executable collocation: the bg step is jitted onto a gap
            # submesh disjoint from the foreground training mesh
            from repro.train.step import bg_step_factory

            bg_fn = bg_step_factory(args.bg_arch, batch=4, seq=32,
                                    seed=1)(bg_mesh)
            ids = sorted(d.id for d in bg_mesh.devices.flat)
            print(f"bg job on disjoint submesh devices {ids}")
        else:
            from repro.models.api import get_model, make_batch
            from repro.optim.optimizer import make_optimizer
            from repro.train.state import init_state
            from repro.train.step import make_train_step

            bcfg = get_config(args.bg_arch).reduced()
            bapi = get_model(bcfg)
            bopt = make_optimizer(bcfg)
            bstate = init_state(jax.random.PRNGKey(1), bapi, bopt)
            bstep = jax.jit(make_train_step(bapi, bopt))
            bbatch = make_batch(jax.random.PRNGKey(2), bcfg, 2, 32)
            holder = {"state": bstate}

            def bg_fn():
                holder["state"], _ = bstep(holder["state"], bbatch)

    tc = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir, bg_step_fn=bg_fn)
    report = train(run_cfg, shape, mesh, tc)
    print(
        f"done: steps={report.steps_done} loss {report.losses[0]:.3f} -> "
        f"{report.losses[-1]:.3f} restarts={report.restarts} "
        f"bg_steps={report.bg_steps} "
        f"mean_step={1e3 * sum(report.step_times) / len(report.step_times):.1f} ms"
    )


if __name__ == "__main__":
    main()
