"""Training entrypoint.

  python -m repro.launch.train --arch llama3-8b [--smoke] [--steps N]
      [--data N --model N] [--ckpt-dir DIR]
      [--bg-arch qwen2-1.5b [--bg-arch minicpm-2b ...]]

--smoke uses the arch's reduced config on the host devices; the full config
is exercised via the dry-run (AOT only) per the assignment.  --bg-arch
(repeatable) enables DeepPool multiplexing: each background job's steps are
paced into the foreground plan's gaps on its own disjoint submesh — the
first --bg-arch is the highest-priority tenant and gets the largest chunk.
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools


def _bg_submeshes(fg_devices: int, amp_limit: float, hw, cfg, n: int):
    """Per-tenant plan-gap submeshes disjoint from the fg training mesh.

    The production plan assumes 256 devices, so the foreground graph is
    re-planned at the host device count and its per-stage free device
    ranges — clipped to clear the fg mesh's prefix [0, fg_devices) — are
    packed into up to ``n`` disjoint chunks (``pack_ranges``, largest chunk
    to the first --bg-arch).  Falls back to the raw spare devices when the
    host plan leaves no usable gap.

    Returns (meshes, dropped): ``meshes`` has ``n`` entries where tenants
    beyond the packable chunk count get None (plain same-device jit
    fallback), and ``dropped`` lists those tenant indices explicitly — the
    caller must surface them (log + CollocationResult.rejected_tenants),
    never silently vanish a requested tenant.
    """
    import jax

    from repro.configs import TRAIN_4K
    from repro.core.plan import pack_ranges
    from repro.core.planner import plan as make_plan
    from repro.launch.mesh import submesh_from_range
    from repro.models.graph import build_lm_graph

    n_dev = len(jax.devices())
    if n_dev <= fg_devices:
        return [None] * n, list(range(n))
    host_plan = make_plan(build_lm_graph(cfg, TRAIN_4K), n_dev, amp_limit, hw)
    free = []
    for si in range(len(host_plan.stages())):
        for lo, hi in host_plan.free_device_ranges(si):
            lo = max(lo, fg_devices)
            if hi - lo > 0:
                free.append((lo, hi))
    if not free:
        free = [(fg_devices, n_dev)]
    chunks = pack_ranges(free, n)
    meshes = [submesh_from_range(lo, hi) for lo, hi in chunks]
    dropped = list(range(len(meshes), n))
    return meshes + [None] * (n - len(meshes)), dropped


def _register_bg_jobs(coord, archs, meshes):
    """Register each --bg-arch as a background Job WITH its step factory.

    The factory (not just the built step fn) goes through
    ``Job.step_fn_factory`` — ``background_tenants()`` rosters only jobs
    carrying a factory, so registering bare ``Job(..., [])`` shells (the
    old behavior) made coordinator-driven ``collocate()``/admission
    silently see zero tenants.  The factory's ``signature`` feeds the
    executable-cache key, scoping compiled steps per (arch, batch, seed).

    Tenants with a gap submesh use ``bg_step_factory`` directly; tenants
    without one (mesh None) get a same-device jit fallback factory that
    ignores the mesh argument but still carries a distinct signature.
    Returns the per-tenant zero-arg bg step fns for the train loop's
    paced slot, in CLI (priority) order.
    """
    import jax

    from repro.configs import get_config
    from repro.core.coordinator import Job

    bg_fns = []
    for i, (bg_arch, bg_mesh) in enumerate(zip(archs, meshes)):
        if bg_mesh is not None:
            # executable collocation: the bg step is jitted onto a gap
            # submesh disjoint from the foreground training mesh; the
            # step's global batch is sized to the tenant's own chunk
            # width (per-device batch), not a one-size-fits-all quantum
            from repro.train.step import bg_step_factory

            factory = bg_step_factory(bg_arch, seq=32, seed=1 + i,
                                      per_device_batch=2)
            bg_fns.append(factory(bg_mesh))
            ids = sorted(d.id for d in bg_mesh.devices.flat)
            print(f"bg tenant {i} ({bg_arch}) on disjoint submesh "
                  f"devices {ids} (batch 2/device)")
        else:
            from repro.models.api import get_model, make_batch
            from repro.optim.optimizer import make_optimizer
            from repro.train.state import init_state
            from repro.train.step import make_train_step

            bcfg = get_config(bg_arch).reduced()
            bapi = get_model(bcfg)
            bopt = make_optimizer(bcfg)
            bstate = init_state(jax.random.PRNGKey(1 + i), bapi, bopt)
            bstep = jax.jit(make_train_step(bapi, bopt))
            bbatch = make_batch(jax.random.PRNGKey(2 + i), bcfg, 2, 32)
            holder = {"state": bstate}

            def same_device_fn(holder=holder, bstep=bstep, bbatch=bbatch):
                holder["state"], _ = bstep(holder["state"], bbatch)

            def factory(mesh, fn=same_device_fn):
                return fn

            factory.signature = f"{bg_arch}-samedev-b2-s32-r{1 + i}"
            bg_fns.append(same_device_fn)
            print(f"bg tenant {i} ({bg_arch}) same-device fallback")
        # register the tenant with the coordinator (priority: CLI order,
        # first --bg-arch highest) so collocate()/re-plans/admission
        # actually roster it
        coord.submit_background(
            Job(f"bg{i}-{bg_arch}", "background", [],
                priority=len(archs) - i, step_fn_factory=factory)
        )
    return bg_fns


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--bg-arch", action="append", default=None,
                    help="background tenant arch; repeat for multiple "
                         "tenants (first = highest priority)")
    ap.add_argument("--amp-limit", type=float, default=2.0)
    ap.add_argument("--hb-timeout", type=float, default=10.0,
                    help="heartbeat timeout (s) before a silent worker is "
                         "declared failed by the live control plane")
    ap.add_argument("--admit-every", type=int, default=5,
                    help="re-sweep tenant admission every N steps "
                         "(continuous admission; 0 disables)")
    args = ap.parse_args()

    import jax

    from repro.configs import TRAIN_4K, get_config
    from repro.core.coordinator import ClusterCoordinator, Job
    from repro.launch.mesh import make_mesh
    from repro.models.graph import build_lm_graph
    from repro.train.loop import TrainConfig, TrainReport, train

    cfg = get_config(args.arch)
    shape = dataclasses.replace(
        TRAIN_4K, seq_len=args.seq, global_batch=args.batch, name="cli"
    )
    run_cfg = cfg.reduced() if args.smoke else cfg
    mesh = make_mesh(args.data, args.model)

    # burst-parallel plan for the FULL config (what production would run)
    coord = ClusterCoordinator(256)
    plan = coord.submit_foreground(
        Job(args.arch, "foreground", build_lm_graph(cfg, TRAIN_4K),
            amp_limit=args.amp_limit)
    )
    print(plan.summary())

    bg_fn = None
    if args.bg_arch:
        archs = list(args.bg_arch)
        meshes, dropped = _bg_submeshes(args.data * args.model,
                                        args.amp_limit, coord.hw, cfg,
                                        len(archs))
        if dropped:
            # a requested tenant must never vanish silently: say exactly
            # which --bg-arch lost its gap submesh and what happens instead
            print(
                "WARNING: no gap submesh for bg tenant(s) "
                + ", ".join(f"{i} ({archs[i]})" for i in dropped)
                + f" — the plan's gaps packed only {len(archs) - len(dropped)}"
                f" chunk(s); dropped tenants fall back to same-device jit "
                f"(they share the fg devices instead of a disjoint submesh)"
            )
        bg_fns = _register_bg_jobs(coord, archs, meshes)
        if len(bg_fns) == 1:
            bg_fn = bg_fns[0]
        else:
            # round-robin the tenants through the train loop's single paced
            # bg slot, highest priority first within each cycle
            cycle = itertools.cycle(bg_fns)

            def bg_fn():
                next(cycle)()

    # live control plane: this single-process entrypoint co-hosts both
    # sides — the worker beats over the transport, the CoordinatorLoop
    # consumes them, so a stalled worker is detected from live beats
    # (handle_failure + re-plan + reconfig event) instead of only via the
    # fail-stop exception path.  Multi-host runs swap the fake pair for
    # KVStoreTransport over the jax.distributed KV store.
    from repro.dist.faults import HeartbeatMonitor
    from repro.dist.transport import CoordinatorLoop, fake_transport_pair

    worker_end, coord_end = fake_transport_pair()
    hb = HeartbeatMonitor(n_workers=1, timeout=args.hb_timeout)
    control_loop = CoordinatorLoop(coord_end, hb, coordinator=coord)

    tc = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                     bg_step_fn=bg_fn, coordinator=coord, heartbeat=hb,
                     transport=worker_end, control_loop=control_loop,
                     admit_every=max(0, args.admit_every))
    report = train(run_cfg, shape, mesh, tc)
    print(
        f"done: steps={report.steps_done} loss {report.losses[0]:.3f} -> "
        f"{report.losses[-1]:.3f} restarts={report.restarts} "
        f"bg_steps={report.bg_steps} "
        f"mitigations={len(report.mitigations)} "
        f"mean_step={1e3 * sum(report.step_times) / len(report.step_times):.1f} ms"
    )


if __name__ == "__main__":
    main()
