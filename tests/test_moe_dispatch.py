"""MoE dispatch correctness: group-local capacity semantics, gate math,
EP/TP sharding constraints (the §Perf-optimized path)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.moe import _capacity, moe_apply, moe_schema
from repro.models.layers import init_params


def _setup(E=4, K=2, D=16, F=8, T=32, cf=8.0):
    cfg = dataclasses.replace(
        get_config("qwen3-moe-30b-a3b").reduced(),
        num_experts=E, experts_per_tok=K, moe_d_ff=F, d_model=D,
        capacity_factor=cf,
    )
    params = init_params(jax.random.PRNGKey(0), moe_schema(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, T // 2, D), jnp.float32)
    return cfg, params, x


def test_moe_output_shape_and_finite():
    cfg, params, x = _setup()
    out, aux = moe_apply(params, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0.0  # load-balancing loss positive by construction


def test_moe_generous_capacity_equals_dense_mixture():
    """With capacity that admits every token, the MoE equals the explicit
    dense gate-weighted mixture of expert outputs."""
    cfg, params, x = _setup(cf=100.0)
    out, _ = moe_apply(params, x, cfg)

    # dense reference
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, cfg.experts_per_tok)
    vals = vals / vals.sum(-1, keepdims=True)

    def expert(e, v):
        g = v @ params["wg"][e]
        u = v @ params["wu"][e]
        return (jax.nn.silu(g) * u) @ params["wd"][e]

    ref = jnp.zeros_like(xt)
    for k in range(cfg.experts_per_tok):
        outs = jnp.stack([expert(e, xt) for e in range(cfg.num_experts)], 0)
        ref = ref + vals[:, k, None] * jnp.take_along_axis(
            outs, idx[:, k][None, :, None], axis=0)[0]
    np.testing.assert_allclose(np.asarray(out.reshape(-1, D)), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_moe_capacity_drops_tokens():
    """With capacity 1 slot/expert, most (t,k) routes are dropped —
    the output shrinks but stays finite (GShard drop semantics)."""
    cfg, params, x = _setup(cf=100.0)
    full, _ = moe_apply(params, x, cfg)
    cfg_tight = dataclasses.replace(cfg, capacity_factor=0.01)
    tight, _ = moe_apply(params, x, cfg_tight)
    assert bool(jnp.all(jnp.isfinite(tight)))
    assert float(jnp.mean(jnp.abs(tight))) < float(jnp.mean(jnp.abs(full)))


def test_capacity_formula():
    cfg, _, _ = _setup(E=8, K=2, cf=1.0)
    assert _capacity(64, cfg) == 64 * 2 // 8
    assert _capacity(4, cfg) >= cfg.experts_per_tok  # floor


def test_moe_grad_flows_to_all_param_groups():
    cfg, params, x = _setup(cf=100.0)

    def loss(p):
        out, aux = moe_apply(p, x, cfg)
        return jnp.sum(out ** 2) + aux

    grads = jax.grad(loss)(params)
    for name in ("router", "wg", "wu", "wd"):
        assert float(jnp.sum(jnp.abs(grads[name]))) > 0.0, name
