"""End-to-end system behaviour: training loop with faults, coordinator
elasticity, multiplexed background work, loss goes down."""
import dataclasses
import os

import jax
import pytest

from repro.configs import TRAIN_4K, get_config
from repro.core.coordinator import ClusterCoordinator, Job
from repro.launch.mesh import make_mesh
from repro.models.graph import build_lm_graph, build_vgg_graph
from repro.train.loop import TrainConfig, train

SMOKE_SHAPE = dataclasses.replace(TRAIN_4K, seq_len=64, global_batch=4, name="smoke")


@pytest.fixture(scope="module")
def mesh():
    return make_mesh(1, 1)


def test_train_loss_decreases(mesh):
    cfg = get_config("qwen2-1.5b").reduced()
    report = train(cfg, SMOKE_SHAPE, mesh, TrainConfig(steps=25, seed=0))
    assert report.steps_done == 25
    first = sum(report.losses[:5]) / 5
    last = sum(report.losses[-5:]) / 5
    assert last < first, (first, last)


def test_train_restart_from_checkpoint(mesh, tmp_path):
    """Inject a failure mid-run; the loop restores the checkpoint and
    completes all steps."""
    cfg = get_config("llama3-8b").reduced()
    ckpt_dir = str(tmp_path / "ck")
    fired = {"done": False}

    def injector(step):
        if step == 12 and not fired["done"]:
            fired["done"] = True
            raise RuntimeError("injected device failure")

    tc = TrainConfig(steps=15, ckpt_dir=ckpt_dir, ckpt_every=5)
    report = train(cfg, SMOKE_SHAPE, mesh, tc, fault_injector=injector)
    assert report.steps_done >= 15
    assert report.restarts >= 1
    assert report.mitigations.count("failure") == 1
    from repro.checkpoint.ckpt import latest_step

    assert latest_step(ckpt_dir) == 15


def test_train_with_multiplexed_background(mesh):
    cfg = get_config("qwen2-1.5b").reduced()
    counter = {"n": 0}

    def bg():
        counter["n"] += 1

    tc = TrainConfig(steps=6, bg_step_fn=bg)
    report = train(cfg, SMOKE_SHAPE, mesh, tc)
    assert report.bg_steps == counter["n"] > 0


def test_coordinator_elastic_replan():
    coord = ClusterCoordinator(16)
    job = Job("fg", "foreground", build_lm_graph(get_config("llama3-8b"), TRAIN_4K),
              amp_limit=2.0)
    p16 = coord.submit_foreground(job)
    assert p16.num_gpus == 16
    p15 = coord.handle_failure(0)  # 15 healthy -> plan all 15 survivors
    assert p15.num_gpus == 15
    assert p15.total_time >= p16.total_time - 1e-12
    p17 = coord.handle_join([16, 17])  # 17 healthy -> plan all 17
    assert p17.num_gpus == 17
    assert p17.total_time <= p15.total_time + 1e-12


def test_coordinator_collocation_sim():
    coord = ClusterCoordinator(8)
    from repro.configs.vgg16 import CONFIG as VCFG

    coord.submit_foreground(Job("fg", "foreground", build_vgg_graph(VCFG, 32)))
    res = coord.simulate_collocation()
    assert res.fg_slowdown < 1.2
    assert res.cluster_throughput > 0.0


def test_register_bg_jobs_rosters_tenants_with_factories():
    """Regression: launch/train.py used to register background jobs as bare
    Job(..., []) shells without step_fn_factory — background_tenants()
    skips factory-less jobs, so coordinator-driven collocation/admission
    silently saw ZERO tenants.  The registration helper must attach the
    factory (and a cache-key signature) to every job it submits."""
    from repro.configs.vgg16 import CONFIG as VCFG
    from repro.launch.train import _register_bg_jobs

    coord = ClusterCoordinator(8)
    coord.submit_foreground(
        Job("fg", "foreground", build_vgg_graph(VCFG, 32), amp_limit=1.5)
    )
    bg_fns = _register_bg_jobs(coord, ["qwen2-1.5b"], [None])
    assert len(bg_fns) == 1 and callable(bg_fns[0])
    tenants = coord.background_tenants()  # no default factory passed
    assert len(tenants) == 1
    t = tenants[0]
    assert t.job == "bg0-qwen2-1.5b" and t.step_fn_factory is not None
    # the factory carries a distinct cache signature (arch/batch/seed
    # scoped) so two tenants never share a compiled step through the cache
    assert t.cache_signature == "qwen2-1.5b-samedev-b2-s32-r1"
    # the rostered factory is the paced slot's step fn for any mesh
    assert t.step_fn_factory(None) is bg_fns[0]
