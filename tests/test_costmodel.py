"""Cost model (paper §4.1) unit + property tests."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 must collect without hypothesis
    from _prop import given, settings, strategies as st

from repro.core.costmodel import (
    A100,
    V5E,
    allreduce_time,
    comm_time,
    comp_time,
    efficiency,
    sync_time,
)
from repro.models.graph import LayerNode


def _node(flops=1e12, units=256):
    return LayerNode("n", flops=flops, param_bytes=1e8, act_out_bytes=1e7,
                     parallel_units=units)


def test_efficiency_monotone():
    assert efficiency(0.5) < efficiency(1) < efficiency(8) < efficiency(1e6)
    assert efficiency(1) == pytest.approx(0.5)
    assert efficiency(1e9) == pytest.approx(1.0, abs=1e-6)


def test_comp_decreasing_until_units():
    n = _node(units=16)
    ts = [comp_time(n, g, V5E) for g in (1, 2, 4, 8, 16)]
    assert all(a >= b for a, b in zip(ts, ts[1:]))
    # beyond parallel_units no further speedup
    assert comp_time(n, 64, V5E) >= ts[-1] - 1e-15


def test_comm_zero_when_same_scale():
    assert comm_time(1e9, 8, 8, V5E) == 0.0


def test_comm_symmetric():
    assert comm_time(1e9, 2, 16, V5E) == pytest.approx(comm_time(1e9, 16, 2, V5E))


def test_sync_zero_single():
    assert sync_time(1e9, 1, V5E) == 0.0
    assert sync_time(1e9, 2, V5E) > 0.0


def test_sync_bandwidth_bound():
    # ring all-reduce: asymptotically 2×bytes/bw per device
    t = sync_time(1e9, 1024, V5E)
    assert t == pytest.approx(2 * 1e9 / V5E.chip_bw, rel=0.1)


@settings(max_examples=50, deadline=None)
@given(st.floats(1e3, 1e12), st.sampled_from([1, 2, 4, 8, 64]),
       st.sampled_from([1, 2, 4, 8, 64]))
def test_property_comm_nonneg_triangleish(bytes_, g, h):
    t = comm_time(bytes_, g, h, V5E)
    assert t >= 0.0
    if g != h:
        assert t > 0.0


@settings(max_examples=50, deadline=None)
@given(st.floats(1e6, 1e14), st.integers(1, 10))
def test_property_comp_positive(flops, logg):
    n = _node(flops=flops, units=1 << 12)
    assert comp_time(n, 1 << logg, V5E) > 0.0


def test_allreduce_scaling():
    assert allreduce_time(1e9, 1, V5E) == 0.0
    t2 = allreduce_time(1e9, 2, V5E)
    t1024 = allreduce_time(1e9, 1024, V5E)
    assert t1024 > t2  # (n-1)/n factor + latency grows
