"""Trace-driven cluster simulator (repro.sim): determinism + failure storms.

Pins the ISSUE 6 tentpole invariants:
  * seeded trace generation -> simulate -> serialize -> load -> re-simulate
    is bit-identical (reports compare equal as JSON, segments included),
  * a 25%-device-loss failure storm keeps the ExecutableCache bounded
    (evict_stale after every re-plan) and no predicted collocation chunk
    ever references a dead device,
  * trace JSON round-trips exactly and rejects unknown versions/kinds,
  * the admission sweep inside the replay enforces the QoS bound under a
    pessimistic interference model (tenants actually get rejected).
"""
import json

import pytest

from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.costmodel import A100
from repro.core.multiplex import InterferenceModel
from repro.models.graph import build_vgg_graph
from repro.sim import (
    ClusterSim,
    Trace,
    TraceEvent,
    generate_failure_storm,
    generate_heartbeat_loss,
    generate_lease_churn,
    generate_trace,
    load_trace,
    save_trace,
)

GRAPH = build_vgg_graph(VCFG, 32)
AMP = 1.5


def _sim(trace, **kw):
    kw.setdefault("interference", InterferenceModel(gap_inflation=1.12))
    return ClusterSim(trace, GRAPH, hw=A100, amp_limit=AMP, **kw)


def test_trace_generation_is_seed_deterministic():
    a = generate_trace(64, seed=3, horizon=120.0)
    b = generate_trace(64, seed=3, horizon=120.0)
    assert a.to_json() == b.to_json()
    c = generate_trace(64, seed=4, horizon=120.0)
    assert a.to_json() != c.to_json()
    # sorted by time, all kinds well-formed, devices in range
    ts = [e.t for e in a.events]
    assert ts == sorted(ts)
    for e in a.events:
        if e.device is not None:
            assert 0 <= e.device < 64


def test_trace_json_roundtrip(tmp_path):
    tr = generate_trace(32, seed=9, horizon=90.0)
    p = tmp_path / "t.json"
    save_trace(tr, p)
    back = load_trace(p)
    assert back.to_json() == tr.to_json()
    # version/kind validation
    bad = tr.to_json()
    bad["version"] = 2
    with pytest.raises(ValueError):
        Trace.from_json(bad)
    with pytest.raises(ValueError):
        TraceEvent.from_json({"t": 0.0, "kind": "meteor_strike"})


def test_replay_is_bit_identical_across_serialization(tmp_path):
    """generate -> simulate, then save -> load -> re-simulate: the two
    reports (segments included) serialize identically."""
    tr = generate_trace(64, seed=7, horizon=150.0)
    rep1 = _sim(tr).run()
    p = tmp_path / "trace.json"
    save_trace(tr, p)
    rep2 = _sim(load_trace(p)).run()
    assert rep1.to_json(with_segments=True) == rep2.to_json(with_segments=True)
    # and the report itself survives a JSON round-trip (no NaN/inf floats)
    assert json.loads(json.dumps(rep1.to_json(with_segments=True))) == \
        rep1.to_json(with_segments=True)


def test_replay_tracks_cluster_state():
    tr = generate_trace(64, seed=7, horizon=150.0)
    rep = _sim(tr).run()
    assert rep.n_epochs >= 1 and rep.n_events == len(tr.events)
    assert rep.horizon == tr.horizon
    # every failure/join that changed the pool re-planned the foreground
    assert rep.n_replans >= 1
    assert rep.fg_goodput > 0.0
    assert 0.0 < rep.jain_time_avg <= 1.0 + 1e-12
    assert rep.mean_fg_slowdown >= 1.0 - 1e-9
    # segments tile [0, horizon) without gaps
    segs = rep.segments
    assert segs[0].t0 == 0.0 and segs[-1].t1 == pytest.approx(rep.horizon)
    for a, b in zip(segs, segs[1:]):
        assert a.t1 == pytest.approx(b.t0)
        assert a.plan_gpus == a.n_healthy  # exact-survivor planning


def test_failure_storm_keeps_cache_bounded_and_plans_on_survivors():
    """25% device loss: evict_stale drops every executable touching a dead
    device, the LRU bound holds throughout, and every post-storm plan /
    predicted chunk lives on surviving devices only (the chunk containment
    assert inside ClusterSim._epoch runs on every epoch)."""
    storm = generate_failure_storm(64, seed=11, dead_fraction=0.25)
    n_failures = sum(1 for e in storm.events if e.kind == "device_failure")
    assert n_failures >= 16  # a real storm
    sim = _sim(storm)
    rep = sim.run()
    assert rep.n_replans == n_failures
    assert rep.cache_final_size <= 64  # ExecutableCache.max_entries
    assert rep.cache_evictions > 0    # the storm actually evicted
    # final epoch plans exactly the surviving pool
    assert rep.segments[-1].n_healthy == 64 - n_failures
    assert rep.segments[-1].plan_gpus == 64 - n_failures


def test_pessimistic_interference_rejects_tenants():
    """Under heavy calibrated interference the admission sweep refuses
    tenants (predicted fg slowdown above the 1.33x bound) — the sim's
    fg slowdown stays within the bound it promised."""
    ev = [TraceEvent(t=1.0 + i, kind="job_arrival", job=f"bg{i}",
                     priority=1, weight=1.0, quantum=1) for i in range(4)]
    tr = Trace(n_devices=32, events=ev, horizon=50.0)
    rep = _sim(tr, interference=InterferenceModel(gap_inflation=2.0)).run()
    assert rep.rejected_total > 0
    assert rep.mean_fg_slowdown <= 1.33 + 1e-9


def test_departures_shrink_roster_and_service_accrues_per_job():
    ev = [
        TraceEvent(t=1.0, kind="job_arrival", job="bgA", priority=1,
                   weight=1.0, quantum=1),
        TraceEvent(t=2.0, kind="job_arrival", job="bgB", priority=1,
                   weight=1.0, quantum=1),
        TraceEvent(t=30.0, kind="job_departure", job="bgA"),
    ]
    tr = Trace(n_devices=16, events=ev, horizon=60.0)
    rep = _sim(tr).run()
    assert set(rep.per_job_service) == {"fg", "bgA", "bgB"}
    # bgB outlived bgA and accrued strictly more service
    assert rep.per_job_service["bgB"] > rep.per_job_service["bgA"] > 0.0
    assert rep.segments[-1].n_tenants == 1


def test_committed_traces_replay_and_gate():
    """The checked-in benchmark traces load, replay deterministically, and
    the 128-device one beats the DP baseline (the bench gate's smallest
    scale, kept fast enough for tier-1)."""
    import os

    from repro.core.planner import plan_data_parallel

    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "traces", "trace_128.json")
    tr = load_trace(path)
    assert tr.n_devices == 128
    rep = _sim(tr).run()
    dp = plan_data_parallel(GRAPH, 128, hw=A100)
    assert rep.mean_goodput_rate > dp.speedup


# -- heartbeat-loss traces: the LIVE detection path ---------------------------


def test_heartbeat_loss_generator_deterministic_and_well_formed():
    a = generate_heartbeat_loss(64, seed=5, n_losses=3, n_jobs=2)
    b = generate_heartbeat_loss(64, seed=5, n_losses=3, n_jobs=2)
    assert a.to_json() == b.to_json()
    losses = [e for e in a.events if e.kind == "heartbeat_loss"]
    assert len(losses) == 3
    assert len({e.device for e in losses}) == 3  # distinct victims
    assert all(0 <= e.device < 64 for e in losses)
    assert sum(1 for e in a.events if e.kind == "job_arrival") == 2
    ts = [e.t for e in a.events]
    assert ts == sorted(ts)


def test_heartbeat_loss_detected_by_live_consumption_path():
    """A silenced device is never announced: the replay must DETECT each
    loss from missing beats (CoordinatorLoop.pump over the InProcessBus,
    exactly the train loop's consumption path) for the pool to reach
    n - n_losses.  Mitigation counts are deterministic and the fg re-plans
    onto the exact (non-pow2) surviving pool at every detection."""
    tr = generate_heartbeat_loss(16, seed=3, n_losses=3, n_jobs=2)
    sim = _sim(tr, hb_timeout=5.0)
    rep = sim.run()
    assert rep.mitigations == {"failure_detected": 3, "replan": 3}
    assert rep.n_replans == 3
    assert rep.segments[-1].n_healthy == 13
    assert rep.segments[-1].plan_gpus == 13  # exact survivors, non-pow2
    # detection lands exactly hb_timeout after each loss: some segment
    # boundary sits at t_loss + hb_timeout for every silenced device
    bounds = {round(s.t0, 6) for s in rep.segments}
    for e in tr.events:
        if e.kind == "heartbeat_loss":
            assert round(e.t + 5.0, 6) in bounds
    # bit-identical replay: same trace, fresh sim, same report
    rep2 = _sim(tr, hb_timeout=5.0).run()
    assert rep.to_json(with_segments=True) == rep2.to_json(with_segments=True)


def test_heartbeat_loss_roundtrips_through_json(tmp_path):
    tr = generate_heartbeat_loss(32, seed=9, n_losses=2, n_jobs=1)
    p = tmp_path / "hb.json"
    save_trace(tr, p)
    rep1 = _sim(tr, hb_timeout=4.0).run()
    rep2 = _sim(load_trace(p), hb_timeout=4.0).run()
    assert rep1.to_json(with_segments=True) == rep2.to_json(with_segments=True)
    assert rep1.mitigations["failure_detected"] == 2


def test_committed_heartbeat_loss_trace_gates_mitigations():
    """The checked-in heartbeat-loss trace replays deterministically with
    every loss detected — the CI gate's tier-1 counterpart."""
    import os

    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "traces", "heartbeat_loss_128.json")
    tr = load_trace(path)
    assert tr.n_devices == 128
    n_losses = sum(1 for e in tr.events if e.kind == "heartbeat_loss")
    assert n_losses == 3
    rep = _sim(tr).run()
    assert rep.mitigations["failure_detected"] == n_losses
    assert rep.mitigations["replan"] == n_losses
    assert rep.segments[-1].n_healthy == 128 - n_losses
    assert rep.segments[-1].plan_gpus == 128 - n_losses
    assert rep.mean_fg_slowdown <= 1.33 + 1e-9


# -- lease-churn traces: coordinator election / failover ----------------------


def test_lease_churn_generator_deterministic_and_well_formed(tmp_path):
    a = generate_lease_churn(64, seed=5, n_churns=3, n_jobs=2)
    b = generate_lease_churn(64, seed=5, n_churns=3, n_jobs=2)
    assert a.to_json() == b.to_json()
    churns = [e for e in a.events if e.kind == "lease_churn"]
    assert len(churns) == 3
    # no victim device in the trace: the victim is whoever HOLDS the lease
    # at replay time, so the same trace exercises a churn chain
    assert all(e.device is None for e in churns)
    assert sum(1 for e in a.events if e.kind == "job_arrival") == 2
    ts = [e.t for e in a.events]
    assert ts == sorted(ts)
    p = tmp_path / "lc.json"
    save_trace(a, p)
    assert load_trace(p).to_json() == a.to_json()


def test_lease_churn_replays_real_failover_path():
    """Each churn kills the CURRENT lease holder: the lowest survivor wins
    the election lease_timeout later (fresh loop, bootstrap_from_log — the
    old holder's mitigations are adopted, never re-fired), and the dead
    ex-holder's device loss is then DETECTED by the new holder's pump one
    hb_timeout after its bootstrap re-join.  Counts are exact: one
    failover + one detection + one replan per churn, and GC keeps the
    topic backlog bounded across the whole chain."""
    tr = generate_lease_churn(16, seed=3, n_churns=3, n_jobs=2)
    rep = _sim(tr, hb_timeout=5.0, lease_timeout=2.0, gc_every=1).run()
    assert rep.n_failovers == 3
    assert rep.mitigations["coordinator_failover"] == 3
    assert rep.mitigations["failure_detected"] == 3
    assert rep.mitigations["replan"] == 3
    assert rep.n_replans == 3
    assert rep.segments[-1].n_healthy == 13
    assert rep.segments[-1].plan_gpus == 13  # exact survivors, non-pow2
    # election lands lease_timeout after each churn; the ex-holder's
    # detection one hb_timeout after that — both are segment boundaries
    bounds = {round(s.t0, 6) for s in rep.segments}
    for e in tr.events:
        if e.kind == "lease_churn":
            assert round(e.t + 2.0, 6) in bounds
            assert round(e.t + 7.0, 6) in bounds
    assert sum(rep.topic_backlog.values()) <= 4  # GC bounded the logs
    # bit-identical replay
    rep2 = _sim(tr, hb_timeout=5.0, lease_timeout=2.0, gc_every=1).run()
    assert rep.to_json(with_segments=True) == rep2.to_json(with_segments=True)


def test_lease_churn_without_gc_grows_backlog():
    """Negative control for the GC satellite: the same churn trace with
    gc_every=0 retains every beat — the backlog the compaction path is
    there to bound."""
    tr = generate_lease_churn(16, seed=3, n_churns=3, n_jobs=2)
    rep = _sim(tr, hb_timeout=5.0, lease_timeout=2.0, gc_every=0).run()
    assert rep.n_failovers == 3  # failover itself does not need GC
    assert sum(rep.topic_backlog.values()) > 50


def test_committed_lease_churn_trace_gates_failovers():
    """The checked-in lease-churn trace replays deterministically through
    the election path at 128 devices — the cluster-sim CI gate's tier-1
    counterpart."""
    import os

    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "traces", "lease_churn_128.json")
    tr = load_trace(path)
    assert tr.n_devices == 128
    n_churns = sum(1 for e in tr.events if e.kind == "lease_churn")
    assert n_churns == 3
    rep = _sim(tr, lease_timeout=2.0, gc_every=1).run()
    assert rep.n_failovers == 3
    assert rep.mitigations["coordinator_failover"] == 3
    assert rep.mitigations["failure_detected"] == 3
    assert rep.mitigations["replan"] == 3
    assert rep.segments[-1].n_healthy == 125
    assert rep.segments[-1].plan_gpus == 125
    assert sum(rep.topic_backlog.values()) <= 4
    assert rep.mean_fg_slowdown <= 1.33 + 1e-9
