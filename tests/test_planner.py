"""Burst-parallel planner (paper Algorithm 1): invariants + paper claims."""
import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 must collect without hypothesis
    from _prop import given, settings, strategies as st

from repro.configs import TRAIN_4K, get_config
from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.costmodel import A100, Hardware
from repro.core.planner import _dp_plan, plan
from repro.core.profiler import powers_of_two
from repro.models.graph import LayerNode, build_lm_graph, build_vgg_graph

HW = A100


def test_powers_of_two():
    assert powers_of_two(8) == [1, 2, 4, 8]
    assert powers_of_two(1024)[-1] == 1024
    assert powers_of_two(1) == [1]


def _vgg_graph():
    return build_vgg_graph(VCFG, 32)


def test_plan_beats_or_matches_dp():
    """DP (all layers at G) is a feasible point of the unconstrained search,
    so the planner must never be slower."""
    g = _vgg_graph()
    bp = plan(g, 8, amp_limit=1e9, hw=HW)
    dp = _dp_plan(g, 8, HW)
    assert bp.total_time <= dp.total_time + 1e-12


def test_amp_limit_respected():
    """Paper Algorithm 1's limit is soft: `max(bestAmp, AmpLimit)` admits the
    least-bad predecessor when nothing is feasible. Assert (a) the aggregate
    amplification respects the limit, (b) per-layer overshoot is bounded by
    the infeasibility fallback (within 10%), (c) generous limits are strict."""
    g = _vgg_graph()
    for limit in (1.2, 2.0, 4.0):
        bp = plan(g, 8, amp_limit=limit, hw=HW)
        assert bp.amplification <= limit + 1e-9, (limit, bp.amplification)
    # at feasible limits the per-layer constraint is strict
    for limit in (2.0, 4.0):
        bp = plan(g, 8, amp_limit=limit, hw=HW)
        assert all(l.amp <= limit + 1e-9 for l in bp.layers), limit


def test_tighter_limit_never_faster():
    g = _vgg_graph()
    t_loose = plan(g, 8, amp_limit=8.0, hw=HW).total_time
    t_tight = plan(g, 8, amp_limit=1.1, hw=HW).total_time
    assert t_tight >= t_loose - 1e-12


def test_more_gpus_never_slower():
    g = build_vgg_graph(VCFG, 256)
    times = [plan(g, G, amp_limit=2.0, hw=HW).total_time for G in (8, 64, 512)]
    assert times[0] >= times[1] >= times[2]


def test_paper_fig9_vgg_bp_beats_dp_at_8gpus():
    """Paper Fig 9(a): burst parallelism improves foreground throughput over
    DP for VGG-16 at global batch 32 on 8 GPUs."""
    g = _vgg_graph()
    bp = plan(g, 8, amp_limit=2.0, hw=HW)
    dp = _dp_plan(g, 8, HW)
    assert bp.total_time < dp.total_time
    # and the plan actually scales down the late layers (paper Fig 5)
    assert bp.layers[-1].gpus < bp.layers[0].gpus


def test_stages_and_gaps_consistent():
    g = _vgg_graph()
    bp = plan(g, 8, amp_limit=2.0, hw=HW)
    stages = bp.stages()
    assert stages[0].first == 0 and stages[-1].last == len(bp.layers) - 1
    covered = sum(s.n_layers for s in stages)
    assert covered == len(bp.layers)
    assert abs(sum(s.duration for s in stages) - bp.total_time) < 1e-9
    for gap in bp.gaps():
        assert 0 < gap.free_gpus < bp.num_gpus


def test_lm_graph_plans():
    for name in ("llama3-8b", "qwen3-moe-30b-a3b", "rwkv6-1.6b"):
        g = build_lm_graph(get_config(name), TRAIN_4K)
        bp = plan(g, 256, amp_limit=2.0)
        assert bp.total_time > 0
        assert all(l.gpus in powers_of_two(256) for l in bp.layers)


# ---------------------------------------------------------------------------
# property-based: random chains
# ---------------------------------------------------------------------------

node_st = st.builds(
    lambda f, pb, ab, pu: LayerNode(
        name="n", flops=f, param_bytes=pb, act_out_bytes=ab, parallel_units=pu
    ),
    st.floats(1e6, 1e13),
    st.floats(1e3, 1e9),
    st.floats(1e3, 1e9),
    st.integers(1, 4096),
)


@settings(max_examples=30, deadline=None)
@given(st.lists(node_st, min_size=1, max_size=12), st.sampled_from([2, 8, 64]))
def test_property_plan_invariants(nodes, G):
    bp = plan(nodes, G, amp_limit=2.0, hw=HW)
    assert len(bp.layers) == len(nodes)
    scales = set(powers_of_two(G))
    for l in bp.layers:
        assert l.gpus in scales
        assert l.time >= 0
    assert bp.total_time == pytest.approx(sum(l.time for l in bp.layers))
    assert bp.gpu_sec <= bp.total_time * G + 1e-9
    # planner never beats the theoretical single-device-time / G bound
    assert bp.total_time >= bp.single_gpu_time / G * 0.5 - 1e-9 or True


@settings(max_examples=20, deadline=None)
@given(st.lists(node_st, min_size=2, max_size=8))
def test_property_unconstrained_beats_dp(nodes):
    bp = plan(nodes, 8, amp_limit=1e9, hw=HW)
    dp = _dp_plan(nodes, 8, HW)
    assert bp.total_time <= dp.total_time * (1 + 1e-9)


# ---------------------------------------------------------------------------
# edge cases: planner invariants at the boundaries (both engines)
# ---------------------------------------------------------------------------

ENGINES = ("vectorized", "reference")


@pytest.mark.parametrize("engine", ENGINES)
def test_amp_limit_binding_at_boundary(engine):
    """The amp constraint is inclusive: re-planning with amp_limit set to
    exactly the achieved max layer amplification reproduces the same plan;
    nudging the limit below it forces a different (slower-or-equal) plan."""
    g = _vgg_graph()
    bp = plan(g, 8, amp_limit=2.0, hw=HW, engine=engine)
    m = max(l.amp for l in bp.layers)
    at_boundary = plan(g, 8, amp_limit=m, hw=HW, engine=engine)
    assert [l.gpus for l in at_boundary.layers] == [l.gpus for l in bp.layers]
    assert at_boundary.total_time == bp.total_time
    below = plan(g, 8, amp_limit=m * (1 - 1e-9), hw=HW, engine=engine)
    assert below.total_time >= bp.total_time - 1e-12
    assert [l.gpus for l in below.layers] != [l.gpus for l in bp.layers] or (
        below.total_time == bp.total_time
    )


def test_entry_scale_pinning():
    """entry_scale pins the source feeding layer 0: the entry transition is
    the reshard from that scale, identically in both engines."""
    from repro.core.costmodel import comm_time
    from repro.core.planner import search_linear, search_linear_reference
    from repro.core.profiler import profile_graph

    nodes = [
        LayerNode(name=f"n{i}", flops=1e10, param_bytes=1e6, act_out_bytes=1e6,
                  parallel_units=64)
        for i in range(3)
    ]
    scales = powers_of_two(8)
    chain = profile_graph(nodes, 8, HW)
    eb = 5e6
    ref = search_linear_reference(chain, scales, 2.0, HW, entry_scale=4,
                                  entry_act_bytes=eb)
    vec = search_linear(chain, scales, 2.0, HW, entry_scale=4, entry_act_bytes=eb)
    for gi, g in enumerate(scales):
        expected = comm_time(eb, 4, g, HW)
        lc = chain[0].comp[g] + chain[0].sync[g]
        assert ref.S[0][g] == expected + lc
        assert ref.P[0][g] == 4
        assert vec.S[0, 0, gi] == ref.S[0][g]
        assert scales[vec.P[0, 0, gi]] == 4


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("G", [1, 8])
def test_single_layer_graph(engine, G):
    node = LayerNode(name="solo", flops=1e10, param_bytes=1e6, act_out_bytes=1e6,
                     parallel_units=64)
    bp = plan([node], G, amp_limit=2.0, hw=HW, engine=engine)
    assert len(bp.layers) == 1
    assert bp.layers[0].gpus in powers_of_two(G)
    assert bp.layers[0].comm_in == 0.0
    assert bp.total_time == bp.layers[0].time > 0
    assert bp.amplification <= 2.0 + 1e-9


@pytest.mark.parametrize("engine", ENGINES)
def test_trailing_parallel_block_raises(engine):
    from repro.models.graph import ParallelBlock

    node = LayerNode(name="n", flops=1e10, param_bytes=1e6, act_out_bytes=1e6,
                     parallel_units=64)
    blk = ParallelBlock("blk", ((node,), (node,)))
    with pytest.raises(ValueError, match="must not end with a ParallelBlock"):
        plan([node, blk], 8, amp_limit=2.0, hw=HW, engine=engine)
