"""Live control-plane transport (repro.dist.transport): ISSUE 7 tentpole.

Pins the transport contract (append-only per-topic logs, cursor-based
polling, deterministic order), the three implementations (InProcessBus,
fake two-endpoint pair with JSON enforcement + disconnect, KVStoreTransport
over an injected KV client), and the consumption path that makes failure
handling LIVE: a worker whose heartbeats stop is detected within the
timeout by ``CoordinatorLoop.pump()`` and the foreground re-plans onto the
exact (non-pow2) surviving pool — no injected events anywhere.
"""
import dataclasses

import pytest

from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.coordinator import ClusterCoordinator, Job
from repro.dist.faults import HeartbeatMonitor, MitigationLog
from repro.dist.transport import (
    HEARTBEAT_TOPIC,
    LEASE_TOPIC,
    RECONFIG_TOPIC,
    CoordinatorLease,
    CoordinatorLoop,
    InProcessBus,
    KVStoreTransport,
    WorkerClient,
    fake_transport_pair,
)
from repro.models.graph import build_vgg_graph

GRAPH = build_vgg_graph(VCFG, 32)


# -- transport contract -----------------------------------------------------


def test_inprocess_bus_publish_poll_since():
    bus = InProcessBus()
    assert bus.poll("t") == []
    assert bus.publish("t", {"a": 1}) == 0
    assert bus.publish("t", {"a": 2}) == 1
    assert bus.publish("other", {"b": 1}) == 0  # per-topic sequences
    msgs = bus.poll("t")
    assert msgs == [(0, {"a": 1}), (1, {"a": 2})]
    # cursor semantics: poll is non-destructive, `since` resumes exactly
    assert bus.poll("t", since=2) == []
    bus.publish("t", {"a": 3})
    assert bus.poll("t", since=2) == [(2, {"a": 3})]
    assert bus.poll("t") == msgs + [(2, {"a": 3})]  # replay from 0 intact


def test_fake_pair_shares_one_log_and_enforces_json():
    w, c = fake_transport_pair()
    w.publish("hb", {"worker": 0, "step": 1})
    assert c.poll("hb") == [(0, {"worker": 0, "step": 1})]
    # payloads must survive a JSON round trip — a real KV store carries
    # strings, so an object-bearing payload must fail HERE, in tests
    with pytest.raises(TypeError):
        w.publish("hb", {"worker": object()})


def test_fake_pair_disconnect_drops_publishes_silently():
    w, c = fake_transport_pair()
    assert w.publish("hb", {"worker": 0, "step": 1}) == 0
    w.disconnect()
    assert w.publish("hb", {"worker": 0, "step": 2}) == -1  # dropped
    assert w.dropped == 1
    assert w.poll("hb") == []  # partitioned endpoint sees nothing either
    assert c.poll("hb") == [(0, {"worker": 0, "step": 1})]
    w.reconnect()
    assert w.publish("hb", {"worker": 0, "step": 3}) == 1
    assert [p["step"] for _s, p in c.poll("hb")] == [1, 3]


class _FakeKVClient:
    """Dict-backed stand-in for jax's DistributedRuntimeClient KV surface.

    Mirrors the real coordination-service semantics the two-process harness
    exercises: keys are write-once unless ``allow_overwrite`` is passed
    (the real service raises ALREADY_EXISTS), and deletion is explicit.
    """

    def __init__(self):
        self.store = {}

    def key_value_set(self, key, value, allow_overwrite=False):
        if key in self.store and not allow_overwrite:
            raise RuntimeError(f"Config key {key} already exists.")
        self.store[key] = value

    def key_value_delete(self, key):
        self.store.pop(key, None)

    def key_value_dir_get(self, prefix):
        return [(k, v) for k, v in self.store.items() if k.startswith(prefix)]


def test_kvstore_transport_round_trips_over_injected_client():
    client = _FakeKVClient()
    a = KVStoreTransport("test", client=client, uid="host0-1")
    b = KVStoreTransport("test", client=client, uid="host1-1")
    a.publish("hb", {"worker": 0, "step": 1})
    b.publish("hb", {"worker": 1, "step": 1})
    a.publish("hb", {"worker": 0, "step": 2})
    msgs = a.poll("hb")
    # lexicographic key order = (counter, uid): deterministic global order
    assert [p["worker"] for _s, p in msgs] == [0, 1, 0]
    assert [s for s, _p in msgs] == [0, 1, 2]
    assert b.poll("hb", since=2) == [(2, {"step": 2, "worker": 0})]
    # topics are isolated namespaces
    assert a.poll("reconfig") == []


def test_kvstore_transport_requires_jax_distributed():
    # no injected client + jax.distributed never initialized -> hard error
    with pytest.raises(RuntimeError):
        KVStoreTransport("test")


# -- compaction / low-water GC contract --------------------------------------


def _transport_impls():
    """(name, factory) for all three implementations: factory() -> two
    endpoints over one shared store (same object for the bus)."""
    def bus():
        b = InProcessBus()
        return b, b

    def fake():
        return fake_transport_pair()

    def kv():
        client = _FakeKVClient()
        return (KVStoreTransport("par", client=client, uid="host0-1"),
                KVStoreTransport("par", client=client, uid="host1-1"))

    return [("inprocess", bus), ("fake", fake), ("kvstore", kv)]


@pytest.mark.parametrize("name,factory", _transport_impls(),
                         ids=[n for n, _ in _transport_impls()])
def test_compact_contract_parity(name, factory):
    """The GC contract behaves identically across all three transports:
    compaction drops seq < upto, survivors KEEP their numbers, low_water
    tracks, compaction is monotone + clamped, and a fresh consumer starting
    at low_water sees exactly the retained tail."""
    a, b = factory()
    for i in range(6):
        a.publish("t", {"i": i})
    assert a.low_water("t") == 0
    assert a.compact("t", 4) == 4
    assert a.low_water("t") == 4
    # survivors keep their sequence numbers — no renumbering
    assert [(s, p["i"]) for s, p in a.poll("t", since=4)] == [(4, 4), (5, 5)]
    # monotone: compacting backwards is a no-op
    assert a.compact("t", 2) == 4
    # clamped: never past the head
    assert a.compact("t", 99) == 6
    assert a.poll("t", since=6) == []
    a.publish("t", {"i": 6})
    assert [(s, p["i"]) for s, p in a.poll("t", since=6)] == [(6, 6)]
    # the OTHER endpoint agrees on low_water and the retained tail
    assert b.low_water("t") == 6
    assert [(s, p["i"]) for s, p in b.poll("t", since=b.low_water("t"))] \
        == [(6, 6)]


def test_fake_endpoint_asserts_no_read_below_low_water():
    """The fake transport's CI tripwire: polling below the compacted
    low-water mark means a consumer would silently miss messages on the
    real KV store — the fake raises instead."""
    w, c = fake_transport_pair()
    for i in range(4):
        w.publish("t", {"i": i})
    assert c.poll("t", since=0)  # fine before compaction
    c.compact("t", 3)
    with pytest.raises(RuntimeError, match="low-water"):
        c.poll("t", since=1)
    with pytest.raises(RuntimeError, match="low-water"):
        c.poll("t", since=0)  # a stale consumer restarting from scratch
    # polling from the mark (or later) is the sanctioned resume point
    assert [p["i"] for _s, p in c.poll("t", since=c.low_water("t"))] == [3]


def test_kvstore_compact_preserves_lexicographic_order():
    """Multi-publisher KV topic: compaction deletes the first keys in
    lexicographic order, survivors keep both their relative order and
    their sequence numbers, and the persisted low-water mark seeds fresh
    consumers past the hole."""
    client = _FakeKVClient()
    a = KVStoreTransport("gc", client=client, uid="host0-1")
    b = KVStoreTransport("gc", client=client, uid="host1-1")
    a.publish("hb", {"w": 0, "n": 0})   # key 000000000000.host0-1
    b.publish("hb", {"w": 1, "n": 0})   # key 000000000000.host1-1
    a.publish("hb", {"w": 0, "n": 1})   # key 000000000001.host0-1
    b.publish("hb", {"w": 1, "n": 1})   # key 000000000001.host1-1
    order = [(p["w"], p["n"]) for _s, p in a.poll("hb")]
    assert order == [(0, 0), (1, 0), (0, 1), (1, 1)]
    assert a.compact("hb", 2) == 2
    # exactly the first two keys (lexicographically) are gone from the dir
    left = sorted(k for k in client.store if k.startswith("gc/hb/"))
    assert left == ["gc/hb/000000000001.host0-1", "gc/hb/000000000001.host1-1"]
    # the compactor's own numbering is unchanged for survivors
    assert [(s, p["w"], p["n"]) for s, p in a.poll("hb")] \
        == [(2, 0, 1), (3, 1, 1)]
    # a FRESH consumer seeds its numbering at the persisted low-water mark:
    # same absolute seqs for the same keys (single source of truth)
    c = KVStoreTransport("gc", client=client, uid="host2-1")
    assert c.low_water("hb") == 2
    assert [(s, p["w"], p["n"]) for s, p in c.poll("hb", since=2)] \
        == [(2, 0, 1), (3, 1, 1)]


def test_kvstore_cursor_monotone_under_concurrent_publish():
    """A slow publisher's small-counter key lands 'in the middle' of the
    lexicographic order after the consumer already numbered later keys.
    Stable per-consumer assignment gives it the NEXT seq instead of
    renumbering: a cursor-driven consumer never skips and never re-reads."""
    client = _FakeKVClient()
    fast = KVStoreTransport("cc", client=client, uid="host0-1")
    slow = KVStoreTransport("cc", client=client, uid="host1-1")
    consumer = KVStoreTransport("cc", client=client, uid="host2-1")
    fast.publish("hb", {"m": "f0"})
    fast.publish("hb", {"m": "f1"})
    seen = {}
    cursor = 0
    for seq, p in consumer.poll("hb", cursor):
        seen[seq] = p["m"]
        cursor = seq + 1
    assert seen == {0: "f0", 1: "f1"}
    # the slow publisher now flushes counter-0 keys that sort BEFORE f1's
    slow.publish("hb", {"m": "s0"})
    slow.publish("hb", {"m": "s1"})
    for seq, p in consumer.poll("hb", cursor):
        assert seq not in seen, "re-read after renumbering"
        seen[seq] = p["m"]
        cursor = seq + 1
    # every message delivered exactly once, cursor monotone
    assert sorted(seen.values()) == ["f0", "f1", "s0", "s1"]
    assert cursor == 4
    # a FRESH consumer sees the lexicographic order instead — both views
    # are total and complete; only per-consumer stability is promised
    fresh = KVStoreTransport("cc", client=client, uid="host3-1")
    assert [p["m"] for _s, p in fresh.poll("hb")] == ["f0", "s0", "f1", "s1"]


# -- protocol layer ---------------------------------------------------------


def _cluster(n=8, timeout=5.0):
    """Coordinator + monitor + loop over one bus, virtual clock."""
    clk = {"t": 0.0}
    bus = InProcessBus()
    coord = ClusterCoordinator(n, clock=lambda: clk["t"],
                               virtual_devices=True)
    coord.submit_foreground(Job("fg", "foreground", GRAPH, amp_limit=1.5))
    mon = HeartbeatMonitor(n, timeout=timeout, clock=lambda: clk["t"])
    loop = CoordinatorLoop(bus, mon, coordinator=coord, log=MitigationLog())
    workers = [WorkerClient(bus, w) for w in range(n)]
    return clk, bus, coord, mon, loop, workers


def test_live_failure_detection_replans_exact_survivors():
    """THE acceptance path: worker 3's beats stop; pump() detects the loss
    within the timeout and handle_failure re-plans onto the exact non-pow2
    survivor count — driven end-to-end by beats, no injected events."""
    clk, bus, coord, mon, loop, workers = _cluster(n=8, timeout=5.0)
    assert coord.foreground().plan.num_gpus == 8
    for step in range(3):
        clk["t"] = float(step)
        for w in workers:
            w.beat(step)
        assert loop.pump() == []  # everyone fresh: nothing to do
    # worker 3 goes silent; the rest keep beating
    clk["t"] = 4.0
    for w in workers:
        if w.worker_id != 3:
            w.beat(3)
    assert loop.pump() == []  # age(3) = 2.0 < timeout: not failed yet
    clk["t"] = 7.5  # age(3) = 5.5 >= timeout
    for w in workers:
        if w.worker_id != 3:
            w.beat(4)
    events = loop.pump()
    assert len(events) == 1 and events[0]["reason"] == "failure"
    assert events[0]["worker"] == 3
    assert coord.healthy == {0, 1, 2, 4, 5, 6, 7}
    assert coord.foreground().plan.num_gpus == 7  # exact survivors, non-pow2
    assert events[0]["gpus"] == 7
    assert events[0]["devices"] == [0, 1, 2, 4, 5, 6, 7]
    assert loop.log.count("failure_detected") == 1
    assert loop.log.count("replan") == 1
    # detection fires ONCE: the monitor forgot the worker, later pumps with
    # the clock still past its last beat do not re-fire
    clk["t"] = 20.0
    for w in workers:
        if w.worker_id != 3:
            w.beat(5)
    assert loop.pump() == []
    assert loop.log.count("failure_detected") == 1
    # every worker (and any reconfig listener) sees the re-plan event
    wc = workers[0]
    evs = wc.poll_reconfig()
    assert [e["action"] for e in evs] == ["replan"]
    assert wc.poll_reconfig() == []  # cursor advanced


def test_unknown_beat_is_a_join_and_handle_join_is_idempotent():
    clk, bus, coord, mon, loop, workers = _cluster(n=7, timeout=5.0)
    p7 = coord.foreground().plan
    assert p7.num_gpus == 7
    # a beat from an unknown worker id is an explicit join: the monitor
    # registers it and the coordinator re-plans to exploit the new device
    WorkerClient(bus, 7).beat(0)
    events = loop.pump()
    assert mon.n_workers == 8 and 7 in mon.last
    assert coord.healthy == set(range(8))
    assert coord.foreground().plan.num_gpus == 8
    assert [e["reason"] for e in events] == ["join"]
    assert loop.log.count("join") == 1
    n_events = len(coord.events)
    # re-delivered beat from the (now known) worker: no join, no re-plan
    WorkerClient(bus, 7).beat(1)
    assert loop.pump() == []
    assert len(coord.events) == n_events
    # handle_join on already-healthy devices is a no-op (the old code
    # logged a spurious +N join event and re-planned)
    assert coord.handle_join([2, 5]) is None
    assert len(coord.events) == n_events
    assert coord.foreground().plan.num_gpus == 8


def test_straggler_flagging_rearms_on_recovery():
    clk, bus, coord, mon, loop, workers = _cluster(n=4, timeout=100.0)
    for w in workers:
        w.beat(10)
    loop.pump()
    # worker 2 falls behind the front-runner by > lag
    clk["t"] = 1.0
    for w in workers:
        w.beat(2 if w.worker_id == 2 else 12)
    loop.pump()
    assert loop.log.count("straggler_worker") == 1
    # still lagging: no duplicate logs while flagged
    clk["t"] = 2.0
    for w in workers:
        w.beat(3 if w.worker_id == 2 else 13)
    loop.pump()
    assert loop.log.count("straggler_worker") == 1
    # recovers, then lags again -> re-armed, flagged anew
    clk["t"] = 3.0
    for w in workers:
        w.beat(14)
    loop.pump()
    clk["t"] = 4.0
    for w in workers:
        w.beat(5 if w.worker_id == 2 else 15)
    loop.pump()
    assert loop.log.count("straggler_worker") == 2


class _AdversarialBus(InProcessBus):
    """Worst-case delivery the KV store's lexicographic merge plus
    at-least-once semantics can produce: every poll returns the FULL
    retained history again (re-delivered tail), in reverse order."""

    def poll(self, topic, since=0):
        return list(reversed(super().poll(topic, self.low_water(topic))))


def test_pump_orders_and_dedupes_adversarial_poll_batches():
    """Regression for pump() re-delivery: polled batches are sorted by seq
    and anything below the consumed cursor is skipped — so reversed,
    fully-re-delivered batches neither trigger false detections (cursor
    jumping past unconsumed beats) nor resurrect a dead worker (its old
    beats re-reading as a join, which would double-fire the mitigation on
    the next timeout)."""
    clk = {"t": 0.0}
    bus = _AdversarialBus()
    coord = ClusterCoordinator(8, clock=lambda: clk["t"],
                               virtual_devices=True)
    coord.submit_foreground(Job("fg", "foreground", GRAPH, amp_limit=1.5))
    mon = HeartbeatMonitor(8, timeout=5.0, clock=lambda: clk["t"])
    loop = CoordinatorLoop(bus, mon, coordinator=coord, log=MitigationLog())
    workers = [WorkerClient(bus, w) for w in range(8)]
    for step in range(3):
        clk["t"] = float(step)
        for w in workers:
            w.beat(step)
        assert loop.pump() == []  # no false detections despite reversal
    assert loop.log.count("failure_detected") == 0
    clk["t"] = 7.5  # worker 3 silent past the timeout
    for w in workers:
        if w.worker_id != 3:
            w.beat(4)
    events = loop.pump()
    assert [e["worker"] for e in events] == [3]
    assert coord.healthy == {0, 1, 2, 4, 5, 6, 7}
    # every later poll re-delivers the whole history (reversed): the dead
    # worker's old beats must never read as a fresh join
    for t in (9.0, 11.0, 14.0):
        clk["t"] = t
        for w in workers:
            if w.worker_id != 3:
                w.beat(int(t))
        assert loop.pump() == []
    assert loop.log.count("join") == 0
    assert loop.log.count("failure_detected") == 1
    assert loop.log.count("replan") == 1
    assert coord.foreground().plan.num_gpus == 7


# -- coordinator election (CoordinatorLease) --------------------------------


def _leases(n, timeout=6.0):
    clk = {"t": 0.0}
    bus = InProcessBus()
    leases = [CoordinatorLease(bus, w, timeout=timeout,
                               clock=lambda: clk["t"]) for w in range(n)]
    return clk, bus, leases


def test_lease_seed_claim_renewal_and_acquired_oneshot():
    clk, bus, (l0, l1) = _leases(2)
    l0.claim()
    assert l0.tick() is True and l0.acquired is True   # the winning tick
    assert l0.tick() is True and l0.acquired is False  # held, not re-won
    assert l1.tick() is False and l1.holder == 0 and l1.epoch == 1
    # renewal cadence: past renew_every the holder republishes its claim,
    # and the follower's staleness clock refreshes from the renewal
    clk["t"] = l0.renew_every + 0.01
    n_before = bus.backlog(LEASE_TOPIC)
    assert l0.tick() is True
    assert bus.backlog(LEASE_TOPIC) == n_before + 1
    assert l1.tick() is False
    assert not l1.stale()


def test_lease_stale_holder_superseded_via_tick_alone():
    """No manual claim: a follower's tick() observes staleness past the
    timeout and takes the next epoch by itself."""
    clk, bus, (l0, l1) = _leases(2, timeout=6.0)
    l1.claim()
    assert l1.tick() is True and l0.tick() is False
    clk["t"] = 3.0
    assert l1.tick() is True   # renews
    assert l0.tick() is False  # fresh renewal: not stale
    clk["t"] = 7.0             # holder dead since t=3: age 4 < timeout
    assert l0.tick() is False
    clk["t"] = 9.1             # age 6.1 >= timeout: stale
    assert l0.tick() is True and l0.acquired is True
    assert l0.epoch == 2 and l0.holder == 0


def test_lease_concurrent_claims_tiebreak_to_lowest_id():
    """Two survivors observe the stale lease at the same instant and claim
    the SAME epoch; both see both claims in the log's total order and
    converge on the lower worker id without any CAS."""
    clk, bus, leases = _leases(3, timeout=6.0)
    l0, l1, l2 = leases
    l2.claim()
    for lease in leases:
        assert lease.tick() is (lease is l2)
    clk["t"] = 10.0  # holder 2 dies; both survivors claim epoch 2
    l1.claim()       # worker 1's claim hits the log FIRST
    l0.claim()
    assert l1.tick() is False  # converges on 0 despite claiming first
    assert l0.tick() is True and l0.acquired is True
    assert l0.holder == l1.holder == 0
    assert l0.epoch == l1.epoch == 2
    clk["t"] = 10.5  # the winner keeps the lease; the loser follows
    assert l0.tick() is True and l0.acquired is False
    assert l1.tick() is False


def test_lease_partitioned_claimant_cannot_win():
    """A partitioned worker's claim publish is dropped by the transport, so
    it cannot adopt itself as holder while unreachable — claim() never
    mutates local state, adoption only happens via the log."""
    clk = {"t": 0.0}
    w_end, c_end = fake_transport_pair()
    lw = CoordinatorLease(w_end, 1, timeout=6.0, clock=lambda: clk["t"])
    lc = CoordinatorLease(c_end, 0, timeout=6.0, clock=lambda: clk["t"])
    w_end.disconnect()
    assert lw.tick() is False and lw.holder is None  # claim died on the wire
    assert lc.tick() is True and lc.holder == 0      # reachable one wins
    w_end.reconnect()
    assert lw.tick() is False and lw.holder == 0     # adopts the real holder


# -- coordinator failover: bootstrap_from_log --------------------------------


def test_bootstrap_from_log_adopts_pool_without_refiring():
    """A survivor that wins the lease reconstructs coordinator state from
    the topic logs: the pool of record is adopted (worker 3's re-plan is
    NOT re-fired), members get a fresh grace period, and the normal pump
    path keeps working — a later loss is detected exactly once."""
    clk, bus, coord, mon, loop, workers = _cluster(n=8, timeout=5.0)
    for step in range(3):
        clk["t"] = float(step)
        for w in workers:
            w.beat(step)
        loop.pump()
    clk["t"] = 7.5
    for w in workers:
        if w.worker_id != 3:
            w.beat(4)
    assert len(loop.pump()) == 1  # worker 3 re-planned away by the OLD loop
    assert coord.foreground().plan.num_gpus == 7
    # the coordinator host dies: a survivor rebuilds everything fresh
    coord2 = ClusterCoordinator(8, clock=lambda: clk["t"],
                                virtual_devices=True)
    coord2.submit_foreground(Job("fg", "foreground", GRAPH, amp_limit=1.5))
    mon2 = HeartbeatMonitor(0, timeout=5.0, clock=lambda: clk["t"])
    log2 = MitigationLog()
    loop2 = CoordinatorLoop(bus, mon2, coordinator=coord2, log=log2)
    info = loop2.bootstrap_from_log()
    assert coord2.healthy == {0, 1, 2, 4, 5, 6, 7}
    assert coord2.foreground().plan.num_gpus == 7
    assert info["pool"] == [0, 1, 2, 4, 5, 6, 7]
    assert log2.count("coordinator_failover") == 1
    assert log2.count("failure_detected") == 0  # adopted, not re-fired
    assert log2.count("replan") == 0
    clk["t"] = 8.0
    for w in workers:
        if w.worker_id != 3:
            w.beat(5)
    assert loop2.pump() == []
    assert log2.count("join") == 0  # members adopted, not re-joined
    clk["t"] = 14.0  # a LATER loss: worker 5 goes silent
    for w in workers:
        if w.worker_id not in (3, 5):
            w.beat(6)
    events = loop2.pump()
    assert [e["worker"] for e in events] == [5]
    assert coord2.healthy == {0, 1, 2, 4, 6, 7}
    assert coord2.foreground().plan.num_gpus == 6
    assert log2.count("failure_detected") == 1


def test_gc_bounds_topics_and_keeps_pool_of_record():
    """With gc_every wired, a long run keeps both topics bounded: the hb
    log compacts to the loop's cursor (backlog 0 between pumps) and the
    reconfig log compacts to the live workers' acks — except the newest
    event, which survives as the pool of record so a failover bootstrap
    can still restore the coordinator."""
    clk, bus, coord, mon, loop, workers = _cluster(n=8, timeout=5.0)
    loop.gc_every = 1
    for step in range(40):
        clk["t"] = float(step) * 0.5
        for w in workers:
            if w.worker_id == 3 and step >= 4:
                continue  # worker 3 dies early in the run
            w.poll_reconfig()  # advance the ack the next beat carries
            w.beat(step)
        loop.pump()
    assert loop.log.count("failure_detected") == 1
    assert bus.backlog(HEARTBEAT_TOPIC) == 0
    assert bus.low_water(HEARTBEAT_TOPIC) > 200  # ~280 beats compacted away
    assert bus.backlog(RECONFIG_TOPIC) == 1      # newest event retained
    coord2 = ClusterCoordinator(8, clock=lambda: clk["t"],
                                virtual_devices=True)
    coord2.submit_foreground(Job("fg", "foreground", GRAPH, amp_limit=1.5))
    mon2 = HeartbeatMonitor(0, timeout=5.0, clock=lambda: clk["t"])
    loop2 = CoordinatorLoop(bus, mon2, coordinator=coord2,
                            log=MitigationLog())
    loop2.bootstrap_from_log()
    assert coord2.healthy == {0, 1, 2, 4, 5, 6, 7}
    assert coord2.foreground().plan.num_gpus == 7


def test_monitor_join_forget_membership():
    clk = {"t": 0.0}
    mon = HeartbeatMonitor(2, timeout=5.0, clock=lambda: clk["t"])
    # beat from an unregistered worker is a hard error, not silent growth
    with pytest.raises(KeyError):
        mon.beat(5, 0)
    assert mon.join(5) is True and mon.n_workers == 3
    mon.beat(5, 0)  # now fine
    assert mon.join(5) is False  # idempotent re-join
    assert mon.n_workers == 3
    clk["t"] = 10.0
    assert mon.failed() == [0, 1, 5]
    assert mon.forget(5) is True and mon.forget(5) is False
    assert mon.failed() == [0, 1] and mon.n_workers == 2


# -- live train-loop integration --------------------------------------------


def test_train_loop_detects_silent_worker_from_live_beats():
    """End-to-end inside train(): the loop beats over the fake transport,
    the co-hosted CoordinatorLoop consumes them, and a phantom worker whose
    beats stop is detected mid-run — handle_failure fires from the live
    loop (never from the exception path), the fg re-plans onto the exact
    surviving pool, and the reconfig event comes back to the worker."""
    from repro.configs import TRAIN_4K, get_config
    from repro.launch.mesh import make_mesh
    from repro.train.loop import TrainConfig, train

    clk = {"t": 0.0}
    worker_end, coord_end = fake_transport_pair()
    coord = ClusterCoordinator(8, clock=lambda: clk["t"],
                               virtual_devices=True)
    coord.submit_foreground(Job("fg", "foreground", GRAPH, amp_limit=1.5))
    mon = HeartbeatMonitor(2, timeout=5.0, clock=lambda: clk["t"])
    loop = CoordinatorLoop(coord_end, mon, coordinator=coord)
    # the phantom worker (id 1) beats once at t=0, then goes silent
    WorkerClient(worker_end, 1).beat(0)

    def advance_clock(step):
        clk["t"] = float(step)  # the REAL worker (id 0) beats every step

    cfg = get_config("qwen2-1.5b").reduced()
    shape = dataclasses.replace(TRAIN_4K, seq_len=64, global_batch=4,
                                name="smoke")
    tc = TrainConfig(steps=8, coordinator=coord, heartbeat=mon,
                     transport=worker_end, control_loop=loop)
    report = train(cfg, shape, make_mesh(1, 1), tc,
                   fault_injector=advance_clock)
    assert report.steps_done == 8
    # the phantom's silence was detected from live beats: worker 1's device
    # left the pool and the fg re-planned onto the 7 survivors
    assert report.mitigations.count("failure_detected") == 1
    assert report.mitigations.count("replan") == 1
    assert report.mitigations.count("failure") == 0  # NOT the except path
    assert coord.healthy == {0, 2, 3, 4, 5, 6, 7}
    assert coord.foreground().plan.num_gpus == 7
    # the worker saw the pushed-back reconfiguration event
    assert report.mitigations.count("reconfig") == 1
    ev = next(e for e in report.mitigations.events if e["kind"] == "reconfig")
    assert ev["reason"] == "failure" and ev["gpus"] == 7
    # the real worker stayed healthy the whole run
    assert 0 in coord.healthy


def test_train_loop_continuous_admission_resweeps_roster():
    """admit_every triggers coordinator.readmit on the epoch cadence: with
    a pessimistic density-aware model, the sweep rejects the marginal
    tenant (not all-or-nothing) and logs the admission decision."""
    from repro.configs import TRAIN_4K, get_config
    from repro.core.multiplex import InterferenceModel
    from repro.launch.mesh import make_mesh
    from repro.train.loop import TrainConfig, train

    coord = ClusterCoordinator(8, virtual_devices=True)
    coord.submit_foreground(Job("fg", "foreground", GRAPH, amp_limit=1.5))
    coord.interference = InterferenceModel(gap_inflation=1.28,
                                           density_slope=3.0)
    for i in range(3):
        coord.submit_background(Job(f"bg{i}", "background", [], priority=1,
                                    step_fn_factory=lambda mesh: (lambda: None)))
    cfg = get_config("qwen2-1.5b").reduced()
    shape = dataclasses.replace(TRAIN_4K, seq_len=64, global_batch=4,
                                name="smoke")
    tc = TrainConfig(steps=4, coordinator=coord, admit_every=2)
    report = train(cfg, shape, make_mesh(1, 1), tc)
    assert report.steps_done == 4
    decision = coord.last_admission
    assert decision is not None
    # marginal rejection: some but not all tenants admitted
    assert 0 < decision.n_admitted < 3, decision.row()
    # stable roster across the run: the decision is logged as a coordinator
    # event once (first sweep), not once per cadence tick
    admissions = [e for e in coord.events if e.kind == "admission"]
    assert len(admissions) == 1


def test_train_loop_apply_reconfig_noop_when_carving_unchanged():
    """apply_reconfig on a 1-device host: the replan event's surviving pool
    still contains this host's device, so the re-carve is an identity —
    the event is logged but no remesh happens and every step completes.
    (The mesh-actually-shrinks path needs >1 host device and lives in
    tests/test_distributed.py.)"""
    from repro.configs import TRAIN_4K, get_config
    from repro.launch.mesh import make_mesh
    from repro.train.loop import TrainConfig, train

    clk = {"t": 0.0}
    worker_end, coord_end = fake_transport_pair()
    coord = ClusterCoordinator(8, clock=lambda: clk["t"],
                               virtual_devices=True)
    coord.submit_foreground(Job("fg", "foreground", GRAPH, amp_limit=1.5))
    mon = HeartbeatMonitor(2, timeout=5.0, clock=lambda: clk["t"])
    loop = CoordinatorLoop(coord_end, mon, coordinator=coord)
    WorkerClient(worker_end, 1).beat(0)  # phantom: beats once, goes silent

    def advance_clock(step):
        clk["t"] = float(step)

    cfg = get_config("qwen2-1.5b").reduced()
    shape = dataclasses.replace(TRAIN_4K, seq_len=64, global_batch=4,
                                name="smoke")
    tc = TrainConfig(steps=8, coordinator=coord, heartbeat=mon,
                     transport=worker_end, control_loop=loop,
                     apply_reconfig=True)
    report = train(cfg, shape, make_mesh(1, 1), tc,
                   fault_injector=advance_clock)
    assert report.steps_done == 8
    assert report.mitigations.count("reconfig") == 1
    assert report.remeshes == 0
    assert report.mitigations.count("reconfig_applied") == 0
    assert coord.healthy == {0, 2, 3, 4, 5, 6, 7}


def test_train_loop_lease_gates_pump_and_bootstraps_on_acquire():
    """Election-gated coordination inside train(): with a lease wired, the
    first tick claims the vacant lease, the acquisition triggers exactly
    one bootstrap_from_log, and the pump path then runs normally — the
    phantom's silence is still detected once."""
    from repro.configs import TRAIN_4K, get_config
    from repro.launch.mesh import make_mesh
    from repro.train.loop import TrainConfig, train

    clk = {"t": 0.0}
    worker_end, coord_end = fake_transport_pair()
    coord = ClusterCoordinator(2, clock=lambda: clk["t"],
                               virtual_devices=True)
    coord.submit_foreground(Job("fg", "foreground", GRAPH, amp_limit=1.5))
    mon = HeartbeatMonitor(2, timeout=5.0, clock=lambda: clk["t"])
    loop = CoordinatorLoop(coord_end, mon, coordinator=coord)
    lease = CoordinatorLease(coord_end, 0, timeout=5.0,
                             clock=lambda: clk["t"])
    WorkerClient(worker_end, 1).beat(0)  # phantom: beats once, goes silent

    def advance_clock(step):
        clk["t"] = float(step)

    cfg = get_config("qwen2-1.5b").reduced()
    shape = dataclasses.replace(TRAIN_4K, seq_len=64, global_batch=4,
                                name="smoke")
    tc = TrainConfig(steps=8, coordinator=coord, heartbeat=mon,
                     transport=worker_end, control_loop=loop, lease=lease)
    report = train(cfg, shape, make_mesh(1, 1), tc,
                   fault_injector=advance_clock)
    assert report.steps_done == 8
    assert lease.holder == 0 and lease.epoch == 1
    assert report.mitigations.count("coordinator_failover") == 1
    assert report.mitigations.count("failure_detected") == 1
    assert report.mitigations.count("replan") == 1
    assert coord.healthy == {0}
    assert coord.foreground().plan.num_gpus == 1
