"""Live control-plane transport (repro.dist.transport): ISSUE 7 tentpole.

Pins the transport contract (append-only per-topic logs, cursor-based
polling, deterministic order), the three implementations (InProcessBus,
fake two-endpoint pair with JSON enforcement + disconnect, KVStoreTransport
over an injected KV client), and the consumption path that makes failure
handling LIVE: a worker whose heartbeats stop is detected within the
timeout by ``CoordinatorLoop.pump()`` and the foreground re-plans onto the
exact (non-pow2) surviving pool — no injected events anywhere.
"""
import dataclasses

import pytest

from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.coordinator import ClusterCoordinator, Job
from repro.dist.faults import HeartbeatMonitor, MitigationLog
from repro.dist.transport import (
    HEARTBEAT_TOPIC,
    RECONFIG_TOPIC,
    CoordinatorLoop,
    InProcessBus,
    KVStoreTransport,
    WorkerClient,
    fake_transport_pair,
)
from repro.models.graph import build_vgg_graph

GRAPH = build_vgg_graph(VCFG, 32)


# -- transport contract -----------------------------------------------------


def test_inprocess_bus_publish_poll_since():
    bus = InProcessBus()
    assert bus.poll("t") == []
    assert bus.publish("t", {"a": 1}) == 0
    assert bus.publish("t", {"a": 2}) == 1
    assert bus.publish("other", {"b": 1}) == 0  # per-topic sequences
    msgs = bus.poll("t")
    assert msgs == [(0, {"a": 1}), (1, {"a": 2})]
    # cursor semantics: poll is non-destructive, `since` resumes exactly
    assert bus.poll("t", since=2) == []
    bus.publish("t", {"a": 3})
    assert bus.poll("t", since=2) == [(2, {"a": 3})]
    assert bus.poll("t") == msgs + [(2, {"a": 3})]  # replay from 0 intact


def test_fake_pair_shares_one_log_and_enforces_json():
    w, c = fake_transport_pair()
    w.publish("hb", {"worker": 0, "step": 1})
    assert c.poll("hb") == [(0, {"worker": 0, "step": 1})]
    # payloads must survive a JSON round trip — a real KV store carries
    # strings, so an object-bearing payload must fail HERE, in tests
    with pytest.raises(TypeError):
        w.publish("hb", {"worker": object()})


def test_fake_pair_disconnect_drops_publishes_silently():
    w, c = fake_transport_pair()
    assert w.publish("hb", {"worker": 0, "step": 1}) == 0
    w.disconnect()
    assert w.publish("hb", {"worker": 0, "step": 2}) == -1  # dropped
    assert w.dropped == 1
    assert w.poll("hb") == []  # partitioned endpoint sees nothing either
    assert c.poll("hb") == [(0, {"worker": 0, "step": 1})]
    w.reconnect()
    assert w.publish("hb", {"worker": 0, "step": 3}) == 1
    assert [p["step"] for _s, p in c.poll("hb")] == [1, 3]


class _FakeKVClient:
    """Dict-backed stand-in for jax's DistributedRuntimeClient KV surface."""

    def __init__(self):
        self.store = {}

    def key_value_set(self, key, value):
        self.store[key] = value

    def key_value_dir_get(self, prefix):
        return [(k, v) for k, v in self.store.items() if k.startswith(prefix)]


def test_kvstore_transport_round_trips_over_injected_client():
    client = _FakeKVClient()
    a = KVStoreTransport("test", client=client, uid="host0-1")
    b = KVStoreTransport("test", client=client, uid="host1-1")
    a.publish("hb", {"worker": 0, "step": 1})
    b.publish("hb", {"worker": 1, "step": 1})
    a.publish("hb", {"worker": 0, "step": 2})
    msgs = a.poll("hb")
    # lexicographic key order = (counter, uid): deterministic global order
    assert [p["worker"] for _s, p in msgs] == [0, 1, 0]
    assert [s for s, _p in msgs] == [0, 1, 2]
    assert b.poll("hb", since=2) == [(2, {"step": 2, "worker": 0})]
    # topics are isolated namespaces
    assert a.poll("reconfig") == []


def test_kvstore_transport_requires_jax_distributed():
    # no injected client + jax.distributed never initialized -> hard error
    with pytest.raises(RuntimeError):
        KVStoreTransport("test")


# -- protocol layer ---------------------------------------------------------


def _cluster(n=8, timeout=5.0):
    """Coordinator + monitor + loop over one bus, virtual clock."""
    clk = {"t": 0.0}
    bus = InProcessBus()
    coord = ClusterCoordinator(n, clock=lambda: clk["t"],
                               virtual_devices=True)
    coord.submit_foreground(Job("fg", "foreground", GRAPH, amp_limit=1.5))
    mon = HeartbeatMonitor(n, timeout=timeout, clock=lambda: clk["t"])
    loop = CoordinatorLoop(bus, mon, coordinator=coord, log=MitigationLog())
    workers = [WorkerClient(bus, w) for w in range(n)]
    return clk, bus, coord, mon, loop, workers


def test_live_failure_detection_replans_exact_survivors():
    """THE acceptance path: worker 3's beats stop; pump() detects the loss
    within the timeout and handle_failure re-plans onto the exact non-pow2
    survivor count — driven end-to-end by beats, no injected events."""
    clk, bus, coord, mon, loop, workers = _cluster(n=8, timeout=5.0)
    assert coord.foreground().plan.num_gpus == 8
    for step in range(3):
        clk["t"] = float(step)
        for w in workers:
            w.beat(step)
        assert loop.pump() == []  # everyone fresh: nothing to do
    # worker 3 goes silent; the rest keep beating
    clk["t"] = 4.0
    for w in workers:
        if w.worker_id != 3:
            w.beat(3)
    assert loop.pump() == []  # age(3) = 2.0 < timeout: not failed yet
    clk["t"] = 7.5  # age(3) = 5.5 >= timeout
    for w in workers:
        if w.worker_id != 3:
            w.beat(4)
    events = loop.pump()
    assert len(events) == 1 and events[0]["reason"] == "failure"
    assert events[0]["worker"] == 3
    assert coord.healthy == {0, 1, 2, 4, 5, 6, 7}
    assert coord.foreground().plan.num_gpus == 7  # exact survivors, non-pow2
    assert events[0]["gpus"] == 7
    assert events[0]["devices"] == [0, 1, 2, 4, 5, 6, 7]
    assert loop.log.count("failure_detected") == 1
    assert loop.log.count("replan") == 1
    # detection fires ONCE: the monitor forgot the worker, later pumps with
    # the clock still past its last beat do not re-fire
    clk["t"] = 20.0
    for w in workers:
        if w.worker_id != 3:
            w.beat(5)
    assert loop.pump() == []
    assert loop.log.count("failure_detected") == 1
    # every worker (and any reconfig listener) sees the re-plan event
    wc = workers[0]
    evs = wc.poll_reconfig()
    assert [e["action"] for e in evs] == ["replan"]
    assert wc.poll_reconfig() == []  # cursor advanced


def test_unknown_beat_is_a_join_and_handle_join_is_idempotent():
    clk, bus, coord, mon, loop, workers = _cluster(n=7, timeout=5.0)
    p7 = coord.foreground().plan
    assert p7.num_gpus == 7
    # a beat from an unknown worker id is an explicit join: the monitor
    # registers it and the coordinator re-plans to exploit the new device
    WorkerClient(bus, 7).beat(0)
    events = loop.pump()
    assert mon.n_workers == 8 and 7 in mon.last
    assert coord.healthy == set(range(8))
    assert coord.foreground().plan.num_gpus == 8
    assert [e["reason"] for e in events] == ["join"]
    assert loop.log.count("join") == 1
    n_events = len(coord.events)
    # re-delivered beat from the (now known) worker: no join, no re-plan
    WorkerClient(bus, 7).beat(1)
    assert loop.pump() == []
    assert len(coord.events) == n_events
    # handle_join on already-healthy devices is a no-op (the old code
    # logged a spurious +N join event and re-planned)
    assert coord.handle_join([2, 5]) is None
    assert len(coord.events) == n_events
    assert coord.foreground().plan.num_gpus == 8


def test_straggler_flagging_rearms_on_recovery():
    clk, bus, coord, mon, loop, workers = _cluster(n=4, timeout=100.0)
    for w in workers:
        w.beat(10)
    loop.pump()
    # worker 2 falls behind the front-runner by > lag
    clk["t"] = 1.0
    for w in workers:
        w.beat(2 if w.worker_id == 2 else 12)
    loop.pump()
    assert loop.log.count("straggler_worker") == 1
    # still lagging: no duplicate logs while flagged
    clk["t"] = 2.0
    for w in workers:
        w.beat(3 if w.worker_id == 2 else 13)
    loop.pump()
    assert loop.log.count("straggler_worker") == 1
    # recovers, then lags again -> re-armed, flagged anew
    clk["t"] = 3.0
    for w in workers:
        w.beat(14)
    loop.pump()
    clk["t"] = 4.0
    for w in workers:
        w.beat(5 if w.worker_id == 2 else 15)
    loop.pump()
    assert loop.log.count("straggler_worker") == 2


def test_monitor_join_forget_membership():
    clk = {"t": 0.0}
    mon = HeartbeatMonitor(2, timeout=5.0, clock=lambda: clk["t"])
    # beat from an unregistered worker is a hard error, not silent growth
    with pytest.raises(KeyError):
        mon.beat(5, 0)
    assert mon.join(5) is True and mon.n_workers == 3
    mon.beat(5, 0)  # now fine
    assert mon.join(5) is False  # idempotent re-join
    assert mon.n_workers == 3
    clk["t"] = 10.0
    assert mon.failed() == [0, 1, 5]
    assert mon.forget(5) is True and mon.forget(5) is False
    assert mon.failed() == [0, 1] and mon.n_workers == 2


# -- live train-loop integration --------------------------------------------


def test_train_loop_detects_silent_worker_from_live_beats():
    """End-to-end inside train(): the loop beats over the fake transport,
    the co-hosted CoordinatorLoop consumes them, and a phantom worker whose
    beats stop is detected mid-run — handle_failure fires from the live
    loop (never from the exception path), the fg re-plans onto the exact
    surviving pool, and the reconfig event comes back to the worker."""
    from repro.configs import TRAIN_4K, get_config
    from repro.launch.mesh import make_mesh
    from repro.train.loop import TrainConfig, train

    clk = {"t": 0.0}
    worker_end, coord_end = fake_transport_pair()
    coord = ClusterCoordinator(8, clock=lambda: clk["t"],
                               virtual_devices=True)
    coord.submit_foreground(Job("fg", "foreground", GRAPH, amp_limit=1.5))
    mon = HeartbeatMonitor(2, timeout=5.0, clock=lambda: clk["t"])
    loop = CoordinatorLoop(coord_end, mon, coordinator=coord)
    # the phantom worker (id 1) beats once at t=0, then goes silent
    WorkerClient(worker_end, 1).beat(0)

    def advance_clock(step):
        clk["t"] = float(step)  # the REAL worker (id 0) beats every step

    cfg = get_config("qwen2-1.5b").reduced()
    shape = dataclasses.replace(TRAIN_4K, seq_len=64, global_batch=4,
                                name="smoke")
    tc = TrainConfig(steps=8, coordinator=coord, heartbeat=mon,
                     transport=worker_end, control_loop=loop)
    report = train(cfg, shape, make_mesh(1, 1), tc,
                   fault_injector=advance_clock)
    assert report.steps_done == 8
    # the phantom's silence was detected from live beats: worker 1's device
    # left the pool and the fg re-planned onto the 7 survivors
    assert report.mitigations.count("failure_detected") == 1
    assert report.mitigations.count("replan") == 1
    assert report.mitigations.count("failure") == 0  # NOT the except path
    assert coord.healthy == {0, 2, 3, 4, 5, 6, 7}
    assert coord.foreground().plan.num_gpus == 7
    # the worker saw the pushed-back reconfiguration event
    assert report.mitigations.count("reconfig") == 1
    ev = next(e for e in report.mitigations.events if e["kind"] == "reconfig")
    assert ev["reason"] == "failure" and ev["gpus"] == 7
    # the real worker stayed healthy the whole run
    assert 0 in coord.healthy


def test_train_loop_continuous_admission_resweeps_roster():
    """admit_every triggers coordinator.readmit on the epoch cadence: with
    a pessimistic density-aware model, the sweep rejects the marginal
    tenant (not all-or-nothing) and logs the admission decision."""
    from repro.configs import TRAIN_4K, get_config
    from repro.core.multiplex import InterferenceModel
    from repro.launch.mesh import make_mesh
    from repro.train.loop import TrainConfig, train

    coord = ClusterCoordinator(8, virtual_devices=True)
    coord.submit_foreground(Job("fg", "foreground", GRAPH, amp_limit=1.5))
    coord.interference = InterferenceModel(gap_inflation=1.28,
                                           density_slope=3.0)
    for i in range(3):
        coord.submit_background(Job(f"bg{i}", "background", [], priority=1,
                                    step_fn_factory=lambda mesh: (lambda: None)))
    cfg = get_config("qwen2-1.5b").reduced()
    shape = dataclasses.replace(TRAIN_4K, seq_len=64, global_batch=4,
                                name="smoke")
    tc = TrainConfig(steps=4, coordinator=coord, admit_every=2)
    report = train(cfg, shape, make_mesh(1, 1), tc)
    assert report.steps_done == 4
    decision = coord.last_admission
    assert decision is not None
    # marginal rejection: some but not all tenants admitted
    assert 0 < decision.n_admitted < 3, decision.row()
    # stable roster across the run: the decision is logged as a coordinator
    # event once (first sweep), not once per cadence tick
    admissions = [e for e in coord.events if e.kind == "admission"]
    assert len(admissions) == 1
