"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.mamba2 import ssd_chunked
from repro.models.rwkv6 import wkv6_chunked

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dt):
    return TOL[dt]


@pytest.mark.parametrize("S", [128, 256])
@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 96])
def test_flash_attention_sweep(S, H, KV, dtype, window):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(S * H + KV), 3)
    B, d = 2, 64
    q = jax.random.normal(k0, (B, S, H, d), jnp.float32).astype(dtype)
    k = jax.random.normal(k1, (B, S, KV, d), jnp.float32).astype(dtype)
    v = jax.random.normal(k2, (B, S, KV, d), jnp.float32).astype(dtype)
    o_ref = ref.attention_reference(q, k, v, causal=True, window=window)
    o_pal = ops.attention(q, k, v, causal=True, window=window,
                          force="pallas_interpret")
    np.testing.assert_allclose(
        np.asarray(o_pal, np.float32), np.asarray(o_ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


@pytest.mark.parametrize("S,chunk", [(128, 64), (256, 128)])
@pytest.mark.parametrize("P,N", [(32, 16), (64, 64)])
def test_ssd_sweep(S, chunk, P, N):
    ks = jax.random.split(jax.random.PRNGKey(S + P), 5)
    B, H = 2, 3
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    y_ref = ref.ssd_reference(x, dt, A, Bm, Cm)
    y_pal = ops.ssd(x, dt, A, Bm, Cm, chunk=chunk, force="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=5e-4, rtol=5e-4)
    y_xla = ops.ssd(x, dt, A, Bm, Cm, chunk=chunk)  # CPU jnp path
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_ref),
                               atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("S,chunk", [(128, 64), (192, 64)])
@pytest.mark.parametrize("K,V", [(32, 32), (64, 64)])
def test_wkv6_sweep(S, chunk, K, V):
    ks = jax.random.split(jax.random.PRNGKey(S + K), 5)
    B, H = 2, 3
    r = jax.random.normal(ks[0], (B, S, H, K)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, K)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, V)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, K))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (H, K)) * 0.3
    o_ref = ref.wkv6_reference(r, k, v, w, u)
    o_pal = ops.wkv(r, k, v, w, u, chunk=chunk, force="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               atol=5e-4, rtol=5e-4)


def test_chunked_paths_match_sequential_long():
    """Chunk-boundary correctness over many chunks."""
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    B, S, H, P, N = 1, 512, 2, 16, 8
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.2)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
    y_ref = ref.ssd_reference(x, dt, A, Bm, Cm)
    for chunk in (32, 64, 128):
        y, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)


def test_wkv_init_state_carried():
    """Chunked WKV with an initial state == sequential on concat sequence."""
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    B, S, H, K, V = 1, 128, 2, 16, 16
    r = jax.random.normal(ks[0], (B, S, H, K)) * 0.4
    k = jax.random.normal(ks[1], (B, S, H, K)) * 0.4
    v = jax.random.normal(ks[2], (B, S, H, V)) * 0.4
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, K))) * 0.4 + 0.5
    u = jax.random.normal(ks[4], (H, K)) * 0.3
    o_full = ref.wkv6_reference(r, k, v, w, u)
    half = S // 2
    o1, s1 = wkv6_chunked(r[:, :half], k[:, :half], v[:, :half], w[:, :half], u,
                          chunk=32)
    o2, _ = wkv6_chunked(r[:, half:], k[:, half:], v[:, half:], w[:, half:], u,
                         chunk=32, init_state=s1)
    o_cat = jnp.concatenate([o1, o2], axis=1)
    np.testing.assert_allclose(np.asarray(o_cat), np.asarray(o_full),
                               atol=1e-4, rtol=1e-4)
