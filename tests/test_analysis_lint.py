"""JAX-hazard linter: each corpus file trips exactly its rule, and the
real tree is clean modulo the committed allowlist."""
import pathlib
import subprocess
import sys

import pytest

from repro.analysis.lint import (
    AllowEntry,
    lint_file,
    lint_paths,
    load_allowlist,
)

CORPUS = pathlib.Path(__file__).parent / "analysis_corpus" / "lint"
SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


@pytest.mark.parametrize("fname,rule,min_hits", [
    ("bad_jh001.py", "JH001", 3),       # immediate, in-loop, hot-path
    ("sim/bad_jh002.py", "JH002", 3),   # time.time, time.sleep, from-import
    ("bad_jh003.py", "JH003", 2),
    ("bad_jh004.py", "JH004", 2),
])
def test_corpus_file_trips_exactly_its_rule(fname, rule, min_hits):
    findings = lint_file(CORPUS / fname)
    assert len(findings) >= min_hits, [str(f) for f in findings]
    assert {f.rule for f in findings} == {rule}, [str(f) for f in findings]


def test_jh002_only_applies_to_virtual_clock_modules():
    # the same source outside sim/ (or serve/scheduler.py) is legal:
    # wall-clock reads are only a hazard under deterministic replay
    src = (CORPUS / "sim" / "bad_jh002.py").read_text()
    elsewhere = CORPUS / "sim" / ".." / "jh002_copy_outside_sim.py"
    try:
        elsewhere.write_text(src)
        assert lint_file(elsewhere.resolve()) == []
    finally:
        elsewhere.unlink()


def test_src_tree_is_clean_modulo_allowlist():
    findings, suppressed = lint_paths([str(SRC)])
    assert findings == [], "\n".join(str(f) for f in findings)
    # the two committed intentional sites, nothing more
    assert sorted((s.rule, s.qualname) for s in suppressed) == [
        ("JH001", "_register_bg_jobs"),
        ("JH001", "calibrate_kinds"),
    ]


def test_allowlist_suppression_is_narrow():
    # without the allowlist the two intentional sites surface again —
    # proving the suppression is the allowlist, not a blind spot
    findings, suppressed = lint_paths([str(SRC)], allowlist=[])
    assert suppressed == []
    assert sorted((f.rule, f.qualname) for f in findings) == [
        ("JH001", "_register_bg_jobs"),
        ("JH001", "calibrate_kinds"),
    ]
    # a mismatched qualname does not suppress
    findings, _ = lint_paths(
        [str(SRC)],
        allowlist=[AllowEntry("JH001", "repro/core/profiler.py",
                              "wrong_name", "x")])
    assert any(f.qualname == "calibrate_kinds" for f in findings)


def test_committed_allowlist_entries_are_justified():
    for entry in load_allowlist():
        assert entry.justification, f"{entry} lacks a justification"


def test_cli_exit_codes():
    env_src = str(SRC.parent)
    clean = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(SRC)],
        capture_output=True, text=True, env={"PYTHONPATH": str(SRC)},
        cwd=env_src)
    assert clean.returncode == 0, clean.stdout + clean.stderr
    dirty = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(CORPUS)],
        capture_output=True, text=True, env={"PYTHONPATH": str(SRC)},
        cwd=env_src)
    assert dirty.returncode == 1
    assert "JH00" in dirty.stdout
