"""Continuous batching, paged KV, disaggregation, request-level admission.

ISSUE 9 tentpole coverage: the continuous engine must be greedy-equivalent
to the fixed-batch engine, reuse lanes and pages across a request stream,
be invariant to *which* physical pages a request lands on, place prefill
and decode on verifiably disjoint submeshes, and defer (never drop)
requests the page pool or the admission sweep can't take yet.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.multiplex import BgTenant, Collocator, InterferenceModel, MultiplexConfig
from repro.core.plan import serving_plan
from repro.models import get_model
from repro.serve.engine import ContinuousBatchingEngine, ServingEngine
from repro.serve.kvcache import (
    SCRATCH_PAGE,
    cache_to_pages,
    gather_view,
    init_paged_cache,
    scatter_token,
    write_pages,
)
from repro.serve.scheduler import (
    ContinuousScheduler,
    Request,
    ServingAdmission,
    VirtualClock,
)


@pytest.fixture(scope="module")
def serving_setup(rng):
    cfg = get_config("qwen2-1.5b").reduced()
    api = get_model(cfg)
    params = api.init(rng)
    return cfg, api, params


def _requests(cfg, n, plen=6, max_new=5, stagger=0.0, seed=5):
    gen = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=gen.integers(0, cfg.vocab_size, (plen,), dtype=np.int32),
            max_new_tokens=max_new,
            arrival=stagger * i,
        )
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# Continuous engine
# ---------------------------------------------------------------------------


def test_continuous_matches_fixed_batch_greedy(serving_setup):
    """Same prompts through the paged continuous engine and the contiguous
    fixed-batch engine produce identical greedy tokens."""
    cfg, _, params = serving_setup
    reqs = _requests(cfg, 2)
    fixed = ServingEngine(cfg, params, batch=2, capacity=32)
    want = fixed.generate(np.stack([r.prompt for r in reqs]), 5)
    eng = ContinuousBatchingEngine(cfg, params, debug_checks=True, lanes=2, n_pages=17,
                                   page_tokens=4, lane_capacity=16)
    rep = ContinuousScheduler(eng).run(reqs)
    got = np.stack([np.array(r.tokens) for r in
                    sorted(rep.completed, key=lambda r: r.rid)])
    np.testing.assert_array_equal(got, want)


def test_staggered_arrivals_reuse_lanes_and_pages(serving_setup):
    """More requests than lanes: retired lanes are refilled mid-decode and
    every page returns to the pool afterwards."""
    cfg, _, params = serving_setup
    eng = ContinuousBatchingEngine(cfg, params, debug_checks=True, lanes=2, n_pages=9,
                                   page_tokens=4, lane_capacity=16)
    reqs = _requests(cfg, 5, max_new=4, stagger=1e-4)
    rep = ContinuousScheduler(eng).run(reqs)
    assert len(rep.completed) == 5
    assert all(len(r.tokens) == 4 for r in rep.completed)
    assert eng.stats.prefills == 5  # 5 requests through 2 lanes
    eng.alloc.check_invariants()
    assert eng.alloc.used_pages == 0, "pages not returned on finish"
    # per-request records are monotone: admit <= first token <= finish
    for r in rep.completed:
        assert r.arrival <= r.admitted_at <= r.first_token_at <= r.finished_at


def test_continuous_engine_output_stable_across_lane_assignment(serving_setup):
    """A request's tokens don't depend on which lane/pages it lands on:
    replaying the same trace with different lane counts agrees."""
    cfg, _, params = serving_setup
    outs = []
    for lanes in (2, 3):
        eng = ContinuousBatchingEngine(cfg, params, debug_checks=True, lanes=lanes, n_pages=17,
                                       page_tokens=4, lane_capacity=16)
        rep = ContinuousScheduler(eng).run(_requests(cfg, 4, max_new=4))
        outs.append({r.rid: tuple(r.tokens) for r in rep.completed})
    assert outs[0] == outs[1]


def test_page_pool_exhaustion_defers_never_drops(serving_setup):
    """A pool too small for all requests at once still completes them all —
    requests wait for pages, they are not dropped."""
    cfg, _, params = serving_setup
    # 4 usable pages; each request needs 3 (6 prompt + 4 new over 4-token
    # pages) -> only one fits at a time
    eng = ContinuousBatchingEngine(cfg, params, debug_checks=True, lanes=2, n_pages=5,
                                   page_tokens=4, lane_capacity=12)
    sched = ContinuousScheduler(eng)
    rep = sched.run(_requests(cfg, 3, max_new=4))
    assert len(rep.completed) == 3
    assert rep.page_deferrals > 0
    eng.alloc.check_invariants()
    assert eng.alloc.used_pages == 0


def test_oversize_request_rejected_upfront(serving_setup):
    cfg, _, params = serving_setup
    eng = ContinuousBatchingEngine(cfg, params, debug_checks=True, lanes=1, n_pages=5,
                                   page_tokens=4, lane_capacity=8)
    big = _requests(cfg, 1, plen=7, max_new=8)  # 15 tokens > 8 capacity
    with pytest.raises(ValueError, match="lanes hold"):
        ContinuousScheduler(eng).run(big)


# ---------------------------------------------------------------------------
# Paged gather/scatter
# ---------------------------------------------------------------------------


def test_gather_view_invariant_to_page_permutation(serving_setup):
    """The contiguous view a request sees depends only on its page *table
    order*, not on which physical pages it holds."""
    cfg, api, params = serving_setup
    toks = np.arange(8, dtype=np.int32)[None, :]
    _, cache = api.prefill(params, jnp.asarray(toks), 8)
    chunks = cache_to_pages(cache, 4)  # 2 pages of 4 tokens
    for pages in ([1, 2], [5, 3]):
        pool = write_pages(init_paged_cache(api, 9, 4), pages, chunks)
        view = gather_view(pool, jnp.asarray([pages], jnp.int32))
        v = jax.tree.leaves(view)[0]
        want = jax.tree.leaves(cache)[0]
        np.testing.assert_allclose(np.asarray(v), np.asarray(want))


def test_scatter_token_lands_in_owned_page_only(serving_setup):
    """scatter_token writes lane b's appended KV at (page, offset) of its
    own table; a dead lane (all-scratch table) writes only to scratch."""
    cfg, api, params = serving_setup
    pool = init_paged_cache(api, 9, 4)
    tables = jnp.asarray([[3, 7], [SCRATCH_PAGE, SCRATCH_PAGE]], jnp.int32)
    lens = jnp.asarray([5, 0], jnp.int32)  # lane 0 appends at page 7, slot 1
    view = gather_view(pool, tables)
    view = jax.tree.map(lambda v: v + 1.0, view)  # distinctive nonzero KV
    out = scatter_token(pool, view, tables, lens)
    leaf = np.asarray(jax.tree.leaves(out)[0])
    assert np.all(leaf[:, 7, 1] != 0.0), "live lane's write missing"
    assert np.all(leaf[:, [1, 2, 3, 4, 5, 6, 8]][:, :, [0, 2, 3]] == 0.0)
    assert np.all(leaf[:, 7, [0, 2, 3]] == 0.0)
    # the dead lane's write landed in scratch, nowhere else
    assert np.all(leaf[:, SCRATCH_PAGE, 1:] == 0.0)


# ---------------------------------------------------------------------------
# Disaggregation
# ---------------------------------------------------------------------------


def test_serving_plan_shape():
    plan = serving_plan(8, 3, prefill_time=0.5)
    gaps = plan.gaps()
    assert len(gaps) == 1 and gaps[0].free_gpus == 5
    assert plan.free_device_ranges(0) == [(3, 8)]
    with pytest.raises(ValueError):
        serving_plan(8, 8)
    with pytest.raises(ValueError):
        serving_plan(8, 0)


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices")
def test_split_mesh_for_serving_disjoint():
    from repro.launch.mesh import split_mesh_for_serving

    n = len(jax.devices())
    sm = split_mesh_for_serving(n // 2)
    assert sm.prefill_range == (0, n // 2)
    assert sm.disjoint() and sm.device_sets_disjoint()


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices")
def test_disaggregated_engine_matches_collocated(serving_setup):
    """Prefill on one carving, decode on the other, handoff in between —
    tokens identical to the single-mesh engine."""
    from repro.launch.mesh import split_mesh_for_serving

    cfg, _, params = serving_setup
    sm = split_mesh_for_serving(1, devices=jax.devices()[:2])
    base = ContinuousBatchingEngine(cfg, params, debug_checks=True, lanes=2, n_pages=17,
                                    page_tokens=4, lane_capacity=16)
    want = ContinuousScheduler(base).run(_requests(cfg, 3, max_new=4))
    eng = ContinuousBatchingEngine(cfg, params, debug_checks=True, lanes=2, n_pages=17,
                                   page_tokens=4, lane_capacity=16,
                                   submeshes=sm)
    got = ContinuousScheduler(eng).run(_requests(cfg, 3, max_new=4))
    assert ({r.rid: tuple(r.tokens) for r in got.completed}
            == {r.rid: tuple(r.tokens) for r in want.completed})


# ---------------------------------------------------------------------------
# Request-level admission
# ---------------------------------------------------------------------------


def test_admission_tight_bound_rejects_marginal_request():
    """Under a density-aware interference fit, a tight TTFT SLO admits a
    strict, nonzero prefix of the candidate requests."""
    adm = ServingAdmission(
        8, 4, prefill_time=10e-3, decode_step_time=1e-3,
        ttft_slo=12.4e-3,  # allows 1.24x prefill inflation
        interference=InterferenceModel(gap_inflation=1.2, density_slope=0.5),
    )
    dec = adm.max_concurrent(4)
    assert 0 < dec.n_admitted < 4
    # the bound is respected along the predicted curve
    for k, slowdown, _ in dec.curve[: dec.n_admitted + 1]:
        assert slowdown <= adm.bound + 1e-9


def test_admission_loose_bound_admits_all():
    """With no measured interference, each extra request adds gap work at
    zero predicted cost, so a loose SLO admits every candidate (throughput
    ties go to the larger roster)."""
    adm = ServingAdmission(
        8, 4, prefill_time=10e-3, decode_step_time=1e-3,
        ttft_slo=100e-3, interference=InterferenceModel(),
    )
    assert adm.max_concurrent(4).n_admitted == 4


def test_fit_interference_recovers_base_and_slope():
    iso = 10e-3
    model = InterferenceModel(gap_inflation=1.3, density_slope=0.5)
    samples = [(d, iso * model.gap_inflation_at(0, d)) for d in (1.0, 2.0, 3.0)]
    fit = ServingAdmission.fit_interference(iso, samples)
    assert fit.gap_inflation == pytest.approx(1.3, rel=1e-6)
    assert fit.density_slope == pytest.approx(0.5, rel=1e-6)


def test_scheduler_admission_defers_but_completes(serving_setup):
    """An admission sweep that only allows one concurrent request still
    serves the whole trace (deferred, not dropped)."""
    cfg, _, params = serving_setup
    eng = ContinuousBatchingEngine(cfg, params, debug_checks=True, lanes=3, n_pages=17,
                                   page_tokens=4, lane_capacity=16)
    adm = ServingAdmission(
        8, 4, prefill_time=10e-3, decode_step_time=1e-3,
        ttft_slo=10.5e-3,  # barely above isolated prefill: nearly fg-only
        interference=InterferenceModel(gap_inflation=1.5, density_slope=1.0),
    )
    sched = ContinuousScheduler(eng, admission=adm, clock=VirtualClock())
    rep = sched.run(_requests(cfg, 4, max_new=3))
    assert len(rep.completed) == 4
    assert rep.admission_deferrals > 0
    assert eng.alloc.used_pages == 0


def test_collocator_set_tenants_preserves_state():
    plan = serving_plan(8, 4, prefill_time=10e-3)
    col = Collocator(plan, MultiplexConfig(bg_step_time=1e-3),
                     interference=InterferenceModel(gap_inflation=1.7,
                                                    density_slope=0.3))
    sim, quantum = col._sim, col.bg_step_quantum
    col._deficits[0] = 0.5
    col.set_tenants([BgTenant("b", priority=1), BgTenant("a", priority=5)])
    assert [t.job for t in col.tenants] == ["a", "b"]  # re-sorted
    assert col._sim is sim and col.bg_step_quantum == quantum
    assert col.interference.gap_inflation == 1.7
    assert col._deficits[0] == 0.5  # positional deficits survive re-rostering
    # the re-rostered collocator admits without rebuilds, sweeping the new
    # roster, and the predicted slowdown reflects the preserved 1.7x model
    dec = col.admit(max_fg_slowdown=2.0)
    assert [k for k, _, _ in dec.curve] == [0, 1, 2]
    assert dec.curve[1][1] == pytest.approx(1.7, rel=1e-6)