"""Golden-plan regression + coordinator elasticity round-trip.

Pins the planner invariants the rest of the stack relies on (beyond the
seed's unit tests): the burst plan never loses to the data-parallel
baseline, amplification limits hold per layer, and a failure -> join cycle
through the coordinator restores the original plan bit-for-bit.
"""
import dataclasses

import pytest

from repro.configs import TRAIN_4K, get_config
from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.coordinator import ClusterCoordinator, Job
from repro.core.costmodel import A100
from repro.core.plan import BranchPlacement
from repro.core.planner import plan, plan_data_parallel
from repro.models.graph import (
    build_encdec_graph,
    build_inception_like_graph,
    build_lm_graph,
    build_vgg_graph,
)

AMP_LIMIT = 2.0

GRAPHS = {
    "vgg16": lambda: build_vgg_graph(VCFG, 32),
    "llama3-8b": lambda: build_lm_graph(get_config("llama3-8b"), TRAIN_4K),
}


@pytest.mark.parametrize("arch", sorted(GRAPHS))
@pytest.mark.parametrize("G", [8, 64])
def test_golden_burst_plan_vs_dp(arch, G):
    g = GRAPHS[arch]()
    dp = plan_data_parallel(g, G, hw=A100)
    # DP (all layers at G) is a feasible point of the unconstrained search,
    # so the unconstrained burst plan can never be slower.
    bp_free = plan(g, G, amp_limit=1e9, hw=A100)
    assert bp_free.total_time <= dp.total_time * (1 + 1e-9), (arch, G)
    # The shipped amp limit holds: aggregate strictly, per-layer within the
    # soft-limit fallback bound (`max(bestAmp, AmpLimit)` admits the
    # least-bad predecessor when nothing is feasible — at most +10%).
    bp = plan(g, G, amp_limit=AMP_LIMIT, hw=A100)
    assert bp.amplification <= AMP_LIMIT + 1e-9, (arch, G, bp.amplification)
    assert all(l.amp <= AMP_LIMIT * 1.1 + 1e-9 for l in bp.layers), (arch, G)


def test_golden_vgg_burst_strictly_beats_dp_at_8():
    """Paper Fig 9(a): the amp-limited plan still beats DP for VGG-16@8."""
    g = GRAPHS["vgg16"]()
    bp = plan(g, 8, amp_limit=AMP_LIMIT, hw=A100)
    dp = plan_data_parallel(g, 8, hw=A100)
    assert bp.total_time < dp.total_time
    assert bp.layers[-1].gpus < bp.layers[0].gpus  # late layers scale down


# ---------------------------------------------------------------------------
# Golden DAG plans: branch-parallel placement must not silently regress
# ---------------------------------------------------------------------------


def test_golden_inception_dag_placements():
    """Inception-style DAG at 8 devices: every block plans per-branch device
    ranges — exactly one critical branch at [0, peak), parallel branches on
    disjoint ranges above it, sequential branches reusing [0, peak)."""
    g = build_inception_like_graph(32, n_blocks=3)
    bp = plan(g, 8, amp_limit=AMP_LIMIT, hw=A100)
    blocks = {k: v for k, v in bp.block_details.items() if k.startswith("block")}
    assert sorted(blocks) == ["block0", "block1", "block2"]
    for name, placements in blocks.items():
        assert all(isinstance(p, BranchPlacement) for p in placements)
        assert len(placements) == 4  # the builder's 4 branches
        crits = [p for p in placements if p.critical]
        assert len(crits) == 1 and not crits[0].parallel
        assert crits[0].device_start == 0 and crits[0].device_end == crits[0].gpus
        # critical branch is the slowest
        assert crits[0].time == max(p.time for p in placements)
        occupied = [(crits[0].device_start, crits[0].device_end)]
        for p in placements:
            assert p.gpus >= 1 and p.device_end - p.device_start == p.gpus
            assert len(p.scales) >= 1 and all(s >= 1 for s in p.scales)
            assert p.gpus == max(p.scales)
            if p.parallel:
                # disjoint from the critical branch and every other parallel one
                for lo, hi in occupied:
                    assert p.device_end <= lo or p.device_start >= hi, (name, p)
                occupied.append((p.device_start, p.device_end))
            elif not p.critical:
                assert p.device_start == 0  # sequential: reuses critical range
    # genuine branch parallelism is planned (not everything serialized)
    assert any(p.parallel for ps in blocks.values() for p in ps)
    # placements stay inside the machine, with no demoted-parallel slack
    assert all(p.device_end <= 8 for ps in blocks.values() for p in ps)
    assert bp.placement_slack() == 0.0
    # the plan's foreground layers still cover stem + classifier
    names = [l.name for l in bp.layers]
    assert names[0] == "stem" and names[-1] == "classifier"
    # golden: plan beats flattened DP and respects the amp limit
    dp = plan_data_parallel(g, 8, hw=A100)
    assert bp.total_time <= dp.total_time * (1 + 1e-9)
    assert bp.amplification <= AMP_LIMIT + 1e-9


def test_golden_encdec_cross_edge_plan():
    """Enc-dec two-chain DAG: the resharding join is planned and recorded,
    and the vectorized plan matches the pure-Python oracle bit-for-bit."""
    cfg = get_config("seamless-m4t-large-v2").reduced()
    shape = dataclasses.replace(TRAIN_4K, seq_len=256, global_batch=8, name="encdec-reg")
    eg = build_encdec_graph(cfg, shape)
    bp = plan(eg, 16, amp_limit=AMP_LIMIT, hw=A100)
    ref = plan(eg, 16, amp_limit=AMP_LIMIT, hw=A100, engine="reference")
    assert [l.gpus for l in bp.layers] == [l.gpus for l in ref.layers]
    assert bp.total_time == ref.total_time  # bit-for-bit
    join = bp.block_details["encdec_join"]
    n_enc = join["encoder_layers"]
    assert n_enc == len(eg.encoder) and len(bp.layers) == n_enc + len(eg.decoder)
    # join bookkeeping is consistent with the emitted layers
    assert join["encoder_exit_gpus"] == bp.layers[n_enc - 1].gpus
    assert join["decoder_entry_gpus"] == bp.layers[n_enc].gpus
    assert bp.layers[n_enc].comm_in == join["reshard_time"]
    if join["encoder_exit_gpus"] != join["decoder_entry_gpus"]:
        assert join["reshard_time"] > 0.0
    assert bp.amplification <= AMP_LIMIT + 1e-9
    # the DP baseline (both chains back-to-back at full scale) is a feasible
    # point of the unconstrained search, so it can never win
    bp_free = plan(eg, 16, amp_limit=1e9, hw=A100)
    dp = plan_data_parallel(eg, 16, hw=A100)
    assert bp_free.total_time <= dp.total_time * (1 + 1e-9)


def test_coordinator_failure_join_roundtrip():
    """handle_failure re-plans onto the exact surviving pool (non-pow2 scale
    set, no rounding down to pow2_floor); handle_join restores the original
    plan exactly."""
    coord = ClusterCoordinator(16)
    job = Job("fg", "foreground", GRAPHS["llama3-8b"](), amp_limit=AMP_LIMIT)
    p16 = coord.submit_foreground(job)
    assert p16.num_gpus == 16

    p15 = coord.handle_failure(0)  # 15 healthy -> plan all 15 survivors
    assert p15.num_gpus == 15
    assert p15.total_time >= p16.total_time - 1e-12

    p16b = coord.handle_join([16])  # back to 16 healthy
    assert p16b.num_gpus == 16
    assert p16b.total_time == pytest.approx(p16.total_time, rel=0, abs=0)
    assert [l.gpus for l in p16b.layers] == [l.gpus for l in p16.layers]


def test_non_pow2_pool_plans_most_survivors():
    """ISSUE 6 regression: a 7-device pool (one failure on 8) must plan at
    7 devices with the peak layer on >= 6 of them — not round down to a
    4-device pow2 subset that discards ~half the survivors."""
    from repro.configs.vgg16 import CONFIG as VCFG
    from repro.models.graph import build_vgg_graph

    coord = ClusterCoordinator(8, hw=A100)
    job = Job("fg", "foreground", build_vgg_graph(VCFG, 32), amp_limit=1.5)
    p8 = coord.submit_foreground(job)
    assert p8.num_gpus == 8
    p7 = coord.handle_failure(7)
    assert p7.num_gpus == 7
    assert max(l.gpus for l in p7.layers) >= 6
    # both planner engines agree on the extended (non-pow2) scale set
    ref = plan(build_vgg_graph(VCFG, 32), 7, amp_limit=1.5, hw=A100,
               engine="reference")
    assert [l.gpus for l in ref.layers] == [l.gpus for l in p7.layers]


def test_train_loop_reports_replan_through_coordinator():
    """A loop failure feeds ClusterCoordinator.handle_failure: the healthy
    set shrinks and the mitigation log records the re-plan."""
    import dataclasses

    from repro.launch.mesh import make_mesh
    from repro.train.loop import TrainConfig, train

    cfg = get_config("qwen2-1.5b").reduced()
    shape = dataclasses.replace(TRAIN_4K, seq_len=32, global_batch=2, name="smoke")
    coord = ClusterCoordinator(16)
    coord.submit_foreground(
        Job("fg", "foreground", GRAPHS["llama3-8b"](), amp_limit=AMP_LIMIT)
    )
    fired = {"done": False}

    def injector(step):
        if step == 2 and not fired["done"]:
            fired["done"] = True
            raise RuntimeError("injected device failure")

    tc = TrainConfig(steps=4, coordinator=coord, worker_id=3)
    report = train(cfg, shape, make_mesh(1, 1), tc, fault_injector=injector)
    assert report.steps_done >= 4
    assert report.mitigations.count("failure") == 1
    assert report.mitigations.count("replan") == 1
    assert 3 not in coord.healthy
    assert coord.foreground().plan.num_gpus == 15  # 15 healthy -> plan 15
