"""Unit tests for the trajectory regression gate (benchmarks/check_regression)."""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))

from check_regression import check, goodput_at, load_records, main  # noqa: E402


def _rec(g512, g1024=None):
    curve = [{"devices": 128, "multi_task_goodput": 40.0},
             {"devices": 512, "multi_task_goodput": g512}]
    if g1024 is not None:
        curve.append({"devices": 1024, "multi_task_goodput": g1024})
    return {"curve": curve}


def test_goodput_at_reads_curve_points():
    r = _rec(100.0, 200.0)
    assert goodput_at(r, 512) == 100.0
    assert goodput_at(r, 1024) == 200.0
    assert goodput_at(r, 2048) is None
    assert goodput_at({"curve": []}, 512) is None


def test_single_record_passes_trivially():
    ok, rows = check([_rec(100.0, 200.0)])
    assert ok is True and rows == []
    ok, rows = check([])
    assert ok is True and rows == []


def test_fresh_within_threshold_passes():
    ok, rows = check([_rec(100.0, 200.0), _rec(85.0, 170.0)])
    assert ok is True
    assert [r["devices"] for r in rows] == [512, 1024]
    assert all(r["ok"] for r in rows)


def test_drop_beyond_threshold_fails_per_scale():
    # 512 drops 30% (fails), 1024 holds (passes)
    ok, rows = check([_rec(100.0, 200.0), _rec(70.0, 190.0)])
    assert ok is False
    by_dev = {r["devices"]: r for r in rows}
    assert by_dev[512]["ok"] is False
    assert by_dev[1024]["ok"] is True


def test_baseline_is_best_earlier_point_not_last():
    # trajectory dipped in the middle: the baseline is the MAX of the
    # earlier records, so a fresh point matching the dip still fails
    ok, rows = check([_rec(100.0), _rec(60.0), _rec(65.0)])
    assert ok is False
    assert rows[0]["baseline"] == 100.0 and rows[0]["fresh"] == 65.0


def test_missing_scale_is_skipped_not_failed():
    # earlier records never measured 1024: only 512 is gated
    ok, rows = check([_rec(100.0), _rec(95.0, 300.0)])
    assert ok is True
    assert [r["devices"] for r in rows] == [512]


def test_cli_round_trip_and_exit_codes(tmp_path):
    p = tmp_path / "traj.json"
    p.write_text(json.dumps([_rec(100.0, 200.0), _rec(95.0, 190.0)]))
    assert main(["--file", str(p)]) == 0
    p.write_text(json.dumps([_rec(100.0, 200.0), _rec(50.0, 190.0)]))
    assert main(["--file", str(p)]) == 1
    # custom threshold rescues the same data
    assert main(["--file", str(p), "--threshold", "0.4"]) == 0
    assert load_records(str(p))[0]["curve"][0]["devices"] == 128


def test_committed_trajectory_passes_the_gate():
    """The repo's own committed trajectory must be green under the gate
    that CI enforces."""
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_cluster_sim.json")
    ok, rows = check(load_records(path))
    assert ok is True, rows
