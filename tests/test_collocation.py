"""Executable gap collocation: device-range arithmetic, submesh construction
(disjoint fg/bg sets, BranchPlacement exclusion), elastic re-mesh at
non-power-of-two device counts, and the real dispatch path.

Range-level tests are pure and run everywhere; Mesh-level tests need >1
device and either run in-process (the tier1-multidevice CI job forces 8
host devices) or in a subprocess with a forced device count.
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.costmodel import A100
from repro.core.plan import (
    BranchPlacement,
    BurstPlan,
    LayerPlan,
    complement_ranges,
    map_plan_to_mesh,
    merge_ranges,
)
from repro.core.planner import plan
from repro.models.graph import build_inception_like_graph, build_vgg_graph

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def _ndev():
    import jax

    return len(jax.devices())


# -- range arithmetic (pure) -------------------------------------------------


def test_merge_and_complement_ranges():
    assert merge_ranges([(4, 6), (0, 2), (1, 3)]) == [(0, 3), (4, 6)]
    assert merge_ranges([(2, 2), (5, 4)]) == []  # empty/inverted dropped
    assert complement_ranges([(0, 3), (4, 6)], 8) == [(3, 4), (6, 8)]
    assert complement_ranges([], 4) == [(0, 4)]
    assert complement_ranges([(0, 4)], 4) == []
    assert complement_ranges([(-2, 1), (3, 99)], 4) == [(1, 3)]  # clamped


def _toy_plan(num_gpus=8, block_details=None):
    mk = lambda i, g: LayerPlan(index=i, name=f"l{i}", gpus=g, time=1.0,
                                comp=1.0, sync=0.0, comm_in=0.0, amp=1.0)
    return BurstPlan(
        layers=(mk(0, 2), mk(1, num_gpus)),
        num_gpus=num_gpus,
        amp_limit=2.0,
        single_gpu_time=2.0,
        block_details=block_details or {},
    )


def _placement(start, end, *, parallel=True, critical=False, demoted=False,
               layer_index=-1):
    return BranchPlacement(
        block="b", branch=0, critical=critical, parallel=parallel, time=1.0,
        gpus=end - start, device_start=start, device_end=end,
        scales=(end - start,), demoted=demoted, layer_index=layer_index,
    )


def test_branch_ranges_excluded_from_free_set():
    p = _toy_plan(block_details={"b": (_placement(4, 6),)})
    assert p.branch_device_ranges() == [(4, 6)]
    # stage 0 uses [0, 2); branch holds [4, 6): free = [2,4) + [6,8)
    assert p.free_device_ranges(0) == [(2, 4), (6, 8)]
    assert p.busy_device_ranges(0) == [(0, 2), (4, 6)]
    # full-width stage leaves nothing free
    assert p.free_device_ranges(1) == []


def test_branch_exclusion_is_per_stage():
    """Regression: a branch window is busy only during the stage whose layer
    folds its block — other stages reclaim the range for the gap pool."""
    mk = lambda i, g: LayerPlan(index=i, name=f"l{i}", gpus=g, time=1.0,
                                comp=1.0, sync=0.0, comm_in=0.0, amp=1.0)
    p = BurstPlan(
        layers=(mk(0, 2), mk(1, 4)),
        num_gpus=8,
        amp_limit=2.0,
        single_gpu_time=2.0,
        # block folded into layer 1 (stage 1): devices [5, 7) busy there only
        block_details={"b": (_placement(5, 7, layer_index=1),)},
    )
    # stage 0 (layers 0-0): branches idle -> the window returns to the gap
    assert p.branch_device_ranges(0) == []
    assert p.free_device_ranges(0) == [(2, 8)]  # reclaimed range pinned
    # stage 1 (layers 1-1): branch active -> excluded
    assert p.branch_device_ranges(1) == [(5, 7)]
    assert p.free_device_ranges(1) == [(4, 5), (7, 8)]
    # iteration-wide view (no stage) stays conservative
    assert p.branch_device_ranges() == [(5, 7)]
    # unknown provenance (layer_index=-1) is excluded everywhere
    p2 = BurstPlan(
        layers=(mk(0, 2), mk(1, 4)), num_gpus=8, amp_limit=2.0,
        single_gpu_time=2.0, block_details={"b": (_placement(5, 7),)},
    )
    assert p2.free_device_ranges(0) == [(2, 5), (7, 8)]


def test_planner_assigns_branch_layer_indices():
    """Real planned DAGs tag every placement with its folding layer, so the
    per-stage exclusion actually engages (no -1 conservative fallback)."""
    p = plan(build_inception_like_graph(32, n_blocks=3), 16, amp_limit=2.0,
             hw=A100)
    placements = [
        pl for v in p.block_details.values() if isinstance(v, tuple)
        for pl in v
    ]
    assert placements
    for pl in placements:
        assert 0 <= pl.layer_index < len(p.layers)


def test_critical_and_demoted_branches_do_not_widen_busy_set():
    details = {
        "b": (
            _placement(0, 2, parallel=True, critical=True),  # inside stage
            _placement(3, 5, parallel=False, demoted=True),  # time-muxed
        )
    }
    p = _toy_plan(block_details=details)
    assert p.branch_device_ranges() == []
    assert p.free_device_ranges(0) == [(2, 8)]


def test_map_plan_to_mesh_carries_free_ranges():
    p = _toy_plan(block_details={"b": (_placement(4, 6),)})
    shardings = map_plan_to_mesh(p, {"data": 4, "model": 2})
    assert shardings[0].free_ranges == ((2, 4), (6, 8))
    assert shardings[1].free_ranges == ()
    assert not shardings[0].model_active and shardings[1].model_active


def test_planner_dag_branch_ranges_flow_to_stage_shardings():
    """A real planned DAG: parallel branch placements leave the bg pool of
    exactly the stages whose layers fold them (per-stage exclusion)."""
    p = plan(build_inception_like_graph(32, n_blocks=3), 16, amp_limit=2.0,
             hw=A100)
    for idx in range(len(p.stages())):
        free = p.free_device_ranges(idx)
        branch = p.branch_device_ranges(idx)  # active in THIS stage
        for fs, fe in free:
            for bs, be in branch:
                assert fe <= bs or fs >= be  # disjoint from branch hosts
        # free + busy tile [0, num_gpus) exactly
        busy = p.busy_device_ranges(idx)
        covered = sorted(busy + free)
        assert sum(e - s for s, e in covered) == p.num_gpus
    # per-stage exclusion is no looser than the iteration-wide union: every
    # stage-active branch range appears in the global set
    global_branch = p.branch_device_ranges()
    for idx in range(len(p.stages())):
        for bs, be in p.branch_device_ranges(idx):
            assert any(gs <= bs and be <= ge for gs, ge in global_branch)


def test_coordinator_collocate_fallback_and_validation():
    from repro.core.coordinator import ClusterCoordinator, Job
    from repro.core.multiplex import SimResult

    coord = ClusterCoordinator(4096)  # far more than any host has
    coord.submit_foreground(
        Job("fg", "foreground", build_vgg_graph(VCFG, 32), amp_limit=1.5)
    )
    with pytest.raises(ValueError):
        coord.collocate(executable=True)  # factories are mandatory
    res = coord.collocate(executable=True,
                          make_fg_stage_fn=lambda st, m: (lambda: None),
                          make_bg_step_fn=lambda m: (lambda: None))
    assert isinstance(res, SimResult)  # device shortfall -> sim fallback
    assert any(e.kind == "fallback" for e in coord.events)


def test_split_mesh_rejects_undersized_device_set():
    from repro.launch.mesh import split_mesh_for_plan

    p = plan(build_vgg_graph(VCFG, 32), 8, amp_limit=1.5, hw=A100)
    if _ndev() >= p.num_gpus:
        pytest.skip("process has enough devices; rejection path not reachable")
    with pytest.raises(ValueError):
        split_mesh_for_plan(p)


# -- Mesh-level invariants (>1 device: tier1-multidevice job in-process) -----


def test_submesh_disjointness_multidevice():
    if _ndev() < 8:
        pytest.skip("needs 8 devices (tier1-multidevice job)")
    import jax

    from repro.launch.mesh import split_mesh_for_plan, submesh_from_range

    p = plan(build_vgg_graph(VCFG, 32), 8, amp_limit=1.5, hw=A100)
    split = split_mesh_for_plan(p)
    assert split.bg, "vgg plan should expose gap submeshes"
    fg_devs = list(split.fg_mesh.devices.flat)
    for si, (rng, mesh) in split.bg.items():
        lo, hi = split.stage_fg_range[si]
        stage_fg_ids = {d.id for d in fg_devs[lo:hi]}
        bg_ids = {d.id for d in mesh.devices.flat}
        assert bg_ids and not (stage_fg_ids & bg_ids)
        assert len(bg_ids) == rng[1] - rng[0]
    # explicit range API: adjacent ranges are device-disjoint
    a = submesh_from_range(0, 4)
    b = submesh_from_range(4, 8)
    assert not ({d.id for d in a.devices.flat} & {d.id for d in b.devices.flat})
    with pytest.raises(ValueError):
        submesh_from_range(4, 4)
    with pytest.raises(ValueError):
        submesh_from_range(0, 3, model=2)  # 3 not divisible by model


def test_split_mesh_multi_tenant_disjointness():
    """tenants=k carves each gap into k disjoint per-tenant submeshes, all
    disjoint from the stage's fg window (tier1-multidevice job)."""
    if _ndev() < 8:
        pytest.skip("needs 8 devices (tier1-multidevice job)")
    from repro.launch.mesh import split_mesh_for_plan

    p = plan(build_vgg_graph(VCFG, 32), 8, amp_limit=1.5, hw=A100)
    split = split_mesh_for_plan(p, tenants=2)
    assert split.bg_tenants, "vgg plan should expose tenant submeshes"
    fg_devs = list(split.fg_mesh.devices.flat)
    two_tenant_gaps = 0
    for si, slots in split.bg_tenants.items():
        lo, hi = split.stage_fg_range[si]
        stage_fg_ids = {d.id for d in fg_devs[lo:hi]}
        seen: set = set()
        sizes = []
        for rng, mesh in slots:
            ids = {d.id for d in mesh.devices.flat}
            assert ids and len(ids) == rng[1] - rng[0]
            assert not (ids & stage_fg_ids)   # never on fg devices
            assert not (ids & seen)           # tenants pairwise disjoint
            seen |= ids
            sizes.append(len(ids))
        # priority packing: slot 0 (highest priority) gets the biggest chunk
        assert sizes == sorted(sizes, reverse=True)
        two_tenant_gaps += len(slots) >= 2
        # the legacy single-tenant view mirrors slot 0
        assert split.bg[si] == slots[0]
        assert split.tenant_mesh(si, 0) is slots[0][1]
        assert split.tenant_mesh(si, 99) is None
    assert two_tenant_gaps > 0  # at least one gap big enough to share


def test_largest_pow2_mesh_non_pow2_counts():
    if _ndev() < 8:
        pytest.skip("needs 8 devices (tier1-multidevice job)")
    import jax

    from repro.launch.mesh import largest_pow2_mesh, mesh_axis_sizes

    # non-pow2 survivor counts keep every device a pow2 model width allows
    # (7 -> 7x1, 6 -> 3x2, 5 -> 5x1), instead of rounding down to pow2_floor
    for n, want in ((8, 8), (7, 7), (6, 6), (5, 5), (3, 3), (2, 2), (1, 1)):
        mesh = largest_pow2_mesh(n, devices=jax.devices()[:n])
        sizes = mesh_axis_sizes(mesh)
        assert sizes["data"] * sizes["model"] == want, (n, sizes)
        # survivors only: the mesh never reaches past the first n devices
        assert {d.id for d in mesh.devices.flat} <= {
            d.id for d in jax.devices()[:n]
        }


def test_executable_rotation_unstarves_equal_priority_tenants():
    """Two equal-priority tenants, but every gap packs only ONE chunk: the
    deficit rotation must alternate chunk ownership across iterations so
    both tenants launch real steps (pre-rotation, slot 1 stayed at zero
    forever)."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.multiplex import BgTenant, Collocator, MultiplexConfig
        from repro.core.plan import BurstPlan, LayerPlan

        mk = lambda i, g: LayerPlan(index=i, name=f"l{i}", gpus=g, time=4e-3,
                                    comp=4e-3, sync=0.0, comm_in=0.0, amp=1.0)
        # alternating full/7-wide stages: each gap has exactly 1 free device
        p = BurstPlan(layers=(mk(0, 8), mk(1, 7), mk(2, 8), mk(3, 7)),
                      num_gpus=8, amp_limit=2.0, single_gpu_time=16e-3)
        assert all(g.free_gpus == 1 for g in p.gaps())

        def mk_factory(sig):
            def factory(mesh):
                x = jax.device_put(jnp.ones((16, 16)),
                                   NamedSharding(mesh, P(None, None)))
                f = jax.jit(lambda x: (x @ x).sum())
                return lambda: f(x)
            factory.signature = sig
            return factory

        from repro.core.multiplex import ExecutableCache

        tenants = [BgTenant("ta", 1, mk_factory("A")),
                   BgTenant("tb", 1, mk_factory("B"))]
        cache = ExecutableCache()
        col = Collocator(p, MultiplexConfig(max_inflight=2,
                                            use_feedback=False),
                         tenants=tenants, cache=cache)

        def make_fg(stage, mesh):
            x = jax.device_put(jnp.full((64, 64), 0.01),
                               NamedSharding(mesh, P(None, None)))
            f = jax.jit(lambda x: jnp.tanh(x @ x).sum())
            return lambda: f(x)

        res = col.run_executable(make_fg, iterations=4)
        assert res.iterations >= 4
        # the starvation guard: BOTH tenants ran despite 1 chunk per gap
        for t in res.tenants:
            assert t.bg_steps_per_iter > 0, res.tenants
        # and ownership actually rotated (neither got everything)
        total = sum(t.bg_steps_per_iter for t in res.tenants)
        for t in res.tenants:
            assert t.bg_steps_per_iter < total, res.tenants
        assert res.jain_fairness() > 0.6, res.jain_fairness()

        # second run on the warm cache: rotated combos are cache HITS, not
        # compiles, so iterations must keep their QoS measurements — the
        # per-stage slowdowns (calibration input) cover the gap stages
        col2 = Collocator(p, MultiplexConfig(max_inflight=2,
                                             use_feedback=False),
                          tenants=tenants, cache=cache)
        res2 = col2.run_executable(make_fg, iterations=4)
        assert res2.cache_misses == 0 and res2.cache_hits > 0
        assert {si for si, _ in res2.stage_slowdowns} == \
            {g.stage_index for g in p.gaps()}, res2.stage_slowdowns
        print("OK", [t.bg_steps_per_iter for t in res.tenants])
        """)
    assert "OK" in out


def test_coordinator_admission_rejects_before_compile():
    """A hostile calibrated model must reject tenants BEFORE anything
    compiles: zero executable-cache activity, rejected tenants surfaced on
    the result and as an 'admission' event."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.vgg16 import CONFIG as VCFG
        from repro.core.coordinator import ClusterCoordinator, Job
        from repro.core.multiplex import InterferenceModel, MultiplexConfig
        from repro.models.graph import build_vgg_graph

        built = []

        def mk_factory(sig):
            def factory(mesh):
                built.append(sig)
                x = jax.device_put(jnp.ones((16, 16)),
                                   NamedSharding(mesh, P(None, None)))
                f = jax.jit(lambda x: (x @ x).sum())
                return lambda: f(x)
            factory.signature = sig
            return factory

        def make_fg(stage, mesh):
            x = jax.device_put(jnp.full((64, 64), 0.01),
                               NamedSharding(mesh, P(None, None)))
            f = jax.jit(lambda x: jnp.tanh(x @ x).sum())
            return lambda: f(x)

        coord = ClusterCoordinator(8)
        coord.submit_foreground(
            Job("fg", "foreground", build_vgg_graph(VCFG, 32), amp_limit=1.5)
        )
        for i in range(2):
            coord.submit_background(
                Job(f"bg{i}", "background", [], priority=2 - i,
                    step_fn_factory=mk_factory(f"s{i}"))
            )
        coord.interference = InterferenceModel(gap_inflation=2.0)
        cfg = MultiplexConfig(max_inflight=2, use_feedback=False)
        # seed stale bans for every gap stage (e.g. from a prior simulated
        # run on the shared monitor): run_executable resets these before
        # measuring, so the admission sweep must predict against the SAME
        # reset state — honoring the bans would predict slowdown 1.0 (no
        # collocation) and wrongly admit everyone
        for g in coord.foreground().plan.gaps():
            coord.monitor.banned.add(f"stage{g.stage_index}")
        res = coord.collocate(cfg, executable=True, make_fg_stage_fn=make_fg)
        assert res.iterations == 0               # predicted, never measured
        assert res.fg_slowdown == 1.0            # fg-only operating point
        assert set(res.rejected_tenants) == {"bg0", "bg1"}
        assert built == []                       # nothing compiled
        assert coord.exec_cache.misses == 0 and len(coord.exec_cache.entries) == 0
        assert any(e.kind == "admission" for e in coord.events)
        assert coord.last_admission.n_admitted == 0

        # benign calibration: everyone admitted, tenants actually run
        coord.interference = InterferenceModel()
        res2 = coord.collocate(cfg, executable=True, make_fg_stage_fn=make_fg)
        assert res2.iterations > 0 and res2.rejected_tenants == ()
        assert set(built) == {"s0", "s1"}
        assert coord.last_admission.n_admitted == 2
        print("OK", res2.bg_steps_per_iter)
        """)
    assert "OK" in out


def test_coordinator_collocates_on_survivors_after_low_index_failure():
    """Regression: after device 0 fails, the coordinator's executable
    collocation must carve meshes over the SURVIVORS — never placing fg or
    bg work (or cache entries) back on the dead device."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.vgg16 import CONFIG as VCFG
        from repro.core.coordinator import ClusterCoordinator, Job
        from repro.core.multiplex import MultiplexConfig
        from repro.models.graph import build_vgg_graph

        fg_ids, bg_ids = set(), set()

        def factory(mesh):
            bg_ids.update(d.id for d in mesh.devices.flat)
            x = jax.device_put(jnp.ones((16, 16)),
                               NamedSharding(mesh, P(None, None)))
            f = jax.jit(lambda x: (x @ x).sum())
            return lambda: f(x)
        factory.signature = "s0"

        def make_fg(stage, mesh):
            fg_ids.update(d.id for d in mesh.devices.flat)
            x = jax.device_put(jnp.full((64, 64), 0.01),
                               NamedSharding(mesh, P(None, None)))
            f = jax.jit(lambda x: jnp.tanh(x @ x).sum())
            return lambda: f(x)

        coord = ClusterCoordinator(8)
        coord.submit_foreground(
            Job("fg", "foreground", build_vgg_graph(VCFG, 32), amp_limit=1.5)
        )
        coord.submit_background(
            Job("bg", "background", [], priority=1, step_fn_factory=factory)
        )
        coord.handle_failure(0)
        cfg = MultiplexConfig(max_inflight=2, use_feedback=False)
        res = coord.collocate(cfg, executable=True, make_fg_stage_fn=make_fg,
                              iterations=1)
        dead = jax.devices()[0].id
        assert res.iterations > 0 and res.bg_steps_per_iter > 0
        assert dead not in fg_ids and dead not in bg_ids, (fg_ids, bg_ids)
        assert all(dead not in k[1] for k in coord.exec_cache.entries)
        print("OK", sorted(fg_ids), sorted(bg_ids))
        """)
    assert "OK" in out


def test_executable_collocation_dispatches_real_steps():
    """run_executable on a subprocess with 8 forced host devices: bg steps
    actually execute on gap submeshes and the QoS monitor sees baselines."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.vgg16 import CONFIG as VCFG
        from repro.core.costmodel import A100
        from repro.core.multiplex import Collocator, MultiplexConfig
        from repro.core.planner import plan
        from repro.models.graph import build_vgg_graph

        p = plan(build_vgg_graph(VCFG, 32), 8, amp_limit=1.5, hw=A100)
        col = Collocator(p, MultiplexConfig(max_inflight=2))
        # poison the monitor with simulated-domain state (a shared
        # coordinator monitor fed by MultiplexSim): run_executable must
        # re-derive baselines from wall-clock measurement, not min() with
        # these, or every stage reads as a ~1000x slowdown and gets banned
        col.monitor.record_baseline("stage1", 1e-9)
        col.monitor.ema["stage1"] = 1e-9
        col.monitor.banned.add("stage2")
        bg_devices = set()

        def make_fg(stage, mesh):
            x = jax.device_put(jnp.full((64, 64), 0.01),
                               NamedSharding(mesh, P(None, None)))
            f = jax.jit(lambda x: jnp.tanh(x @ x).sum())
            return lambda: f(x)

        def make_bg(mesh):
            bg_devices.update(d.id for d in mesh.devices.flat)
            x = jax.device_put(jnp.ones((32, 32)),
                               NamedSharding(mesh, P(None, None)))
            f = jax.jit(lambda x: (x @ x).sum())
            return lambda: f(x)

        res = col.run_executable(make_fg, make_bg, iterations=2)
        assert res.bg_steps_per_iter > 0, res
        assert res.fg_iter_time_isolated > 0 and res.fg_iter_time > 0
        assert len(col.monitor.baseline) == len(p.stages())
        assert col.monitor.baseline["stage1"] > 1e-7  # measured, not poisoned
        assert 0 not in bg_devices  # device 0 always hosts fg
        print("OK", res.bg_steps_per_iter)
        """)
    assert "OK" in out
