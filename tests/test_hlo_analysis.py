"""HLO analysis: trip-aware FLOPs/bytes/collectives (the roofline's source)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import _nbytes, analyze_hlo


def test_shape_bytes():
    assert _nbytes("f32[4,8]") == 128
    assert _nbytes("bf16[10]") == 20
    assert _nbytes("(f32[2,2], s32[3])") == 28
    assert _nbytes("pred[]") == 1
    assert _nbytes("no shapes here") == 0


def _compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_scan_trip_multiplied_flops():
    L, B, D = 6, 4, 32

    def f(x, w):
        def body(h, wl):
            return jnp.tanh(h @ wl), None

        h, _ = jax.lax.scan(body, x, w)
        return h.sum()

    txt = _compile_text(
        jax.grad(f, argnums=1),
        jax.ShapeDtypeStruct((B, D), jnp.float32),
        jax.ShapeDtypeStruct((L, D, D), jnp.float32),
    )
    hc = analyze_hlo(txt, default_trip_count=999)
    per_layer_fwd = 2 * B * D * D
    # fwd+bwd ≈ 3 dots per layer; trip count must come from the HLO (6), not
    # the 999 default
    assert per_layer_fwd * L * 2 <= hc.dot_flops <= per_layer_fwd * L * 8
    assert hc.diag["n_while"] >= 1


def test_distinct_trip_counts():
    def f(x):
        def body(h, _):
            return jnp.tanh(h @ h.T @ h * 0.01), None

        h, _ = jax.lax.scan(body, x, None, length=13)
        return h.sum()

    txt = _compile_text(f, jax.ShapeDtypeStruct((8, 8), jnp.float32))
    hc = analyze_hlo(txt, default_trip_count=999)
    per_iter = 2 * (2 * 8 * 8 * 8)
    assert hc.dot_flops == pytest.approx(per_iter * 13, rel=0.01)


def test_no_collectives_single_device():
    txt = _compile_text(lambda x: (x @ x).sum(),
                        jax.ShapeDtypeStruct((16, 16), jnp.float32))
    hc = analyze_hlo(txt)
    assert hc.collective_bytes == 0.0
    assert hc.dot_flops == pytest.approx(2 * 16 ** 3)


def test_bytes_exclude_alias_ops():
    def f(x):
        def body(h, _):
            return h * 2.0, None

        h, _ = jax.lax.scan(body, x, None, length=50)
        return h

    txt = _compile_text(f, jax.ShapeDtypeStruct((1024,), jnp.float32))
    hc = analyze_hlo(txt, default_trip_count=50)
    # real traffic ≈ 50 × 4KB writes; alias/tuple plumbing must not inflate
    # it by orders of magnitude
    assert hc.bytes_written <= 50 * 4096 * 20
