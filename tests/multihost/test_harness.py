"""Pytest entry for the two-process jax.distributed harness.

Opt-in via ``RUN_MULTIHOST=1`` (the tier1-multihost CI job sets it): the
harness spawns two interpreters, initializes a real coordination service
and force-kills one side — too heavy and too environment-sensitive for
the default tier-1 sweep, which covers the same protocol logic against
the in-process and fake transports.
"""
import os
import subprocess
import sys

import pytest

HARNESS = os.path.join(os.path.dirname(__file__), "run_two_proc.py")


@pytest.mark.skipif(os.environ.get("RUN_MULTIHOST") != "1",
                    reason="set RUN_MULTIHOST=1 to run the two-process "
                           "jax.distributed harness")
def test_two_process_failover_harness():
    r = subprocess.run([sys.executable, HARNESS], capture_output=True,
                       text=True, timeout=240)
    assert r.returncode == 0, f"stdout:\n{r.stdout[-4000:]}\n" \
                              f"stderr:\n{r.stderr[-2000:]}"
    assert "HARNESS OK" in r.stdout
