"""Two-process jax.distributed harness: KVStoreTransport end-to-end.

The one place the real multi-host control plane runs against a real
``jax.distributed.initialize()`` coordination service instead of the
dict-backed fake — two processes on localhost, CPU only, each with 4
forced host devices, sharing an 8-worker virtual pool:

  process 0  owns virtual workers {0, 1, 2} (worker 3 of its 4 local
             devices stays spare).  It is the SURVIVOR: it beats its
             workers, watches the lease, and after the coordinator host
             dies it must win the election, bootstrap coordinator state
             from the KV topic log, detect the dead workers, re-plan onto
             the exact non-pow2 survivor pool and re-carve a real mesh.
             It also hosts the jax coordination service (the KV store must
             outlive the kill, so the DOOMED process cannot host it).
  process 1  owns virtual workers {3, 4, 5, 6, 7} and initially holds the
             coordinator lease (it seeds the first claim before process 0
             starts ticking).  Worker 7 never beats — the coordinator must
             *detect* that loss live over the KV transport (churn 1);
             then worker 6 is silenced (churn 2); then the whole process
             force-kills itself via os._exit, taking workers 3-5 and the
             coordinator role with it (churn 3 — the failover).

Assertions (driver-side, on the survivor's output):

  * churn 1 + 2: each silent worker is detected from missing beats and
    re-planned exactly once — reconfig events with devices [0..6] then
    [0..5] arrive at the survivor through the KV store,
  * failover: the survivor's lease tick claims the next epoch, exactly one
    ``coordinator_failover`` bootstrap runs, and the bootstrap adopts the
    old holder's last pool [0..5] — workers 6 and 7 are NEVER re-detected
    (no double-fired mitigations),
  * churn 3: workers 3, 4, 5 are detected by the new holder, the final
    pool is exactly {0, 1, 2} (non-pow2) and the survivor re-carves a
    real 3-device mesh over its local devices (through ``remesh_for_pool``
    + the ``ExecutableCache``) and runs a jitted computation on it,
  * GC: per-pump compaction keeps the KV heartbeat backlog bounded across
    all three churn cycles (low-water advanced, retained keys small).

Run directly (the CI tier1-multihost job does)::

    python tests/multihost/run_two_proc.py

Exit code 0 = all assertions passed.  The whole run finishes in well
under the coordination service's own ~100 s dead-client detection, so the
surviving process never trips on the runtime noticing the kill.
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import time

SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..",
                   "src")

HB_TIMEOUT = 2.0      # monitor: silent worker declared dead after this
LEASE_TIMEOUT = 3.0   # lease: holder declared dead after this
BEAT_PERIOD = 0.1
NS = "mh-harness"
N_VIRTUAL = 8
P0_WORKERS = (0, 1, 2)
P1_WORKERS = (3, 4, 5, 6)   # worker 7 exists in the pool but never beats
DEADLINE = 90.0


def _log(role: int, msg: str) -> None:
    print(f"P{role} {msg}", flush=True)


def _init(role: int, port: int):
    import jax

    jax.distributed.initialize(
        coordinator_address=f"localhost:{port}",
        num_processes=2,
        process_id=role,
    )
    assert len(jax.local_devices()) == 4, jax.local_devices()
    from repro.dist.transport import KVStoreTransport

    return KVStoreTransport(NS, uid=f"p{role}")


def _kv_client():
    from jax._src import distributed

    return distributed.global_state.client


def _sync_set(key: str) -> None:
    _kv_client().key_value_set(f"{NS}/sync/{key}", "1")


def _sync_wait(key: str, timeout_s: float = 30.0) -> None:
    _kv_client().blocking_key_value_get(f"{NS}/sync/{key}",
                                        int(timeout_s * 1000))


# ---------------------------------------------------------------------------
# process 1: initial coordinator host — detects two losses, then dies hard
# ---------------------------------------------------------------------------


def run_coordinator(port: int) -> None:
    transport = _init(1, port)
    from repro.configs.vgg16 import CONFIG as VCFG
    from repro.core.coordinator import ClusterCoordinator, Job
    from repro.dist.faults import HeartbeatMonitor, MitigationLog
    from repro.dist.transport import CoordinatorLease, CoordinatorLoop, \
        WorkerClient
    from repro.models.graph import build_vgg_graph

    coord = ClusterCoordinator(N_VIRTUAL, virtual_devices=True)
    coord.submit_foreground(
        Job("fg", "foreground", build_vgg_graph(VCFG, 32), amp_limit=1.5)
    )
    monitor = HeartbeatMonitor(N_VIRTUAL, timeout=HB_TIMEOUT)
    mlog = MitigationLog()
    cloop = CoordinatorLoop(transport, monitor, coordinator=coord, log=mlog,
                            gc_every=1)
    lease = CoordinatorLease(transport, worker_id=3, timeout=LEASE_TIMEOUT)
    lease.claim()                  # seed the initial holder deterministically
    assert lease.tick(), "seed claim must win"
    _sync_set("lease-seeded")
    workers = {w: WorkerClient(transport, w) for w in P1_WORKERS}

    silenced: set = set()
    replans = 0
    t0 = time.monotonic()
    while time.monotonic() - t0 < DEADLINE:
        for w in P1_WORKERS:
            if w not in silenced:
                workers[w].poll_reconfig()   # ack -> reconfig GC can advance
                workers[w].beat(int((time.monotonic() - t0) / BEAT_PERIOD))
        assert lease.tick(), "nobody can contest a renewed lease"
        for ev in cloop.pump():
            replans += 1
            _log(1, f"REPLAN devices={ev['devices']}")
            if replans == 1:
                # churn 1 handled (worker 7 detected) -> silence worker 6
                assert ev["devices"] == [0, 1, 2, 3, 4, 5, 6], ev
                silenced.add(6)
            elif replans == 2:
                # churn 2 handled (worker 6 detected) -> die without any
                # cleanup: no distributed shutdown, no lease release, no
                # atexit — the forced-kill the failover path must survive
                assert ev["devices"] == [0, 1, 2, 3, 4, 5], ev
                _log(1, "DYING")
                os._exit(42)
        time.sleep(BEAT_PERIOD)
    raise SystemExit("coordinator never reached the kill point")


# ---------------------------------------------------------------------------
# process 0: the survivor — wins the lease, bootstraps, re-carves its mesh
# ---------------------------------------------------------------------------


def run_survivor(port: int) -> None:
    transport = _init(0, port)
    import jax

    from repro.configs.vgg16 import CONFIG as VCFG
    from repro.core.coordinator import ClusterCoordinator, Job
    from repro.core.multiplex import ExecutableCache
    from repro.dist.faults import HeartbeatMonitor, MitigationLog
    from repro.dist.transport import (
        HEARTBEAT_TOPIC,
        CoordinatorLease,
        CoordinatorLoop,
        WorkerClient,
    )
    from repro.launch.mesh import remesh_for_pool
    from repro.models.graph import build_vgg_graph

    _sync_wait("lease-seeded")
    lease = CoordinatorLease(transport, worker_id=0, timeout=LEASE_TIMEOUT)
    workers = {w: WorkerClient(transport, w) for w in P0_WORKERS}

    cloop = None
    mlog = MitigationLog()
    pool = None
    failovers = 0
    t0 = time.monotonic()
    while time.monotonic() - t0 < DEADLINE:
        for w in P0_WORKERS:
            for ev in workers[w].poll_reconfig():
                if w == 0 and ev.get("action") == "replan":
                    pool = [int(d) for d in ev["devices"]]
                    _log(0, f"RECONFIG devices={pool}")
            workers[w].beat(int((time.monotonic() - t0) / BEAT_PERIOD))
        if lease.tick():
            if lease.acquired:
                # failover: fresh coordinator-side state, rebuilt from the
                # topic log (restore_pool adopts the dead holder's last
                # published pool; NO mitigations re-fire for it)
                failovers += 1
                coord = ClusterCoordinator(N_VIRTUAL, virtual_devices=True)
                coord.submit_foreground(Job(
                    "fg", "foreground", build_vgg_graph(VCFG, 32),
                    amp_limit=1.5,
                ))
                monitor = HeartbeatMonitor(0, timeout=HB_TIMEOUT)
                cloop = CoordinatorLoop(transport, monitor, coordinator=coord,
                                        log=mlog, gc_every=1)
                info = cloop.bootstrap_from_log()
                _log(0, f"FAILOVER epoch={lease.epoch} "
                        f"pool={info['pool']}")
            cloop.pump()
        if pool == [0, 1, 2]:
            break
        time.sleep(BEAT_PERIOD)
    # -- the acceptance assertions -----------------------------------------
    assert failovers == 1, f"expected exactly one failover, got {failovers}"
    assert pool == [0, 1, 2], f"never re-planned to the survivor pool: {pool}"
    detected = sorted(e["worker"] for e in mlog.events
                      if e["kind"] == "failure_detected")
    # workers 6 and 7 were handled by the OLD holder — re-detecting them
    # after failover would be a double-fired mitigation
    assert detected == [3, 4, 5], f"double-fired or missed: {detected}"
    assert mlog.count("coordinator_failover") == 1
    # GC kept the heartbeat key log bounded across all three churn cycles
    lw = transport.low_water(HEARTBEAT_TOPIC)
    backlog = len(transport.poll(HEARTBEAT_TOPIC, lw))
    assert lw > 0, "heartbeat topic was never compacted"
    assert backlog <= 64, f"unbounded heartbeat backlog: {backlog}"
    _log(0, f"GC lw={lw} backlog={backlog}")
    # re-carve a REAL mesh over the survivor pool and run on it: the ids
    # map positionally onto this process's local devices
    cache = ExecutableCache()
    mesh = remesh_for_pool(pool, devices=jax.local_devices())
    assert len(mesh.devices.flat) == 3, mesh  # non-pow2 pool kept whole
    key = ExecutableCache.key("harness-step", mesh)

    def build():
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        sh = NamedSharding(mesh, P("data"))
        return jax.jit(lambda x: (x * 2).sum(), in_shardings=sh)

    fn = cache.get_or_build(key, build)
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    x = jax.device_put(jnp.arange(12.0),
                       NamedSharding(mesh, P("data")))
    assert float(fn(x)) == 132.0
    assert cache.get_or_build(key, build) is fn  # cache hit on re-carve
    _log(0, f"REMESH devices={[d.id for d in mesh.devices.flat]} "
            f"shape={tuple(mesh.devices.shape)}")
    _log(0, "HARNESS OK")
    # skip jax's atexit distributed shutdown: its barrier would wait on the
    # killed peer, notice the heartbeat timeout and terminate us fatally —
    # everything is validated, leave without touching the dead runtime
    sys.stdout.flush()
    os._exit(0)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def _free_port() -> int:
    s = socket.socket()
    s.bind(("localhost", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def main() -> int:
    port = _free_port()
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    here = os.path.abspath(__file__)
    procs = [
        subprocess.Popen(
            [sys.executable, here, "--role", str(role), "--port", str(port)],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True,
        )
        for role in (0, 1)
    ]
    outs = []
    try:
        for p in procs:
            out, _ = p.communicate(timeout=DEADLINE + 60)
            outs.append(out)
    except subprocess.TimeoutExpired:
        for p in procs:
            p.kill()
        for p in procs:
            outs.append(p.communicate()[0] or "")
        print("\n".join(outs))
        print("TIMEOUT")
        return 1
    p0_out, p1_out = outs
    print(p1_out)
    print(p0_out)
    ok = True

    def check(cond: bool, what: str) -> None:
        nonlocal ok
        print(f"{'OK  ' if cond else 'FAIL'} {what}")
        ok &= cond

    check(procs[1].returncode == 42, "coordinator died via forced kill")
    check(procs[0].returncode == 0, "survivor exited clean")
    check("REPLAN devices=[0, 1, 2, 3, 4, 5, 6]" in p1_out,
          "churn 1: worker 7 detected over the KV transport")
    check("REPLAN devices=[0, 1, 2, 3, 4, 5]" in p1_out,
          "churn 2: worker 6 detected, then forced kill")
    check("FAILOVER" in p0_out and "pool=[0, 1, 2, 3, 4, 5]" in p0_out,
          "survivor won the lease and adopted the dead holder's pool")
    check("RECONFIG devices=[0, 1, 2]" in p0_out,
          "churn 3: re-planned onto the exact non-pow2 survivor pool")
    check("REMESH devices=[0, 1, 2] shape=(3, 1)" in p0_out,
          "mesh actually re-carved over the survivors")
    check("HARNESS OK" in p0_out, "all survivor-side assertions held")
    print(f"two-process harness: {'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--role", type=int, default=None)
    ap.add_argument("--port", type=int, default=None)
    args = ap.parse_args()
    if args.role is None:
        sys.exit(main())
    elif args.role == 1:
        run_coordinator(args.port)
    else:
        run_survivor(args.port)  # exits via os._exit(0)
