"""PowerSGD gradient compression + error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.grad_compression import (
    compress_decompress,
    compression_ratio,
    init_state,
)


def test_low_rank_exact_for_low_rank_matrix():
    """A rank-2 gradient is reconstructed (nearly) exactly at rank >= 2
    after a couple of power iterations."""
    k = jax.random.PRNGKey(0)
    u = jax.random.normal(k, (32, 2))
    v = jax.random.normal(jax.random.fold_in(k, 1), (16, 2))
    g = {"w": u @ v.T}
    st = init_state(g, rank=4)
    for _ in range(3):
        approx, st = compress_decompress(g, st, rank=4)
    err = jnp.linalg.norm(approx["w"] - g["w"]) / jnp.linalg.norm(g["w"])
    assert float(err) < 1e-3


def test_error_feedback_accumulates():
    """With error feedback, repeated application of the SAME gradient
    transfers all of it over time (sum of approximations -> k*g)."""
    k = jax.random.PRNGKey(1)
    g = {"w": jax.random.normal(k, (24, 24))}
    st = init_state(g, rank=2)
    # single-shot error (no feedback accumulation)
    one, _ = compress_decompress(g, init_state(g, rank=2), rank=2)
    err_one = float(jnp.linalg.norm(one["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
    total = jnp.zeros_like(g["w"])
    K = 30
    for _ in range(K):
        approx, st = compress_decompress(g, st, rank=2)
        total = total + approx["w"]
    err = float(jnp.linalg.norm(total / K - g["w"]) / jnp.linalg.norm(g["w"]))
    assert err < err_one * 0.5, (err, err_one)  # feedback transfers the residual
    assert err < 0.3


def test_rank_improves_fidelity():
    k = jax.random.PRNGKey(2)
    g = {"w": jax.random.normal(k, (32, 32))}
    errs = []
    for rank in (1, 4, 16):
        st = init_state(g, rank=rank)
        for _ in range(2):
            approx, st = compress_decompress(g, st, rank=rank)
        errs.append(float(jnp.linalg.norm(approx["w"] - g["w"])))
    assert errs[0] > errs[1] > errs[2]


def test_small_leaves_exact():
    g = {"b": jnp.arange(3.0)}
    st = init_state(g, rank=4)
    approx, _ = compress_decompress(g, st, rank=4)
    np.testing.assert_allclose(np.asarray(approx["b"]), np.asarray(g["b"]))


def test_compression_ratio():
    params = {"w": jnp.zeros((1024, 1024)), "b": jnp.zeros((4,))}
    r = compression_ratio(params, rank=4)
    assert r < 0.02  # 4*(1024+1024) / 1024^2 ≈ 0.008
