"""Trace schema validator: committed traces are clean, corpus traces are
flagged with the expected check codes, and round-tripped generator output
always validates."""
import json
import pathlib

import pytest

from repro.analysis.tracecheck import check_paths, check_trace_file

REPO = pathlib.Path(__file__).resolve().parents[1]
TRACES = REPO / "benchmarks" / "traces"
CORPUS = pathlib.Path(__file__).parent / "analysis_corpus" / "traces"


def test_committed_traces_are_clean():
    violations = check_paths([str(TRACES)])
    assert violations == [], "\n".join(str(v) for v in violations)


@pytest.mark.parametrize("fname,expected", [
    ("bad_version.json", {"trace-version"}),
    ("bad_kind.json", {"trace-event-kind"}),
    ("bad_device_range.json", {"trace-device-range"}),
    ("bad_order.json", {"trace-order"}),
    # lease_churn carrying a device + job_arrival missing its weight
    ("bad_payload.json", {"trace-field"}),
    ("bad_requests.json", {"req-top", "req-id", "req-order", "req-row"}),
])
def test_corpus_trace_is_flagged(fname, expected):
    violations = check_trace_file(CORPUS / fname)
    assert violations, fname
    codes = {v.check for v in violations}
    assert codes == expected, (fname, codes)


def test_unknown_shape_is_flagged(tmp_path):
    p = tmp_path / "mystery.json"
    p.write_text('{"data": []}')
    assert {v.check for v in check_trace_file(p)} == {"trace-kind"}
    p.write_text("not json at all {")
    assert {v.check for v in check_trace_file(p)} == {"trace-json"}


def test_generator_output_always_validates(tmp_path):
    """Whatever the trace generators emit must satisfy the schema — the
    validator and the generators may never drift apart."""
    from repro.sim.trace import (
        generate_failure_storm,
        generate_heartbeat_loss,
        generate_lease_churn,
        generate_trace,
        save_trace,
    )

    cases = {
        "gen.json": generate_trace(16, seed=3, horizon=60.0),
        "storm.json": generate_failure_storm(16, seed=5),
        "hb.json": generate_heartbeat_loss(16, seed=7),
        "lease.json": generate_lease_churn(16, seed=9),
    }
    for fname, trace in cases.items():
        save_trace(trace, tmp_path / fname)
    violations = check_paths([str(tmp_path)])
    assert violations == [], "\n".join(str(v) for v in violations)


def test_request_trace_generator_validates(tmp_path):
    from repro.serve.trace import generate_request_trace, save_request_trace

    trace = generate_request_trace(seed=11, qps=5.0, n_requests=20,
                                   vocab_size=64)
    p = tmp_path / "reqs.json"
    save_request_trace(trace, p)
    violations = check_paths([str(p)])
    assert violations == [], "\n".join(str(v) for v in violations)


def test_mutated_committed_trace_is_caught(tmp_path):
    """Seed a single-field corruption of a real committed trace — the
    validator must notice (guards against schema drift that silently
    accepts everything)."""
    doc = json.loads((TRACES / "heartbeat_loss_128.json").read_text())
    ev = next(e for e in doc["events"] if e["kind"] == "heartbeat_loss")
    ev["device"] = doc["n_devices"]  # one past the pool
    p = tmp_path / "mutated.json"
    p.write_text(json.dumps(doc))
    assert any(v.check == "trace-device-range"
               for v in check_trace_file(p))
