"""Golden-violations corpus for ``repro.analysis.verify``.

One minimal bad example per constructible verifier check: each entry in
``CASES`` is ``(expected_check, thunk)`` where the thunk runs the verifier
on a deliberately broken input and returns the violation list.  The test
asserts every case yields at least one violation with exactly the expected
check code — and that the same verifier stays clean on real planner output
(``tests/test_analysis_verify.py``).

Checks derived *from* the plan itself (``stage-cover``, ``gap-stage``,
``free-busy``, ``carve-*``) guard against drift between ``BurstPlan``'s
range algebra and the verifier's re-derivation; they cannot be seeded by
constructing a plan (the properties hold by construction) and are covered
by the randomized sweep instead.
"""
from types import SimpleNamespace

import numpy as np

from repro.analysis.verify import (
    verify_plan,
    verify_serving_submeshes,
    verify_stage_shardings,
    verify_submeshes,
)
from repro.core.plan import BranchPlacement, BurstPlan, LayerPlan, StageSharding


def _layer(index=0, gpus=1, *, time=1.0, amp=1.0, name=None):
    return LayerPlan(index=index, name=name or f"l{index}", gpus=gpus,
                     time=time, comp=time, sync=0.0, comm_in=0.0, amp=amp)


def _plan(layers, num_gpus=4, amp_limit=8.0, single_gpu_time=None,
          block_details=None):
    if single_gpu_time is None:
        single_gpu_time = sum(l.time for l in layers) or 1.0
    return BurstPlan(layers=tuple(layers), num_gpus=num_gpus,
                     amp_limit=amp_limit, single_gpu_time=single_gpu_time,
                     block_details=block_details or {})


def _branch(start, end, *, branch=1, block="blk", layer_index=0):
    return BranchPlacement(
        block=block, branch=branch, critical=False, parallel=True,
        time=1.0, gpus=end - start, device_start=start, device_end=end,
        scales=(end - start,), layer_index=layer_index)


def _good_plan(num_gpus=4):
    # stage 0 at scale 2, stage 1 at full scale — one real gap
    return _plan([_layer(0, 2), _layer(1, num_gpus)], num_gpus=num_gpus)


def _fake_mesh(n):
    return SimpleNamespace(devices=np.empty((n,), dtype=np.int8))


# -- verify_plan ------------------------------------------------------------

def bad_plan_empty():
    return verify_plan(_plan([], num_gpus=4))


def bad_plan_pool():
    return verify_plan(_plan([_layer(0, 1)], num_gpus=0))


def bad_layer_bounds():
    # a layer claiming more devices than the plan's pool
    return verify_plan(_plan([_layer(0, 8)], num_gpus=4))


def bad_layer_amp():
    return verify_plan(_plan([_layer(0, 1, amp=float("inf"))]))


def bad_layer_amp_soft_limit():
    # finite but past amp_limit * 1.1 — only the strict (chain-planner)
    # contract flags it
    return verify_plan(
        _plan([_layer(0, 1, amp=1.0), _layer(1, 1, amp=5.0)], amp_limit=2.0,
              single_gpu_time=100.0),
        strict_layer_amp=True)


def bad_plan_amp():
    # 4 devices the whole time over a single-gpu baseline of the same
    # duration: aggregate amplification 4 > limit 2
    return verify_plan(
        _plan([_layer(0, 4)], num_gpus=4, amp_limit=2.0,
              single_gpu_time=1.0))


def bad_pool_exact():
    # 7 survivors must be planned as 7, never rounded down
    return verify_plan(_good_plan(num_gpus=4), pool_size=7)


def bad_branch_bounds():
    return verify_plan(_plan(
        [_layer(0, 2), _layer(1, 4)], num_gpus=4,
        block_details={"blk": (_branch(3, 6),)}))


def bad_branch_overlap_fg():
    # parallel branch leaking into the fg window [0, 2) of its host stage
    return verify_plan(_plan(
        [_layer(0, 2), _layer(1, 4)], num_gpus=4,
        block_details={"blk": (_branch(1, 3),)}))


def bad_branch_overlap_pair():
    # two parallel branches of the SAME block sharing device 4
    return verify_plan(_plan(
        [_layer(0, 2), _layer(1, 8)], num_gpus=8,
        block_details={"blk": (_branch(2, 5, branch=1),
                               _branch(4, 7, branch=2))}))


# -- verify_submeshes -------------------------------------------------------

def _fake_submeshes(plan, **kw):
    peak = max(s.gpus for s in plan.stages())
    base = dict(fg_range=(0, peak), fg_mesh=_fake_mesh(peak),
                bg={}, bg_tenants={})
    base.update(kw)
    return SimpleNamespace(**base)


def bad_submesh_fg():
    plan = _good_plan()
    return verify_submeshes(plan, _fake_submeshes(
        plan, fg_range=(1, 3), fg_mesh=_fake_mesh(2)))


def bad_submesh_size():
    plan = _good_plan()
    peak = max(s.gpus for s in plan.stages())
    return verify_submeshes(plan, _fake_submeshes(
        plan, fg_mesh=_fake_mesh(peak + 1)))


def bad_submesh_stage():
    plan = _good_plan()
    return verify_submeshes(plan, _fake_submeshes(
        plan, bg_tenants={9: [((2, 4), _fake_mesh(2))]}))


def bad_submesh_overlap():
    # tenant chunk overlapping the stage-0 fg window [0, 2)
    plan = _good_plan()
    sub = _fake_submeshes(
        plan,
        bg={0: ((1, 3), _fake_mesh(2))},
        bg_tenants={0: [((1, 3), _fake_mesh(2))]})
    return verify_submeshes(plan, sub)


def bad_submesh_bounds():
    plan = _good_plan()
    sub = _fake_submeshes(
        plan,
        bg={0: ((2, 6), _fake_mesh(4))},
        bg_tenants={0: [((2, 6), _fake_mesh(4))]})
    return verify_submeshes(plan, sub)


def bad_submesh_slot0():
    # the plain bg carving must be one of the per-tenant slots
    plan = _good_plan()
    sub = _fake_submeshes(
        plan,
        bg={0: ((2, 3), _fake_mesh(1))},
        bg_tenants={0: [((3, 4), _fake_mesh(1))]})
    return verify_submeshes(plan, sub)


# -- verify_serving_submeshes ----------------------------------------------

def bad_serving_bounds():
    sub = SimpleNamespace(prefill_range=(0, 5), prefill_mesh=_fake_mesh(5),
                          decode_range=(5, 8), decode_mesh=_fake_mesh(3))
    return verify_serving_submeshes(sub, n_devices=6)


def bad_serving_overlap():
    sub = SimpleNamespace(prefill_range=(0, 3), prefill_mesh=_fake_mesh(3),
                          decode_range=(2, 6), decode_mesh=_fake_mesh(4))
    return verify_serving_submeshes(sub, n_devices=6)


def bad_serving_size():
    sub = SimpleNamespace(prefill_range=(0, 2), prefill_mesh=_fake_mesh(3),
                          decode_range=(2, 6), decode_mesh=_fake_mesh(4))
    return verify_serving_submeshes(sub, n_devices=6)


# -- verify_stage_shardings -------------------------------------------------

def _sharding(plan, si, batch_axes=("data",), free=None):
    st = plan.stages()[si]
    if free is None:
        free = tuple(plan.free_device_ranges(si))
    return StageSharding(stage=st, batch_axes=tuple(batch_axes),
                         model_active=True, free_ranges=tuple(free))


def bad_sharding_count():
    plan = _good_plan()
    return verify_stage_shardings(
        plan, [_sharding(plan, 0)], {"data": 2, "model": 2})


def bad_sharding_axis():
    plan = _good_plan()
    shs = [_sharding(plan, 0, batch_axes=("replica",)),
           _sharding(plan, 1)]
    return verify_stage_shardings(plan, shs, {"data": 2, "model": 2})


def bad_sharding_free():
    plan = _good_plan()
    shs = [_sharding(plan, 0, free=((0, 1),)), _sharding(plan, 1)]
    return verify_stage_shardings(plan, shs, {"data": 2, "model": 2})


CASES = [
    ("plan-empty", bad_plan_empty),
    ("plan-pool", bad_plan_pool),
    ("layer-bounds", bad_layer_bounds),
    ("layer-amp", bad_layer_amp),
    ("layer-amp", bad_layer_amp_soft_limit),
    ("plan-amp", bad_plan_amp),
    ("pool-exact", bad_pool_exact),
    ("branch-bounds", bad_branch_bounds),
    ("branch-overlap", bad_branch_overlap_fg),
    ("branch-overlap", bad_branch_overlap_pair),
    ("submesh-fg", bad_submesh_fg),
    ("submesh-size", bad_submesh_size),
    ("submesh-stage", bad_submesh_stage),
    ("submesh-overlap", bad_submesh_overlap),
    ("submesh-bounds", bad_submesh_bounds),
    ("submesh-slot0", bad_submesh_slot0),
    ("serving-bounds", bad_serving_bounds),
    ("serving-overlap", bad_serving_overlap),
    ("serving-size", bad_serving_size),
    ("sharding-count", bad_sharding_count),
    ("sharding-axis", bad_sharding_axis),
    ("sharding-free", bad_sharding_free),
]
