"""Corpus: wall-clock reads in a virtual-clock (sim/) module."""
import time
from time import sleep  # noqa: F401  (flagged: from-import of sleep)


def advance(events):
    now = time.time()  # flagged: wall clock in a deterministic replay
    time.sleep(0.01)   # flagged
    return [e for e in events if e.t <= now]
