"""Corpus: Python assert over traced jax/jnp values."""
import jax
import jax.numpy as jnp


def loss(params, x):
    y = jnp.dot(params, x)
    assert jnp.all(jnp.isfinite(y)), "non-finite activations"  # flagged
    assert jax.numpy.sum(y) > 0  # flagged
    return y
