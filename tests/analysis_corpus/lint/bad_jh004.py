"""Corpus: PartitionSpec axes outside the {pod, data, model} vocabulary."""
from jax.sharding import PartitionSpec as P


def specs():
    a = P("data", "modle")          # flagged: typo'd axis
    b = P(("pod", "replica"), None)  # flagged: unknown axis in a tuple dim
    return a, b
