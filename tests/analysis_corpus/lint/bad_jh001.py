"""Corpus: every JH001 jit-retracing shape the linter must flag."""
import jax


def run_immediate(f, x):
    # compiled callable discarded after one call
    return jax.jit(f)(x)


def build_all(fns):
    out = []
    for f in fns:
        g = jax.jit(f)  # plain-name bind inside a loop: recompiles each time
        out.append(g)
    return out


def decode_step(f, x):
    g = jax.jit(f)  # per-step function body, no attribute/subscript cache
    return g(x)
