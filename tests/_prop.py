"""Thin fallback for ``hypothesis`` when it is not installed.

The property-test modules import ``given/settings/strategies`` from
``hypothesis`` when available and from here otherwise.  This shim replays a
fixed number of deterministic pseudo-random examples per property (seeded
``random.Random``), so tier-1 collection and the properties' invariants
still run — just without shrinking or coverage-guided generation.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys

N_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rnd: random.Random):
        return self._draw(rnd)


def integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value, max_value):
    # log-uniform across wide ranges (hypothesis-ish coverage of magnitudes)
    import math

    lo, hi = math.log(min_value), math.log(max_value)
    return _Strategy(lambda r: math.exp(r.uniform(lo, hi)))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


def lists(elements, min_size=0, max_size=10):
    return _Strategy(
        lambda r: [elements.draw(r) for _ in range(r.randint(min_size, max_size))]
    )


def builds(target, *args):
    return _Strategy(lambda r: target(*[a.draw(r) for a in args]))


def settings(**_kwargs):
    def deco(fn):
        return fn

    return deco


def given(*strategies_):
    def deco(fn):
        @functools.wraps(fn)
        def run():
            rnd = random.Random(0)
            for _ in range(N_EXAMPLES):
                fn(*[s.draw(rnd) for s in strategies_])

        # pytest must not mistake the property's params for fixtures
        del run.__wrapped__
        run.__signature__ = inspect.Signature()
        return run

    return deco


# allow `from _prop import strategies as st`
strategies = sys.modules[__name__]
