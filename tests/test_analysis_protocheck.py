"""Transport protocol model checker: clean on the real protocol objects,
and each seeded mutant (a real PR 7-8 bug class) is re-detected."""
import pytest

from repro.analysis.protocheck import MUTANTS, ProtocolModel, explore
from repro.dist.faults import HeartbeatMonitor
from repro.dist.transport import (
    HEARTBEAT_TOPIC,
    CoordinatorLoop,
    WorkerClient,
    fake_transport_pair,
)


def test_clean_protocol_has_no_violations():
    report = explore(n_workers=2, depth=3, samples=300)
    assert report.ok, "\n".join(str(v) for v in report.violations)
    assert report.schedules > 1000


def test_clean_protocol_three_workers():
    report = explore(n_workers=3, depth=2, samples=150)
    assert report.ok, "\n".join(str(v) for v in report.violations)


@pytest.mark.parametrize("name", sorted(MUTANTS))
def test_mutant_is_detected(name):
    """Each mutant re-introduces a shipped bug class; the checker must
    find a schedule that trips the matching property."""
    report = explore(n_workers=2, depth=2, samples=2000, mutant=name)
    assert not report.ok, f"mutant {name} survived {report.schedules} schedules"
    expect = {
        "cursor-reread": "proto-cursor",
        "adopt-skip": "proto-mitigation",
        "gc-head": "proto-pool-of-record",
    }[name]
    codes = {v.check for v in report.violations}
    assert expect in codes, (name, codes)
    assert report.failing_schedule is not None  # reproducible witness


def test_exploration_is_deterministic():
    a = explore(n_workers=2, depth=2, samples=50, mutant="cursor-reread")
    b = explore(n_workers=2, depth=2, samples=50, mutant="cursor-reread")
    assert a.failing_schedule == b.failing_schedule
    assert a.schedules == b.schedules


def test_failing_schedule_replays():
    report = explore(n_workers=2, depth=2, samples=2000,
                     mutant="cursor-reread")
    assert report.failing_schedule is not None
    replay = ProtocolModel(2, MUTANTS["cursor-reread"]).run_schedule(
        report.failing_schedule)
    assert {v.check for v in replay} == {v.check for v in report.violations}


def test_bootstrap_after_full_hb_compaction_regression():
    """Flushed out by the model checker: a failover holder whose
    predecessor compacted the entire heartbeat log (and no beat arrived
    since) must not leave its cursor below low-water — the first pump()
    on a strict transport would raise instead of resuming."""
    worker_end, coord_end = fake_transport_pair()
    WorkerClient(worker_end, 0).beat(1)

    old = CoordinatorLoop(coord_end, HeartbeatMonitor(1, timeout=10.0))
    old.pump()
    old.gc()  # hb log fully compacted to the old holder's cursor
    assert coord_end.low_water(HEARTBEAT_TOPIC) == 1

    new = CoordinatorLoop(coord_end, HeartbeatMonitor(1, timeout=10.0))
    new.bootstrap_from_log()
    assert new._seen_beats >= coord_end.low_water(HEARTBEAT_TOPIC)
    new.pump()  # strict transport: raised before the fix
