"""Checkpoint: atomic save, keep-k GC, restore, cursor round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


def _state(v=0.0):
    return {
        "params": {"w": jnp.full((4, 4), v), "b": jnp.full((4,), v)},
        "opt": {"m": {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))},
                "count": jnp.int32(3)},
        "step": jnp.int32(7),
    }


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    s = _state(1.5)
    ckpt.save(d, s, step=10, async_=False, extra_meta={"data": {"seed": 0, "step": 10}})
    restored, meta = ckpt.restore(d, _state(0.0))
    assert meta["step"] == 10 and meta["data"]["step"] == 10
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(s["params"]["w"]))
    assert int(restored["opt"]["count"]) == 3


def test_keep_k_gc(tmp_path):
    d = str(tmp_path / "ck")
    for step in (1, 2, 3, 4, 5):
        ckpt.save(d, _state(float(step)), step=step, keep=2, async_=False)
    assert ckpt.latest_step(d) == 5
    steps = [int(n.split("_")[1]) for n in os.listdir(d) if n.startswith("step_")]
    assert sorted(steps) == [4, 5]


def test_restore_latest_of_many(tmp_path):
    d = str(tmp_path / "ck")
    for step in (3, 9, 6):
        ckpt.save(d, _state(float(step)), step=step, keep=10, async_=False)
    restored, meta = ckpt.restore(d, _state(0.0))
    assert meta["step"] == 9
    assert float(restored["params"]["w"][0, 0]) == 9.0


def test_restore_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, _state(1.0), step=1, async_=False)
    bad = _state(0.0)
    bad["params"]["w"] = jnp.zeros((2, 2))
    with pytest.raises(ValueError):
        ckpt.restore(d, bad)


def test_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "nope"), _state())


def test_atomic_no_partial(tmp_path):
    """A tmp dir from a crashed save is never visible as a checkpoint."""
    d = str(tmp_path / "ck")
    os.makedirs(os.path.join(d, "step_0000000099.tmp"))
    assert ckpt.latest_step(d) is None
    ckpt.save(d, _state(2.0), step=1, async_=False)
    assert ckpt.latest_step(d) == 1
