"""Serving engine: generation shapes, greedy consistency, stats, and the
fixed-batch engine's regression fixes (stale cache, trailing decode,
post-EOS masking, prefill retracing, token-based throughput) plus the
paged-allocator invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.serve.engine import ServingEngine
from repro.serve.kvcache import PageAllocator, cache_bytes, init_cache


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "rwkv6-1.6b"])
def test_generate_shapes(arch, rng):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(rng)
    eng = ServingEngine(cfg, params, batch=2, capacity=64)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8),
                                                dtype=np.int32)
    out = eng.generate(prompts, max_new_tokens=6)
    assert out.shape == (2, 6)
    assert eng.stats.decode_steps > 0


def test_greedy_matches_forward_argmax(rng):
    """Greedy first token == argmax of teacher-forcing logits (fp32)."""
    cfg = dataclasses.replace(get_config("llama3-8b").reduced(), dtype="float32")
    api = get_model(cfg)
    params = api.init(rng)
    toks = jax.random.randint(rng, (1, 8), 0, cfg.vocab_size, jnp.int32)
    full = api.forward(params, toks)
    want = int(jnp.argmax(full[0, -1]))
    eng = ServingEngine(cfg, params, batch=1, capacity=32)
    out = eng.generate(np.asarray(toks), max_new_tokens=1)
    assert int(out[0, 0]) == want


def test_cache_bytes_scales_with_capacity():
    cfg = get_config("llama3-8b").reduced()
    api = get_model(cfg)
    b64 = cache_bytes(api, 2, 64)
    b128 = cache_bytes(api, 2, 128)
    assert b128 == 2 * b64


def test_rwkv_cache_capacity_free():
    cfg = get_config("rwkv6-1.6b").reduced()
    api = get_model(cfg)
    assert cache_bytes(api, 2, 64) == cache_bytes(api, 2, 4096)  # O(1) state


# ---------------------------------------------------------------------------
# Seed-engine regression fixes (ISSUE 9 satellites)
# ---------------------------------------------------------------------------


def _engine_and_prompts(rng, arch="qwen2-1.5b", batch=2, plen=6):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(rng)
    eng = ServingEngine(cfg, params, batch=batch, capacity=32)
    prompts = np.random.default_rng(3).integers(
        0, cfg.vocab_size, (batch, plen), dtype=np.int32
    )
    return cfg, params, eng, prompts


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "rwkv6-1.6b"])
def test_generate_resets_state_between_batches(arch, rng):
    """Two sequential generate() calls == two fresh engines: the KV state
    must not leak from the first batch into the second."""
    cfg, params, eng, prompts = _engine_and_prompts(rng, arch)
    first = eng.generate(prompts, max_new_tokens=5)
    second = eng.generate(prompts, max_new_tokens=5)
    fresh = ServingEngine(cfg, params, batch=2, capacity=32)
    want = fresh.generate(prompts, max_new_tokens=5)
    np.testing.assert_array_equal(first, want)
    np.testing.assert_array_equal(second, want)


def test_no_wasted_trailing_decode(rng):
    """Exiting via max_new_tokens must not run (or count) a decode step
    whose logits are discarded: n tokens need exactly n-1 decode steps."""
    _, _, eng, prompts = _engine_and_prompts(rng)
    eng.generate(prompts, max_new_tokens=6)
    assert eng.stats.decode_steps == 5
    eng.generate(prompts, max_new_tokens=1)  # prefill-only: zero steps
    assert eng.stats.decode_steps == 5


def test_post_eos_rows_masked_and_frozen(rng):
    """A finished row emits eos_id (not lane garbage) for the rest of the
    batch's decode, and unfinished rows are unaffected by the masking."""
    _, _, eng, prompts = _engine_and_prompts(rng, batch=2)
    free = eng.generate(prompts, max_new_tokens=6)
    # force row 0 to finish at its second emitted token
    eos = int(free[0, 1])
    out = eng.generate(prompts, max_new_tokens=6, eos_id=eos)
    row = list(out[0])
    k = row.index(eos)
    assert all(t == eos for t in row[k:]), "post-EOS output not masked"
    # row 1 decodes on, unchanged, until/unless it emits eos itself
    for a, b in zip(out[1], free[1]):
        assert a == b
        if a == eos:
            break


def test_fused_prefill_compiles_once(rng):
    """Repeated same-shape prefills reuse one cached jitted callable."""
    _, _, eng, prompts = _engine_and_prompts(rng)
    for _ in range(3):
        eng.prefill(prompts)
    assert eng.prefill_compiles == 1
    assert eng.stats.prefills == 3


def test_tokens_per_s_counts_live_rows(rng):
    """Throughput counts tokens (live rows x steps), not batch steps."""
    _, _, eng, prompts = _engine_and_prompts(rng, batch=2)
    free = eng.generate(prompts, max_new_tokens=6)
    assert eng.stats.decode_tokens == 2 * eng.stats.decode_steps
    assert eng.stats.tokens_per_s > 0
    # finish row 0 early: the remaining steps produce one live token each
    eos = int(free[0, 1])
    eng2 = ServingEngine(eng.cfg, eng.params, batch=2, capacity=32)
    eng2.generate(prompts, max_new_tokens=6, eos_id=eos)
    assert eng2.stats.decode_tokens < 2 * eng2.stats.decode_steps


# ---------------------------------------------------------------------------
# Paged-allocator invariants (ISSUE 9 tentpole)
# ---------------------------------------------------------------------------


def test_page_allocator_alloc_free_invariants():
    al = PageAllocator(n_pages=9, page_tokens=4)  # 8 usable pages
    a = al.alloc("a", 10)  # 3 pages
    b = al.alloc("b", 4)   # 1 page
    assert len(a) == 3 and len(b) == 1
    assert not set(a) & set(b), "page owned by two live requests"
    al.check_invariants()
    assert al.alloc("c", 100) is None  # exhaustion queues, never corrupts
    al.check_invariants()
    freed = al.free("a")
    assert freed == 3 and al.free_pages == 7
    al.check_invariants()
    # freed pages are reusable; double-alloc under one id is an error
    c = al.alloc("c", 17)  # 5 pages, needs a's returned ones
    assert c is not None and not set(c) & set(b)
    with pytest.raises(ValueError):
        al.alloc("c", 4)
    al.check_invariants()


def test_page_allocator_grow_and_scratch():
    from repro.serve.kvcache import SCRATCH_PAGE

    al = PageAllocator(n_pages=5, page_tokens=4)
    t = al.alloc("r", 4)
    assert SCRATCH_PAGE not in t
    grown = al.grow("r", 12)  # 3 pages total
    assert len(grown) == 3 and grown[:1] == t
    assert al.grow("r", 1000) is None  # exhaustion: caller waits or retires
    al.check_invariants()
    al.free("r")
    assert al.used_pages == 0
