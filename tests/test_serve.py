"""Serving engine: generation shapes, greedy consistency, stats."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.serve.engine import ServingEngine
from repro.serve.kvcache import cache_bytes, init_cache


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "rwkv6-1.6b"])
def test_generate_shapes(arch, rng):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(rng)
    eng = ServingEngine(cfg, params, batch=2, capacity=64)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8),
                                                dtype=np.int32)
    out = eng.generate(prompts, max_new_tokens=6)
    assert out.shape == (2, 6)
    assert eng.stats.decode_steps > 0


def test_greedy_matches_forward_argmax(rng):
    """Greedy first token == argmax of teacher-forcing logits (fp32)."""
    cfg = dataclasses.replace(get_config("llama3-8b").reduced(), dtype="float32")
    api = get_model(cfg)
    params = api.init(rng)
    toks = jax.random.randint(rng, (1, 8), 0, cfg.vocab_size, jnp.int32)
    full = api.forward(params, toks)
    want = int(jnp.argmax(full[0, -1]))
    eng = ServingEngine(cfg, params, batch=1, capacity=32)
    out = eng.generate(np.asarray(toks), max_new_tokens=1)
    assert int(out[0, 0]) == want


def test_cache_bytes_scales_with_capacity():
    cfg = get_config("llama3-8b").reduced()
    api = get_model(cfg)
    b64 = cache_bytes(api, 2, 64)
    b128 = cache_bytes(api, 2, 128)
    assert b128 == 2 * b64


def test_rwkv_cache_capacity_free():
    cfg = get_config("rwkv6-1.6b").reduced()
    api = get_model(cfg)
    assert cache_bytes(api, 2, 64) == cache_bytes(api, 2, 4096)  # O(1) state
