"""Optimizers + schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.optimizer import (
    adafactor,
    adamw,
    clip_by_global_norm,
    constant_schedule,
    cosine_schedule,
    wsd_schedule,
)


def _quadratic_losses(opt, steps=60):
    target = jnp.array([[1.0, -2.0], [3.0, 0.5]])
    params = {"w": jnp.zeros((2, 2))}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2)

    losses = []
    for _ in range(steps):
        l, g = jax.value_and_grad(loss_fn)(params)
        params, state = opt.update(g, state, params)
        losses.append(float(l))
    return losses


def test_adamw_converges():
    losses = _quadratic_losses(adamw(constant_schedule(0.05), weight_decay=0.0))
    assert losses[-1] < losses[0] * 0.05


def test_adafactor_converges():
    losses = _quadratic_losses(adafactor(constant_schedule(0.3)))
    assert losses[-1] < losses[0] * 0.2


def test_wsd_schedule_shape():
    lr = wsd_schedule(1.0, warmup=10, stable=30, decay=10)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(25)) == pytest.approx(1.0)  # stable
    assert float(lr(45)) < 1.0  # decaying
    assert float(lr(100)) == pytest.approx(0.1)  # floor


def test_cosine_schedule_monotone_after_warmup():
    lr = cosine_schedule(1.0, warmup=5, total=50)
    vals = [float(lr(s)) for s in range(5, 50, 5)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    cn = jnp.sqrt(jnp.sum(jnp.square(clipped["a"])))
    assert float(cn) == pytest.approx(1.0, rel=1e-4)
    g2 = {"a": jnp.ones((4,)) * 0.01}
    c2, _ = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(np.asarray(c2["a"]), np.asarray(g2["a"]))


def test_state_schema_matches_init():
    from repro.models.layers import ParamSpec, abstract_params, init_params

    schema = {"w": ParamSpec((8, 4), ("embed", "mlp")),
              "b": ParamSpec((4,), ("norm",))}
    params = init_params(jax.random.PRNGKey(0), schema)
    for opt in (adamw(constant_schedule(1e-3)), adafactor(constant_schedule(1e-2))):
        st = opt.init(params)
        abstract = abstract_params(opt.state_schema(schema))
        assert jax.tree.structure(st) == jax.tree.structure(abstract)
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(abstract)):
            assert a.shape == b.shape, (a.shape, b.shape)


def test_adafactor_memory_factored():
    """Adafactor's state for a (m, n) matrix is O(m+n), not O(mn)."""
    opt = adafactor(constant_schedule(1e-2))
    params = {"w": jnp.zeros((512, 256))}
    st = opt.init(params)
    n_state = sum(x.size for x in jax.tree.leaves(st))
    assert n_state < 512 * 256 * 0.02
