"""Multi-chain graph reduction (paper §4.2, Fig 7)."""
import pytest

from repro.core.costmodel import A100
from repro.core.graph_reduce import block_transition, block_transition_table
from repro.core.planner import plan
from repro.core.profiler import powers_of_two, profile_graph
from repro.models.graph import LayerNode, ParallelBlock, build_inception_like_graph

HW = A100


def _node(name, flops=1e10, units=64):
    return LayerNode(name=name, flops=flops, param_bytes=1e6,
                     act_out_bytes=1e6, parallel_units=units)


def _block_graph():
    branches = (
        ( _node("b0_0"), _node("b0_1") ),
        ( _node("b1_0", flops=5e10), ),
    )
    return [_node("pre"), ParallelBlock("blk", branches), _node("post")]


def test_block_transition_critical_branch():
    chain = profile_graph(_block_graph(), 8, HW)
    block = chain[1]
    scales = powers_of_two(8)
    bt = block_transition(block, 8, 8, scales, 2.0, HW, entry_act_bytes=1e6)
    # the slow branch (5e10 flops) is critical; total >= its best time
    branch_times = [b.time for b in bt.branches]
    assert bt.time >= max(branch_times) - 1e-12
    # non-critical branches that run in parallel don't extend the block
    seq_extra = sum(b.time for b in bt.branches if not b.parallel) - max(branch_times)
    assert bt.time == pytest.approx(max(branch_times) + max(seq_extra, 0.0), rel=1e-6)


def test_block_table_complete():
    chain = profile_graph(_block_graph(), 8, HW)
    scales = powers_of_two(8)
    table = block_transition_table(chain[1], scales, 2.0, HW, 1e6)
    assert set(table) == {(g, h) for g in scales for h in scales}
    assert all(t >= 0 for t, _ in table.values())


def test_plan_with_blocks_vs_flat():
    """A multi-branch graph plan is at least as fast as running every branch
    sequentially at full scale (the DP baseline flattens blocks)."""
    from repro.core.planner import _dp_plan

    g = _block_graph()
    bp = plan(g, 8, amp_limit=1e9, hw=HW)
    dp = _dp_plan(g, 8, HW)
    assert bp.total_time <= dp.total_time * (1 + 1e-9)


def test_inception_like_graph_plans():
    g = build_inception_like_graph(32, n_blocks=3)
    bp = plan(g, 8, amp_limit=2.0, hw=HW)
    assert bp.total_time > 0
    # blocks are represented in the plan (reduced as part of transitions)
    names = [l.name for l in bp.layers]
    assert "stem" in names and "classifier" in names
