"""Multi-chain graph reduction (paper §4.2, Fig 7)."""
import pytest

from repro.core.costmodel import A100
from repro.core.graph_reduce import block_transition, block_transition_table
from repro.core.planner import plan
from repro.core.profiler import powers_of_two, profile_graph
from repro.models.graph import LayerNode, ParallelBlock, build_inception_like_graph

HW = A100


def _node(name, flops=1e10, units=64):
    return LayerNode(name=name, flops=flops, param_bytes=1e6,
                     act_out_bytes=1e6, parallel_units=units)


def _block_graph():
    branches = (
        ( _node("b0_0"), _node("b0_1") ),
        ( _node("b1_0", flops=5e10), ),
    )
    return [_node("pre"), ParallelBlock("blk", branches), _node("post")]


def test_block_transition_critical_branch():
    chain = profile_graph(_block_graph(), 8, HW)
    block = chain[1]
    scales = powers_of_two(8)
    bt = block_transition(block, 8, 8, scales, 2.0, HW, entry_act_bytes=1e6)
    # the slow branch (5e10 flops) is critical; total >= its best time
    branch_times = [b.time for b in bt.branches]
    assert bt.time >= max(branch_times) - 1e-12
    # non-critical branches that run in parallel don't extend the block
    seq_extra = sum(b.time for b in bt.branches if not b.parallel) - max(branch_times)
    assert bt.time == pytest.approx(max(branch_times) + max(seq_extra, 0.0), rel=1e-6)


def test_block_table_complete():
    chain = profile_graph(_block_graph(), 8, HW)
    scales = powers_of_two(8)
    table = block_transition_table(chain[1], scales, 2.0, HW, 1e6)
    assert set(table) == {(g, h) for g in scales for h in scales}
    assert all(t >= 0 for t, _ in table.values())


def test_plan_with_blocks_vs_flat():
    """A multi-branch graph plan is at least as fast as running every branch
    sequentially at full scale (the DP baseline flattens blocks)."""
    from repro.core.planner import _dp_plan

    g = _block_graph()
    bp = plan(g, 8, amp_limit=1e9, hw=HW)
    dp = _dp_plan(g, 8, HW)
    assert bp.total_time <= dp.total_time * (1 + 1e-9)


def test_inception_like_graph_plans():
    g = build_inception_like_graph(32, n_blocks=3)
    bp = plan(g, 8, amp_limit=2.0, hw=HW)
    assert bp.total_time > 0
    # blocks are represented in the plan (reduced as part of transitions)
    names = [l.name for l in bp.layers]
    assert "stem" in names and "classifier" in names


def test_block_transition_surfaces_critical_branch():
    """BlockTransition.critical names the longest branch; the new placement
    code keys device-range assignment off it."""
    chain = profile_graph(_block_graph(), 8, HW)
    block = chain[1]
    scales = powers_of_two(8)
    bt = block_transition(block, 8, 8, scales, 2.0, HW, entry_act_bytes=1e6)
    assert bt.critical == max(
        range(len(bt.branches)), key=lambda i: bt.branches[i].time
    )
    assert not bt.branches[bt.critical].parallel

    # a decisively slow branch must be the critical one
    heavy = profile_graph(
        [ParallelBlock("hv", ((_node("fast"),), (_node("slow", flops=1e13),))),
         _node("tail")],
        8, HW,
    )[0]
    bt2 = block_transition(heavy, 8, 8, scales, 2.0, HW, entry_act_bytes=1e6)
    assert bt2.critical == 1
    assert bt2.branches[1].time > bt2.branches[0].time


def test_noncritical_branch_parallel_only_when_free_and_under_amp():
    """A non-critical branch is marked parallel=True only when it neither
    extends the block's time nor pushes gpu-sec amplification past the
    limit; a tight amp limit forces it sequential (extending the block)."""
    chain = profile_graph(_block_graph(), 8, HW)
    block = chain[1]
    scales = powers_of_two(8)

    generous = block_transition(block, 8, 8, scales, 1e9, HW, entry_act_bytes=1e6)
    crit_t = generous.branches[generous.critical].time
    for i, br in enumerate(generous.branches):
        if i == generous.critical:
            continue
        # with no amp pressure, every non-critical branch fits in parallel
        assert br.parallel and br.time <= crit_t + 1e-15
    assert generous.time == pytest.approx(crit_t, rel=1e-12)

    tight = block_transition(block, 8, 8, scales, 1e-6, HW, entry_act_bytes=1e6)
    noncrit = [b for i, b in enumerate(tight.branches) if i != tight.critical]
    assert all(not b.parallel for b in noncrit)  # amp budget exhausted
    # sequential branches extend the block beyond the critical time
    crit_t_tight = tight.branches[tight.critical].time
    assert tight.time == pytest.approx(
        crit_t_tight + sum(b.time for b in noncrit), rel=1e-9
    )


def test_block_matrix_placements_device_ranges():
    """The vectorized reduction's placements: parallel branches get disjoint
    device ranges above the critical branch inside the block's gap window."""
    from repro.core.graph_reduce import block_placements

    chain = profile_graph(_block_graph(), 8, HW)
    block = chain[1]
    scales = powers_of_two(8)
    n = len(scales)
    placements = block_placements(block, n - 1, n - 1, scales, 1e9, HW, 1e6, 8)
    assert len(placements) == 2
    crit = [p for p in placements if p.critical]
    assert len(crit) == 1
    assert crit[0].device_start == 0 and crit[0].device_end == crit[0].gpus
    for p in placements:
        if p.parallel:
            assert p.device_start >= crit[0].device_end  # disjoint from critical
        assert len(p.scales) >= 1 and p.gpus == max(p.scales)
        assert p.device_end <= 8 or not p.parallel


def test_placement_demotion_when_gap_window_full():
    """A branch the reduction decided to run in parallel is demoted (and
    flagged) when the machine has no idle devices left for it; with enough
    devices the same branch is genuinely placed in parallel."""
    from repro.core.graph_reduce import block_placements

    chain = profile_graph(_block_graph(), 8, HW)
    block = chain[1]
    scales = powers_of_two(8)
    n = len(scales)
    # both branches peak at 8 devices in the (8, 8) cell under a loose limit
    small = block_placements(block, n - 1, n - 1, scales, 1e9, HW, 1e6, 8)
    noncrit_small = [p for p in small if not p.critical]
    big = block_placements(block, n - 1, n - 1, scales, 1e9, HW, 1e6, 32)
    noncrit_big = [p for p in big if not p.critical]
    assert any(p.parallel for p in noncrit_big)  # fits on the 32-dev machine
    demoted = [p for p in noncrit_small if p.demoted]
    if any(p.gpus + max(c.gpus for c in small if c.critical) > 8
           for p in noncrit_small):
        assert demoted, small  # could not fit -> must be flagged
        assert all(not p.parallel and p.device_start == 0 for p in demoted)
