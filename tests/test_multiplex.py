"""Multiplexing (paper §5): ablation ordering, pacing, feedback loop."""
from dataclasses import replace

import pytest

from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.costmodel import A100
from repro.core.multiplex import (
    Collocator,
    InterferenceModel,
    MultiplexConfig,
    MultiplexSim,
    QoSMonitor,
)
from repro.core.planner import plan
from repro.models.graph import build_vgg_graph


@pytest.fixture(scope="module")
def vgg_plan():
    return plan(build_vgg_graph(VCFG, 32), 8, amp_limit=2.0, hw=A100)


def _run(plan_, **kw):
    cfg = MultiplexConfig(collocate_same_device=True, **kw)
    return MultiplexSim(plan_, cfg).run(20)


def test_fig11_ablation_ordering(vgg_plan):
    """Paper Fig 11: each mechanism improves foreground QoS."""
    naive = _run(vgg_plan, use_priorities=False, use_pacing=False,
                 use_feedback=False, use_granularity=False)
    prio = _run(vgg_plan, use_pacing=False, use_feedback=False,
                use_granularity=False)
    paced = _run(vgg_plan, use_feedback=False, use_granularity=False)
    fb = _run(vgg_plan, use_granularity=False)
    full = _run(vgg_plan)
    # paper: naive dramatically slows fg; priorities alone barely help
    assert naive.fg_slowdown > 1.5
    assert prio.fg_slowdown <= naive.fg_slowdown + 1e-9
    assert prio.fg_slowdown > paced.fg_slowdown  # pacing is the big win
    assert fb.fg_slowdown <= paced.fg_slowdown + 1e-9
    assert full.fg_slowdown <= fb.fg_slowdown + 1e-9


def test_tpu_submesh_mode_protects_fg(vgg_plan):
    res = MultiplexSim(vgg_plan, MultiplexConfig(collocate_same_device=False)).run(20)
    assert res.fg_slowdown < 1.15
    assert res.bg_steps_per_iter > 0  # gaps actually used


def test_granularity_fills_gaps_more(vgg_plan):
    fb = _run(vgg_plan, use_granularity=False)
    full = _run(vgg_plan)
    assert full.bg_steps_per_iter >= fb.bg_steps_per_iter


def test_cluster_util_bounded(vgg_plan):
    for kw in (dict(), dict(use_feedback=False), dict(use_pacing=False,
               use_feedback=False, use_priorities=False, use_granularity=False)):
        res = _run(vgg_plan, **kw)
        assert 0.0 <= res.cluster_throughput <= 1.0 + 1e-9


def test_qos_monitor_bans_sensitive_ops():
    m = QoSMonitor(slowdown_threshold=1.3)
    m.record_baseline("sync", 1.0)
    m.record("sync", 2.5, collocated=True)
    m.record("sync", 2.5, collocated=True)
    assert not m.collocation_allowed("sync")
    m.record_baseline("mlp", 1.0)
    m.record("mlp", 1.05, collocated=True)
    assert m.collocation_allowed("mlp")


def test_collocator_schedule_paced(vgg_plan):
    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=2))
    sched = col.schedule()
    assert all(n <= 2 for _, n in sched)  # pacing bound
    stages = {s for s, _ in sched}
    gap_stages = {g.stage_index for g in vgg_plan.gaps()}
    assert stages <= gap_stages


def test_collocator_hoists_bg_step_time(vgg_plan, monkeypatch):
    """The bg step quantum is computed once at construction — schedule()
    must not rebuild a MultiplexSim per call (the old per-iteration cost)."""
    import repro.core.multiplex as mx

    cfg = MultiplexConfig(max_inflight=2)
    col = Collocator(vgg_plan, cfg)
    assert col.bg_step_quantum == MultiplexSim(vgg_plan, cfg).bg_step_time()
    first = col.schedule()

    def boom(*a, **k):
        raise AssertionError("MultiplexSim rebuilt inside schedule()")

    monkeypatch.setattr(mx, "MultiplexSim", boom)
    assert col.schedule() == first
    assert col.schedule() == first


def test_collocator_respects_feedback(vgg_plan):
    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=4))
    gaps = vgg_plan.gaps()
    banned_stage = gaps[0].stage_index
    op = f"stage{banned_stage}"
    col.monitor.record_baseline(op, 1.0)
    col.monitor.record(op, 10.0, collocated=True)
    sched = dict(col.schedule())
    assert banned_stage not in sched
