"""Multiplexing (paper §5): ablation ordering, pacing, feedback loop,
multi-tenant gap scheduling, executable caching and calibration."""
from dataclasses import replace

import pytest

from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.costmodel import A100
from repro.core.multiplex import (
    BgTenant,
    Collocator,
    CollocationResult,
    ExecutableCache,
    InterferenceModel,
    MultiplexConfig,
    MultiplexSim,
    QoSMonitor,
    TenantResult,
)
from repro.core.planner import plan
from repro.models.graph import build_vgg_graph


@pytest.fixture(scope="module")
def vgg_plan():
    return plan(build_vgg_graph(VCFG, 32), 8, amp_limit=2.0, hw=A100)


def _run(plan_, **kw):
    cfg = MultiplexConfig(collocate_same_device=True, **kw)
    return MultiplexSim(plan_, cfg).run(20)


def test_fig11_ablation_ordering(vgg_plan):
    """Paper Fig 11: each mechanism improves foreground QoS."""
    naive = _run(vgg_plan, use_priorities=False, use_pacing=False,
                 use_feedback=False, use_granularity=False)
    prio = _run(vgg_plan, use_pacing=False, use_feedback=False,
                use_granularity=False)
    paced = _run(vgg_plan, use_feedback=False, use_granularity=False)
    fb = _run(vgg_plan, use_granularity=False)
    full = _run(vgg_plan)
    # paper: naive dramatically slows fg; priorities alone barely help
    assert naive.fg_slowdown > 1.5
    assert prio.fg_slowdown <= naive.fg_slowdown + 1e-9
    assert prio.fg_slowdown > paced.fg_slowdown  # pacing is the big win
    assert fb.fg_slowdown <= paced.fg_slowdown + 1e-9
    assert full.fg_slowdown <= fb.fg_slowdown + 1e-9


def test_tpu_submesh_mode_protects_fg(vgg_plan):
    res = MultiplexSim(vgg_plan, MultiplexConfig(collocate_same_device=False)).run(20)
    assert res.fg_slowdown < 1.15
    assert res.bg_steps_per_iter > 0  # gaps actually used


def test_granularity_fills_gaps_more(vgg_plan):
    fb = _run(vgg_plan, use_granularity=False)
    full = _run(vgg_plan)
    assert full.bg_steps_per_iter >= fb.bg_steps_per_iter


def test_cluster_util_bounded(vgg_plan):
    for kw in (dict(), dict(use_feedback=False), dict(use_pacing=False,
               use_feedback=False, use_priorities=False, use_granularity=False)):
        res = _run(vgg_plan, **kw)
        assert 0.0 <= res.cluster_throughput <= 1.0 + 1e-9


def test_qos_monitor_bans_sensitive_ops():
    m = QoSMonitor(slowdown_threshold=1.3)
    m.record_baseline("sync", 1.0)
    m.record("sync", 2.5, collocated=True)
    m.record("sync", 2.5, collocated=True)
    assert not m.collocation_allowed("sync")
    m.record_baseline("mlp", 1.0)
    m.record("mlp", 1.05, collocated=True)
    assert m.collocation_allowed("mlp")


def test_collocator_schedule_paced(vgg_plan):
    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=2))
    sched = col.schedule()
    assert all(n <= 2 for _, n in sched)  # pacing bound
    stages = {s for s, _ in sched}
    gap_stages = {g.stage_index for g in vgg_plan.gaps()}
    assert stages <= gap_stages


def test_collocator_hoists_bg_step_time(vgg_plan, monkeypatch):
    """The bg step quantum is computed once at construction — schedule()
    must not rebuild a MultiplexSim per call (the old per-iteration cost)."""
    import repro.core.multiplex as mx

    cfg = MultiplexConfig(max_inflight=2)
    col = Collocator(vgg_plan, cfg)
    assert col.bg_step_quantum == MultiplexSim(vgg_plan, cfg).bg_step_time()
    first = col.schedule()

    def boom(*a, **k):
        raise AssertionError("MultiplexSim rebuilt inside schedule()")

    monkeypatch.setattr(mx, "MultiplexSim", boom)
    assert col.schedule() == first
    assert col.schedule() == first


def test_collocator_respects_feedback(vgg_plan):
    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=4))
    gaps = vgg_plan.gaps()
    banned_stage = gaps[0].stage_index
    op = f"stage{banned_stage}"
    col.monitor.record_baseline(op, 1.0)
    col.monitor.record(op, 10.0, collocated=True)
    sched = dict(col.schedule())
    assert banned_stage not in sched


# -- multi-tenant gap scheduling ---------------------------------------------


def _tenants(n, base_priority=0):
    return [BgTenant(f"job{i}", base_priority + n - i, lambda m: (lambda: None))
            for i in range(n)]


def test_collocator_orders_tenants_by_priority(vgg_plan):
    low = BgTenant("low", 1, lambda m: (lambda: None))
    high = BgTenant("high", 9, lambda m: (lambda: None))
    mid_a = BgTenant("mid_a", 5, lambda m: (lambda: None))
    mid_b = BgTenant("mid_b", 5, lambda m: (lambda: None))
    col = Collocator(vgg_plan, MultiplexConfig(), tenants=[low, mid_a, mid_b, high])
    # slot 0 = highest priority; equal priorities keep submission order
    assert [t.job for t in col.tenants] == ["high", "mid_a", "mid_b", "low"]


def test_schedule_tenants_packs_by_priority(vgg_plan):
    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=2),
                     tenants=_tenants(2))
    sched = col.schedule_tenants()
    assert sched, "vgg plan gaps must admit tenants"
    single = dict(col.schedule())
    by_stage = {}
    for si, slot, n in sched:
        assert n <= 2  # pacing bound per tenant
        by_stage.setdefault(si, []).append((slot, n))
    gap_stages = {g.stage_index for g in vgg_plan.gaps()}
    assert set(by_stage) <= gap_stages
    for si, slots in by_stage.items():
        # same paced step count as the single-tenant schedule, per tenant
        assert all(n == single[si] for _, n in slots)
        # slots are 0..k-1 (priority-ordered chunks)
        assert [s for s, _ in sorted(slots)] == list(range(len(slots)))
    # at least one gap is wide enough for both tenants to co-run
    assert any(len(s) == 2 for s in by_stage.values())
    # feedback ban empties the whole gap for every tenant
    banned = sched[0][0]
    col.monitor.record_baseline(f"stage{banned}", 1.0)
    col.monitor.record(f"stage{banned}", 10.0, collocated=True)
    assert all(si != banned for si, _, _ in col.schedule_tenants())


def test_schedule_tenants_never_exceeds_free_devices(vgg_plan):
    from repro.core.plan import pack_ranges

    for n in (1, 2, 3, 8):
        col = Collocator(vgg_plan, MultiplexConfig(), tenants=_tenants(n))
        sched = col.schedule_tenants()
        for si, slot, _ in sched:
            free = vgg_plan.free_device_ranges(si)
            chunks = pack_ranges(free, n)
            assert slot < len(chunks)  # a slot only exists if it got devices


def test_schedule_tenants_per_tenant_quanta(vgg_plan):
    """Tenants with their own quantum get chunks aligned to it, and their
    step-time quantum is sized to the gaps THEY occupy, not the global
    minimum."""
    tenants = [
        BgTenant("wide", 2, lambda m: (lambda: None), quantum=2),
        BgTenant("narrow", 1, lambda m: (lambda: None)),
    ]
    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=4),
                     tenants=tenants)
    detail = col._schedule_detail()
    assert detail
    for si, slot, pos, (cs, ce), nsteps, bg_t in detail:
        q = tenants[slot].quantum or 1
        assert (ce - cs) % q == 0  # chunk aligned to ITS tenant's quantum
        assert nsteps <= 4
    # a tenant occupying only a subset of gaps sizes its step to that
    # subset's smallest gap, not the min over ALL gaps: feed a canonical
    # layout where slot 0 holds only the longest gap and slot 1 nothing
    gaps = sorted(vgg_plan.gaps(), key=lambda g: -g.duration)
    big = gaps[0]
    times = col._slot_step_times(2, {big.stage_index: [(0, 2), None]})
    global_t = col.bg_step_quantum
    expect = min(col.cfg.bg_step_time,
                 max(col.cfg.bg_min_step_time, big.duration / 2.0))
    assert times[0] == pytest.approx(expect)
    assert times[0] >= global_t  # its only gap is the biggest one
    assert times[1] == global_t  # slot with no gaps keeps the global quantum


def test_submeshes_whatif_padding_matches_scheduler(vgg_plan):
    """Regression: a what-if tenant count beyond the roster pads submesh
    carving quanta with placeholder slots (quantum = bg_model), exactly as
    the scheduler does — NOT with the last real tenant's quantum."""
    import jax

    if len(jax.devices()) < vgg_plan.num_gpus:
        pytest.skip("needs 8 devices (tier1-multidevice job)")
    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=2),
                     tenants=[BgTenant("a", 1, lambda m: (lambda: None),
                                       quantum=2)])
    split = col.submeshes(tenants=2)
    sched_rows = col._schedule_detail(2)
    chunks_by_stage = {}
    for si, _slot, pos, chunk, _n, _t in sched_rows:
        chunks_by_stage.setdefault(si, {})[pos] = chunk
    for si, slots in split.bg_tenants.items():
        for pos, entry in enumerate(slots):
            want = chunks_by_stage.get(si, {}).get(pos)
            if entry is None:
                continue
            # every carved chunk the scheduler also packs must agree exactly
            if want is not None:
                assert entry[0] == want, (si, pos, entry[0], want)


def test_equal_priority_rotation_and_deficit(vgg_plan):
    """Equal-priority tenants rotate chunk ownership across iterations; a
    deficit promotes the starved tenant to the largest chunk."""
    tenants = [BgTenant(f"t{i}", 1, lambda m: (lambda: None))
               for i in range(2)]
    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=2),
                     tenants=tenants)
    d0 = col._schedule_detail(iteration=0)
    d1 = col._schedule_detail(iteration=1)
    pos_of = lambda d, slot: {(si, pos) for si, s, pos, _, _, _ in d
                              if s == slot}
    # rotation: slot 0 owns position 0 at iteration 0, position 1 at 1
    assert pos_of(d0, 0) == pos_of(d1, 1)
    assert pos_of(d0, 1) == pos_of(d1, 0)
    # distinct priorities never rotate
    fixed = Collocator(vgg_plan, MultiplexConfig(max_inflight=2),
                       tenants=_tenants(2))
    assert fixed._schedule_detail(iteration=0) == \
        fixed._schedule_detail(iteration=7)
    # deficit dominates rotation: starve slot 1, it takes position 0
    col._deficits[1] = 100.0
    d = col._schedule_detail(iteration=0)
    assert all(slot == 1 for _, slot, pos, _, _, _ in d if pos == 0)
    # note_launched books the weighted fair share and advances the round
    r0 = col._round
    col.note_launched([4, 0])
    assert col._round == r0 + 1
    assert col._deficits[1] > 100.0  # starved again -> deficit grew
    assert col._deficits[0] == 0.0   # overserved -> floored at zero


def test_rotation_zero_step_falls_back_to_canonical_owner():
    """A rotated-in tenant whose (canonically-sized) step is too big for a
    short gap must hand the chunk back to the canonical owner instead of
    leaving the gap idle for that iteration."""
    from repro.core.plan import BurstPlan, LayerPlan

    mk = lambda i, g, t: LayerPlan(index=i, name=f"l{i}", gpus=g, time=t,
                                   comp=t, sync=0.0, comm_in=0.0, amp=1.0)
    # wide 40ms gap (4 free -> 2 chunks); narrow 1.5ms gap (1 free -> 1
    # chunk).  Slot 1 canonically holds only the wide gap, so its step
    # quantum is bg_step_time (2ms) > the narrow gap's duration.
    p = BurstPlan(
        layers=(mk(0, 8, 1e-3), mk(1, 4, 40e-3), mk(2, 8, 1e-3),
                mk(3, 7, 1.5e-3)),
        num_gpus=8, amp_limit=2.0, single_gpu_time=43.5e-3,
    )
    narrow_si = 3
    tenants = [BgTenant(f"t{i}", 1, lambda m: (lambda: None))
               for i in range(2)]
    col = Collocator(p, MultiplexConfig(max_inflight=2, use_feedback=False),
                     tenants=tenants)
    assert col._slot_step_times(2, {1: [(4, 6), (6, 8)], 3: [(7, 8), None]})
    for it in range(6):
        rows = [r for r in col._schedule_detail(iteration=it)
                if r[0] == narrow_si]
        # the narrow gap never idles, and only the canonical owner (whose
        # step fits) ever runs there
        assert rows, it
        for _si, slot, pos, _c, n, _t in rows:
            assert slot == 0 and n > 0, (it, rows)


def test_mixed_quanta_rotation_and_jain_recorded():
    """ISSUE 6 satellite: equal-priority tenants with DIFFERENT quanta still
    rotate chunk ownership (the old scheduler split them into singleton
    same-quantum subgroups that never rotated), every carved chunk stays
    aligned to its owner's quantum, and the schedule-level Jain index is
    recorded on CollocationResult."""
    from repro.core.plan import BurstPlan, LayerPlan

    mk = lambda i, g, t: LayerPlan(index=i, name=f"l{i}", gpus=g, time=t,
                                   comp=t, sync=0.0, comm_in=0.0, amp=1.0)
    p = BurstPlan(
        layers=(mk(0, 8, 1e-3), mk(1, 4, 40e-3), mk(2, 8, 1e-3)),
        num_gpus=8, amp_limit=2.0, single_gpu_time=42e-3,
    )
    tenants = [
        BgTenant("narrow", 1, lambda m: (lambda: None), quantum=1),
        BgTenant("wide", 1, lambda m: (lambda: None), quantum=2),
    ]
    col = Collocator(p, MultiplexConfig(max_inflight=4, use_feedback=False),
                     tenants=tenants)
    pos0_owner = set()
    for it in range(4):
        rows = col._schedule_detail(iteration=it)
        assert rows, it
        for _si, slot, pos, (cs, ce), _n, _t in rows:
            q = tenants[slot].quantum or 1
            assert (ce - cs) % q == 0, (it, rows)
            if pos == 0:
                pos0_owner.add(slot)
    # rotation spans the mixed-quanta group: both tenants lead at some point
    assert pos0_owner == {0, 1}
    res = col.predict(2)
    assert 0.0 < res.jain_index <= 1.0
    assert res.jain_index == pytest.approx(res.jain_fairness())


def test_note_launched_respects_weights(vgg_plan):
    tenants = [BgTenant("heavy", 1, lambda m: (lambda: None), weight=3.0),
               BgTenant("light", 1, lambda m: (lambda: None), weight=1.0)]
    col = Collocator(vgg_plan, MultiplexConfig(), tenants=tenants)
    col.note_launched([2, 2])  # equal split of 4 steps (same step quantum)
    # total service 4q; fair shares 3q and q: heavy is owed q, light owes q
    q = col.bg_step_quantum
    assert col._deficits[0] == pytest.approx(q)
    assert col._deficits[1] == 0.0


def test_deficit_accounting_is_service_time_not_step_counts():
    """Regression: tenants with different step-time quanta must book
    service seconds, not raw step counts, into the deficit — otherwise a
    big-step tenant can never match a small-step peer's count, its deficit
    diverges, and the rotation freezes with it pinned to the best chunk."""
    from repro.core.plan import BurstPlan, LayerPlan

    mk = lambda i, g, t: LayerPlan(index=i, name=f"l{i}", gpus=g, time=t,
                                   comp=t, sync=0.0, comm_in=0.0, amp=1.0)
    # wide 40ms gap (4 free -> 2 chunks) + narrow 3ms gap (1 free -> 1
    # chunk): slot 1 canonically holds only the wide gap, so its step
    # quantum (2ms) is larger than slot 0's (1.5ms, set by the narrow gap)
    p = BurstPlan(
        layers=(mk(0, 8, 1e-3), mk(1, 4, 40e-3), mk(2, 8, 1e-3),
                mk(3, 7, 3e-3)),
        num_gpus=8, amp_limit=2.0, single_gpu_time=45e-3,
    )
    tenants = [BgTenant(f"t{i}", 1, lambda m: (lambda: None))
               for i in range(2)]
    col = Collocator(p, MultiplexConfig(max_inflight=2, use_feedback=False),
                     tenants=tenants)
    pos0_owner = []
    for _ in range(30):
        detail = col._schedule_detail()
        launched = [0, 0]
        for _si, slot, pos, _c, n, _t in detail:
            launched[slot] += n
            if pos == 0:
                pos0_owner.append(slot)
        col.note_launched(launched)
    # deficits stay bounded (no monotonic divergence)...
    per_iter_service = sum(
        n * t for _si, _slot, _pos, _c, n, t in col._schedule_detail()
    )
    assert max(col._deficits.values()) < 2 * per_iter_service
    # ...and best-chunk ownership keeps rotating to BOTH tenants
    assert {0, 1} <= set(pos0_owner[-8:])


def test_executable_cache_semantics():
    cache = ExecutableCache()
    built = []

    def build_a():
        built.append("a")
        return lambda: "a"

    k1 = ("sigA", (0, 1), (2, 1))
    assert cache.get_or_build(k1, build_a)() == "a"
    assert (cache.hits, cache.misses) == (0, 1)
    # same key -> reuse, no rebuild
    assert cache.get_or_build(k1, build_a)() == "a"
    assert (cache.hits, cache.misses) == (1, 1)
    assert built == ["a"]
    # different device ids or shape -> distinct executable
    cache.get_or_build(("sigA", (2, 3), (2, 1)), build_a)
    cache.get_or_build(("sigB", (0, 1), (2, 1)), build_a)
    assert (cache.hits, cache.misses) == (1, 3)


def test_executable_cache_lru_bound():
    cache = ExecutableCache(max_entries=3)
    for i in range(3):
        cache.get_or_build((f"s{i}", (i,), (1,)), lambda i=i: (lambda: i))
    assert len(cache) == 3 and cache.evictions == 0
    # refresh s0 (recency), then insert a 4th: s1 is now the LRU victim
    cache.get_or_build(("s0", (0,), (1,)), lambda: (lambda: None))
    cache.get_or_build(("s3", (3,), (1,)), lambda: (lambda: None))
    assert len(cache) == 3 and cache.evictions == 1
    keys = set(cache.entries)
    assert ("s1", (1,), (1,)) not in keys
    assert ("s0", (0,), (1,)) in keys and ("s3", (3,), (1,)) in keys
    # the evicted entry rebuilds on next use (miss, not a stale hit)
    m0 = cache.misses
    cache.get_or_build(("s1", (1,), (1,)), lambda: (lambda: None))
    assert cache.misses == m0 + 1


def test_executable_cache_evict_stale_device_subsets():
    cache = ExecutableCache()
    cache.get_or_build(("a", (0, 1), (2, 1)), lambda: (lambda: None))
    cache.get_or_build(("b", (2, 3), (2, 1)), lambda: (lambda: None))
    cache.get_or_build(("c", (1, 3), (2, 1)), lambda: (lambda: None))
    # device 3 dies: every entry whose submesh touched it is dropped
    n = cache.evict_stale({0, 1, 2})
    assert n == 2 and cache.evictions == 2
    assert list(cache.entries) == [("a", (0, 1), (2, 1))]
    # idempotent; a fully-live set evicts nothing
    assert cache.evict_stale({0, 1, 2}) == 0
    assert cache.evict_stale({0, 1, 2, 3}) == 0


def test_bg_tenant_cache_signature_fallbacks():
    def factory(mesh):
        return lambda: None

    def other_factory(mesh):
        return lambda: None

    # untagged factories key on the factory OBJECT: two different factories
    # under the same job name never share a compiled executable
    assert BgTenant("jobX", 0, factory).cache_signature is factory
    assert (BgTenant("jobX", 0, factory).cache_signature
            != BgTenant("jobX", 0, other_factory).cache_signature)
    factory.signature = "arch-b4-s8"
    assert BgTenant("jobX", 0, factory).cache_signature == "arch-b4-s8"
    assert BgTenant("jobX", 0, factory,
                    signature="explicit").cache_signature == "explicit"
    # no factory at all: fall back to the job name
    assert BgTenant("jobY", 0).cache_signature == "jobY"


# -- calibration -------------------------------------------------------------


def _measured(slowdown, steps=6.0):
    return CollocationResult(
        fg_iter_time=slowdown, fg_iter_time_isolated=1.0,
        fg_slowdown=slowdown, bg_steps_per_iter=steps,
        bg_throughput=steps / slowdown, iterations=3,
    )


def test_calibrate_inverts_to_measured_slowdown(vgg_plan):
    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=2),
                     tenants=_tenants(2))
    model = col.calibrate([_measured(1.20)])
    assert model.gap_inflation > 1.0
    pred = col.predict()
    # closed-form inversion: prediction reproduces the measurement exactly
    assert pred.fg_slowdown == pytest.approx(1.20, abs=1e-9)
    assert pred.iterations == 0  # marked as predicted, not measured
    # predicted steps mirror the tenant schedule
    sched = col.schedule_tenants()
    assert pred.bg_steps_per_iter == pytest.approx(
        sum(n for _, _, n in sched))
    assert len(pred.tenants) == 2
    # admission-control what-if beyond the roster: placeholder rows keep
    # per-tenant steps summing to the aggregate (no phantom slots)
    pred3 = col.predict(n_tenants=3)
    assert len(pred3.tenants) == 3
    assert sum(t.bg_steps_per_iter for t in pred3.tenants) == pytest.approx(
        pred3.bg_steps_per_iter)
    # geometric mean over several results; sub-1.0 measurements clamp
    m2 = col.calibrate([_measured(1.2), _measured(1.2), _measured(0.8)])
    assert 1.0 < m2.gap_inflation < model.gap_inflation
    # no measured results -> model unchanged
    assert col.calibrate([]) is m2
    # predictions without measurements are excluded
    assert col.calibrate([pred]) is m2


def _measured_staged(slowdown, stage_slowdowns, steps=6.0):
    return CollocationResult(
        fg_iter_time=slowdown, fg_iter_time_isolated=1.0,
        fg_slowdown=slowdown, bg_steps_per_iter=steps,
        bg_throughput=steps / slowdown, iterations=3,
        stage_slowdowns=tuple(stage_slowdowns),
    )


def test_calibrate_clamps_sub_unity_measurements(vgg_plan):
    """Regression: on a noisy host a measured geomean slowdown s < 1 must
    NOT fit a sub-1.0 multiplier — predict()/MultiplexSim would otherwise
    forecast that interference *speeds up* the foreground."""
    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=2),
                     tenants=_tenants(2))
    model = col.calibrate([_measured(0.8), _measured(0.9)])
    assert model.gap_inflation == 1.0
    assert all(v >= 1.0 for _, v in model.gap_inflation_stages)
    assert col.predict().fg_slowdown == pytest.approx(1.0)
    sim = MultiplexSim(vgg_plan,
                       MultiplexConfig(collocate_same_device=False),
                       model).run(10)
    assert sim.fg_slowdown >= 1.0 - 1e-9
    # per-stage raw ratios below 1.0 clamp too
    m2 = col.calibrate([_measured_staged(0.9, [(1, 0.7), (2, 0.95)])])
    assert m2.gap_inflation == 1.0
    assert all(v >= 1.0 for _, v in m2.gap_inflation_stages)


def test_per_stage_calibration_fits_vector(vgg_plan):
    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=2),
                     tenants=_tenants(2))
    sched_stages = sorted({si for si, _, _ in col.schedule_tenants()})
    assert len(sched_stages) >= 2
    hot, cold = sched_stages[0], sched_stages[1]
    model = col.calibrate([_measured_staged(
        1.20, [(hot, 1.5), (cold, 1.01)]
    )])
    fitted = dict(model.gap_inflation_stages)
    # per-stage shape: the hot stage carries more of the inflation
    assert fitted[hot] > fitted[cold] >= 1.0
    assert model.gap_inflation_for(hot) == fitted[hot]
    # stages without a fit fall back to the scalar
    unfitted = [si for si in range(len(vgg_plan.stages()))
                if si not in fitted]
    for si in unfitted:
        assert model.gap_inflation_for(si) == model.gap_inflation
    # the vector is rescaled so the aggregate inversion stays exact: the
    # prediction reproduces the measured slowdown despite per-stage shape
    pred = col.predict()
    assert pred.fg_slowdown == pytest.approx(1.20, abs=1e-6)
    # PARTIAL stage coverage must not double-count: unfitted collocated
    # stages keep the scalar, the vector explains only the residual, and
    # the aggregate still reproduces the measurement exactly
    col2 = Collocator(vgg_plan, MultiplexConfig(max_inflight=2),
                      tenants=_tenants(2))
    m_partial = col2.calibrate([_measured_staged(1.20, [(hot, 1.5)])])
    assert len(m_partial.gap_inflation_stages) == 1
    assert col2.predict().fg_slowdown == pytest.approx(1.20, abs=1e-6)
    # a measured stage the feedback loop has since BANNED is excluded from
    # the fit (it never inflates in predict), and the aggregate inversion
    # over the remaining collocated stages stays exact
    col3 = Collocator(vgg_plan, MultiplexConfig(max_inflight=2),
                      tenants=_tenants(2))
    col3.monitor.record_baseline(f"stage{hot}", 1.0)
    col3.monitor.record(f"stage{hot}", 10.0, collocated=True)
    assert not col3.monitor.collocation_allowed(f"stage{hot}")
    m_banned = col3.calibrate(
        [_measured_staged(1.10, [(hot, 1.5), (cold, 1.2)])]
    )
    assert hot not in dict(m_banned.gap_inflation_stages)
    assert col3.predict().fg_slowdown == pytest.approx(1.10, abs=1e-6)
    # and the per-stage vector flows into the sim
    cfg = MultiplexConfig(collocate_same_device=False)
    flat = MultiplexSim(vgg_plan, cfg, InterferenceModel()).run(10)
    staged = MultiplexSim(vgg_plan, cfg, model).run(10)
    assert staged.fg_slowdown > flat.fg_slowdown


# -- admission control --------------------------------------------------------


def test_admit_rejects_over_bound(vgg_plan):
    from repro.core.multiplex import InterferenceModel as IM

    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=2),
                     tenants=_tenants(3),
                     interference=IM(gap_inflation=2.0))
    decision = col.admit(max_fg_slowdown=1.33)
    # every k >= 1 collocates the same gap stages -> same predicted
    # slowdown -> all infeasible: nothing is admitted
    assert decision.n_admitted == 0
    assert [t.job for t in decision.rejected] == [t.job for t in col.tenants]
    assert decision.curve[0] == (0, 1.0, pytest.approx(
        decision.curve[0][2]))
    assert all(s > 1.33 for k, s, _ in decision.curve if k >= 1)
    assert "rejected" in decision.row()


def test_admit_uncalibrated_admits_all_and_prefers_larger_roster(vgg_plan):
    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=2),
                     tenants=_tenants(3))
    decision = col.admit()
    # ideal disjointness (gap_inflation 1.0): every tenant is predicted
    # harmless; cluster-throughput ties go to the larger roster
    assert decision.n_admitted == 3 and not decision.rejected
    assert len(decision.curve) == 4
    ks = [k for k, _, _ in decision.curve]
    assert ks == [0, 1, 2, 3]
    # k=0 is the fg-only operating point: slowdown exactly 1.0 and strictly
    # less cluster throughput than any packed roster
    assert decision.curve[0][1] == 1.0
    assert decision.curve[0][2] < decision.curve[1][2]


def test_replan_drops_stale_stage_vector_keeps_scalar():
    """Regression: a plan-changing re-plan must drop the fitted per-stage
    inflation vector (keyed by OLD plan stage indices) but keep the scalar
    (a host property) — otherwise admission applies old-plan multipliers to
    the wrong stages of the new plan."""
    from repro.configs.vgg16 import CONFIG as VCFG
    from repro.core.coordinator import ClusterCoordinator, Job
    from repro.models.graph import build_vgg_graph

    coord = ClusterCoordinator(8)
    coord.submit_foreground(
        Job("fg", "foreground", build_vgg_graph(VCFG, 32), amp_limit=1.5)
    )
    coord.interference = InterferenceModel(
        gap_inflation=1.2, gap_inflation_stages=((3, 1.4),)
    )
    # no-op re-plan (same plan): calibration state survives
    coord.handle_join([])
    assert coord.interference.gap_inflation_stages == ((3, 1.4),)
    # real failure -> differently-shaped plan: stage vector dropped,
    # scalar kept, stale measurements cleared
    coord.collocation_results.append(_measured(1.2))
    coord.handle_failure(7)
    assert coord.interference.gap_inflation_stages == ()
    assert coord.interference.gap_inflation == pytest.approx(1.2)
    assert coord.collocation_results == []


def test_predict_zero_tenants_is_fg_only(vgg_plan):
    col = Collocator(vgg_plan, MultiplexConfig(), tenants=_tenants(2))
    pred = col.predict(0)
    assert pred.fg_slowdown == 1.0
    assert pred.bg_steps_per_iter == 0.0 and pred.tenants == ()
    assert 0.0 < pred.cluster_throughput <= 1.0 + 1e-9


def test_predict_cluster_throughput_monotone_in_tenants(vgg_plan):
    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=2),
                     tenants=_tenants(2))
    c = [col.predict(k).cluster_throughput for k in (0, 1, 2)]
    assert c[0] < c[1] <= c[2] + 1e-9
    assert all(0.0 < x <= 1.0 + 1e-9 for x in c)


def test_jain_fairness_index():
    even = CollocationResult(
        fg_iter_time=1.0, fg_iter_time_isolated=1.0, fg_slowdown=1.0,
        bg_steps_per_iter=8.0, bg_throughput=8.0, iterations=1,
        tenants=(
            TenantResult("a", 1, 4.0, 4.0),
            TenantResult("b", 1, 4.0, 4.0),
        ),
    )
    skewed = CollocationResult(
        fg_iter_time=1.0, fg_iter_time_isolated=1.0, fg_slowdown=1.0,
        bg_steps_per_iter=8.0, bg_throughput=8.0, iterations=1,
        tenants=(
            TenantResult("a", 1, 8.0, 8.0),
            TenantResult("b", 1, 0.0, 0.0),
        ),
    )
    assert even.jain_fairness() == pytest.approx(1.0)
    assert skewed.jain_fairness() == pytest.approx(0.5)
    # weighted: a 3:1 split under 3:1 weights IS fair
    weighted = CollocationResult(
        fg_iter_time=1.0, fg_iter_time_isolated=1.0, fg_slowdown=1.0,
        bg_steps_per_iter=8.0, bg_throughput=8.0, iterations=1,
        tenants=(
            TenantResult("a", 1, 6.0, 6.0, weight=3.0),
            TenantResult("b", 1, 2.0, 2.0, weight=1.0),
        ),
    )
    assert weighted.jain_fairness() == pytest.approx(1.0)
    assert _measured(1.0).jain_fairness() == 1.0  # no tenants
    # service-time units: a big-step tenant launching fewer steps for the
    # same device-time is NOT unfair (same rationale as note_launched)
    svc = CollocationResult(
        fg_iter_time=1.0, fg_iter_time_isolated=1.0, fg_slowdown=1.0,
        bg_steps_per_iter=10.0, bg_throughput=10.0, iterations=1,
        tenants=(
            TenantResult("big", 1, 2.0, 2.0, step_time=2e-3),
            TenantResult("small", 1, 8.0, 8.0, step_time=0.5e-3),
        ),
    )
    assert svc.jain_fairness() == pytest.approx(1.0)


def test_calibrated_model_flows_into_sim(vgg_plan):
    cfg = MultiplexConfig(collocate_same_device=False)
    base = MultiplexSim(vgg_plan, cfg, InterferenceModel()).run(10)
    cal = MultiplexSim(
        vgg_plan, cfg, InterferenceModel(gap_inflation=1.5)
    ).run(10)
    assert cal.fg_slowdown > base.fg_slowdown  # gap stages inflate
    # same-device (GPU) mode ignores the submesh multiplier
    gpu_cfg = MultiplexConfig(collocate_same_device=True)
    a = MultiplexSim(vgg_plan, gpu_cfg, InterferenceModel()).run(10)
    b = MultiplexSim(vgg_plan, gpu_cfg,
                     InterferenceModel(gap_inflation=1.5)).run(10)
    assert a.fg_slowdown == pytest.approx(b.fg_slowdown)


# -- tenant-density-aware interference ---------------------------------------


def test_density_factor_and_gap_inflation_at():
    m = InterferenceModel(gap_inflation=1.2, density_slope=2.0)
    assert m.density_factor(1.0) == 1.0
    assert m.density_factor(0.5) == 1.0  # degenerate densities are safe
    assert m.density_factor(3.0) == pytest.approx(5.0)
    assert m.gap_inflation_at(0, 1.0) == pytest.approx(1.2)
    # excess scales with density: 1 + 0.2 * (1 + 2*(3-1))
    assert m.gap_inflation_at(0, 3.0) == pytest.approx(2.0)
    # slope 0 (the default) is density-blind: prior behavior everywhere
    blind = InterferenceModel(gap_inflation=1.2)
    assert blind.gap_inflation_at(0, 4.0) == pytest.approx(1.2)
    assert blind.gap_inflation_at(0, 4.0) == blind.gap_inflation_for(0)


def test_predict_density_monotone_and_marginal_admission(vgg_plan):
    """With a positive density slope each extra collocated tenant inflates
    the shared gap stages a bit more, so the admission curve peaks at some
    0 < k < n — the sweep rejects the MARGINAL tenant, not all-or-nothing
    (a density-blind model predicts the same slowdown for every k >= 1)."""
    col = Collocator(
        vgg_plan, MultiplexConfig(max_inflight=2), tenants=_tenants(4),
        interference=InterferenceModel(gap_inflation=1.15, density_slope=2.0),
    )
    s = [col.predict(k).fg_slowdown for k in range(5)]
    assert s[0] == 1.0
    assert all(s[i] <= s[i + 1] + 1e-12 for i in range(4))  # monotone in k
    assert s[4] > s[1] + 1e-6  # density genuinely binds
    decision = col.admit(max_fg_slowdown=1.33)
    assert 0 < decision.n_admitted < 4, decision.row()
    assert decision.rejected
    # the chosen operating point is feasible; the rejected tail is not
    slows = {k: sl for k, sl, _ in decision.curve}
    assert slows[decision.n_admitted] <= 1.33 + 1e-9
    assert max(slows.values()) > 1.33


def _measured_at_density(slowdown, density, steps=6.0):
    """A measured result whose tenant rows all share gap stage 0, so the
    result's mean collocated density is exactly ``density``."""
    rows = tuple(
        TenantResult(f"t{i}", 0, steps / density,
                     steps / density / slowdown, gap_stages=(0,))
        for i in range(density)
    )
    return CollocationResult(
        fg_iter_time=slowdown, fg_iter_time_isolated=1.0,
        fg_slowdown=slowdown, bg_steps_per_iter=steps,
        bg_throughput=steps / slowdown, iterations=3, tenants=rows,
    )


def test_calibrate_fits_density_slope(vgg_plan):
    import math

    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=2),
                     tenants=_tenants(2))
    model = col.calibrate([_measured_at_density(1.06, 1),
                           _measured_at_density(1.12, 2)])
    # excess doubles when density goes 1 -> 2: slope identifies as 1.0
    assert model.density_slope == pytest.approx(1.0)
    assert model.gap_inflation > 1.0
    # the stored multipliers are density-1 BASES: prediction at the
    # calibration density still reproduces the measured geomean exactly
    geomean = math.exp((math.log(1.06) + math.log(1.12)) / 2)
    assert col.predict().fg_slowdown == pytest.approx(geomean, abs=1e-9)
    # interference SHRINKING with density is measurement noise: slope -> 0
    m_noise = col.calibrate([_measured_at_density(1.2, 1),
                             _measured_at_density(1.05, 2)])
    assert m_noise.density_slope == 0.0
    # results at a single density cannot identify the slope: prior kept
    col2 = Collocator(vgg_plan, MultiplexConfig(max_inflight=2),
                      tenants=_tenants(2),
                      interference=InterferenceModel(density_slope=0.7))
    m_single = col2.calibrate([_measured(1.1), _measured(1.2)])
    assert m_single.density_slope == pytest.approx(0.7)


def test_coordinator_readmit_continuous_admission():
    """`readmit` re-sweeps the live roster per epoch / on churn: with the
    density-aware model it keeps the feasible prefix and rejects the
    marginal tenant, logging an 'admission' event only when the admitted
    set CHANGES (stable rosters stay silent)."""
    from repro.core.coordinator import ClusterCoordinator, Job
    from repro.models.graph import build_vgg_graph

    coord = ClusterCoordinator(8, virtual_devices=True)
    coord.submit_foreground(
        Job("fg", "foreground", build_vgg_graph(VCFG, 32), amp_limit=1.5)
    )
    assert coord.readmit() is None  # no tenants: nothing to decide
    for i in range(3):
        coord.submit_background(
            Job(f"bg{i}", "background", [], priority=3 - i)
        )
    coord.interference = InterferenceModel(gap_inflation=1.28,
                                           density_slope=3.0)
    d1 = coord.readmit()
    assert d1 is not None and 0 < d1.n_admitted < 3, d1.row()
    assert coord.last_admission is d1
    admissions = [e for e in coord.events if e.kind == "admission"]
    assert len(admissions) == 1 and "epoch" in admissions[0].detail
    # stable roster re-admitted at the next epoch: same set, no new event
    d2 = coord.readmit()
    assert tuple(t.job for t in d2.admitted) == tuple(
        t.job for t in d1.admitted)
    assert len([e for e in coord.events if e.kind == "admission"]) == 1
    # churn: an admitted tenant departs -> the re-sweep decides anew and
    # logs the changed set
    gone = d1.admitted[0].job
    assert coord.handle_departure(gone)
    d3 = coord.readmit(reason="churn")
    assert gone not in [t.job for t in d3.admitted]
    churn = [e for e in coord.events if e.kind == "admission"]
    assert len(churn) == 2 and "churn" in churn[1].detail
