"""Multiplexing (paper §5): ablation ordering, pacing, feedback loop,
multi-tenant gap scheduling, executable caching and calibration."""
from dataclasses import replace

import pytest

from repro.configs.vgg16 import CONFIG as VCFG
from repro.core.costmodel import A100
from repro.core.multiplex import (
    BgTenant,
    Collocator,
    CollocationResult,
    ExecutableCache,
    InterferenceModel,
    MultiplexConfig,
    MultiplexSim,
    QoSMonitor,
)
from repro.core.planner import plan
from repro.models.graph import build_vgg_graph


@pytest.fixture(scope="module")
def vgg_plan():
    return plan(build_vgg_graph(VCFG, 32), 8, amp_limit=2.0, hw=A100)


def _run(plan_, **kw):
    cfg = MultiplexConfig(collocate_same_device=True, **kw)
    return MultiplexSim(plan_, cfg).run(20)


def test_fig11_ablation_ordering(vgg_plan):
    """Paper Fig 11: each mechanism improves foreground QoS."""
    naive = _run(vgg_plan, use_priorities=False, use_pacing=False,
                 use_feedback=False, use_granularity=False)
    prio = _run(vgg_plan, use_pacing=False, use_feedback=False,
                use_granularity=False)
    paced = _run(vgg_plan, use_feedback=False, use_granularity=False)
    fb = _run(vgg_plan, use_granularity=False)
    full = _run(vgg_plan)
    # paper: naive dramatically slows fg; priorities alone barely help
    assert naive.fg_slowdown > 1.5
    assert prio.fg_slowdown <= naive.fg_slowdown + 1e-9
    assert prio.fg_slowdown > paced.fg_slowdown  # pacing is the big win
    assert fb.fg_slowdown <= paced.fg_slowdown + 1e-9
    assert full.fg_slowdown <= fb.fg_slowdown + 1e-9


def test_tpu_submesh_mode_protects_fg(vgg_plan):
    res = MultiplexSim(vgg_plan, MultiplexConfig(collocate_same_device=False)).run(20)
    assert res.fg_slowdown < 1.15
    assert res.bg_steps_per_iter > 0  # gaps actually used


def test_granularity_fills_gaps_more(vgg_plan):
    fb = _run(vgg_plan, use_granularity=False)
    full = _run(vgg_plan)
    assert full.bg_steps_per_iter >= fb.bg_steps_per_iter


def test_cluster_util_bounded(vgg_plan):
    for kw in (dict(), dict(use_feedback=False), dict(use_pacing=False,
               use_feedback=False, use_priorities=False, use_granularity=False)):
        res = _run(vgg_plan, **kw)
        assert 0.0 <= res.cluster_throughput <= 1.0 + 1e-9


def test_qos_monitor_bans_sensitive_ops():
    m = QoSMonitor(slowdown_threshold=1.3)
    m.record_baseline("sync", 1.0)
    m.record("sync", 2.5, collocated=True)
    m.record("sync", 2.5, collocated=True)
    assert not m.collocation_allowed("sync")
    m.record_baseline("mlp", 1.0)
    m.record("mlp", 1.05, collocated=True)
    assert m.collocation_allowed("mlp")


def test_collocator_schedule_paced(vgg_plan):
    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=2))
    sched = col.schedule()
    assert all(n <= 2 for _, n in sched)  # pacing bound
    stages = {s for s, _ in sched}
    gap_stages = {g.stage_index for g in vgg_plan.gaps()}
    assert stages <= gap_stages


def test_collocator_hoists_bg_step_time(vgg_plan, monkeypatch):
    """The bg step quantum is computed once at construction — schedule()
    must not rebuild a MultiplexSim per call (the old per-iteration cost)."""
    import repro.core.multiplex as mx

    cfg = MultiplexConfig(max_inflight=2)
    col = Collocator(vgg_plan, cfg)
    assert col.bg_step_quantum == MultiplexSim(vgg_plan, cfg).bg_step_time()
    first = col.schedule()

    def boom(*a, **k):
        raise AssertionError("MultiplexSim rebuilt inside schedule()")

    monkeypatch.setattr(mx, "MultiplexSim", boom)
    assert col.schedule() == first
    assert col.schedule() == first


def test_collocator_respects_feedback(vgg_plan):
    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=4))
    gaps = vgg_plan.gaps()
    banned_stage = gaps[0].stage_index
    op = f"stage{banned_stage}"
    col.monitor.record_baseline(op, 1.0)
    col.monitor.record(op, 10.0, collocated=True)
    sched = dict(col.schedule())
    assert banned_stage not in sched


# -- multi-tenant gap scheduling ---------------------------------------------


def _tenants(n, base_priority=0):
    return [BgTenant(f"job{i}", base_priority + n - i, lambda m: (lambda: None))
            for i in range(n)]


def test_collocator_orders_tenants_by_priority(vgg_plan):
    low = BgTenant("low", 1, lambda m: (lambda: None))
    high = BgTenant("high", 9, lambda m: (lambda: None))
    mid_a = BgTenant("mid_a", 5, lambda m: (lambda: None))
    mid_b = BgTenant("mid_b", 5, lambda m: (lambda: None))
    col = Collocator(vgg_plan, MultiplexConfig(), tenants=[low, mid_a, mid_b, high])
    # slot 0 = highest priority; equal priorities keep submission order
    assert [t.job for t in col.tenants] == ["high", "mid_a", "mid_b", "low"]


def test_schedule_tenants_packs_by_priority(vgg_plan):
    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=2),
                     tenants=_tenants(2))
    sched = col.schedule_tenants()
    assert sched, "vgg plan gaps must admit tenants"
    single = dict(col.schedule())
    by_stage = {}
    for si, slot, n in sched:
        assert n <= 2  # pacing bound per tenant
        by_stage.setdefault(si, []).append((slot, n))
    gap_stages = {g.stage_index for g in vgg_plan.gaps()}
    assert set(by_stage) <= gap_stages
    for si, slots in by_stage.items():
        # same paced step count as the single-tenant schedule, per tenant
        assert all(n == single[si] for _, n in slots)
        # slots are 0..k-1 (priority-ordered chunks)
        assert [s for s, _ in sorted(slots)] == list(range(len(slots)))
    # at least one gap is wide enough for both tenants to co-run
    assert any(len(s) == 2 for s in by_stage.values())
    # feedback ban empties the whole gap for every tenant
    banned = sched[0][0]
    col.monitor.record_baseline(f"stage{banned}", 1.0)
    col.monitor.record(f"stage{banned}", 10.0, collocated=True)
    assert all(si != banned for si, _, _ in col.schedule_tenants())


def test_schedule_tenants_never_exceeds_free_devices(vgg_plan):
    from repro.core.plan import pack_ranges

    for n in (1, 2, 3, 8):
        col = Collocator(vgg_plan, MultiplexConfig(), tenants=_tenants(n))
        sched = col.schedule_tenants()
        for si, slot, _ in sched:
            free = vgg_plan.free_device_ranges(si)
            chunks = pack_ranges(free, n)
            assert slot < len(chunks)  # a slot only exists if it got devices


def test_executable_cache_semantics():
    cache = ExecutableCache()
    built = []

    def build_a():
        built.append("a")
        return lambda: "a"

    k1 = ("sigA", (0, 1), (2, 1))
    assert cache.get_or_build(k1, build_a)() == "a"
    assert (cache.hits, cache.misses) == (0, 1)
    # same key -> reuse, no rebuild
    assert cache.get_or_build(k1, build_a)() == "a"
    assert (cache.hits, cache.misses) == (1, 1)
    assert built == ["a"]
    # different device ids or shape -> distinct executable
    cache.get_or_build(("sigA", (2, 3), (2, 1)), build_a)
    cache.get_or_build(("sigB", (0, 1), (2, 1)), build_a)
    assert (cache.hits, cache.misses) == (1, 3)


def test_bg_tenant_cache_signature_fallbacks():
    def factory(mesh):
        return lambda: None

    def other_factory(mesh):
        return lambda: None

    # untagged factories key on the factory OBJECT: two different factories
    # under the same job name never share a compiled executable
    assert BgTenant("jobX", 0, factory).cache_signature is factory
    assert (BgTenant("jobX", 0, factory).cache_signature
            != BgTenant("jobX", 0, other_factory).cache_signature)
    factory.signature = "arch-b4-s8"
    assert BgTenant("jobX", 0, factory).cache_signature == "arch-b4-s8"
    assert BgTenant("jobX", 0, factory,
                    signature="explicit").cache_signature == "explicit"
    # no factory at all: fall back to the job name
    assert BgTenant("jobY", 0).cache_signature == "jobY"


# -- calibration -------------------------------------------------------------


def _measured(slowdown, steps=6.0):
    return CollocationResult(
        fg_iter_time=slowdown, fg_iter_time_isolated=1.0,
        fg_slowdown=slowdown, bg_steps_per_iter=steps,
        bg_throughput=steps / slowdown, iterations=3,
    )


def test_calibrate_inverts_to_measured_slowdown(vgg_plan):
    col = Collocator(vgg_plan, MultiplexConfig(max_inflight=2),
                     tenants=_tenants(2))
    model = col.calibrate([_measured(1.20)])
    assert model.gap_inflation > 1.0
    pred = col.predict()
    # closed-form inversion: prediction reproduces the measurement exactly
    assert pred.fg_slowdown == pytest.approx(1.20, abs=1e-9)
    assert pred.iterations == 0  # marked as predicted, not measured
    # predicted steps mirror the tenant schedule
    sched = col.schedule_tenants()
    assert pred.bg_steps_per_iter == pytest.approx(
        sum(n for _, _, n in sched))
    assert len(pred.tenants) == 2
    # admission-control what-if beyond the roster: placeholder rows keep
    # per-tenant steps summing to the aggregate (no phantom slots)
    pred3 = col.predict(n_tenants=3)
    assert len(pred3.tenants) == 3
    assert sum(t.bg_steps_per_iter for t in pred3.tenants) == pytest.approx(
        pred3.bg_steps_per_iter)
    # geometric mean over several results; sub-1.0 measurements clamp
    m2 = col.calibrate([_measured(1.2), _measured(1.2), _measured(0.8)])
    assert 1.0 < m2.gap_inflation < model.gap_inflation
    # no measured results -> model unchanged
    assert col.calibrate([]) is m2
    # predictions without measurements are excluded
    assert col.calibrate([pred]) is m2


def test_calibrated_model_flows_into_sim(vgg_plan):
    cfg = MultiplexConfig(collocate_same_device=False)
    base = MultiplexSim(vgg_plan, cfg, InterferenceModel()).run(10)
    cal = MultiplexSim(
        vgg_plan, cfg, InterferenceModel(gap_inflation=1.5)
    ).run(10)
    assert cal.fg_slowdown > base.fg_slowdown  # gap stages inflate
    # same-device (GPU) mode ignores the submesh multiplier
    gpu_cfg = MultiplexConfig(collocate_same_device=True)
    a = MultiplexSim(vgg_plan, gpu_cfg, InterferenceModel()).run(10)
    b = MultiplexSim(vgg_plan, gpu_cfg,
                     InterferenceModel(gap_inflation=1.5)).run(10)
    assert a.fg_slowdown == pytest.approx(b.fg_slowdown)
