"""Data pipeline determinism + fault/straggler detection."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import SyntheticLMData
from repro.dist.faults import HeartbeatMonitor, MitigationLog, StepTimer


def test_data_deterministic_and_resumable():
    cfg = get_config("llama3-8b").reduced()
    d1 = SyntheticLMData(cfg, batch=2, seq=16, seed=7)
    batches = [next(d1) for _ in range(4)]
    d1.close()
    # resume from step 2 reproduces batches 2,3
    d2 = SyntheticLMData(cfg, batch=2, seq=16, seed=7, start_step=2)
    b2 = next(d2)
    d2.close()
    np.testing.assert_array_equal(np.asarray(batches[2]["tokens"]),
                                  np.asarray(b2["tokens"]))


def test_data_labels_shifted():
    cfg = get_config("llama3-8b").reduced()
    d = SyntheticLMData(cfg, batch=1, seq=16, seed=0)
    b = next(d)
    d.close()
    np.testing.assert_array_equal(np.asarray(b["labels"][0, :-1]),
                                  np.asarray(b["tokens"][0, 1:]))


def test_data_enc_dec_shapes():
    cfg = get_config("seamless-m4t-large-v2").reduced()
    d = SyntheticLMData(cfg, batch=2, seq=32, seed=0)
    b = next(d)
    d.close()
    assert b["frames"].shape == (2, 32, cfg.d_model)
    assert b["tokens"].shape[1] == 8  # seq // DEC_RATIO


def test_step_timer_deadline():
    t = StepTimer(deadline_factor=2.0, warmup_steps=3)
    for _ in range(5):
        t.record(1.0)
    assert not t.is_straggler_step(1.5)
    assert t.is_straggler_step(2.5)


def test_heartbeat_failure_and_straggler():
    clock = {"t": 0.0}
    hb = HeartbeatMonitor(n_workers=4, timeout=10.0, lag=1,
                          clock=lambda: clock["t"])
    for w in range(4):
        hb.beat(w, step=5)
    assert hb.failed() == [] and hb.stragglers() == []
    # worker 3 goes silent and lags
    clock["t"] = 5.0
    for w in range(3):
        hb.beat(w, step=9)
    assert hb.stragglers() == [3]
    clock["t"] = 20.0
    assert 3 in hb.failed()


def test_mitigation_log():
    m = MitigationLog()
    m.log("straggler", step=3)
    m.log("failure", step=4)
    m.log("straggler", step=9)
    assert m.count("straggler") == 2 and m.count("failure") == 1


def test_step_timer_ema_not_poisoned_by_stragglers():
    """Regression: over-deadline samples folded into the EMA inflated the
    deadline after one slow step, so a persistently slow worker stopped
    being flagged within a few steps.  Straggler samples must be excluded
    from the EMA — the worker stays flagged for as long as it is slow."""
    t = StepTimer(deadline_factor=2.0, warmup_steps=3, ema_alpha=0.2)
    for _ in range(5):
        t.record(1.0)
    ema0 = t.ema
    for _ in range(20):  # persistently slow: EVERY step stays flagged
        assert t.is_straggler_step(3.0)
        t.record(3.0)
    assert t.is_straggler_step(3.0)
    assert t.ema == pytest.approx(ema0)  # straggler samples never folded in
    t.record(1.1)  # healthy samples still adapt the deadline
    assert t.ema > ema0


def test_heartbeat_unknown_beat_join_forget():
    """Regression: beat() silently accepted unknown worker ids — `last`
    grew past n_workers with no join semantics and the coordinator never
    learned a device appeared.  Unknown beats are now a hard error; the
    explicit join()/forget() lifecycle is idempotent."""
    clock = {"t": 0.0}
    hb = HeartbeatMonitor(n_workers=2, timeout=5.0, clock=lambda: clock["t"])
    with pytest.raises(KeyError):
        hb.beat(9, step=0)
    assert hb.join(9) is True and hb.n_workers == 3
    hb.beat(9, step=0)  # registered now
    assert hb.join(9) is False  # idempotent re-join
    clock["t"] = 10.0
    assert hb.failed() == [0, 1, 9]
    assert hb.forget(9) is True and hb.forget(9) is False
    assert hb.failed() == [0, 1] and hb.n_workers == 2
