"""Seeded differential tests for the multi-tenant gap scheduler.

Three contracts, all on the 8-host-device smoke configs (subprocesses with a
forced host device count, like tests/test_collocation.py):

1. Calibration: after ``Collocator.calibrate`` on a measured
   ``CollocationResult``, the analytic ``predict()`` must agree with the
   measurement — fg slowdown within ``SLOWDOWN_TOL`` (absolute) and bg
   steps/iter within ``STEPS_REL_TOL`` (relative) — and the calibrated
   ``MultiplexSim.run`` submesh path must land within ``SIM_SLOWDOWN_TOL``.
2. Executable-cache transparency: a cache-hit run must produce the same
   tenant schedule and per-tenant launched step counts as the cache-miss
   run that populated it (feedback off, so the schedule is deterministic).
3. Re-plan reuse + eviction: a ``ClusterCoordinator`` re-plan with an
   unchanged gap shape must hit the executable cache instead of rebuilding
   bg steps, a device *failure* must evict the jitted steps whose submesh
   touched the dead device (their device-committed state is gone), and the
   cache's entry count must stay bounded across repeated failure/join
   re-plan cycles (the acceptance criterion for bounded executable reuse).
"""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# stated tolerances (contract 1)
SLOWDOWN_TOL = 0.15      # predict() vs measured fg slowdown, absolute
STEPS_REL_TOL = 1e-6     # predict() vs measured bg steps/iter (feedback off:
                         # the executable launches exactly the schedule)
SIM_SLOWDOWN_TOL = 0.40  # MultiplexSim.run (adds overrun modeling), absolute


def _run(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


_PRELUDE = """
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.vgg16 import CONFIG as VCFG
    from repro.core.costmodel import A100
    from repro.core.multiplex import (
        BgTenant, Collocator, ExecutableCache, MultiplexConfig, MultiplexSim,
    )
    from repro.core.planner import plan
    from repro.models.graph import build_vgg_graph

    p = plan(build_vgg_graph(VCFG, 32), 8, amp_limit=1.5, hw=A100)

    def make_fg(stage, mesh):
        x = jax.device_put(jnp.full((128, 128), 0.01),
                           NamedSharding(mesh, P(None, None)))
        f = jax.jit(lambda x: jnp.tanh(x @ x).sum())
        return lambda: f(x)

    def mk_factory(sig):
        def factory(mesh):
            x = jax.device_put(jnp.ones((32, 32)),
                               NamedSharding(mesh, P(None, None)))
            g = jax.jit(lambda x: (x @ x).sum())
            return lambda: g(x)
        factory.signature = sig
        return factory

    tenants = [BgTenant("jobA", 2, mk_factory("A")),
               BgTenant("jobB", 1, mk_factory("B"))]
    cfg = MultiplexConfig(max_inflight=2, use_feedback=False)
"""


def test_calibrated_prediction_tracks_measurement():
    out = _run(_PRELUDE + f"""
    col = Collocator(p, cfg, tenants=tenants)
    res = col.run_executable(make_fg, iterations=3)
    assert res.bg_steps_per_iter > 0, res

    model = col.calibrate([res])
    assert model.gap_inflation >= 1.0
    pred = col.predict()

    # predict() replays the same tenant schedule through the fitted model:
    # slowdown within {SLOWDOWN_TOL} abs (calibration clamps measured
    # slowdown at 1.0), steps/iter exact (feedback off -> the executable
    # launched exactly the schedule every iteration)
    meas_s = max(res.fg_slowdown, 1.0)
    assert abs(pred.fg_slowdown - meas_s) <= {SLOWDOWN_TOL}, (
        pred.fg_slowdown, res.fg_slowdown)
    assert abs(pred.bg_steps_per_iter - res.bg_steps_per_iter) <= (
        {STEPS_REL_TOL} * max(res.bg_steps_per_iter, 1.0)), (
        pred.bg_steps_per_iter, res.bg_steps_per_iter)
    # per-tenant prediction matches per-tenant measurement
    for pt, mt in zip(pred.tenants, res.tenants):
        assert pt.job == mt.job
        assert abs(pt.bg_steps_per_iter - mt.bg_steps_per_iter) <= 1e-6

    # the calibrated discrete-event sim tracks the measured slowdown too
    # (looser: it adds non-preemptive overrun modeling on top)
    sim = MultiplexSim(p, cfg, model).run(20)
    assert abs(sim.fg_slowdown - meas_s) <= {SIM_SLOWDOWN_TOL}, (
        sim.fg_slowdown, meas_s)
    print("OK", pred.fg_slowdown, res.fg_slowdown, sim.fg_slowdown)
    """)
    assert "OK" in out


def test_cache_hit_vs_miss_identical_schedules():
    out = _run(_PRELUDE + """
    cache = ExecutableCache()
    col1 = Collocator(p, cfg, tenants=tenants, cache=cache)
    res1 = col1.run_executable(make_fg, iterations=2)
    assert res1.cache_misses > 0 and res1.bg_steps_per_iter > 0
    miss_after_first = cache.misses

    col2 = Collocator(p, cfg, tenants=tenants, cache=cache)
    res2 = col2.run_executable(make_fg, iterations=2)
    # warm cache: every bg step fn is reused, none rebuilt
    assert cache.misses == miss_after_first, (cache.misses, miss_after_first)
    assert res2.cache_misses == 0 and res2.cache_hits > 0

    # identical schedules: same (stage, slot, n) triples...
    assert col1.schedule_tenants() == col2.schedule_tenants()
    # ...and identical launched work per tenant and per iteration
    for t1, t2 in zip(res1.tenants, res2.tenants):
        assert t1.job == t2.job and t1.gap_stages == t2.gap_stages
        assert abs(t1.bg_steps_per_iter - t2.bg_steps_per_iter) <= 1e-9
        assert t1.devices == t2.devices
    assert [n for _, n in res1.iter_details] == \
        [n for _, n in res2.iter_details]
    print("OK", res1.bg_steps_per_iter, res2.bg_steps_per_iter)
    """)
    assert "OK" in out


def test_replan_unchanged_gap_shape_hits_cache():
    out = _run(_PRELUDE + """
    from repro.core.coordinator import ClusterCoordinator, Job

    coord = ClusterCoordinator(8)
    coord.submit_foreground(
        Job("fg", "foreground", build_vgg_graph(VCFG, 32), amp_limit=1.5)
    )
    coord.submit_background(
        Job("bgA", "background", [], priority=2, step_fn_factory=mk_factory("A"))
    )
    coord.submit_background(
        Job("bgB", "background", [], priority=1, step_fn_factory=mk_factory("B"))
    )
    res1 = coord.collocate(cfg, executable=True, make_fg_stage_fn=make_fg)
    assert res1.iterations > 0 and res1.bg_steps_per_iter > 0
    # both submitted background jobs were admitted and actually co-ran
    assert res1.rejected_tenants == ()
    assert len(res1.tenants) == 2
    assert all(t.bg_steps_per_iter > 0 for t in res1.tenants), res1.tenants
    assert res1.tenants[0].job == "bgA"  # priority order
    assert res1.cache_misses > 0 and coord.exec_cache.misses > 0
    misses = coord.exec_cache.misses

    # elastic no-op re-plan: same healthy set -> identical plan -> identical
    # gap submesh shapes -> compiled bg steps are reused, not rebuilt (and
    # nothing is evicted: every cached submesh is still on live devices)
    plan_before = coord.foreground().plan
    coord.handle_join([])
    assert coord.foreground().plan.layers == plan_before.layers
    assert coord.exec_cache.evictions == 0
    res2 = coord.collocate(cfg, executable=True, make_fg_stage_fn=make_fg)
    assert coord.exec_cache.misses == misses, (coord.exec_cache.misses, misses)
    assert res2.cache_misses == 0 and res2.cache_hits >= res1.cache_misses

    # a real failure kills device 7: every jitted step whose submesh touched
    # it holds dead device-committed state and must be evicted (the PR-4
    # cache held these alive forever); surviving subsets stay cached
    entries_full = len(coord.exec_cache.entries)
    coord.handle_failure(7)
    dead = jax.devices()[7].id
    assert coord.exec_cache.evictions > 0
    assert all(dead not in k[1] for k in coord.exec_cache.entries)
    coord.collocate(cfg, executable=True, make_fg_stage_fn=make_fg)
    misses_small = coord.exec_cache.misses

    # join back to the original set: entries that never touched device 7
    # are reused; the evicted ones recompile (their state died with the
    # device) — the cache must NOT have held them alive
    coord.handle_join([7])
    assert coord.foreground().plan.layers == plan_before.layers
    res4 = coord.collocate(cfg, executable=True, make_fg_stage_fn=make_fg)
    assert res4.cache_hits > 0  # surviving device subsets were reused
    assert coord.exec_cache.misses >= misses_small

    # bounded across repeated failure/join re-plan cycles: entry count and
    # per-cycle compilations reach a fixed point instead of accumulating
    sizes, cycle_misses = [], []
    for _ in range(3):
        coord.handle_failure(7)
        coord.collocate(cfg, executable=True, make_fg_stage_fn=make_fg)
        coord.handle_join([7])
        m0 = coord.exec_cache.misses
        coord.collocate(cfg, executable=True, make_fg_stage_fn=make_fg)
        sizes.append(len(coord.exec_cache.entries))
        cycle_misses.append(coord.exec_cache.misses - m0)
    assert sizes[0] == sizes[1] == sizes[2], sizes  # no unbounded growth
    assert len(coord.exec_cache.entries) <= coord.exec_cache.max_entries
    assert cycle_misses[1] == cycle_misses[2], cycle_misses  # steady state
    print("OK", res1.bg_steps_per_iter, res4.bg_steps_per_iter)
    """)
    assert "OK" in out
