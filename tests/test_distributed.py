"""Distributed behaviour under forced host-device counts (subprocesses —
jax device count locks at first init, so each scenario gets a fresh
interpreter)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_sharded_train_step_runs():
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import TRAIN_4K, get_config
        from repro.launch.mesh import make_mesh
        from repro.models import get_model
        from repro.models.api import make_batch
        from repro.optim.optimizer import make_optimizer
        from repro.train.state import init_state
        from repro.train.step import jit_train_step
        mesh = make_mesh(4, 2)
        shape = dataclasses.replace(TRAIN_4K, seq_len=64, global_batch=8)
        cfg = get_config("llama3-8b").reduced()
        api = get_model(cfg)
        opt = make_optimizer(cfg)
        with mesh:
            fn, st_sh, bt_sh = jit_train_step(api, opt, mesh, shape)
            state = jax.device_put(init_state(jax.random.PRNGKey(0), api, opt), st_sh)
            batch = jax.device_put(make_batch(jax.random.PRNGKey(1), cfg, 8, 64), bt_sh)
            l0 = None
            for _ in range(4):
                state, m = fn(state, batch)
                if l0 is None: l0 = float(m["loss"])
            print("LOSS", l0, float(m["loss"]))
        """)
    l0, l1 = [float(x) for x in out.strip().split()[1:]]
    assert l1 < l0


def test_elastic_checkpoint_restore_across_meshes():
    """Save on a (4,2) mesh, restore onto (2,2) — elastic re-shard."""
    out = _run("""
        import dataclasses, tempfile, jax, numpy as np
        from repro.configs import TRAIN_4K, get_config
        from repro.launch.mesh import make_mesh
        from repro.models import get_model
        from repro.optim.optimizer import make_optimizer
        from repro.train.state import init_state, state_shardings
        from repro.dist.sharding import sharding_rules
        from repro.checkpoint import ckpt
        cfg = get_config("qwen2-1.5b").reduced()
        api = get_model(cfg); opt = make_optimizer(cfg)
        d = tempfile.mkdtemp()
        m1 = make_mesh(4, 2)
        sh1 = state_shardings(api, opt, sharding_rules(cfg, m1), m1)
        s = jax.device_put(init_state(jax.random.PRNGKey(0), api, opt), sh1)
        ckpt.save(d, s, step=3, async_=False)
        m2 = make_mesh(2, 2)
        sh2 = state_shardings(api, opt, sharding_rules(cfg, m2), m2)
        restored, meta = ckpt.restore(d, s, shardings=sh2)
        a = np.asarray(restored["params"]["embed"]); b = np.asarray(s["params"]["embed"])
        assert np.array_equal(a, b); assert meta["step"] == 3
        print("OK")
        """)
    assert "OK" in out


def test_powersgd_shard_map_matches_mean():
    """PowerSGD all-reduce inside shard_map approximates psum-mean, and the
    approximation improves with iterations (error feedback)."""
    out = _run("""
        import jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.optim.grad_compression import init_state, powersgd_allreduce
        try:
            shard_map = jax.shard_map
        except AttributeError:  # jax < 0.6 keeps it in experimental
            from jax.experimental.shard_map import shard_map
        mesh = make_mesh(4, 1)
        g_global = jax.random.normal(jax.random.PRNGKey(0), (4, 32, 16))
        st = init_state({"w": jnp.zeros((32, 16))}, rank=8)
        @partial(shard_map, mesh=mesh, in_specs=(P("data"), P(None)),
                 out_specs=(P("data"), P(None)))
        def run(g, q):
            gs = {"w": g[0]}
            state = {"w": {"q": q, "err": jnp.zeros((32, 16))}}
            total = jnp.zeros((32, 16))
            K = 8
            for _ in range(K):
                approx, state = powersgd_allreduce(gs, state, axis="data", rank=8)
                total = total + approx["w"]
            return (total / K)[None], state["w"]["q"]
        avg, _ = run(g_global, st["w"]["q"])
        want = jnp.mean(g_global, axis=0)
        # 1. synchronization: every shard holds the SAME reduced gradient
        spread = jnp.max(jnp.abs(avg - avg[0:1]))
        # 2. error feedback: the running average approaches the true mean
        err = jnp.linalg.norm(avg[0] - want) / jnp.linalg.norm(want)
        print("SPREAD", float(spread), "ERR", float(err))
        """)
    parts = out.strip().split()
    spread, err = float(parts[1]), float(parts[3])
    assert spread < 1e-5  # all-reduce property: shards agree
    assert err < 0.6  # error feedback drives the average toward the mean


def test_dryrun_cell_small():
    """The dry-run machinery end-to-end on a tiny forced mesh."""
    out = _run("""
        import jax
        from repro.launch.hlo_analysis import analyze_hlo
        import jax.numpy as jnp
        def f(x, w):
            def body(h, wl):
                return jnp.tanh(h @ wl), None
            h, _ = jax.lax.scan(body, x, w)
            return h.sum()
        g = jax.jit(jax.grad(f, argnums=1))
        L = 6
        lowered = g.lower(jax.ShapeDtypeStruct((8, 32), jnp.float32),
                          jax.ShapeDtypeStruct((L, 32, 32), jnp.float32))
        hc = analyze_hlo(lowered.compile().as_text(), default_trip_count=L)
        # fwd: L × 2*8*32*32 ; bwd ≈ 2× more. Check the trip multiplier bites:
        per_layer = 2 * 8 * 32 * 32
        print("FLOPS", hc.dot_flops, per_layer * L)
        """)
    flops, fwd = [float(x) for x in out.strip().split()[1:]]
    assert flops >= fwd * 2.0  # at least fwd+bwd, trip-aware
    assert flops <= fwd * 8.0


def test_applied_reconfig_recarves_mesh_mid_run():
    """Applied reconfiguration end-to-end under a forced 8-device host:
    four phantom workers go silent together, the co-hosted loop re-plans
    down to the 4 survivors, and at the next epoch boundary the worker
    actually re-carves its mesh onto the surviving pool (one remesh — the
    latest event wins over the intermediate 7/6/5-device re-plans), then
    finishes every step on the new carving."""
    out = _run("""
        import dataclasses, jax
        from repro.configs import TRAIN_4K, get_config
        from repro.configs.vgg16 import CONFIG as VCFG
        from repro.core.coordinator import ClusterCoordinator, Job
        from repro.dist.faults import HeartbeatMonitor
        from repro.dist.transport import (CoordinatorLoop, WorkerClient,
                                          fake_transport_pair)
        from repro.launch.mesh import make_mesh
        from repro.models.graph import build_vgg_graph
        from repro.train.loop import TrainConfig, train

        clk = {"t": 0.0}
        worker_end, coord_end = fake_transport_pair()
        coord = ClusterCoordinator(8, clock=lambda: clk["t"],
                                   virtual_devices=True)
        coord.submit_foreground(Job("fg", "foreground",
                                    build_vgg_graph(VCFG, 32),
                                    amp_limit=1.5))
        mon = HeartbeatMonitor(1, timeout=5.0, clock=lambda: clk["t"])
        loop = CoordinatorLoop(coord_end, mon, coordinator=coord)
        # four phantoms (4..7) beat once, then go silent: they time out
        # TOGETHER, so one pump publishes the whole re-plan chain and the
        # worker applies only the last pool [0..3]
        for w in (4, 5, 6, 7):
            WorkerClient(worker_end, w).beat(0)

        def advance_clock(step):
            clk["t"] = float(step)

        cfg = get_config("qwen2-1.5b").reduced()
        shape = dataclasses.replace(TRAIN_4K, seq_len=64, global_batch=8,
                                    name="smoke")
        tc = TrainConfig(steps=12, coordinator=coord, heartbeat=mon,
                         transport=worker_end, control_loop=loop,
                         apply_reconfig=True)
        report = train(cfg, shape, make_mesh(8, 1), tc,
                       fault_injector=advance_clock)
        assert report.steps_done == 12
        assert report.mitigations.count("join") == 4
        assert report.mitigations.count("failure_detected") == 4
        assert report.mitigations.count("replan") == 4
        assert report.mitigations.count("reconfig") == 4
        assert report.remeshes == 1  # latest event wins: ONE re-carve
        ev = next(e for e in report.mitigations.events
                  if e["kind"] == "reconfig_applied")
        assert ev["mesh_devices"] == 4 and ev["gpus"] == 4
        assert coord.healthy == {0, 1, 2, 3}
        assert all(l == l for l in report.losses)  # finite across re-shard
        print("REMESHES", report.remeshes, report.steps_done)
        """)
    assert "REMESHES 1 12" in out
