"""Static sharding sweep: the reachable-mesh enumeration matches the live
mesh builder, seeded bad specs are flagged, and the sweep is clean on a
sample of registered configs (CI runs the full sweep)."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.shardcheck import (
    AbstractMesh,
    check_cell,
    check_spec,
    reachable_mesh_shapes,
    sweep,
)
from repro.launch.mesh import pow2_mesh_shape


def test_reachable_shapes_match_live_mesh_builder():
    shapes = reachable_mesh_shapes(range(1, 65))
    assert (1, 1) in shapes and (8, 8) in shapes
    for n in range(1, 65):
        data, model = pow2_mesh_shape(n)
        assert (data, model) in shapes
        assert data * model <= n           # never more devices than exist
        assert model & (model - 1) == 0    # model axis is a power of two
        assert data >= model               # data-major factorization


def test_pow2_mesh_shape_nonpow2_pools():
    # survivor pools: 7 devices keep all 7 (7x1), not the pow2 floor
    assert pow2_mesh_shape(7) == (7, 1)
    assert pow2_mesh_shape(64) == (8, 8)
    with pytest.raises(ValueError):
        pow2_mesh_shape(0)


def test_check_spec_flags_each_invariant():
    sizes = {"data": 4, "model": 2}
    where = "t"
    # unknown mesh axis
    vs = check_spec(P("replica"), (8,), sizes, where)
    assert {v.check for v in vs} == {"shard-axis"}
    # one mesh axis sharding two dims
    vs = check_spec(P("data", "data"), (8, 8), sizes, where)
    assert {v.check for v in vs} == {"shard-reuse"}
    # indivisible dim
    vs = check_spec(P(("data", "model")), (12,), sizes, where)
    assert {v.check for v in vs} == {"shard-divisibility"}
    # rank overflow
    vs = check_spec(P("data", None, None), (8, 8), sizes, where)
    assert {v.check for v in vs} == {"shard-rank"}
    # clean spec
    assert check_spec(P("data", "model"), (8, 8), sizes, where) == []


def test_abstract_mesh_is_tiny_and_shaped():
    m = AbstractMesh((16, 4))
    assert m.axis_names == ("data", "model")
    assert m.devices.shape == (16, 4)
    assert m.devices.nbytes == 64  # int8 stand-in, not real devices
    assert "data=16" in repr(m) and "model=4" in repr(m)


def test_check_cell_flags_unknown_logical_axis():
    """A schema naming a logical axis the rules don't know would silently
    replicate — seeded via a minimal fake config/schema through the same
    pspec machinery."""
    from repro.configs import get_config
    from repro.dist.sharding import RuleReport, pspec, sharding_rules

    cfg = get_config("llama3-8b").reduced()
    mesh = AbstractMesh((2, 2))
    rules = sharding_rules(cfg, mesh, None)
    assert "embed" in rules and "typo_axis" not in rules
    report = RuleReport()
    spec = pspec(("typo_axis",), (8,), rules, mesh, report)
    # the engine silently replicates it — exactly why shard-logical exists
    assert tuple(spec) == ()


@pytest.mark.parametrize("name", ["llama3-8b", "qwen3-moe-30b-a3b"])
def test_sweep_clean_on_sample_configs(name):
    violations, stats = sweep([name], pool_sizes=range(1, 17))
    assert violations == [], [str(v) for v in violations]
    assert stats["cells"] > 0
    # odd pool sizes must degrade (drop), never violate
    assert stats["dropped"] > 0


def test_check_cell_counts_drops_not_violations():
    from repro.configs import get_config

    cfg = get_config("llama3-8b").reduced()
    vs, dropped = check_cell(cfg, None, AbstractMesh((7, 1)))
    assert vs == []
    assert dropped >= 0
