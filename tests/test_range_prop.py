"""Property-based tests for device-range arithmetic and multi-tenant gap
packing (hypothesis when installed, the deterministic tests/_prop.py shim
otherwise).

Invariants:
  merge_ranges      — sorted, pairwise-disjoint (no touching), idempotent,
                      covers exactly the union of its inputs.
  complement_ranges — tiles [0, total) exactly against the merged busy set.
  pack_ranges       — chunks are disjoint, quantum-aligned, inside the free
                      set, sorted largest-first, at most n of them.
  pack_ranges (per-tenant quanta) — exactly n slot entries; slot i's chunk
                      is a multiple of quantum[i] (None when unsatisfiable),
                      chunks stay disjoint and inside the free set, and a
                      uniform quantum vector degenerates to scalar mode.
  plan packing      — for random BurstPlans with random BranchPlacements,
                      tenant chunks never overlap the stage's fg devices or
                      the branch windows active in that stage (scalar and
                      per-tenant modes alike).
  fair rotation     — for equal-priority tenants scheduled over N
                      iterations with deficit accounting, no tenant starves:
                      every tenant runs at least floor(N / n_tenants) times
                      whenever any peer runs (the starvation bound).
"""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 must collect without hypothesis
    from _prop import given, settings, strategies as st

from repro.core.plan import (
    BranchPlacement,
    BurstPlan,
    LayerPlan,
    complement_ranges,
    merge_ranges,
    pack_ranges,
)

MAX_EXAMPLES = 60

raw_range = st.builds(lambda a, b: (a, b), st.integers(0, 40), st.integers(0, 40))
range_lists = st.lists(raw_range, min_size=0, max_size=8)


def _covered(ranges, p):
    return any(s <= p < e for s, e in ranges)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(range_lists)
def test_merge_ranges_invariants(ranges):
    merged = merge_ranges(ranges)
    # sorted + strictly disjoint (touching ranges are coalesced)
    for (s1, e1), (s2, e2) in zip(merged, merged[1:]):
        assert e1 < s2
    for s, e in merged:
        assert s < e
    # idempotent
    assert merge_ranges(merged) == merged
    # pointwise coverage identical to the union of the inputs
    for p in range(42):
        assert _covered(merged, p) == _covered(ranges, p)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(range_lists, st.integers(1, 40))
def test_complement_ranges_tiles_exactly(busy, total):
    free = complement_ranges(busy, total)
    merged = merge_ranges(busy)
    clipped = [(max(0, s), min(e, total)) for s, e in merged]
    clipped = [(s, e) for s, e in clipped if e > s]
    # free + clipped busy tile [0, total): every point in exactly one side
    for p in range(total):
        assert _covered(free, p) != _covered(clipped, p)
    # complement is itself merged (disjoint + sorted) and involutive
    assert merge_ranges(free) == free
    assert complement_ranges(free, total) == clipped


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(range_lists, st.integers(1, 5), st.integers(1, 4))
def test_pack_ranges_invariants(free, n, quantum):
    chunks = pack_ranges(free, n, quantum=quantum)
    assert len(chunks) <= n
    merged_free = merge_ranges(free)
    sizes = [e - s for s, e in chunks]
    # quantum-aligned sizes, each chunk inside one free range
    for (s, e), size in zip(chunks, sizes):
        assert size > 0 and size % quantum == 0
        assert any(fs <= s and e <= fe for fs, fe in merged_free)
    # largest-first (priority slot 0 gets the biggest chunk)
    assert sizes == sorted(sizes, reverse=True)
    # pairwise disjoint
    ordered = sorted(chunks)
    for (s1, e1), (s2, e2) in zip(ordered, ordered[1:]):
        assert e1 <= s2


# -- random plans: tenant packing never overlaps fg or branch devices --------


def _random_plan(num_gpus, layer_gpus, placements):
    layers = tuple(
        LayerPlan(index=i, name=f"l{i}", gpus=min(g, num_gpus), time=1.0,
                  comp=1.0, sync=0.0, comm_in=0.0, amp=1.0)
        for i, g in enumerate(layer_gpus)
    )
    details = {}
    for j, (start, width, parallel, layer_index) in enumerate(placements):
        start = start % num_gpus
        end = min(start + 1 + width, num_gpus)
        if end <= start:
            continue
        details[f"b{j}"] = (
            BranchPlacement(
                block=f"b{j}", branch=0, critical=False, parallel=parallel,
                time=1.0, gpus=end - start, device_start=start,
                device_end=end, scales=(end - start,),
                layer_index=layer_index % (len(layers) + 1) - 1,
            ),
        )
    return BurstPlan(layers=layers, num_gpus=num_gpus, amp_limit=2.0,
                     single_gpu_time=float(len(layers)),
                     block_details=details)


plan_strategy = st.builds(
    _random_plan,
    st.sampled_from([4, 8, 16]),
    st.lists(st.sampled_from([1, 2, 4, 8, 16]), min_size=1, max_size=6),
    st.lists(
        st.builds(lambda a, b, c, d: (a, b, c, d),
                  st.integers(0, 15), st.integers(0, 7),
                  st.sampled_from([True, False]), st.integers(0, 6)),
        min_size=0, max_size=3,
    ),
)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(plan_strategy, st.integers(1, 4), st.integers(1, 2))
def test_tenant_packing_never_overlaps_fg_or_branches(plan, n, quantum):
    for si, stage in enumerate(plan.stages()):
        busy = plan.busy_device_ranges(si)
        free = plan.free_device_ranges(si)
        chunks = pack_ranges(free, n, quantum=quantum)
        for s, e in chunks:
            assert 0 <= s < e <= plan.num_gpus
            # never on the stage's own fg devices
            assert e <= stage.gpus or s >= stage.gpus
            # never on any busy range (fg prefix or active branch window)
            for bs, be in busy:
                assert e <= bs or s >= be
        # fg + branches + free tile the machine exactly
        assert (sum(e - s for s, e in busy) + sum(e - s for s, e in free)
                == plan.num_gpus)


# -- per-tenant quantum vectors ----------------------------------------------


quanta_lists = st.lists(st.integers(1, 4), min_size=1, max_size=5)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(range_lists, quanta_lists)
def test_pack_ranges_per_tenant_quanta_invariants(free, quanta):
    n = len(quanta)
    chunks = pack_ranges(free, n, quantum=quanta)
    # slot-aware mode: exactly one entry per tenant slot
    assert len(chunks) == n
    merged_free = merge_ranges(free)
    taken = [c for c in chunks if c is not None]
    for slot, c in enumerate(chunks):
        if c is None:
            continue
        s, e = c
        # each chunk aligned to ITS tenant's quantum, inside one free range
        assert (e - s) > 0 and (e - s) % quanta[slot] == 0
        assert any(fs <= s and e <= fe for fs, fe in merged_free)
    # pairwise disjoint
    ordered = sorted(taken)
    for (s1, e1), (s2, e2) in zip(ordered, ordered[1:]):
        assert e1 <= s2
    # a None slot is genuinely unsatisfiable: no remaining free device run
    # outside the taken chunks holds quantum[i] contiguous devices
    if any(c is None for c in chunks):
        leftovers = merge_ranges(
            r for fs, fe in merged_free
            for r in complement_ranges(
                [(max(fs, s), min(fe, e)) for s, e in taken], fe
            ) if r[0] >= fs
        )
        for slot, c in enumerate(chunks):
            if c is None:
                assert all(e - s < quanta[slot] for s, e in leftovers)


def test_pack_ranges_wide_quantum_not_starved_by_sharing_split():
    """Regression: the fewer-chunks-than-tenants halving runs at gcd
    alignment, so a wide-quantum (highest-priority) tenant must re-coalesce
    the fragments instead of starving when the unsplit range satisfies its
    quantum."""
    # (0,4) halves into (0,2)/(2,4); slot 0 (quantum 3) must still get (0,3)
    assert pack_ranges([(0, 4)], 2, quantum=[3, 2]) == [(0, 3), None]
    # 5-wide range, quanta [4,1]: slot 0 takes the aligned prefix, slot 1
    # the remainder — nobody is dropped
    assert pack_ranges([(0, 5)], 2, quantum=[4, 1]) == [(0, 4), (4, 5)]
    # equal wide quanta still share the range (gcd carving + halving)
    assert pack_ranges([(0, 8)], 2, quantum=[3, 3]) == [(0, 3), (3, 6)]


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(range_lists, st.integers(1, 5), st.integers(1, 4))
def test_pack_ranges_uniform_vector_matches_scalar(free, n, q):
    scalar = pack_ranges(free, n, quantum=q)
    vector = pack_ranges(free, n, quantum=[q] * n)
    # uniform per-tenant quanta degenerate to scalar mode (None-padded tail)
    assert [c for c in vector if c is not None] == scalar
    assert vector[:len(scalar)] == scalar


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(plan_strategy, quanta_lists)
def test_per_tenant_packing_never_overlaps_fg_or_branches(plan, quanta):
    for si, stage in enumerate(plan.stages()):
        busy = plan.busy_device_ranges(si)
        chunks = pack_ranges(plan.free_device_ranges(si), len(quanta),
                             quantum=quanta)
        for c in chunks:
            if c is None:
                continue
            s, e = c
            assert 0 <= s < e <= plan.num_gpus
            assert e <= stage.gpus or s >= stage.gpus
            for bs, be in busy:
                assert e <= bs or s >= be


# -- deficit-rotation starvation bound ---------------------------------------


@settings(max_examples=30, deadline=None)
@given(plan_strategy, st.integers(2, 4), st.integers(1, 3))
def test_equal_priority_rotation_starvation_bound(plan, n, rounds_per_tenant):
    """Over N = rounds_per_tenant * n iterations of the fair scheduler, every
    equal-priority tenant runs at least floor(N / n) times whenever any peer
    runs (deficit rotation: nobody's throughput stays at zero)."""
    from repro.core.multiplex import BgTenant, Collocator, MultiplexConfig

    tenants = [BgTenant(f"t{i}", priority=1, step_fn_factory=lambda m: None)
               for i in range(n)]
    col = Collocator(plan, MultiplexConfig(max_inflight=2, use_feedback=False),
                     tenants=tenants)
    N = rounds_per_tenant * n
    ran = [0] * n
    steps = [0] * n
    for _ in range(N):
        sched = col.schedule_tenants()
        launched = [0] * n
        for _si, slot, nsteps in sched:
            launched[slot] += nsteps
        for slot in range(n):
            ran[slot] += launched[slot] > 0
            steps[slot] += launched[slot]
        col.note_launched(launched)
    if any(ran):
        bound = N // n
        for slot in range(n):
            assert ran[slot] >= bound, (ran, sched)
        # and the guard's purpose: nobody is pinned at zero while peers run
        assert all(s > 0 for s in steps), steps


def _gap_plan(num_gpus=8, fg=4, gap_ms=40.0):
    """One wide gap: [fg, num_gpus) free for gap_ms during stage 1."""
    mk = lambda i, g, t: LayerPlan(index=i, name=f"l{i}", gpus=g, time=t,
                                   comp=t, sync=0.0, comm_in=0.0, amp=1.0)
    return BurstPlan(
        layers=(mk(0, num_gpus, 1e-3), mk(1, fg, gap_ms * 1e-3),
                mk(2, num_gpus, 1e-3)),
        num_gpus=num_gpus, amp_limit=2.0,
        single_gpu_time=(2 + gap_ms) * 1e-3,
    )


def test_deficit_sizes_wider_chunk_for_lagging_tenant():
    """ISSUE 6 satellite: per-tenant deficit feeds pack_ranges share sizing,
    so a persistently-behind tenant claims a WIDER chunk — not merely a
    rotation into the same equal-split chunk."""
    from repro.core.multiplex import BgTenant, Collocator, MultiplexConfig

    plan = _gap_plan(num_gpus=8, fg=4)  # stage 1 free: (4, 8), 4 devices
    tenants = [BgTenant(f"t{i}", priority=1, step_fn_factory=lambda m: None)
               for i in range(2)]
    col = Collocator(plan, MultiplexConfig(max_inflight=4, use_feedback=False),
                     tenants=tenants)
    # equal deficits: the equal split gives both tenants 2 devices
    base = {r[1]: r[3] for r in col._schedule_detail(iteration=0)}
    assert all(ce - cs == 2 for cs, ce in base.values()), base
    # slot 1 falls far behind (several service units owed)
    col._deficits[1] = 10.0 * col.bg_step_quantum
    rows = {r[1]: r[3] for r in col._schedule_detail(iteration=0)}
    lag_w = rows[1][1] - rows[1][0]
    peer_w = rows[0][1] - rows[0][0]
    assert lag_w > 2, rows       # wider than its equal-split chunk
    assert lag_w > peer_w, rows  # and wider than the non-lagging peer's
    # chunks stay disjoint and quantum-aligned inside the gap's free range
    (s1, e1), (s0, e0) = rows[1], rows[0]
    assert 4 <= min(s0, s1) and max(e0, e1) <= 8 and (e1 <= s0 or e0 <= s1)


def test_deficit_sizing_tightens_starvation_bound():
    """N-iteration rotation property, tightened: after a tenant is starved
    for k rounds, deficit share-sizing gives it MORE cumulative device-
    seconds over the catch-up rounds than the deficit-blind equal split
    would (the old scheduler rotated it into the same-size chunk forever)."""
    from repro.core.multiplex import BgTenant, Collocator, MultiplexConfig

    def catchup_devsec(feed_deficit: bool) -> float:
        plan = _gap_plan(num_gpus=8, fg=4)
        tenants = [BgTenant(f"t{i}", priority=1,
                            step_fn_factory=lambda m: None)
                   for i in range(2)]
        col = Collocator(plan,
                         MultiplexConfig(max_inflight=4, use_feedback=False),
                         tenants=tenants)
        # starve slot 1 for 3 rounds (its launches never happen)
        for _ in range(3):
            rows = col._schedule_detail()
            launched = [0, 0]
            for _si, slot, _pos, _c, nsteps, _t in rows:
                if slot == 0:
                    launched[0] += nsteps
            if not feed_deficit:
                # deficit-blind control: the scheduler never learns
                launched[1] = launched[0]
            col.note_launched(launched)
        # catch-up rounds: device-seconds slot 1 actually gets
        got = 0.0
        for _ in range(2):
            for _si, slot, _pos, (cs, ce), nsteps, bg_t in \
                    col._schedule_detail():
                if slot == 1:
                    got += nsteps * bg_t * (ce - cs)
            col.note_launched([0, 0])
        return got

    assert catchup_devsec(True) > catchup_devsec(False)
