"""Property-based tests for device-range arithmetic and multi-tenant gap
packing (hypothesis when installed, the deterministic tests/_prop.py shim
otherwise).

Invariants:
  merge_ranges      — sorted, pairwise-disjoint (no touching), idempotent,
                      covers exactly the union of its inputs.
  complement_ranges — tiles [0, total) exactly against the merged busy set.
  pack_ranges       — chunks are disjoint, quantum-aligned, inside the free
                      set, sorted largest-first, at most n of them.
  plan packing      — for random BurstPlans with random BranchPlacements,
                      tenant chunks never overlap the stage's fg devices or
                      the branch windows active in that stage.
"""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 must collect without hypothesis
    from _prop import given, settings, strategies as st

from repro.core.plan import (
    BranchPlacement,
    BurstPlan,
    LayerPlan,
    complement_ranges,
    merge_ranges,
    pack_ranges,
)

MAX_EXAMPLES = 60

raw_range = st.builds(lambda a, b: (a, b), st.integers(0, 40), st.integers(0, 40))
range_lists = st.lists(raw_range, min_size=0, max_size=8)


def _covered(ranges, p):
    return any(s <= p < e for s, e in ranges)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(range_lists)
def test_merge_ranges_invariants(ranges):
    merged = merge_ranges(ranges)
    # sorted + strictly disjoint (touching ranges are coalesced)
    for (s1, e1), (s2, e2) in zip(merged, merged[1:]):
        assert e1 < s2
    for s, e in merged:
        assert s < e
    # idempotent
    assert merge_ranges(merged) == merged
    # pointwise coverage identical to the union of the inputs
    for p in range(42):
        assert _covered(merged, p) == _covered(ranges, p)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(range_lists, st.integers(1, 40))
def test_complement_ranges_tiles_exactly(busy, total):
    free = complement_ranges(busy, total)
    merged = merge_ranges(busy)
    clipped = [(max(0, s), min(e, total)) for s, e in merged]
    clipped = [(s, e) for s, e in clipped if e > s]
    # free + clipped busy tile [0, total): every point in exactly one side
    for p in range(total):
        assert _covered(free, p) != _covered(clipped, p)
    # complement is itself merged (disjoint + sorted) and involutive
    assert merge_ranges(free) == free
    assert complement_ranges(free, total) == clipped


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(range_lists, st.integers(1, 5), st.integers(1, 4))
def test_pack_ranges_invariants(free, n, quantum):
    chunks = pack_ranges(free, n, quantum=quantum)
    assert len(chunks) <= n
    merged_free = merge_ranges(free)
    sizes = [e - s for s, e in chunks]
    # quantum-aligned sizes, each chunk inside one free range
    for (s, e), size in zip(chunks, sizes):
        assert size > 0 and size % quantum == 0
        assert any(fs <= s and e <= fe for fs, fe in merged_free)
    # largest-first (priority slot 0 gets the biggest chunk)
    assert sizes == sorted(sizes, reverse=True)
    # pairwise disjoint
    ordered = sorted(chunks)
    for (s1, e1), (s2, e2) in zip(ordered, ordered[1:]):
        assert e1 <= s2


# -- random plans: tenant packing never overlaps fg or branch devices --------


def _random_plan(num_gpus, layer_gpus, placements):
    layers = tuple(
        LayerPlan(index=i, name=f"l{i}", gpus=min(g, num_gpus), time=1.0,
                  comp=1.0, sync=0.0, comm_in=0.0, amp=1.0)
        for i, g in enumerate(layer_gpus)
    )
    details = {}
    for j, (start, width, parallel, layer_index) in enumerate(placements):
        start = start % num_gpus
        end = min(start + 1 + width, num_gpus)
        if end <= start:
            continue
        details[f"b{j}"] = (
            BranchPlacement(
                block=f"b{j}", branch=0, critical=False, parallel=parallel,
                time=1.0, gpus=end - start, device_start=start,
                device_end=end, scales=(end - start,),
                layer_index=layer_index % (len(layers) + 1) - 1,
            ),
        )
    return BurstPlan(layers=layers, num_gpus=num_gpus, amp_limit=2.0,
                     single_gpu_time=float(len(layers)),
                     block_details=details)


plan_strategy = st.builds(
    _random_plan,
    st.sampled_from([4, 8, 16]),
    st.lists(st.sampled_from([1, 2, 4, 8, 16]), min_size=1, max_size=6),
    st.lists(
        st.builds(lambda a, b, c, d: (a, b, c, d),
                  st.integers(0, 15), st.integers(0, 7),
                  st.sampled_from([True, False]), st.integers(0, 6)),
        min_size=0, max_size=3,
    ),
)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(plan_strategy, st.integers(1, 4), st.integers(1, 2))
def test_tenant_packing_never_overlaps_fg_or_branches(plan, n, quantum):
    for si, stage in enumerate(plan.stages()):
        busy = plan.busy_device_ranges(si)
        free = plan.free_device_ranges(si)
        chunks = pack_ranges(free, n, quantum=quantum)
        for s, e in chunks:
            assert 0 <= s < e <= plan.num_gpus
            # never on the stage's own fg devices
            assert e <= stage.gpus or s >= stage.gpus
            # never on any busy range (fg prefix or active branch window)
            for bs, be in busy:
                assert e <= bs or s >= be
        # fg + branches + free tile the machine exactly
        assert (sum(e - s for s, e in busy) + sum(e - s for s, e in free)
                == plan.num_gpus)
