"""Sharding rules: divisibility guard, per-arch layouts, hypothesis props."""
import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 must collect without hypothesis
    from _prop import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import DECODE_32K, LONG_500K, TRAIN_4K, get_config
from repro.dist.sharding import RuleReport, pspec, sharding_rules
from repro.launch.mesh import largest_pow2_mesh, make_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_mesh(1, 1)  # rules logic is mesh-shape driven; use axis names


class FakeMesh:
    """Mesh stand-in (axis names + sizes) — pspec only reads these."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.devices = np.zeros(tuple(axes.values()))


M = FakeMesh(data=16, model=16)
MP = FakeMesh(pod=2, data=16, model=16)


def test_divisibility_guard_drops():
    rep = RuleReport()
    # 36 heads on a 16-way axis -> dropped
    spec = pspec(("embed", "heads", "head_dim"), (2304, 36, 64),
                 {"embed": ("data",), "heads": ("model",), "head_dim": ()}, M, rep)
    assert spec == P("data")  # trailing None trimmed
    assert rep.dropped == [("heads", 36, 16)]


def test_divisible_keeps():
    spec = pspec(("embed", "heads", "head_dim"), (8192, 64, 128),
                 {"embed": ("data",), "heads": ("model",), "head_dim": ()}, M)
    assert spec == P("data", "model")


def test_no_axis_reuse():
    # same mesh axis can't shard two dims of one array
    spec = pspec(("mlp", "mlp"), (256, 256), {"mlp": ("model",)}, M)
    assert spec == P("model")  # second dim dropped (trailing None trimmed)


def test_rules_minicpm_attention_replicated():
    cfg = get_config("minicpm-2b")
    rules = sharding_rules(cfg, M, TRAIN_4K)
    assert rules["heads"] == () and rules["kv_heads"] == ()
    assert rules["mlp"] == ("model",)  # 5760 % 16 == 0


def test_rules_moe_modes():
    qwen = get_config("qwen3-moe-30b-a3b")
    r = sharding_rules(qwen, M, TRAIN_4K)
    assert r["expert"] == ("model",) and r["moe_mlp"] == ()
    grok = get_config("grok-1-314b")
    r = sharding_rules(grok, M, TRAIN_4K)
    assert r["expert"] == () and r["moe_mlp"] == ("model",)


def test_rules_decode_kv_fallbacks():
    qwen72 = get_config("qwen2-72b")  # kv=8 not divisible by 16
    r = sharding_rules(qwen72, M, DECODE_32K)
    assert r["act_kv_seq"] == ("model",)
    # long context (batch=1): sequence shards over DP axes
    zamba = get_config("zamba2-2.7b")
    r = sharding_rules(zamba, MP, LONG_500K)
    assert r["act_kv_seq"] == ("pod", "data")
    assert r["act_batch"] == ()


def test_rules_serving_drops_fsdp_for_small_models():
    small = get_config("qwen2-1.5b")
    assert sharding_rules(small, M, DECODE_32K)["embed"] == ()
    big = get_config("grok-1-314b")
    assert sharding_rules(big, M, DECODE_32K)["embed"] == ("data",)


def test_largest_pow2_mesh():
    m = largest_pow2_mesh(1)
    assert m.devices.size == 1


@settings(max_examples=60, deadline=None)
@given(
    st.lists(st.integers(1, 4096), min_size=1, max_size=4),
    st.sampled_from(["embed", "heads", "mlp", "vocab", "norm"]),
)
def test_property_pspec_always_valid(dims, axis):
    """The guard guarantees: every sharded dim is divisible by its axes."""
    rules = {"embed": ("data",), "heads": ("model",), "mlp": ("model",),
             "vocab": ("model",), "norm": ()}
    axes = tuple(axis for _ in dims)
    spec = pspec(axes, tuple(dims), rules, M)
    sizes = {"data": 16, "model": 16}
    for dim, s in zip(dims, tuple(spec) + (None,) * (len(dims) - len(spec))):
        if s is None:
            continue
        parts = s if isinstance(s, tuple) else (s,)
        total = int(np.prod([sizes[a] for a in parts]))
        assert dim % total == 0
